"""Router training tests: profiling-set statistics + convergence + export."""

import json

import numpy as np

from compile import simparams as sp
from compile.train_router import (
    adamw_init,
    adamw_step,
    export_router_meta,
    generate_profile_set,
    train_router,
)


def test_profile_set_shapes_and_ranges():
    feats, c_used, targets = generate_profile_set(n_queries=50, seed=1)
    n = feats.shape[0]
    assert feats.shape == (n, sp.FEAT_DIM)
    assert c_used.shape == (n, 1)
    assert targets.shape == (n,)
    assert 50 * 3 <= n <= 50 * sp.NMAX
    assert np.all(targets >= 0) and np.all(targets <= 1)
    assert np.all(c_used >= 0)
    # role one-hot is exactly one-hot
    roles = feats[:, sp.FEAT_ROLE:sp.FEAT_ROLE + 3]
    np.testing.assert_allclose(roles.sum(axis=1), 1.0)
    doms = feats[:, sp.FEAT_DOMAIN:sp.FEAT_DOMAIN + 4]
    np.testing.assert_allclose(doms.sum(axis=1), 1.0)


def test_profile_set_is_deterministic():
    a = generate_profile_set(n_queries=20, seed=7)
    b = generate_profile_set(n_queries=20, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_profile_set_utility_signal_exists():
    """Targets must carry learnable structure: utility peaks at mid
    difficulty (easy -> edge suffices, very hard -> cloud fails too) and
    rises with the criticality hint."""
    feats, _, targets = generate_profile_set(n_queries=300, seed=3)
    d = feats[:, sp.FEAT_DIFF1]
    mid = targets[(d > 0.3) & (d < 0.55)].mean()
    very_hard = targets[d > 0.65].mean()
    assert mid > very_hard + 0.05
    crit = feats[:, sp.FEAT_CRIT]
    assert targets[crit > 0.5].mean() > targets[crit < 0.3].mean() + 0.05
    # Targets are spread, not saturated.
    assert 0.15 < targets.std()
    assert (targets == 1.0).mean() < 0.5


def test_adamw_reduces_quadratic():
    import jax.numpy as jnp
    import jax

    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(p)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(p))
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, opt = adamw_step(p, g, opt, lr=5e-2, wd=0.0)
    assert float(loss(p)) < l0 * 0.01


def test_train_router_converges_fast_config():
    params, metrics = train_router(epochs=25, n_queries=200, seed=11, verbose=False)
    mse = metrics["train_mse"]
    assert mse[-1] < mse[0]
    assert metrics["val_r2"] > 0.1  # clearly better than predicting the mean
    assert metrics["val_mse"] < 0.1


def test_export_router_meta_roundtrip(tmp_path):
    params, metrics = train_router(epochs=2, n_queries=60, seed=13, verbose=False)
    path = tmp_path / "router_meta.json"
    export_router_meta(params, metrics, str(path))
    meta = json.loads(path.read_text())
    assert meta["dims"] == [sp.ROUTER_IN_DIM, sp.ROUTER_HIDDEN, sp.ROUTER_HIDDEN, 1]
    assert len(meta["layers"]) == 3
    w0 = np.asarray(meta["layers"][0]["w"])
    assert w0.shape == (sp.ROUTER_IN_DIM, sp.ROUTER_HIDDEN)
    # Weights must round-trip close to the trained params.
    np.testing.assert_allclose(w0, np.asarray(params.layers[0][0]), atol=1e-6)

"""AOT path tests: HLO text generation, manifest integrity, numeric parity.

These tests exercise the exact code ``make artifacts`` runs, with a tiny
training config so they stay fast.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import simparams as sp
from compile.aot import ROUTER_BATCHES, build_all, lower_fn, to_hlo_text
from compile.model import init_router, make_router_fn, router_forward


def test_to_hlo_text_smoke():
    fn = lambda x: (jnp.tanh(x) * 2.0,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True -> tuple-shaped root
    assert "(" in text.split("ENTRY")[1]


def test_router_hlo_contains_trained_constants():
    p = init_router(jax.random.PRNGKey(0))
    fn, example = make_router_fn(p, 2)
    text = lower_fn(fn, example)
    # Weights are baked: expect f32[17,64] constants in the module text.
    assert f"f32[{sp.ROUTER_IN_DIM},{sp.ROUTER_HIDDEN}]" in text
    assert "parameter(0)" in text and "parameter(1)" in text


@pytest.mark.slow
def test_build_all_tiny(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = build_all(out, epochs=2, verbose=False)
    files = set(os.listdir(out))
    for b in ROUTER_BATCHES:
        assert f"router_b{b}.hlo.txt" in files
    assert {"router.hlo.txt", "edge_lm.hlo.txt", "router_meta.json",
            "simparams.json", "manifest.json"} <= files
    # Manifest shapes match simparams layout.
    for b in ROUTER_BATCHES:
        info = manifest["artifacts"][f"router_b{b}.hlo.txt"]
        assert info["inputs"] == [[b, sp.FEAT_DIM], [b, 1]]
    # simparams.json round-trips the python constants.
    got = json.loads(open(os.path.join(out, "simparams.json")).read())
    assert got["router_in_dim"] == sp.ROUTER_IN_DIM
    assert got["model_caps"]["gpt-4.1"] == sp.MODEL_CAPS["gpt-4.1"]


def test_router_meta_mirror_matches_jax_forward(tmp_path):
    """A numpy re-implementation from the exported JSON must reproduce the
    jax forward - this is exactly what the rust fallback mirror does."""
    from compile.train_router import export_router_meta

    p = init_router(jax.random.PRNGKey(1))
    export_router_meta(p, {"val_mse": 0.0, "val_r2": 0.0, "n_samples": 0,
                           "target_mean": 0.0}, str(tmp_path / "m.json"))
    meta = json.loads((tmp_path / "m.json").read_text())

    feats = np.random.default_rng(0).uniform(size=(6, sp.FEAT_DIM)).astype(np.float32)
    c = np.random.default_rng(1).uniform(size=(6, 1)).astype(np.float32)

    def gelu(x):
        return 0.5 * x * (1 + np.tanh(np.sqrt(2 / np.pi) * (x + 0.044715 * x**3)))

    h = np.concatenate([feats, c], axis=1)
    for li, layer in enumerate(meta["layers"]):
        w = np.asarray(layer["w"], np.float32)
        b = np.asarray(layer["b"], np.float32)
        h = h @ w + b
        if li < len(meta["layers"]) - 1:
            h = gelu(h)
        else:
            h = 1 / (1 + np.exp(-h))
    want = np.asarray(router_forward(p, jnp.asarray(feats), jnp.asarray(c)))
    np.testing.assert_allclose(h[:, 0], want, rtol=2e-3, atol=2e-3)

"""L1 correctness: Pallas ``linear_act`` vs the pure-jnp oracle.

This is the core numeric signal for the kernel layer.  Hypothesis sweeps
shapes, dtypes, activations, and block configurations; every case asserts
allclose against ``ref.ref_linear_act``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.linear import (
    ACTIVATIONS,
    linear_act,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.layernorm import layernorm
from compile.kernels.ref import ref_causal_attention, ref_layernorm, ref_linear_act, ref_mlp

TOL = dict(rtol=2e-5, atol=2e-5)
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("act", ACTIVATIONS)
def test_linear_act_matches_ref_basic(act):
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    x = _rand(k1, (16, 32))
    w = _rand(k2, (32, 48))
    b = _rand(k3, (48,))
    got = linear_act(x, w, b, act=act)
    want = ref_linear_act(x, w, b, act=act)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_shape_sweep(m, k, n, act, seed):
    """Arbitrary (ragged) shapes exercise the padding path."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (m, k))
    w = _rand(k2, (k, n))
    b = _rand(k3, (n,))
    got = linear_act(x, w, b, act=act)
    assert got.shape == (m, n)
    want = ref_linear_act(x, w, b, act=act)
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_block_config_sweep(bm, bn, bk, seed):
    """Result must be invariant to the chosen block decomposition."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (24, 40))
    w = _rand(k2, (40, 24))
    b = _rand(k3, (24,))
    got = linear_act(x, w, b, act="gelu", bm=bm, bn=bn, bk=bk)
    want = ref_linear_act(x, w, b, act="gelu")
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_linear_act_dtypes(dtype):
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (8, 16), dtype)
    w = _rand(k2, (16, 8), dtype)
    b = _rand(k3, (8,), dtype)
    got = linear_act(x, w, b, act="none")
    want = ref_linear_act(x, w, b, act="none")
    assert got.dtype == dtype
    tol = TOL if dtype == jnp.float32 else BF16_TOL
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


def test_linear_act_zero_and_identity():
    # act(0 @ w + b) == act(b) broadcast over rows.
    w = jnp.ones((4, 6))
    b = jnp.arange(6, dtype=jnp.float32)
    x = jnp.zeros((3, 4))
    got = linear_act(x, w, b, act="relu")
    np.testing.assert_allclose(got, jnp.broadcast_to(jnp.maximum(b, 0), (3, 6)), **TOL)
    # Identity weight reproduces x + b.
    eye = jnp.eye(5)
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 5))
    got = linear_act(x, eye, jnp.zeros(5), act="none")
    np.testing.assert_allclose(got, x, **TOL)


def test_linear_act_rejects_bad_shapes():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))  # K mismatch
    b = jnp.zeros((7,))
    with pytest.raises(ValueError):
        linear_act(x, w, b)
    with pytest.raises(ValueError):
        linear_act(x, jnp.zeros((5, 7)), jnp.zeros((3,)))
    with pytest.raises(ValueError):
        linear_act(x, jnp.zeros((5, 7)), b, act="swish")


def test_kernel_matches_ref_on_training_shapes():
    """Training runs on the ref path and the artifact on the kernel path;
    the two must agree bitwise-closely on the router's exact layer shapes
    (17->64, 64->64, 64->1) so swapping paths cannot shift predictions."""
    key = jax.random.PRNGKey(9)
    for (m, k, n) in [(256, 17, 64), (256, 64, 64), (256, 64, 1)]:
        key, k1, k2, k3 = jax.random.split(key, 4)
        x = _rand(k1, (m, k))
        w = _rand(k2, (k, n))
        b = _rand(k3, (n,))
        for act in ("gelu", "sigmoid"):
            np.testing.assert_allclose(
                linear_act(x, w, b, act=act),
                ref_linear_act(x, w, b, act=act), **TOL)


def test_ref_mlp_composes():
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 4)
    p = [(_rand(ks[0], (8, 16)), _rand(ks[1], (16,))),
         (_rand(ks[2], (16, 2)), _rand(ks[3], (2,)))]
    x = _rand(key, (5, 8))
    out = ref_mlp(x, p, hidden_act="gelu", final_act="sigmoid")
    assert out.shape == (5, 2)
    assert bool(jnp.all(out >= 0)) and bool(jnp.all(out <= 1))


def test_ref_causal_attention_is_causal():
    """Changing a future token must not affect earlier outputs."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 5)
    d = 8
    x = _rand(ks[0], (6, d))
    mats = [_rand(k, (d, d)) for k in ks[1:5]]
    out1 = ref_causal_attention(x, *mats)
    x2 = x.at[5].set(x[5] + 100.0)
    out2 = ref_causal_attention(x2, *mats)
    np.testing.assert_allclose(out1[:5], out2[:5], rtol=1e-4, atol=1e-4)


def test_vmem_and_mxu_estimates():
    # 128^3 block: operands double-buffered + f32 acc must fit well under 16 MiB.
    fp = vmem_footprint_bytes(128, 128, 128)
    assert fp < 2 * 1024 * 1024
    # Aligned problem -> perfect utilization; ragged problem -> less.
    assert mxu_utilization_estimate(256, 256, 256, 128, 128, 128) == 1.0
    u = mxu_utilization_estimate(130, 130, 130, 128, 128, 128)
    assert 0.0 < u < 0.2


# ---------------------------------------------------------------------------
# LayerNorm kernel.
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(1, 40),
    d=st.integers(2, 96),
    bt=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_shape_sweep(t, d, bt, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (t, d))
    g = _rand(k2, (d,)) + 1.0
    b = _rand(k3, (d,))
    got = layernorm(x, g, b, bt=bt)
    assert got.shape == (t, d)
    want = ref_layernorm(x, g, b)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_layernorm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 64)) * 7.0 + 3.0
    out = layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(jnp.mean(out, axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.std(out, axis=-1), 1.0, atol=1e-3)


def test_layernorm_rejects_bad_shapes():
    with pytest.raises(ValueError):
        layernorm(jnp.zeros((4, 8)), jnp.zeros(7), jnp.zeros(8))
    with pytest.raises(ValueError):
        layernorm(jnp.zeros(8), jnp.zeros(8), jnp.zeros(8))

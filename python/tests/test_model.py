"""L2 tests: router network + edge LM shapes, ranges, and kernel/ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import simparams as sp
from compile.kernels.ref import ref_mlp
from compile.model import (
    EDGE_LM_D,
    EDGE_LM_T,
    EDGE_LM_V,
    edge_lm_forward,
    init_edge_lm,
    init_mlp,
    init_router,
    make_edge_lm_fn,
    make_router_fn,
    mlp_forward,
    router_forward,
    router_loss,
)


def test_router_dims_match_simparams():
    p = init_router(jax.random.PRNGKey(0))
    assert p.dims == [sp.ROUTER_IN_DIM, sp.ROUTER_HIDDEN, sp.ROUTER_HIDDEN, 1]


def test_router_forward_shape_and_range():
    p = init_router(jax.random.PRNGKey(0))
    f = jax.random.uniform(jax.random.PRNGKey(1), (5, sp.FEAT_DIM))
    c = jnp.zeros((5, 1))
    u = router_forward(p, f, c)
    assert u.shape == (5,)
    assert bool(jnp.all(u > 0)) and bool(jnp.all(u < 1))


def test_router_kernel_path_matches_ref_path():
    """The AOT artifact graph (Pallas) must agree with the training graph (ref)."""
    p = init_router(jax.random.PRNGKey(2))
    f = jax.random.uniform(jax.random.PRNGKey(3), (9, sp.FEAT_DIM))
    c = jax.random.uniform(jax.random.PRNGKey(4), (9, 1))
    kern = router_forward(p, f, c, interpret=True)
    x = jnp.concatenate([f, c], axis=1)
    ref = ref_mlp(x, p.layers, hidden_act="gelu", final_act="sigmoid")[:, 0]
    np.testing.assert_allclose(kern, ref, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 16), seed=st.integers(0, 1000))
def test_router_batch_invariance(batch, seed):
    """Scoring a batch must equal scoring each row alone (no cross-talk)."""
    p = init_router(jax.random.PRNGKey(42))
    f = jax.random.uniform(jax.random.PRNGKey(seed), (batch, sp.FEAT_DIM))
    c = jax.random.uniform(jax.random.PRNGKey(seed + 1), (batch, 1))
    full = router_forward(p, f, c)
    rows = jnp.concatenate([router_forward(p, f[i:i + 1], c[i:i + 1]) for i in range(batch)])
    np.testing.assert_allclose(full, rows, rtol=3e-5, atol=3e-5)


def test_router_loss_decreases_with_grad_step():
    """Gradients flow through the training (ref) path; the kernel-path loss
    must drop by the same step, confirming path interchangeability."""
    p = init_router(jax.random.PRNGKey(5))
    f = jax.random.uniform(jax.random.PRNGKey(6), (64, sp.FEAT_DIM))
    c = jnp.zeros((64, 1))
    t = jax.random.uniform(jax.random.PRNGKey(7), (64,))

    def ref_loss(p):
        x = jnp.concatenate([f, c], axis=1)
        pred = ref_mlp(x, p.layers, hidden_act="gelu", final_act="sigmoid")[:, 0]
        return jnp.mean((pred - t) ** 2)

    loss0, grads = jax.value_and_grad(ref_loss)(p)
    p2 = jax.tree_util.tree_map(lambda x, g: x - 0.5 * g, p, grads)
    assert float(ref_loss(p2)) < float(loss0)
    # Kernel-path (artifact) loss agrees before and after the step.
    np.testing.assert_allclose(float(router_loss(p, f, c, t)), float(loss0), rtol=1e-4)
    np.testing.assert_allclose(float(router_loss(p2, f, c, t)), float(ref_loss(p2)), rtol=1e-4)


def test_mlp_forward_matches_ref():
    key = jax.random.PRNGKey(8)
    params = init_mlp(key, [12, 20, 3])
    x = jax.random.normal(jax.random.PRNGKey(9), (7, 12))
    got = mlp_forward(x, params, hidden_act="relu", final_act="tanh")
    want = ref_mlp(x, params, hidden_act="relu", final_act="tanh")
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_edge_lm_shapes():
    p = init_edge_lm(jax.random.PRNGKey(10))
    x = jax.random.normal(jax.random.PRNGKey(11), (EDGE_LM_T, EDGE_LM_D))
    logits = edge_lm_forward(p, x)
    assert logits.shape == (EDGE_LM_T, EDGE_LM_V)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_edge_lm_causality():
    """Future tokens must not influence past logits."""
    p = init_edge_lm(jax.random.PRNGKey(12))
    x = jax.random.normal(jax.random.PRNGKey(13), (EDGE_LM_T, EDGE_LM_D))
    l1 = edge_lm_forward(p, x)
    x2 = x.at[-1].set(x[-1] * 3.0 + 1.0)
    l2 = edge_lm_forward(p, x2)
    np.testing.assert_allclose(l1[:-1], l2[:-1], rtol=1e-4, atol=1e-4)


def test_make_fns_are_lowerable():
    """jit(...).lower must succeed on the exact example shapes used by aot.py."""
    p = init_router(jax.random.PRNGKey(14))
    fn, example = make_router_fn(p, 4)
    lowered = jax.jit(fn).lower(*example)
    assert "func" in str(lowered.compiler_ir("stablehlo"))

    lm = init_edge_lm(jax.random.PRNGKey(15))
    fn2, example2 = make_edge_lm_fn(lm)
    lowered2 = jax.jit(fn2).lower(*example2)
    assert "func" in str(lowered2.compiler_ir("stablehlo"))

"""L2: jax compute graphs for the HybridFlow learned components.

Two graphs are AOT-lowered by ``aot.py`` and executed from the rust request
path via PJRT:

* **Router network** (the paper's Sec. 3.3 utility predictor): a fused
  embedder + two-hidden-layer MLP head.  Input is the packed subtask feature
  vector (simparams feature layout) concatenated with the scalar cumulative
  budget ``C_used(t)`` (Eq. 8); output is ``u_hat in (0,1)`` via a sigmoid.
  The rust scheduler scores the whole ready frontier in one batched call.

* **Edge LM block** (the simulated on-device executor's compute): a tiny
  pre-LN transformer decoder block + vocab projection.  The rust edge-model
  simulator runs it once per decode chunk so that "edge execution" burns
  real PJRT compute rather than just sleeping.

Every dense layer routes through the L1 Pallas kernel
(`kernels.linear.linear_act`), so the whole stack lowers into HLO containing
the kernel's tiled loops.  Router *training* differentiates the pure-jnp
reference path (the scratch-accumulator kernel has no JVP rule); the tests
pin kernel/ref parity on the router's exact layer shapes so the exported
kernel graph computes the same function the ref path was trained on.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import simparams as sp
from .kernels.layernorm import layernorm
from .kernels.linear import linear_act


# ---------------------------------------------------------------------------
# Generic MLP built on the Pallas kernel.
# ---------------------------------------------------------------------------

def init_mlp(key: jax.Array, dims: list[int], scale: float = 1.0) -> list[tuple[jax.Array, jax.Array]]:
    """He-style init for an MLP with layer dims ``dims[0] -> ... -> dims[-1]``."""
    params = []
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        fan_in = dims[i]
        w = jax.random.normal(sub, (dims[i], dims[i + 1]), jnp.float32)
        w = w * (scale * jnp.sqrt(2.0 / fan_in))
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def mlp_forward(x: jax.Array, params, *, hidden_act: str = "gelu",
                final_act: str = "none", interpret: bool = True) -> jax.Array:
    """MLP stack where every layer is the fused Pallas linear kernel."""
    h = x
    for li, (w, b) in enumerate(params):
        act = final_act if li == len(params) - 1 else hidden_act
        h = linear_act(h, w, b, act=act, interpret=interpret)
    return h


# ---------------------------------------------------------------------------
# Router network (Sec. 3.3 / Eq. 8).
# ---------------------------------------------------------------------------

class RouterParams(NamedTuple):
    """Embedder trunk + prediction head; flat list of (w, b) layers."""
    layers: list

    @property
    def dims(self) -> list[int]:
        d = [self.layers[0][0].shape[0]]
        d += [w.shape[1] for (w, _) in self.layers]
        return d


def init_router(key: jax.Array) -> RouterParams:
    """in = FEAT_DIM + 1 (C_used); two hidden layers (paper Sec. 4.1)."""
    dims = [sp.ROUTER_IN_DIM, sp.ROUTER_HIDDEN, sp.ROUTER_HIDDEN, 1]
    return RouterParams(init_mlp(key, dims))


def router_forward(params: RouterParams, feats: jax.Array, c_used: jax.Array,
                   *, interpret: bool = True) -> jax.Array:
    """Predicted utility ``u_hat`` for a batch of subtasks.

    feats: (B, FEAT_DIM) packed feature vectors; c_used: (B, 1) cumulative
    normalized cost at decision time.  Returns (B,) in (0, 1).
    """
    x = jnp.concatenate([feats, c_used], axis=1)
    out = mlp_forward(x, params.layers, hidden_act="gelu", final_act="sigmoid",
                      interpret=interpret)
    return out[:, 0]


def router_loss(params: RouterParams, feats: jax.Array, c_used: jax.Array,
                targets: jax.Array, *, interpret: bool = True) -> jax.Array:
    """MSE regression to profiled utility targets (Eq. 9 / Eq. 26)."""
    pred = router_forward(params, feats, c_used, interpret=interpret)
    return jnp.mean((pred - targets) ** 2)


# ---------------------------------------------------------------------------
# Tiny edge LM block (simulated on-device executor compute).
# ---------------------------------------------------------------------------

EDGE_LM_T = 32      # decode chunk length
EDGE_LM_D = 64      # model width
EDGE_LM_FF = 128    # feed-forward width
EDGE_LM_V = 256     # byte-level vocab


class EdgeLmParams(NamedTuple):
    ln1_g: jax.Array
    ln1_b: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ln2_g: jax.Array
    ln2_b: jax.Array
    ff: list  # [(w1,b1),(w2,b2)] through the Pallas kernel
    head: tuple  # (w, b) vocab projection through the Pallas kernel


def init_edge_lm(key: jax.Array) -> EdgeLmParams:
    ks = jax.random.split(key, 8)
    d, f, v = EDGE_LM_D, EDGE_LM_FF, EDGE_LM_V
    s = 1.0 / jnp.sqrt(d)
    return EdgeLmParams(
        ln1_g=jnp.ones((d,)), ln1_b=jnp.zeros((d,)),
        wq=jax.random.normal(ks[0], (d, d)) * s,
        wk=jax.random.normal(ks[1], (d, d)) * s,
        wv=jax.random.normal(ks[2], (d, d)) * s,
        wo=jax.random.normal(ks[3], (d, d)) * s,
        ln2_g=jnp.ones((d,)), ln2_b=jnp.zeros((d,)),
        ff=init_mlp(ks[4], [d, f, d]),
        head=init_mlp(ks[5], [d, v])[0],
    )


def edge_lm_forward(params: EdgeLmParams, x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Pre-LN decoder block + vocab head over a (T, D) chunk -> (T, V) logits.

    Attention stays in plain jnp (it is small); both LayerNorms, both
    feed-forward layers, and the vocab projection run through the L1
    Pallas kernels.
    """
    t, d = x.shape
    h = layernorm(x, params.ln1_g, params.ln1_b, interpret=interpret)
    q, k, v = h @ params.wq, h @ params.wk, h @ params.wv
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    attn = jax.nn.softmax(jnp.where(mask, scores, -1e30), axis=-1)
    x = x + (attn @ v) @ params.wo
    h = layernorm(x, params.ln2_g, params.ln2_b, interpret=interpret)
    h = mlp_forward(h, params.ff, hidden_act="gelu", final_act="none", interpret=interpret)
    x = x + h
    w, b = params.head
    return linear_act(x, w, b, act="none", interpret=interpret)


# ---------------------------------------------------------------------------
# Bake params into an argument-free-weights callable for AOT lowering.
# ---------------------------------------------------------------------------

def make_router_fn(params: RouterParams, batch: int):
    """Returns f(feats[B,F], c_used[B,1]) -> (u_hat[B],) with weights baked
    as HLO constants - the rust side passes only runtime tensors."""
    frozen = jax.tree_util.tree_map(jax.lax.stop_gradient, params)

    def fn(feats, c_used):
        return (router_forward(frozen, feats, c_used),)

    example = (
        jax.ShapeDtypeStruct((batch, sp.FEAT_DIM), jnp.float32),
        jax.ShapeDtypeStruct((batch, 1), jnp.float32),
    )
    return fn, example


def make_edge_lm_fn(params: EdgeLmParams):
    frozen = jax.tree_util.tree_map(jax.lax.stop_gradient, params)

    def fn(x):
        return (edge_lm_forward(frozen, x),)

    example = (jax.ShapeDtypeStruct((EDGE_LM_T, EDGE_LM_D), jnp.float32),)
    return fn, example

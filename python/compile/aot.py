"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once via ``make artifacts``; python never appears on the request path.

Interchange format is HLO text, NOT ``lowered.compile()`` /
``proto.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``).  The text parser on the rust side
(``HloModuleProto::from_text_file``) reassigns ids and round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Artifacts written to ``--out-dir`` (default: ``../artifacts``):

* ``router_b{1,8,32}.hlo.txt`` - trained router network at several batch
  sizes (rust pads the ready frontier to the nearest size).
* ``router.hlo.txt``          - alias of the canonical batch (8).
* ``edge_lm.hlo.txt``         - tiny edge-LM decoder block forward.
* ``router_meta.json``        - dims + weights + val metrics (rust mirror).
* ``simparams.json``          - shared generative-model constants.
* ``manifest.json``           - artifact inventory + feature layout version.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import simparams as sp
from .model import init_edge_lm, make_edge_lm_fn, make_router_fn
from .train_router import export_router_meta, train_router

ROUTER_BATCHES = (1, 8, 32)
CANONICAL_BATCH = 8


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big weight arrays as ``constant({...})``, which the rust-side
    text parser silently reads as zeros — the trained network would ship
    with its weights stripped (caught by ``hybridflow check`` parity).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def build_all(out_dir: str, epochs: int | None = None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"feature_layout_version": 1, "artifacts": {}}

    # --- Router: train, export weights, lower per batch size -------------
    params, metrics = train_router(epochs=epochs or sp.TRAIN_EPOCHS, verbose=verbose)
    export_router_meta(params, metrics, os.path.join(out_dir, "router_meta.json"))
    manifest["router_metrics"] = {"val_mse": metrics["val_mse"], "val_r2": metrics["val_r2"]}

    for b in ROUTER_BATCHES:
        fn, example = make_router_fn(params, b)
        text = lower_fn(fn, example)
        name = f"router_b{b}.hlo.txt"
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "inputs": [[b, sp.FEAT_DIM], [b, 1]],
            "outputs": [[b]],
            "chars": len(text),
        }
        if verbose:
            print(f"[aot] wrote {name} ({len(text)} chars)")

    canonical = os.path.join(out_dir, "router.hlo.txt")
    with open(os.path.join(out_dir, f"router_b{CANONICAL_BATCH}.hlo.txt")) as f:
        text = f.read()
    with open(canonical, "w") as f:
        f.write(text)
    manifest["artifacts"]["router.hlo.txt"] = dict(
        manifest["artifacts"][f"router_b{CANONICAL_BATCH}.hlo.txt"]
    )

    # --- Edge LM block ----------------------------------------------------
    lm_params = init_edge_lm(jax.random.PRNGKey(7))
    fn, example = make_edge_lm_fn(lm_params)
    text = lower_fn(fn, example)
    with open(os.path.join(out_dir, "edge_lm.hlo.txt"), "w") as f:
        f.write(text)
    manifest["artifacts"]["edge_lm.hlo.txt"] = {
        "inputs": [list(example[0].shape)],
        "outputs": [[example[0].shape[0], 256]],
        "chars": len(text),
    }
    if verbose:
        print(f"[aot] wrote edge_lm.hlo.txt ({len(text)} chars)")

    # --- Shared constants ---------------------------------------------------
    sp.dump_json(os.path.join(out_dir, "simparams.json"))
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"[aot] wrote simparams.json + manifest.json -> {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--epochs", type=int, default=None, help="override router training epochs")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    build_all(os.path.abspath(args.out_dir), epochs=args.epochs, verbose=not args.quiet)


if __name__ == "__main__":
    main()

"""Shared generative-model constants for the HybridFlow simulation substrate.

This module is the single python-side source of truth for the synthetic
edge/cloud testbed that replaces the paper's GPT-4.1 / Llama3.2-3B / RTX-3090
deployment (see DESIGN.md section 3).  The rust coordinator mirrors these
constants in ``rust/src/config/simparams.rs``; ``aot.py`` dumps them to
``artifacts/simparams.json`` and a rust test cross-checks the two copies, so
the mirrors cannot silently drift.

The generative model:

* A query ``Q`` from benchmark ``B`` has a latent difficulty
  ``d_q ~ Beta(a_B, b_B)`` and a domain ``dom_B``.
* Decomposition splits ``Q`` into subtasks with latent difficulties
  ``d_i = d_q * phi_i`` (``phi_i ~ U[PHI_LO, PHI_HI]``), criticality
  ``w_i`` and role-dependent token counts.
* A model ``m`` solves a subtask of difficulty ``d`` with probability
  ``p_m(d) = sigmoid((cap_m(dom) - d) / CAP_TEMP)``.
* The router's supervision follows the paper exactly:
  ``dq_i = (p_cloud(d_i) - p_edge(d_i)) * w_i`` (outcome-based credit),
  ``c_i`` from Eq. 24, ``u_i = clip(dq_i / (c_i + EPS), 0, 1)`` from Eq. 25.
"""

from __future__ import annotations

import json

# ---------------------------------------------------------------------------
# Feature layout (input to the embedder+router network).
#
# The rust hot path packs exactly this vector; keep in lockstep with
# rust/src/embed/mod.rs.
# ---------------------------------------------------------------------------

ROLES = ["EXPLAIN", "ANALYZE", "GENERATE"]
DOMAINS = ["math", "science", "general", "logic"]

FEAT_ROLE = 0          # 3 one-hot dims
FEAT_DIFF1 = 3         # noisy difficulty observation #1
FEAT_DIFF2 = 4         # noisy difficulty observation #2
FEAT_TOKENS = 5        # est. output tokens / TOKEN_NORM
FEAT_DOMAIN = 6        # 4 one-hot dims
FEAT_POS = 10          # topological position / n
FEAT_FANIN = 11        # in-degree / FAN_NORM
FEAT_FANOUT = 12       # out-degree / FAN_NORM
FEAT_NSUB = 13         # n subtasks / NMAX
FEAT_SINK = 14         # 1.0 if GENERATE sink
FEAT_CRIT = 15         # noisy criticality hint
FEAT_DIM = 16

ROUTER_IN_DIM = FEAT_DIM + 1   # + C_used(t)  (Eq. 8)
ROUTER_HIDDEN = 64             # two hidden layers (Sec. 4.1 "two-hidden-layer MLP")
TOKEN_NORM = 512.0
FAN_NORM = 4.0

# Observation noise on the latent difficulty / criticality exposed to the
# router (the paper's embedding is informative but imperfect).
DIFF_NOISE_STD = 0.08
CRIT_NOISE_STD = 0.15

# ---------------------------------------------------------------------------
# Capability curves: p_solve = sigmoid((cap - d) / CAP_TEMP).
# Calibrated so the single-model reference rows of Table 1 land close to the
# paper (see rust `hybridflow exp calibrate`).
# ---------------------------------------------------------------------------

CAP_TEMP = 0.12

# per-domain capability: [math, science, general, logic]
MODEL_CAPS = {
    "llama3.2-3b":  [0.35, 0.38, 0.27, 0.25],
    "gpt-4.1":      [0.66, 0.595, 0.55, 0.54],
    "qwen2.5-7b":   [0.42, 0.44, 0.34, 0.32],
    "deepseek-v3":  [0.68, 0.615, 0.57, 0.56],
}

# Serving profile: [tokens/s decode, tokens/s prefill, rtt mean s, rtt jitter
# lognorm sigma, $ per input token, $ per output token]
MODEL_SERVING = {
    "llama3.2-3b":  [42.0,  900.0, 0.0,  0.0,  0.0,     0.0],
    "gpt-4.1":      [75.0, 4000.0, 0.45, 0.35, 2.0e-6,  8.0e-6],
    "qwen2.5-7b":   [28.0,  600.0, 0.0,  0.0,  0.0,     0.0],
    "deepseek-v3":  [24.0, 3000.0, 0.70, 0.40, 0.27e-6, 1.10e-6],
}

# ---------------------------------------------------------------------------
# Benchmarks: difficulty Beta(a, b), domain, token-length multiplier,
# query input-token lognormal (mu, sigma).
# ---------------------------------------------------------------------------

BENCHMARKS = {
    "gpqa":      {"beta": [6.0, 2.5], "domain": "science", "tok_mult": 1.2,
                  "query_tokens": [5.3, 0.35], "n_queries": 195},
    "mmlu_pro":  {"beta": [3.5, 3.0], "domain": "general", "tok_mult": 0.8,
                  "query_tokens": [4.9, 0.35], "n_queries": 200},
    "aime24":    {"beta": [8.0, 1.8], "domain": "math", "tok_mult": 2.6,
                  "query_tokens": [4.6, 0.30], "n_queries": 30},
    "livebench": {"beta": [4.0, 2.5], "domain": "logic", "tok_mult": 2.0,
                  "query_tokens": [5.1, 0.40], "n_queries": 100},
}

# ---------------------------------------------------------------------------
# Decomposition / subtask generative constants.
# ---------------------------------------------------------------------------

NMAX = 7                  # planner cap on subtasks (Def. C.2, size constraint)
PHI_LO, PHI_HI = 0.55, 0.95   # subtask difficulty fraction of query difficulty
# Criticality is CONCENTRATED: most subtasks barely affect the final answer
# (w ~ CRIT_BASE); a sparse subset (prob CRIT_P) are pivotal with
# w = CRIT_BASE + (1 - CRIT_BASE) * Beta(*CRIT_HIGH_BETA).  This is what lets
# a smart router recover near-cloud accuracy at ~40% offload (Table 3): the
# cloud advantage lives in a few high-stakes nodes per query.
CRIT_P = 0.38
CRIT_BASE = 0.06
CRIT_HIGH_BETA = [8.0, 2.0]
# Pivotal probability decays with topological position: early analysis
# resolves the key reasoning steps ("many queries resolve key reasoning
# steps early", paper Sec. 4.3 / Fig. 3); deep nodes are derivative.
CRIT_POS_DECAY = 0.75
GENERATE_CRIT = 0.35          # final aggregation is mostly mechanical

# Cloud models answer subtask prompts more verbosely than the edge SLM; this
# multiplies output tokens (and therefore latency + API cost) of cloud calls.
CLOUD_VERBOSITY = 3.0

# Final-answer correctness model (shared with rust `models::exec`):
#   P(query correct) = prod_i (1 - w_i * (1 - p_i))
# where p_i is the executing model's solve probability on subtask i.  The
# outcome-based credit of App. C follows in closed form:
#   dq_i = (p_cloud(d_i) - p_edge(d_i)) * w_i * prod_{j != i} (1 - w_j (1 - p_j))
# with p_j evaluated under the mixed profiling policy (edge/cloud average).

# Output-token lognormal (mu, sigma) per role, before benchmark tok_mult.
ROLE_TOKENS = {
    "EXPLAIN":  [4.0, 0.35],   # ~55 tokens
    "ANALYZE":  [4.6, 0.40],   # ~100 tokens
    "GENERATE": [4.4, 0.35],   # ~82 tokens
}

# Direct (non-decomposed) prompting output tokens: lognormal (mu, sigma),
# per model family ("edge" small models answer shorter than cloud).
DIRECT_TOKENS = {"edge": [5.6, 0.30], "cloud": [6.9, 0.25]}   # ~270 / ~1000
COT_TOKEN_MULT = 1.7      # CoT inflates output tokens

# ---------------------------------------------------------------------------
# Normalization constants of Eq. 24 / adaptive threshold of Eq. 27.
# ---------------------------------------------------------------------------

EPS_UTILITY = 1.0e-4
L_MAX_SUB = 10.0          # s      (Eq. 24 latency scale)
K_MAX_SUB = 0.02          # $      (Eq. 24 API-cost scale)
TAU0 = 0.1                # base threshold (paper: 0.2; retuned for our
                          # substrate's lower utility median - EXPERIMENTS.md)
K_MAX_GLOBAL = 0.02       # $      (Eq. 27 per-query API budget scale)
L_MAX_GLOBAL = 40.0       # s      (Eq. 27 scale; paper 20, retuned - see EXPERIMENTS.md)
C_MAX = 0.5               # normalized per-query budget (knapsack capacity)
DUAL_ETA = 0.35           # projected subgradient step size (Eq. 10)
DUAL_GAMMA = 0.5          # threshold sensitivity (Eq. 11)

# ---------------------------------------------------------------------------
# Router training.
# ---------------------------------------------------------------------------

TRAIN_N_QUERIES = 2000    # profiling queries (paper: 2000 from MMLU-Pro+Math500)
TRAIN_SEED = 20260710
# The paper warm-starts with AdamW at lr 1e-4 on frozen qwen3 embeddings; our
# encoder is trained from scratch on raw features, where 1e-4 underfits badly
# (val R2 0.36 vs 0.51 in an lr sweep) - we use 1e-3 and note the deviation.
TRAIN_LR = 1.0e-3
TRAIN_WEIGHT_DECAY = 1.0e-4
TRAIN_EPOCHS = 120
TRAIN_BATCH = 256


def as_dict() -> dict:
    """All constants as a JSON-serializable dict (artifacts/simparams.json)."""
    return {
        "roles": ROLES,
        "domains": DOMAINS,
        "feat_dim": FEAT_DIM,
        "router_in_dim": ROUTER_IN_DIM,
        "router_hidden": ROUTER_HIDDEN,
        "token_norm": TOKEN_NORM,
        "fan_norm": FAN_NORM,
        "diff_noise_std": DIFF_NOISE_STD,
        "crit_noise_std": CRIT_NOISE_STD,
        "cap_temp": CAP_TEMP,
        "model_caps": MODEL_CAPS,
        "model_serving": MODEL_SERVING,
        "benchmarks": BENCHMARKS,
        "nmax": NMAX,
        "phi": [PHI_LO, PHI_HI],
        "crit_p": CRIT_P,
        "crit_base": CRIT_BASE,
        "crit_pos_decay": CRIT_POS_DECAY,
        "crit_high_beta": CRIT_HIGH_BETA,
        "generate_crit": GENERATE_CRIT,
        "cloud_verbosity": CLOUD_VERBOSITY,
        "role_tokens": ROLE_TOKENS,
        "direct_tokens": DIRECT_TOKENS,
        "cot_token_mult": COT_TOKEN_MULT,
        "eps_utility": EPS_UTILITY,
        "l_max_sub": L_MAX_SUB,
        "k_max_sub": K_MAX_SUB,
        "tau0": TAU0,
        "k_max_global": K_MAX_GLOBAL,
        "l_max_global": L_MAX_GLOBAL,
        "c_max": C_MAX,
        "dual_eta": DUAL_ETA,
        "dual_gamma": DUAL_GAMMA,
    }


def dump_json(path: str) -> None:
    with open(path, "w") as f:
        json.dump(as_dict(), f, indent=2, sort_keys=True)

"""L1 Pallas kernel: row-wise LayerNorm with fused scale/shift.

Used by the edge-LM decoder block (both pre-LN sites). TPU mapping: the
grid tiles rows into (bt, D) VMEM blocks — the full feature dimension stays
resident so mean/variance are single-pass reductions on the vector unit,
and the gamma/beta epilogue is fused (no second HBM pass).

Like every kernel in this package it runs under ``interpret=True`` here
(CPU PJRT cannot execute Mosaic custom-calls) and is pinned to the
``ref.ref_layernorm`` oracle by hypothesis sweeps in
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_DEFAULT_BT = 8


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) * (x - mu), axis=-1, keepdims=True)
    y = (x - mu) / jnp.sqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def layernorm(
    x: jax.Array,
    g: jax.Array,
    b: jax.Array,
    *,
    eps: float = 1e-5,
    bt: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Row-wise LayerNorm: ``(x - mean) / sqrt(var + eps) * g + b``.

    ``x``: (T, D); ``g``/``b``: (D,). Rows are tiled by ``bt`` (padded rows
    are normalized too but sliced away — padding never leaks because the
    reduction is per-row).
    """
    if x.ndim != 2 or g.ndim != 1 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} g{g.shape} b{b.shape}")
    t, d = x.shape
    if g.shape[0] != d or b.shape[0] != d:
        raise ValueError(f"shape mismatch: x{x.shape} g{g.shape} b{b.shape}")

    bt = bt or min(_DEFAULT_BT, t)
    tp = (t + bt - 1) // bt * bt
    xp = jnp.pad(x, ((0, tp - t), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        interpret=interpret,
    )(xp, g.reshape(1, d), b.reshape(1, d))
    return out[:t]

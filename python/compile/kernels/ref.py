"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
the most obvious jnp form.  ``python/tests/test_kernel.py`` asserts
``assert_allclose(kernel, ref)`` over hypothesis-driven shape/dtype sweeps;
these functions are the correctness ground truth for L1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_linear_act(x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "none") -> jax.Array:
    """Reference for ``linear.linear_act``: act(x @ w + b) in f32 accumulate."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if act == "none":
        pass
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "gelu":
        y = jax.nn.gelu(y)
    elif act == "tanh":
        y = jnp.tanh(y)
    elif act == "sigmoid":
        y = jax.nn.sigmoid(y)
    else:
        raise ValueError(f"unknown activation {act!r}")
    return y.astype(x.dtype)


def ref_mlp(x: jax.Array, params: list[tuple[jax.Array, jax.Array]], *, hidden_act: str = "gelu",
            final_act: str = "none") -> jax.Array:
    """Reference MLP stack: hidden layers with ``hidden_act``, last layer with
    ``final_act``; mirrors model.mlp_forward."""
    h = x
    for li, (w, b) in enumerate(params):
        act = final_act if li == len(params) - 1 else hidden_act
        h = ref_linear_act(h, w, b, act=act)
    return h


def ref_layernorm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def ref_causal_attention(x: jax.Array, wq, wk, wv, wo) -> jax.Array:
    """Single-head causal self-attention reference for the tiny edge LM."""
    t, d = x.shape
    q = x @ wq
    k = x @ wk
    v = x @ wv
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    return (attn @ v) @ wo

"""L1 Pallas kernel: fused tiled ``matmul + bias + activation``.

Every dense layer in the L2 graphs (router MLP, embedder, edge-LM feed
forward) routes through this kernel, so it is the single compute hot-spot of
the AOT artifacts.

TPU mapping (see DESIGN.md section "Hardware adaptation"):

* The grid is ``(M/bm, N/bn, K/bk)``; for each ``(i, j)`` output tile an
  f32 accumulator lives in VMEM scratch and the K-loop streams ``(bm, bk)``
  / ``(bk, bn)`` operand tiles HBM->VMEM via ``BlockSpec``.  This is the
  Pallas analogue of the paper's GPU threadblock tiling.
* Block shapes default to MXU-friendly multiples of 128 when the problem is
  large enough and shrink to the padded problem size otherwise.
* The bias add and the activation run inside the final K step on the VMEM
  accumulator - the epilogue is fused, no extra HBM round trip.

The kernel MUST be lowered with ``interpret=True`` in this environment: the
CPU PJRT plugin cannot execute Mosaic custom-calls.  ``ref.py`` provides the
pure-jnp oracle; ``python/tests/test_kernel.py`` sweeps shapes and dtypes
with hypothesis to pin numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

ACTIVATIONS = ("none", "relu", "gelu", "tanh", "sigmoid")

# MXU-native tile edge; block shapes snap to min(dim, these) and the wrapper
# pads inputs up to block multiples.
_DEFAULT_BM = 128
_DEFAULT_BN = 128
_DEFAULT_BK = 128


def _apply_act(y: jax.Array, act: str) -> jax.Array:
    if act == "none":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        return jax.nn.gelu(y)
    if act == "tanh":
        return jnp.tanh(y)
    if act == "sigmoid":
        return jax.nn.sigmoid(y)
    raise ValueError(f"unknown activation {act!r}")


def _linear_act_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int, act: str):
    """One (bm, bn) output tile; program axis 2 walks the K dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped partial product accumulated in f32 regardless of input dtype.
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(y, act).astype(o_ref.dtype)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block(dim: int, default: int) -> int:
    """Largest power-of-two tile <= default that does not overshoot dim badly."""
    b = default
    while b > 8 and b >= 2 * dim:
        b //= 2
    return b


def linear_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "none",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` as a tiled Pallas kernel.

    ``x``: (M, K); ``w``: (K, N); ``b``: (N,).  Arbitrary M/K/N - inputs are
    zero-padded up to block multiples and the result is sliced back.  Zero
    padding is exact for the matmul and the bias tiles replicate, so padded
    lanes never leak into the real output.
    """
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; expected one of {ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2 or b.shape[0] != n:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    bm = bm or _pick_block(m, _DEFAULT_BM)
    bn = bn or _pick_block(n, _DEFAULT_BN)
    bk = bk or _pick_block(k, _DEFAULT_BK)

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_linear_act_kernel, nk=nk, act=act),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (operands + acc + out).

    Used by the perf notes in DESIGN.md/EXPERIMENTS.md to argue the block
    shapes fit the ~16 MiB TPU VMEM with room for double buffering.
    """
    x_tile = bm * bk * dtype_bytes
    w_tile = bk * bn * dtype_bytes
    b_tile = bn * dtype_bytes
    acc = bm * bn * 4
    out = bm * bn * dtype_bytes
    # x2 for double buffering of the streamed operands.
    return 2 * (x_tile + w_tile) + b_tile + acc + out


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    useful = m * n * k
    issued = mp * np_ * kp
    return useful / issued

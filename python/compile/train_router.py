"""Offline router training (paper Sec. 3.3 "Router Training" / App. C).

Builds the profiling dataset with the reuse-and-recombine generative model
from ``simparams`` (the python mirror of the rust simulation substrate) and
regresses the router MLP to the utility targets of Eq. 25 with a hand-rolled
AdamW (no optax in this environment; lr/weight-decay follow Sec. 4.1).

The paper profiles 2,000 queries from MMLU-Pro + Math500; we mirror that
split: the profiling domains deliberately differ from the GPQA/AIME24/
LiveBench test domains so the router must generalize, as in the paper.

Outputs (consumed by ``aot.py`` and the rust fallback predictor):

* trained ``RouterParams``
* ``artifacts/router_meta.json`` - layer dims + weights + train/val metrics
"""

from __future__ import annotations

import json
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import simparams as sp
from .model import RouterParams, init_router, router_forward, router_loss

# Profiling-time pseudo-benchmark for Math500 (not in the eval set).
PROFILE_BENCHMARKS = {
    "mmlu_pro": sp.BENCHMARKS["mmlu_pro"],
    "math500": {"beta": [5.0, 2.8], "domain": "math", "tok_mult": 1.8,
                "query_tokens": [4.7, 0.30]},
}


def _sigmoid(x: float) -> float:
    return 1.0 / (1.0 + math.exp(-x))


def _p_solve(model: str, domain: str, d: float) -> float:
    cap = sp.MODEL_CAPS[model][sp.DOMAINS.index(domain)]
    return _sigmoid((cap - d) / sp.CAP_TEMP)


def _latency(model: str, in_tokens: float, out_tokens: float, rng: np.random.Generator) -> float:
    tps, prefill, rtt_mu, rtt_sig, _, _ = sp.MODEL_SERVING[model]
    rtt = 0.0
    if rtt_mu > 0:
        rtt = rtt_mu * float(rng.lognormal(0.0, rtt_sig))
    return rtt + in_tokens / prefill + out_tokens / tps


def _api_cost(model: str, in_tokens: float, out_tokens: float) -> float:
    _, _, _, _, pin, pout = sp.MODEL_SERVING[model]
    return in_tokens * pin + out_tokens * pout


def generate_profile_set(
    n_queries: int = sp.TRAIN_N_QUERIES,
    seed: int = sp.TRAIN_SEED,
    edge_model: str = "llama3.2-3b",
    cloud_model: str = "gpt-4.1",
):
    """Sample (features, c_used, utility-target) triples.

    Follows App. C: per query, decompose; per subtask, paired edge/cloud
    executions give (dq, dl, dk); Eq. 24 normalizes cost; Eq. 25 gives the
    target.  Features carry only the *noisy* observations the online router
    will have, so the regression faces realistic irreducible error.
    """
    rng = np.random.default_rng(seed)
    names = list(PROFILE_BENCHMARKS)
    feats, c_useds, targets = [], [], []

    for _ in range(n_queries):
        bench = PROFILE_BENCHMARKS[names[rng.integers(len(names))]]
        a, b = bench["beta"]
        d_q = float(rng.beta(a, b))
        domain = bench["domain"]
        dom_idx = sp.DOMAINS.index(domain)
        tok_mult = bench["tok_mult"]
        q_mu, q_sig = bench["query_tokens"]
        q_tokens = float(rng.lognormal(q_mu, q_sig))

        n = int(rng.integers(3, sp.NMAX + 1))
        out_toks = np.zeros(n)
        # Simple random DAG: node i depends on a subset of earlier nodes.
        deps: list[list[int]] = [[] for _ in range(n)]
        for i in range(1, n):
            k = int(rng.integers(1, min(i, 3) + 1))
            deps[i] = sorted(rng.choice(i, size=k, replace=False).tolist())

        # Latent per-subtask quantities (shared by the paired executions).
        roles, d, w, p_e, p_c = [], [], [], [], []
        for i in range(n):
            role = "EXPLAIN" if i == 0 else ("GENERATE" if i == n - 1 else "ANALYZE")
            roles.append(role)
            phi = float(rng.uniform(sp.PHI_LO, sp.PHI_HI))
            d_i = min(1.0, d_q * phi)
            d.append(d_i)
            pos = i / max(1, n - 1)
            p_pivotal = sp.CRIT_P * (1.0 - sp.CRIT_POS_DECAY * pos)
            if role == "GENERATE":
                w.append(sp.GENERATE_CRIT)
            elif rng.random() < p_pivotal:
                w.append(sp.CRIT_BASE + (1 - sp.CRIT_BASE) * float(rng.beta(*sp.CRIT_HIGH_BETA)))
            else:
                w.append(sp.CRIT_BASE)
            p_e.append(_p_solve(edge_model, domain, d_i))
            p_c.append(_p_solve(cloud_model, domain, d_i))
            mu, sig = sp.ROLE_TOKENS[role]
            out_toks[i] = float(rng.lognormal(mu, sig)) * tok_mult

        # Mixed-context pipeline factor: P(rest of the pipeline does not
        # break) under the profiling policy that averages edge/cloud per
        # node (App. C's reuse-and-recombine averages over sampled routing
        # vectors; the per-node average is its expectation).
        node_ok = [1.0 - w[j] * (1.0 - 0.5 * (p_e[j] + p_c[j])) for j in range(n)]
        prod_all = 1.0
        for v in node_ok:
            prod_all *= v

        c_used = 0.0
        for i in range(n):
            role = roles[i]
            d_i, w_i = d[i], w[i]
            in_toks = q_tokens + float(sum(out_toks[j] for j in deps[i]))
            cloud_out = out_toks[i] * sp.CLOUD_VERBOSITY

            # Outcome-based credit (closed form of the paired executions).
            pipeline = prod_all / max(node_ok[i], 1e-9)
            dq = (p_c[i] - p_e[i]) * w_i * pipeline
            dl = max(0.0, _latency(cloud_model, in_toks, cloud_out, rng)
                     - _latency(edge_model, in_toks, out_toks[i], rng))
            dk = _api_cost(cloud_model, in_toks, cloud_out)

            c = min(1.0, max(0.0, 0.5 * dl / sp.L_MAX_SUB + 0.5 * dk / sp.K_MAX_SUB))
            u = min(1.0, max(0.0, dq / (c + sp.EPS_UTILITY)))

            # Packed feature vector (noisy observations only).
            f = np.zeros(sp.FEAT_DIM, np.float32)
            f[sp.FEAT_ROLE + sp.ROLES.index(role)] = 1.0
            f[sp.FEAT_DIFF1] = np.clip(d_i + rng.normal(0, sp.DIFF_NOISE_STD), 0, 1)
            f[sp.FEAT_DIFF2] = np.clip(d_i + rng.normal(0, sp.DIFF_NOISE_STD), 0, 1)
            f[sp.FEAT_TOKENS] = out_toks[i] / sp.TOKEN_NORM
            f[sp.FEAT_DOMAIN + dom_idx] = 1.0
            f[sp.FEAT_POS] = i / max(1, n - 1)
            f[sp.FEAT_FANIN] = len(deps[i]) / sp.FAN_NORM
            fanout = sum(i in dj for dj in deps)
            f[sp.FEAT_FANOUT] = fanout / sp.FAN_NORM
            f[sp.FEAT_NSUB] = n / sp.NMAX
            f[sp.FEAT_SINK] = 1.0 if role == "GENERATE" else 0.0
            f[sp.FEAT_CRIT] = np.clip(w_i + rng.normal(0, sp.CRIT_NOISE_STD), 0, 1)

            feats.append(f)
            c_useds.append(c_used)
            targets.append(u)

            # Roll the budget forward with a random exploration policy so the
            # C_used input covers its operating range.
            if rng.random() < 0.4:
                c_used = min(2.0, c_used + c)

    return (np.stack(feats), np.asarray(c_useds, np.float32)[:, None],
            np.asarray(targets, np.float32))


# ---------------------------------------------------------------------------
# AdamW (hand-rolled; optax is not installed in this image).
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adamw_step(params, grads, state, lr=sp.TRAIN_LR, b1=0.9, b2=0.999,
               eps=1e-8, wd=sp.TRAIN_WEIGHT_DECAY):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m_, v_):
        return p - lr * (m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps) + wd * p)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def train_router(
    epochs: int = sp.TRAIN_EPOCHS,
    batch: int = sp.TRAIN_BATCH,
    seed: int = sp.TRAIN_SEED,
    n_queries: int = sp.TRAIN_N_QUERIES,
    interpret_kernel: bool = False,
    verbose: bool = True,
):
    """Train and return (params, metrics).

    ``interpret_kernel=False`` trains through the pure-jnp reference path
    (identical math, much faster under jit); the exported artifact always
    uses the Pallas kernel graph, and tests assert the two paths agree.
    """
    feats, c_used, targets = generate_profile_set(n_queries, seed)
    n = feats.shape[0]
    n_val = max(1, n // 10)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(n)
    feats, c_used, targets = feats[perm], c_used[perm], targets[perm]
    fv, cv, tv = feats[:n_val], c_used[:n_val], targets[:n_val]
    ft, ct, tt = feats[n_val:], c_used[n_val:], targets[n_val:]

    params = init_router(jax.random.PRNGKey(seed))
    opt = adamw_init(params)

    if interpret_kernel:
        loss_fn = lambda p, f, c, t: router_loss(p, f, c, t, interpret=True)
        step = jax.value_and_grad(loss_fn)
    else:
        from .kernels.ref import ref_mlp

        def loss_fn(p, f, c, t):
            x = jnp.concatenate([f, c], axis=1)
            pred = ref_mlp(x, p.layers, hidden_act="gelu", final_act="sigmoid")[:, 0]
            return jnp.mean((pred - t) ** 2)

        step = jax.jit(jax.value_and_grad(loss_fn))

    n_train = ft.shape[0]
    steps_per_epoch = max(1, n_train // batch)
    history = []
    for ep in range(epochs):
        ep_perm = rng.permutation(n_train)
        tot = 0.0
        for s in range(steps_per_epoch):
            idx = ep_perm[s * batch:(s + 1) * batch]
            loss, grads = step(params, ft[idx], ct[idx], tt[idx])
            params, opt = adamw_step(params, grads, opt)
            tot += float(loss)
        history.append(tot / steps_per_epoch)
        if verbose and (ep % 10 == 0 or ep == epochs - 1):
            print(f"[train_router] epoch {ep:3d} train_mse={history[-1]:.5f}")

    # Validation metrics through the *kernel* path (the artifact graph).
    pred_val = np.asarray(router_forward(params, jnp.asarray(fv), jnp.asarray(cv),
                                         interpret=True))
    val_mse = float(np.mean((pred_val - tv) ** 2))
    ss_res = float(np.sum((pred_val - tv) ** 2))
    ss_tot = float(np.sum((tv - tv.mean()) ** 2)) + 1e-12
    r2 = 1.0 - ss_res / ss_tot
    metrics = {"train_mse": history, "val_mse": val_mse, "val_r2": r2,
               "n_samples": int(n), "target_mean": float(targets.mean())}
    if verbose:
        print(f"[train_router] val_mse={val_mse:.5f} val_r2={r2:.3f} n={n}")
    return params, metrics


def export_router_meta(params: RouterParams, metrics: dict, path: str) -> None:
    """Dump dims + weights + metrics as JSON for the rust fallback mirror."""
    layers = []
    for (w, b) in params.layers:
        layers.append({
            "w": np.asarray(w).astype(float).round(7).tolist(),
            "b": np.asarray(b).astype(float).round(7).tolist(),
        })
    meta = {
        "dims": params.dims,
        "hidden_act": "gelu",
        "final_act": "sigmoid",
        "feat_dim": sp.FEAT_DIM,
        "layers": layers,
        "metrics": {k: v for k, v in metrics.items() if k != "train_mse"},
    }
    with open(path, "w") as f:
        json.dump(meta, f)


if __name__ == "__main__":
    p, m = train_router()
    export_router_meta(p, m, "/tmp/router_meta.json")

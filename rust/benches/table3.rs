//! Bench target regenerating the paper's Table 3 on the simulation
//! substrate (see DESIGN.md per-experiment index and EXPERIMENTS.md for
//! paper-vs-measured). Scale via env: BENCH_SCALE (default 1.0 = paper
//! sizes), BENCH_SEEDS (default 3).

fn main() {
    let ctx = hybridflow::eval::ExpContext::from_bench_env();
    let t0 = std::time::Instant::now();
    match hybridflow::eval::run_experiment("table3", &ctx) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    println!("[bench table3] {:.1}s (scale {}, {} seeds)",
             t0.elapsed().as_secs_f64(), ctx.scale, ctx.seeds.len());
}

//! Microbench: fingerprint computation + cache lookup on a 10k-entry
//! cache (hit and miss paths, per policy), plus the insert/evict cycle at
//! capacity. Fingerprints and lookups are the per-decision hot path and
//! should stay O(100ns)-ish. Insert-at-capacity used to pay an
//! O(capacity) victim scan (~microseconds per insert at 10k entries);
//! eviction now goes through a `BTreeSet` index keyed on the policy's
//! rank, so the insert+evict cases below should sit within a small
//! constant factor of the lookup cases — that gap closing is the win this
//! bench exists to show (and to catch regressing).
//!
//! Scale via env: CACHE_BENCH_ITERS (default 1_000_000).

use hybridflow::cache::{CachePolicyKind, CachedResult, Fingerprint, SubtaskCache};
use hybridflow::dag::Role;
use hybridflow::models::ExecRecord;
use hybridflow::workload::{generate_queries, Benchmark, SubtaskLatent};
use std::time::Instant;

const ENTRIES: usize = 10_000;

fn iters() -> usize {
    std::env::var("CACHE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000)
}

fn rec(i: u64) -> ExecRecord {
    ExecRecord {
        correct: i % 2 == 0,
        latency: 1.0 + (i % 97) as f64 * 0.01,
        api_cost: 0.001,
        in_tokens: 200.0,
        out_tokens: 120.0,
    }
}

fn bench<F: FnMut(usize) -> u64>(name: &str, n: usize, mut f: F) {
    let t0 = Instant::now();
    let mut sink = 0u64;
    for i in 0..n {
        sink = sink.wrapping_add(f(i));
    }
    let dt = t0.elapsed();
    println!(
        "{name:<44} {n:>9} iters  {:>8.1} ns/op  (sink {sink:x})",
        dt.as_nanos() as f64 / n as f64
    );
}

fn main() {
    let n = iters();
    println!("[bench cache] {ENTRIES}-entry cache, {n} iterations per case\n");

    // --- Fingerprint computation ------------------------------------------
    let queries = generate_queries(Benchmark::Gpqa, 64, 7);
    bench("fingerprint: of_node", n, |i| {
        let q = &queries[i % queries.len()];
        Fingerprint::of_node(q, i % 7, Role::Analyze, i % 2 == 0).0
    });
    let latent = SubtaskLatent { difficulty: 0.5, criticality: 0.4, out_tokens: 120.0 };
    bench("fingerprint: of_call", n, |i| {
        Fingerprint::of_call(i % 4, &latent, 200.0 + (i % 13) as f64, i % 2 == 0, false).0
    });

    // --- Lookup on a full 10k-entry cache ---------------------------------
    for kind in [CachePolicyKind::Lru, CachePolicyKind::Lfu, CachePolicyKind::Ttl(1e12)] {
        let cache = SubtaskCache::new(ENTRIES, kind);
        for i in 0..ENTRIES as u64 {
            cache.insert(0, Fingerprint(i), CachedResult { cloud: true, rec: rec(i) }, i as f64, i as f64);
        }
        assert_eq!(cache.len(0), ENTRIES);
        let label_hit = format!("lookup hit  ({})", kind.label());
        bench(&label_hit, n, |i| {
            let key = Fingerprint((i % ENTRIES) as u64);
            u64::from(cache.lookup(0, key, 1e9).is_some())
        });
        let label_miss = format!("lookup miss ({})", kind.label());
        bench(&label_miss, n, |i| {
            let key = Fingerprint((ENTRIES + i) as u64);
            u64::from(cache.lookup(0, key, 1e9).is_none())
        });
    }

    // --- Insert at capacity (every insert evicts) --------------------------
    // With the O(log n) eviction index these run at the same iteration
    // count as the lookup cases; before it, 10k-entry churn had to be
    // downscaled ~50x to finish. A still-visible slowdown here means the
    // index fell out of lockstep with the entry map.
    let churn_iters = n.max(1_000);
    for kind in [CachePolicyKind::Lru, CachePolicyKind::Lfu, CachePolicyKind::Ttl(1e12)] {
        let cache = SubtaskCache::new(ENTRIES, kind);
        for i in 0..ENTRIES as u64 {
            cache.insert(0, Fingerprint(i), CachedResult { cloud: false, rec: rec(i) }, i as f64, i as f64);
        }
        let label = format!("insert+evict at cap ({})", kind.label());
        bench(&label, churn_iters, |i| {
            let key = Fingerprint((ENTRIES + i) as u64);
            cache.insert(0, key, CachedResult { cloud: false, rec: rec(i as u64) }, 1e6 + i as f64, 1e6 + i as f64);
            key.0
        });
        let s = cache.stats();
        println!("    -> {} evictions, {} entries", s.evictions, cache.len(0));
    }
}

//! Bench target sweeping the fleet simulator across arrival rates
//! (queueing delay, tail sojourn, offload, and budget pressure vs load).
//! Scale via env: BENCH_SCALE (default 1.0), BENCH_SEEDS (default 3,
//! first seed used).

fn main() {
    let ctx = hybridflow::eval::ExpContext::from_bench_env();
    let t0 = std::time::Instant::now();
    match hybridflow::eval::run_experiment("fleet_serve", &ctx) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    println!(
        "[bench fleet] {:.1}s (scale {}, {} seeds)",
        t0.elapsed().as_secs_f64(),
        ctx.scale,
        ctx.seeds.len()
    );
}

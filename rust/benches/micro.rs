//! Micro-benchmarks of the coordinator hot paths (§Perf baseline/after
//! numbers in EXPERIMENTS.md):
//!
//! * router utility prediction — rust mirror vs PJRT artifact, per batch
//!   size (the batched-frontier story);
//! * DAG operations (topo, critical path, validate, repair);
//! * XML plan parse;
//! * full per-query pipeline (plan -> route -> schedule);
//! * knapsack oracle variants;
//! * substrate primitives (json parse/serialize, rng).
//!
//! Budget per case via BENCH_BUDGET_S (default 1.0s).

use hybridflow::bench::Bench;
use hybridflow::config::simparams::{SimParams, FEAT_DIM};
use hybridflow::dag::{parse_plan, validate, validate_and_repair, Role, Subtask, TaskDag};
use hybridflow::models::SimExecutor;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::planner::Planner;
use hybridflow::router::predictor::UtilityPredictor;
use hybridflow::router::{knapsack, MirrorPredictor, RoutePolicy};
use hybridflow::runtime::RouterService;
use hybridflow::util::json::Json;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, Benchmark};
use std::hint::black_box;
use std::sync::Arc;

fn rand_feats(n: usize, rng: &mut Rng) -> Vec<[f32; FEAT_DIM]> {
    (0..n)
        .map(|_| {
            let mut f = [0.0f32; FEAT_DIM];
            for v in f.iter_mut() {
                *v = rng.f64() as f32;
            }
            f
        })
        .collect()
}

fn main() {
    let artifacts = hybridflow::config::default_artifacts_dir();
    let mut rng = Rng::new(0xBEEF);

    // ---------------- router prediction ----------------
    let mut b = Bench::new("router utility prediction");
    b.header();
    let mirror = MirrorPredictor::from_meta_file(&artifacts.join("router_meta.json"))
        .expect("run `make artifacts` first");
    for &n in &[1usize, 8, 32] {
        let feats = rand_feats(n, &mut rng);
        b.bench(&format!("mirror predict (batch {n})"), || {
            black_box(mirror.predict(black_box(&feats), 0.3));
        });
    }
    match RouterService::start(&artifacts) {
        Ok(svc) => {
            for &n in &[1usize, 8, 32] {
                let feats = rand_feats(n, &mut rng);
                b.bench(&format!("pjrt score (batch {n})"), || {
                    black_box(svc.score(black_box(&feats), 0.3).unwrap());
                });
            }
            b.bench("pjrt edge_lm burn (1 chunk)", || {
                black_box(svc.edge_burn(1).unwrap());
            });
        }
        Err(e) => eprintln!("(skipping PJRT benches: {e})"),
    }
    // Engine-direct (no service channel): isolates channel round-trip cost.
    if let Ok(engine) = hybridflow::runtime::PjrtEngine::load(&artifacts) {
        for &n in &[1usize, 32] {
            let feats = rand_feats(n, &mut rng);
            b.bench(&format!("pjrt engine-direct (batch {n})"), || {
                black_box(engine.score(black_box(&feats), 0.3).unwrap());
            });
        }
    }

    // ---------------- DAG ops ----------------
    let mut b = Bench::new("dag operations");
    b.header();
    let dag = TaskDag::new(vec![
        Subtask::new(0, Role::Explain, "root", vec![]),
        Subtask::new(1, Role::Analyze, "a", vec![0]),
        Subtask::new(2, Role::Analyze, "b", vec![0]),
        Subtask::new(3, Role::Analyze, "c", vec![1]),
        Subtask::new(4, Role::Analyze, "d", vec![0, 2]),
        Subtask::new(5, Role::Analyze, "e", vec![3]),
        Subtask::new(6, Role::Generate, "g", vec![4, 5]),
    ]);
    b.bench("topo_order (7 nodes)", || {
        black_box(dag.topo_order());
    });
    b.bench("critical_path + R_comp", || {
        black_box(dag.critical_path_len());
        black_box(dag.compression_ratio());
    });
    b.bench("validate (valid plan)", || {
        black_box(validate(&dag, 7).is_valid());
    });
    let mut broken = dag.clone();
    broken.nodes[2].deps = vec![0, 6];
    broken.nodes[2].edge_conf = vec![1.0, 0.2];
    b.bench("validate_and_repair (cyclic plan)", || {
        black_box(validate_and_repair(black_box(&broken), 7));
    });
    let xml = hybridflow::dag::emit_plan(&dag);
    b.bench("xml parse_plan (7 steps)", || {
        black_box(parse_plan(black_box(&xml)).unwrap());
    });

    // ---------------- planner + pipeline ----------------
    let mut b = Bench::new("pipeline");
    b.header();
    let sp = SimParams::default();
    let planner = SyntheticPlanner::paper_main();
    let queries = generate_queries(Benchmark::Gpqa, 64, 3);
    let mut prng = Rng::new(17);
    let mut qi = 0usize;
    b.bench("planner plan (text+parse+repair)", || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        black_box(planner.plan(q, 7, &mut prng));
    });
    let pipeline = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::new(mirror.clone()),
        PipelineConfig::paper_default(&sp),
    );
    let mut qrng = Rng::new(23);
    let mut qj = 0usize;
    b.bench("full query (plan+route+schedule)", || {
        let q = &queries[qj % queries.len()];
        qj += 1;
        black_box(pipeline.run_query(q, &mut qrng));
    });
    let mut cfg2 = PipelineConfig::paper_default(&sp);
    cfg2.policy = RoutePolicy::AllEdge;
    let pipeline_edge = HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        Arc::new(mirror.clone()),
        cfg2,
    );
    let mut qk = 0usize;
    b.bench("full query (no routing, AllEdge)", || {
        let q = &queries[qk % queries.len()];
        qk += 1;
        black_box(pipeline_edge.run_query(q, &mut qrng));
    });

    // ---------------- PJRT on the pipeline hot path ----------------
    // The batched-frontier optimization: score all same-instant ready
    // nodes in one PJRT call vs one call per decision.
    if let Ok(svc) = RouterService::start(&artifacts) {
        let mut b = Bench::new("pipeline over PJRT (frontier batching)");
        b.header();
        let svc = Arc::new(svc);
        for (label, batch) in [("batched frontier", true), ("per-decision calls", false)] {
            let mut cfg = PipelineConfig::paper_default(&sp);
            cfg.schedule.batch_frontier = batch;
            let p = HybridFlowPipeline::with_predictor(
                SimExecutor::paper_pair(),
                SyntheticPlanner::paper_main(),
                Arc::clone(&svc) as Arc<dyn hybridflow::router::predictor::UtilityPredictor>,
                cfg,
            );
            let mut r = Rng::new(31);
            let mut qi = 0usize;
            b.bench(&format!("full query via pjrt ({label})"), || {
                let q = &queries[qi % queries.len()];
                qi += 1;
                black_box(p.run_query(q, &mut r));
            });
        }
    }

    // ---------------- knapsack ----------------
    let mut b = Bench::new("knapsack oracle");
    b.header();
    let mut krng = Rng::new(5);
    let v: Vec<f64> = (0..7).map(|_| krng.f64()).collect();
    let w: Vec<f64> = (0..7).map(|_| krng.uniform(0.05, 0.3)).collect();
    b.bench("exact 2^7 enumeration", || {
        black_box(knapsack::solve_exact(black_box(&v), black_box(&w), 0.5));
    });
    let v100: Vec<f64> = (0..100).map(|_| krng.f64()).collect();
    let w100: Vec<f64> = (0..100).map(|_| krng.uniform(0.01, 0.1)).collect();
    b.bench("dp n=100 (1e-3 grid)", || {
        black_box(knapsack::solve_dp(black_box(&v100), black_box(&w100), 1.0, 1e-3));
    });
    b.bench("greedy ratio n=100", || {
        black_box(knapsack::solve_greedy_ratio(black_box(&v100), black_box(&w100), 1.0));
    });

    // ---------------- substrates ----------------
    let mut b = Bench::new("substrates");
    b.header();
    let json_text = Json::obj(vec![
        ("values", Json::from_f64_slice(&(0..64).map(|i| i as f64 * 0.5).collect::<Vec<_>>())),
        ("name", Json::Str("hybridflow".into())),
        ("nested", Json::obj(vec![("k", Json::Num(1.0)), ("s", Json::Str("x \"y\"".into()))])),
    ])
    .to_string();
    b.bench("json parse (compact record)", || {
        black_box(Json::parse(black_box(&json_text)).unwrap());
    });
    let parsed = Json::parse(&json_text).unwrap();
    b.bench("json serialize", || {
        black_box(parsed.to_string());
    });
    let mut r = Rng::new(1);
    b.bench("rng normal", || {
        black_box(r.normal());
    });
    b.bench("rng beta(8,2)", || {
        black_box(r.beta(8.0, 2.0));
    });
}

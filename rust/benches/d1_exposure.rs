//! Extension bench: App. D.1 cloud-exposure proxy (Eqs. 29-31)

fn main() {
    let ctx = hybridflow::eval::ExpContext::from_bench_env();
    match hybridflow::eval::run_experiment("d1_exposure", &ctx) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

//! Kernel hot-path benchmark: the engine's machine-readable perf
//! trajectory (`BENCH_kernel.json`).
//!
//! Three sections, all emitted into one JSON artifact so this and every
//! future perf PR is *measured* against a recorded baseline, not
//! asserted:
//!
//! * `pool_microbench` — raw claim/release cost of the O(log W)
//!   [`WorkerPool`] index vs the retained linear `argmin` reference, per
//!   pool size. This isolates the dispatch primitive the overhaul
//!   replaced.
//! * `worker_sweep` — whole-kernel fleet runs across pool sizes 4 → 1024
//!   (`ScheduleConfig::linear_pool_reference` re-enables the pre-PR
//!   linear-scan baseline), reporting events/sec, queries/sec
//!   and wall time for both modes plus their throughput ratio. Flat
//!   indexed events/sec across W is the "no linear-in-W term" check.
//! * `fleet_sweep` — fleet sizes 1k → 10k queries at a fixed pool,
//!   pinning end-to-end kernel scaling in workload size.
//! * `observe_overhead` — the identical fleet with the `obs::` recorders
//!   (spans + metrics) off vs on, pinning the cost of full
//!   instrumentation (observe-off takes the exact uninstrumented code
//!   path, so its cell doubles as the PR 7 baseline).
//! * `fault_overhead` — the identical fleet with the fault-injection +
//!   resilience layer off vs on (preset-shaped failure probabilities,
//!   stragglers, default retry policy), pinning the cost of per-attempt
//!   fault draws and retry bookkeeping (faults-off takes the exact
//!   pre-fault code path, so its cell doubles as the pre-fault baseline).
//! * `shard_scaling` — the same 100k-query fleet partitioned across 1, 2,
//!   4, and 8 kernel shards (`run_fleet_sharded`, one OS thread per
//!   shard), reporting events/sec and queries/sec per shard count plus
//!   the 4-shard-vs-1 throughput ratio, and one million-query cell
//!   (scaled by `BENCH_SCALE`) proving fleets far past the single-heap
//!   comfort zone complete under the bench.
//!
//! Scale via env: `BENCH_SCALE` (default 1.0; `scripts/verify.sh` smoke
//! runs at 0.05), `BENCH_OUT` (default `BENCH_kernel.json`). After
//! writing, the artifact is re-read and parsed with `util::json` — a
//! malformed emission fails the bench (exit 1).

use hybridflow::budget::TenantPool;
use hybridflow::config::simparams::SimParams;
use hybridflow::fault::{FaultConfig, ResilienceConfig};
use hybridflow::models::SimExecutor;
use hybridflow::obs::ObserveConfig;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::router::{MirrorPredictor, RoutePolicy};
use hybridflow::scheduler::fleet::{run_fleet, run_fleet_sharded, FleetArrival, FleetConfig};
use hybridflow::scheduler::pool::WorkerPool;
use hybridflow::scheduler::ScheduleConfig;
use hybridflow::util::json::Json;
use hybridflow::workload::{generate_queries, Benchmark};
use std::hint::black_box;
use std::time::Instant;

fn scale() -> f64 {
    std::env::var("BENCH_SCALE").ok().and_then(|v| v.parse().ok()).unwrap_or(1.0)
}

// ---------------------------------------------------------------------------
// Section 1: pool claim/release microbenchmark.
// ---------------------------------------------------------------------------

/// Scripted churn: claims with an advancing clock plus periodic releases,
/// the same op mix the kernel's dispatch/cancel path issues.
fn pool_ops(pool: &mut WorkerPool, ops: usize) -> f64 {
    let mut now = 0.0f64;
    let mut acc = 0.0f64;
    let t0 = Instant::now();
    for i in 0..ops {
        now += 0.01;
        let (w, start, finish) = pool.claim(now, 1.0 + (i % 7) as f64 * 0.25);
        acc += start;
        if i % 5 == 0 {
            // Cancel-style release of the just-made reservation's tail.
            pool.set_free(w, finish - 0.5);
        }
    }
    black_box(acc);
    t0.elapsed().as_secs_f64()
}

fn pool_microbench(workers: &[usize], ops: usize) -> Vec<Json> {
    workers
        .iter()
        .map(|&w| {
            let mut indexed = WorkerPool::new(w);
            let mut linear = WorkerPool::linear_reference(w);
            let t_idx = pool_ops(&mut indexed, ops);
            let t_lin = pool_ops(&mut linear, ops);
            let ns = |t: f64| t / ops as f64 * 1e9;
            println!(
                "pool  W={w:<5} indexed {:>8.1} ns/op   linear {:>8.1} ns/op   speedup {:.2}x",
                ns(t_idx),
                ns(t_lin),
                t_lin / t_idx.max(1e-12),
            );
            Json::obj(vec![
                ("workers", Json::Num(w as f64)),
                ("ops", Json::Num(ops as f64)),
                ("indexed_ns_per_op", Json::Num(ns(t_idx))),
                ("linear_ns_per_op", Json::Num(ns(t_lin))),
                ("speedup", Json::Num(t_lin / t_idx.max(1e-12))),
            ])
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Section 2/3: whole-kernel fleet runs.
// ---------------------------------------------------------------------------

fn pipeline(workers: usize, linear_pools: bool) -> HybridFlowPipeline {
    let sp = SimParams::default();
    let mut cfg = PipelineConfig::paper_default(&sp);
    // A cheap stochastic policy keeps both pools active without router
    // state dominating the profile: the dispatch path is what we measure.
    cfg.policy = RoutePolicy::Random(0.5);
    cfg.schedule = ScheduleConfig {
        edge_workers: workers,
        cloud_workers: workers,
        linear_pool_reference: linear_pools,
        ..Default::default()
    };
    HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        std::sync::Arc::new(MirrorPredictor::synthetic_for_tests()),
        cfg,
    )
}

struct KernelRunStats {
    wall_s: f64,
    events: usize,
    events_per_s: f64,
    queries_per_s: f64,
}

impl KernelRunStats {
    fn fields(&self, queries: usize) -> Vec<(&'static str, Json)> {
        vec![
            ("queries", Json::Num(queries as f64)),
            ("events", Json::Num(self.events as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("events_per_s", Json::Num(self.events_per_s)),
            ("queries_per_s", Json::Num(self.queries_per_s)),
        ]
    }

    fn to_json(&self, queries: usize) -> Json {
        Json::obj(self.fields(queries))
    }
}

/// One kernel run: `n` queries arriving nearly at once onto `workers`-wide
/// pools, so dispatch contends with a deep frontier (every claim walks a
/// loaded pool). `linear_pools` selects the retained linear-scan
/// reference (`ScheduleConfig::linear_pool_reference`) for the baseline
/// measurement.
fn run_kernel(workers: usize, n: usize, seed: u64, linear_pools: bool) -> KernelRunStats {
    let cfg = FleetConfig { record_trace: false, ..Default::default() };
    run_kernel_cfg(workers, n, seed, linear_pools, cfg)
}

/// [`run_kernel`] with an explicit fleet config, so the observability
/// section can switch the recorders on against the identical workload.
fn run_kernel_cfg(
    workers: usize,
    n: usize,
    seed: u64,
    linear_pools: bool,
    cfg: FleetConfig,
) -> KernelRunStats {
    let p = pipeline(workers, linear_pools);
    let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, n, seed)
        .into_iter()
        .enumerate()
        .map(|(i, query)| FleetArrival { time: i as f64 * 0.005, tenant: 0, query })
        .collect();
    let tenants = vec![TenantPool::unlimited("bench")];
    let t0 = Instant::now();
    let report = run_fleet(&p, &cfg, tenants, arrivals, seed);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let events: usize = report.results.iter().map(|r| r.exec.events.len()).sum();
    assert!(report.clock_monotone, "bench run violated clock monotonicity");
    black_box(report.total_api_cost);
    KernelRunStats {
        wall_s,
        events,
        events_per_s: events as f64 / wall_s,
        queries_per_s: n as f64 / wall_s,
    }
}

/// One sharded kernel run: the same near-simultaneous workload as
/// [`run_kernel`], split across `shards` per-shard kernels on one OS
/// thread each (up to the machine's parallelism). `shards = 1` is the
/// sharded path's overhead baseline.
fn run_sharded_kernel(workers: usize, n: usize, seed: u64, shards: usize) -> KernelRunStats {
    let arrivals: Vec<FleetArrival> = generate_queries(Benchmark::Gpqa, n, seed)
        .into_iter()
        .enumerate()
        .map(|(i, query)| FleetArrival { time: i as f64 * 0.005, tenant: 0, query })
        .collect();
    let cfg = FleetConfig { record_trace: false, ..Default::default() };
    let tenants = vec![TenantPool::unlimited("bench")];
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let t0 = Instant::now();
    let report =
        run_fleet_sharded(move || pipeline(workers, false), &cfg, tenants, arrivals, seed, shards, threads);
    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let events: usize = report.results.iter().map(|r| r.exec.events.len()).sum();
    assert!(report.clock_monotone, "sharded bench run violated clock monotonicity");
    assert_eq!(report.results.len(), n, "sharded merge dropped queries");
    black_box(report.total_api_cost);
    KernelRunStats {
        wall_s,
        events,
        events_per_s: events as f64 / wall_s,
        queries_per_s: n as f64 / wall_s,
    }
}

fn main() {
    let scale = scale();
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_kernel.json".to_string());
    let workers = [4usize, 16, 64, 256, 512, 1024];
    let ops = 100_000usize;
    let n_worker_cell = ((1500.0 * scale).round() as usize).max(40);

    println!("== kernel bench (scale {scale}) ==");
    println!("-- pool claim/release microbench ({ops} ops) --");
    let micro = pool_microbench(&workers, ops);

    println!("-- whole-kernel worker sweep ({n_worker_cell} queries/cell) --");
    let mut ratio_512 = None;
    let worker_sweep: Vec<Json> = workers
        .iter()
        .map(|&w| {
            // One fixed seed across the whole sweep: every cell serves the
            // identical workload, so cross-W throughput differences are
            // dispatch cost, not query-mix noise (the flatness metric
            // depends on this).
            let seed = 1000u64;
            let indexed = run_kernel(w, n_worker_cell, seed, false);
            let linear = run_kernel(w, n_worker_cell, seed, true);
            let ratio = indexed.events_per_s / linear.events_per_s.max(1e-9);
            if w == 512 {
                ratio_512 = Some(ratio);
            }
            println!(
                "kernel W={w:<5} indexed {:>10.0} ev/s   linear-baseline {:>10.0} ev/s   ratio {:.2}x",
                indexed.events_per_s, linear.events_per_s, ratio,
            );
            Json::obj(vec![
                ("workers", Json::Num(w as f64)),
                ("indexed", indexed.to_json(n_worker_cell)),
                ("linear_scan_baseline", linear.to_json(n_worker_cell)),
                ("throughput_ratio", Json::Num(ratio)),
            ])
        })
        .collect();

    println!("-- fleet-size sweep (64-worker pools) --");
    let fleet_sweep: Vec<Json> = [1000usize, 2500, 5000, 10000]
        .iter()
        .map(|&n| {
            let n_eff = ((n as f64 * scale).round() as usize).max(50);
            let stats = run_kernel(64, n_eff, 7, false);
            println!(
                "fleet n={n_eff:<6} {:>10.0} ev/s   {:>8.1} q/s   wall {:.2}s",
                stats.events_per_s, stats.queries_per_s, stats.wall_s,
            );
            stats.to_json(n_eff)
        })
        .collect();

    println!("-- observability overhead (64-worker pools) --");
    let n_obs = ((5000.0 * scale).round() as usize).max(50);
    let obs_off = run_kernel(64, n_obs, 13, false);
    let obs_on = run_kernel_cfg(
        64,
        n_obs,
        13,
        false,
        FleetConfig {
            record_trace: false,
            observe: Some(ObserveConfig::default()),
            ..Default::default()
        },
    );
    let obs_ratio = obs_off.events_per_s / obs_on.events_per_s.max(1e-9);
    println!(
        "observe n={n_obs:<6} off {:>10.0} ev/s   on {:>10.0} ev/s   off/on {:.2}x",
        obs_off.events_per_s, obs_on.events_per_s, obs_ratio,
    );
    let observe_overhead = vec![Json::obj(vec![
        ("queries", Json::Num(n_obs as f64)),
        ("off", obs_off.to_json(n_obs)),
        ("on", obs_on.to_json(n_obs)),
        ("off_vs_on_events_ratio", Json::Num(obs_ratio)),
    ])];

    println!("-- fault-layer overhead (64-worker pools) --");
    let n_fault = ((5000.0 * scale).round() as usize).max(50);
    let fault_off = run_kernel(64, n_fault, 17, false);
    let fault_on = run_kernel_cfg(
        64,
        n_fault,
        17,
        false,
        FleetConfig {
            record_trace: false,
            faults: Some(FaultConfig {
                edge_fail_p: 0.02,
                cloud_fail_p: 0.05,
                straggler_p: 0.02,
                straggler_mult: 4.0,
                seed: 7,
                outages: vec![],
            }),
            resilience: Some(ResilienceConfig::default()),
            ..Default::default()
        },
    );
    // Retries add events, so events/sec (not wall time) is the honest
    // per-event cost comparison against the faults-off baseline.
    let fault_ratio = fault_off.events_per_s / fault_on.events_per_s.max(1e-9);
    println!(
        "faults  n={n_fault:<6} off {:>10.0} ev/s   on {:>10.0} ev/s   off/on {:.2}x",
        fault_off.events_per_s, fault_on.events_per_s, fault_ratio,
    );
    let fault_overhead = vec![Json::obj(vec![
        ("queries", Json::Num(n_fault as f64)),
        ("off", fault_off.to_json(n_fault)),
        ("on", fault_on.to_json(n_fault)),
        ("off_vs_on_events_ratio", Json::Num(fault_ratio)),
    ])];

    println!("-- shard scaling (100k-query fleet, 64-worker pools per shard) --");
    let n_shard_cell = ((100_000.0 * scale).round() as usize).max(1_000);
    let mut shard_ev: Vec<(usize, f64)> = Vec::new();
    let mut shard_scaling: Vec<Json> = [1usize, 2, 4, 8]
        .iter()
        .map(|&shards| {
            let stats = run_sharded_kernel(64, n_shard_cell, 21, shards);
            println!(
                "shards={shards:<2} n={n_shard_cell:<7} {:>10.0} ev/s   {:>8.1} q/s   wall {:.2}s",
                stats.events_per_s, stats.queries_per_s, stats.wall_s,
            );
            shard_ev.push((shards, stats.events_per_s));
            let mut cell = vec![("shards", Json::Num(shards as f64))];
            cell.extend(stats.fields(n_shard_cell));
            Json::obj(cell)
        })
        .collect();
    let ev_at = |target: usize| {
        shard_ev.iter().find(|(s, _)| *s == target).map(|(_, e)| *e).unwrap_or(0.0)
    };
    let shard4_vs_1 = ev_at(4) / ev_at(1).max(1e-9);
    // The million-query cell: far past the single-heap comfort zone, on 8
    // shards. Scaled by BENCH_SCALE like every other cell so verify.sh's
    // smoke run stays fast.
    let n_million = ((1_000_000.0 * scale).round() as usize).max(5_000);
    let big = run_sharded_kernel(64, n_million, 23, 8);
    println!(
        "shards=8  n={n_million:<7} {:>10.0} ev/s   {:>8.1} q/s   wall {:.2}s  (million-query cell)",
        big.events_per_s, big.queries_per_s, big.wall_s,
    );
    let mut big_cell = vec![("shards", Json::Num(8.0)), ("million_query_cell", Json::Bool(true))];
    big_cell.extend(big.fields(n_million));
    shard_scaling.push(Json::obj(big_cell));

    // Flatness check: the indexed kernel's events/sec from the smallest
    // to the largest pool (a linear-in-W dispatch term would collapse the
    // tail of this ratio toward zero).
    let ev = |cell: &Json| {
        cell.path(&["indexed", "events_per_s"]).and_then(Json::as_f64).unwrap_or(0.0)
    };
    let flatness = ev(&worker_sweep[worker_sweep.len() - 1]) / ev(&worker_sweep[0]).max(1e-9);

    let doc = Json::obj(vec![
        ("bench", Json::Str("kernel".into())),
        ("scale", Json::Num(scale)),
        ("queries_per_worker_cell", Json::Num(n_worker_cell as f64)),
        ("pool_microbench", Json::Arr(micro)),
        ("worker_sweep", Json::Arr(worker_sweep)),
        ("fleet_sweep", Json::Arr(fleet_sweep)),
        ("observe_overhead", Json::Arr(observe_overhead)),
        ("fault_overhead", Json::Arr(fault_overhead)),
        ("shard_scaling", Json::Arr(shard_scaling)),
        ("shard_scaling_4_vs_1", Json::Num(shard4_vs_1)),
        ("indexed_flatness_1024_vs_4", Json::Num(flatness)),
    ]);
    let mut text = doc.to_string_pretty();
    text.push('\n');
    if let Err(e) = std::fs::write(&out_path, &text) {
        eprintln!("error: writing {out_path}: {e}");
        std::process::exit(1);
    }

    // Self-validation: the emitted artifact must re-parse with util::json
    // and carry every section (verify.sh relies on this check).
    let reread = match std::fs::read_to_string(&out_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: re-reading {out_path}: {e}");
            std::process::exit(1);
        }
    };
    let parsed = match Json::parse(&reread) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("error: {out_path} does not parse with util::json: {e}");
            std::process::exit(1);
        }
    };
    for key in [
        "pool_microbench",
        "worker_sweep",
        "fleet_sweep",
        "observe_overhead",
        "fault_overhead",
        "shard_scaling",
    ] {
        if parsed.get(key).and_then(Json::as_arr).map_or(true, <[Json]>::is_empty) {
            eprintln!("error: {out_path} is missing section '{key}'");
            std::process::exit(1);
        }
    }
    println!("{out_path} written and validated with util::json");
    if let Some(r) = ratio_512 {
        println!(
            "512-worker kernel throughput vs pre-PR linear-scan baseline: {r:.2}x \
             (indexed events/sec flatness 1024-vs-4 workers: {flatness:.2})"
        );
    }
    println!(
        "shard scaling: 4 shards vs 1 on the {n_shard_cell}-query fleet: {shard4_vs_1:.2}x \
         events/s; {n_million}-query fleet completed on 8 shards in {:.2}s",
        big.wall_s
    );
}

//! Extension bench: design-choice ablations (workers, concurrency, n_max)

fn main() {
    let ctx = hybridflow::eval::ExpContext::from_bench_env();
    match hybridflow::eval::run_experiment("ablations", &ctx) {
        Ok(out) => println!("{out}"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

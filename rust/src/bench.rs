//! Benchmark harness (criterion is not available offline).
//!
//! Two layers:
//! * [`time_fn`] / [`Bench`] — micro-benchmark timing with warmup, adaptive
//!   iteration counts, and percentile reporting.
//! * [`Table`] — paper-style table rendering shared by the per-table bench
//!   binaries (`cargo bench --bench table1` etc.), which print the same
//!   rows the paper reports.

use crate::util::stats::Summary;
// lint:allow(wall_clock): the bench harness exists to measure real time
use std::time::Instant;

/// Result of timing a closure.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    /// Per-iteration seconds.
    pub summary: Summary,
}

impl Timing {
    pub fn per_iter_ns(&self) -> f64 {
        self.summary.mean * 1e9
    }

    pub fn throughput_per_s(&self) -> f64 {
        1.0 / self.summary.mean
    }

    pub fn report(&self) -> String {
        let mean = human_time(self.summary.mean);
        let p50 = human_time(self.summary.p50);
        let p99 = human_time(self.summary.p99);
        format!(
            "{:<44} {:>10}/iter  p50 {:>10}  p99 {:>10}  ({} iters)",
            self.name, mean, p50, p99, self.iters
        )
    }
}

/// Human-friendly duration formatting.
pub fn human_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}\u{b5}s", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Time `f`, auto-scaling iterations to fill ~`budget_s` seconds after a
/// warmup. Returns per-iteration timing statistics over measured batches.
pub fn time_fn<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> Timing {
    // Warmup + calibration: run until 10% of budget or 3 iterations.
    // lint:allow(wall_clock): timing closures is the harness's purpose
    let cal_start = Instant::now();
    let mut cal_iters = 0usize;
    while cal_start.elapsed().as_secs_f64() < budget_s * 0.1 || cal_iters < 3 {
        f();
        cal_iters += 1;
        if cal_iters >= 1_000_000 {
            break;
        }
    }
    let per_iter = cal_start.elapsed().as_secs_f64() / cal_iters as f64;

    // Measurement: batches sized so each batch is >= ~1ms to keep timer
    // overhead negligible, for the remaining budget.
    let batch = ((1e-3 / per_iter).ceil() as usize).clamp(1, 1_000_000);
    let mut samples = Vec::new();
    let mut iters = 0usize;
    // lint:allow(wall_clock): timing closures is the harness's purpose
    let meas_start = Instant::now();
    while meas_start.elapsed().as_secs_f64() < budget_s * 0.9 {
        // lint:allow(wall_clock): per-batch sample timer
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / batch as f64;
        samples.push(dt);
        iters += batch;
        if samples.len() >= 10_000 {
            break;
        }
    }
    Timing { name: name.to_string(), iters, summary: Summary::of(&samples) }
}

/// Collector for a group of named timings.
pub struct Bench {
    pub group: String,
    pub budget_s: f64,
    pub timings: Vec<Timing>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        let budget = std::env::var("BENCH_BUDGET_S")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Bench { group: group.to_string(), budget_s: budget, timings: Vec::new() }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &Timing {
        let t = time_fn(name, self.budget_s, f);
        // lint:allow(print_in_lib): bench binaries report incrementally
        println!("  {}", t.report());
        self.timings.push(t);
        self.timings.last().unwrap()
    }

    pub fn header(&self) {
        // lint:allow(print_in_lib): bench binaries report incrementally
        println!("\n== bench group: {} (budget {:.1}s/case) ==", self.group, self.budget_s);
    }
}

// ---------------------------------------------------------------------------
// Paper-style tables.
// ---------------------------------------------------------------------------

/// Simple aligned-text table used by experiment benches to print rows that
/// mirror the paper's tables.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = format!("\n# {}\n", self.title);
        let head: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        out.push_str(&format!("| {} |\n", head.join(" | ")));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        // lint:allow(print_in_lib): bench binaries print their tables
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_measures_something() {
        let mut x = 0u64;
        let t = time_fn("noop-ish", 0.05, || {
            x = x.wrapping_add(std::hint::black_box(1));
        });
        assert!(t.iters > 100);
        assert!(t.summary.mean > 0.0);
        assert!(t.summary.mean < 1e-3);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(3.2e-9).ends_with("ns"));
        assert!(human_time(4.5e-5).ends_with("\u{b5}s"));
        assert!(human_time(2.5e-2).ends_with("ms"));
        assert!(human_time(3.0).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.row(vec!["HybridFlow".into(), "53.33".into()]);
        t.row(vec!["CoT".into(), "57.28".into()]);
        let s = t.render();
        assert!(s.contains("# Demo"));
        assert!(s.contains("| HybridFlow | 53.33 |"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

//! The HybridFlow end-to-end pipeline (Algorithm 1): decompose -> validate/
//! repair -> dependency-triggered budget-adaptive routing -> aggregate.
//!
//! This is the system the paper contributes; everything in `baselines/`
//! is a comparison pipeline over the same substrate.

use crate::dag::RepairOutcome;
use crate::engine::Backend;
use crate::metrics::QueryOutcome;
use crate::models::SimExecutor;
use crate::planner::synthetic::SyntheticPlanner;
use crate::planner::Planner;
use crate::router::predictor::UtilityPredictor;
use crate::router::{MirrorPredictor, RoutePolicy, RouterState};
use crate::scheduler::{execute_query_arc, QueryExecution, ScheduleConfig};
use crate::util::rng::Rng;
use crate::workload::{sample_latents, Query};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Pipeline configuration.
#[derive(Clone)]
pub struct PipelineConfig {
    pub policy: RoutePolicy,
    pub schedule: ScheduleConfig,
    /// Subtask cap (Def. C.2 rule 5).
    pub n_max: usize,
    /// Carry router state (dual shadow price, bandit head) across queries
    /// (streaming deployment mode; the paper's tables use per-query state).
    pub persist_router: bool,
}

impl PipelineConfig {
    pub fn paper_default(sp: &crate::config::simparams::SimParams) -> PipelineConfig {
        PipelineConfig {
            policy: RoutePolicy::hybridflow(sp),
            schedule: ScheduleConfig::default(),
            n_max: sp.nmax,
            persist_router: false,
        }
    }
}

/// The assembled HybridFlow system. Model endpoints are consumed through
/// the [`Backend`] seam, so the same pipeline drives the calibrated
/// simulator, a recorded-trace replay, or any future network backend.
pub struct HybridFlowPipeline {
    pub executor: Arc<dyn Backend>,
    pub planner: SyntheticPlanner,
    pub predictor: Arc<dyn UtilityPredictor>,
    pub config: PipelineConfig,
    /// Cross-query router state (used when `config.persist_router`).
    router_state: Mutex<Option<RouterState>>,
}

impl HybridFlowPipeline {
    /// Build with the trained-router mirror loaded from artifacts (pure
    /// rust; use [`Self::with_predictor`] + `runtime::RouterService` for
    /// the PJRT path).
    pub fn from_artifacts(artifacts_dir: &Path, config: PipelineConfig) -> anyhow::Result<Self> {
        let predictor =
            MirrorPredictor::from_meta_file(&artifacts_dir.join("router_meta.json"))?;
        Ok(HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(predictor),
            config,
        ))
    }

    /// Assemble from any backend (taken by value and boxed behind the
    /// trait; pass `SimExecutor::paper_pair()` for the paper substrate).
    pub fn with_predictor(
        executor: impl Backend + 'static,
        planner: SyntheticPlanner,
        predictor: Arc<dyn UtilityPredictor>,
        config: PipelineConfig,
    ) -> Self {
        HybridFlowPipeline {
            executor: Arc::new(executor),
            planner,
            predictor,
            config,
            router_state: Mutex::new(None),
        }
    }

    /// Run one query end-to-end. Returns the full execution trace.
    pub fn run_query_traced(&self, query: &Query, rng: &mut Rng) -> (QueryExecution, RepairOutcome) {
        let plan = self.planner.plan(query, self.config.n_max, rng);
        let latents = sample_latents(&plan.dag, query, self.executor.sp(), rng);
        let mut router = if self.config.persist_router {
            let mut guard = self.router_state.lock().expect("router state poisoned");
            guard.take().unwrap_or_else(|| RouterState::new(self.config.policy.clone()))
        } else {
            RouterState::new(self.config.policy.clone())
        };
        router.begin_query(self.config.persist_router);
        // Zero-copy hand-off: the freshly planned DAG and latents move
        // into the kernel job behind Arcs — no subtask text is cloned on
        // the per-query hot path (Query itself is plain-old-data).
        let exec = execute_query_arc(
            Arc::new(plan.dag),
            latents,
            Arc::new(query.clone()),
            self.executor.as_ref(),
            self.predictor.as_ref(),
            &mut router,
            plan.planning_latency,
            &self.config.schedule,
            rng,
        );
        if self.config.persist_router {
            *self.router_state.lock().expect("router state poisoned") = Some(router);
        }
        (exec, plan.outcome)
    }

    /// Run one query, reduced to the metric outcome.
    pub fn run_query(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        let (exec, _) = self.run_query_traced(query, rng);
        QueryOutcome {
            correct: exec.correct,
            latency: exec.latency,
            api_cost: exec.api_cost,
            offload_rate: exec.offload_rate,
            n_subtasks: exec.n_subtasks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simparams::SimParams;
    use crate::workload::{generate_queries, Benchmark};

    fn pipeline(policy: RoutePolicy) -> HybridFlowPipeline {
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = policy;
        HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        )
    }

    #[test]
    fn runs_end_to_end() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let mut rng = Rng::new(0);
        for q in generate_queries(Benchmark::Gpqa, 20, 0) {
            let out = p.run_query(&q, &mut rng);
            assert!(out.latency > 0.0);
            assert!(out.n_subtasks >= 1);
            assert!((0.0..=1.0).contains(&out.offload_rate));
        }
    }

    #[test]
    fn cloud_policy_costs_more_than_edge() {
        let mut rng_e = Rng::new(1);
        let mut rng_c = Rng::new(1);
        let pe = pipeline(RoutePolicy::AllEdge);
        let pc = pipeline(RoutePolicy::AllCloud);
        let qs = generate_queries(Benchmark::Gpqa, 30, 1);
        let cost_e: f64 = qs.iter().map(|q| pe.run_query(q, &mut rng_e).api_cost).sum();
        let cost_c: f64 = qs.iter().map(|q| pc.run_query(q, &mut rng_c).api_cost).sum();
        assert_eq!(cost_e, 0.0);
        assert!(cost_c > 0.0);
    }

    #[test]
    fn traced_run_exposes_plan_outcome_and_events() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let mut rng = Rng::new(2);
        let q = &generate_queries(Benchmark::Gpqa, 1, 2)[0];
        let (exec, outcome) = p.run_query_traced(q, &mut rng);
        assert_eq!(exec.events.len(), exec.n_subtasks);
        let _ = outcome; // any RepairOutcome is fine here
    }
}

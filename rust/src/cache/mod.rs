//! Cross-query subtask result cache.
//!
//! At fleet scale many queries decompose into overlapping subtasks, yet
//! every dispatch pays full edge/cloud cost — the Eq. 8 utility model
//! never sees a "free" option. This module adds that option: a
//! deterministic, caller-clock-driven [`SubtaskCache`] keyed by a
//! canonical [`Fingerprint`] (normalized node signature + executing
//! side), with pluggable eviction ([`CachePolicy`]: LRU / LFU / TTL under
//! a per-partition size cap), per-tenant partitions, and an optional
//! shared global tier for the whole fleet.
//!
//! Three integration layers consume it:
//!
//! 1. [`CachedBackend`] — an [`crate::engine::Backend`] wrapper over any
//!    inner backend; hits replay the stored [`ExecRecord`] with **zero
//!    RNG consumption** (cf. CE-CoLLM-style cloud context caching).
//! 2. Cache-aware routing — the scheduler probes the cache at each
//!    decision point (`ScheduleConfig::cache`); hits short-circuit to a
//!    near-zero-latency completion path in both event loops without
//!    occupying a worker or spending tenant/global budget
//!    (`RouteCtx::cached` is the router-visible hook).
//! 3. Workload diversity — `workload::trace::ZipfMix` repeats popular
//!    queries so fleet traces actually exercise the cache; the
//!    `fleet_cache` experiment sweeps capacity vs hit rate, cloud tokens,
//!    and latency.
//!
//! Determinism contract: the cache consumes **no RNG** anywhere — all
//! state transitions are functions of (key, stored record, caller clock)
//! — and iteration orders are total (`BTreeMap` keyed on the fingerprint,
//! sequence-number tie-breaks), so a fixed workload reproduces the same
//! hit/miss/eviction sequence byte-for-byte. A disabled cache
//! (`capacity == 0`, or none attached) leaves every execution path
//! untouched; the fleet golden-trace regression pins this.

pub mod backend;
pub mod policy;

pub use backend::CachedBackend;
pub use policy::{
    select_victim, CachePolicy, CachePolicyKind, EntryMeta, EvictionRank, LfuPolicy, LruPolicy,
    TtlPolicy,
};

use crate::dag::Role;
use crate::models::ExecRecord;
use crate::workload::{Query, SubtaskLatent};
use policy::ordered_bits;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::sync::Mutex;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Canonical 64-bit subtask fingerprint (FNV-1a over the normalized
/// signature). Two executions share a fingerprint iff they are
/// interchangeable under the cache's keying scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u64);

fn mix_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    h
}

fn mix_u64(h: u64, word: u64) -> u64 {
    mix_bytes(h, &word.to_le_bytes())
}

impl Fingerprint {
    /// Router-level node signature: query *content* (benchmark, domain,
    /// difficulty, prompt tokens, token multiplier — the query id is
    /// deliberately excluded so identical repeated queries normalize to
    /// one key), the node's topological index and role, and the executing
    /// side. Realized token counts and latent draws are excluded so
    /// repeats of the same query hit despite per-job sampling jitter.
    pub fn of_node(query: &Query, node: usize, role: Role, cloud: bool) -> Fingerprint {
        let mut h = FNV_OFFSET;
        h = mix_bytes(h, query.benchmark.name().as_bytes());
        h = mix_u64(h, query.domain as u64);
        h = mix_u64(h, query.difficulty.to_bits());
        h = mix_u64(h, query.query_tokens.to_bits());
        h = mix_u64(h, query.tok_mult.to_bits());
        h = mix_u64(h, node as u64);
        h = mix_u64(h, role.index() as u64);
        h = mix_bytes(h, &[u8::from(cloud)]);
        Fingerprint(h)
    }

    /// Backend-level call signature ([`CachedBackend`]): exact-match over
    /// the observable call arguments — domain, latent bits, input tokens,
    /// side, and whether the call was direct (whole-query) or a subtask.
    pub fn of_call(
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        direct: bool,
    ) -> Fingerprint {
        let mut h = FNV_OFFSET;
        h = mix_u64(h, domain as u64);
        h = mix_u64(h, latent.difficulty.to_bits());
        h = mix_u64(h, latent.criticality.to_bits());
        h = mix_u64(h, latent.out_tokens.to_bits());
        h = mix_u64(h, in_tokens.to_bits());
        h = mix_bytes(h, &[u8::from(cloud), u8::from(direct)]);
        Fingerprint(h)
    }
}

/// A cached execution outcome: the record plus the side that produced it
/// (stats and trace events report the original side; hits themselves run
/// on neither pool).
#[derive(Debug, Clone, Copy)]
pub struct CachedResult {
    pub cloud: bool,
    pub rec: ExecRecord,
}

/// Cumulative cache counters (one snapshot per run; see
/// [`SubtaskCache::stats`]). All rates guard the zero-lookup case so
/// empty-trace fleets report 0.0, never NaN.
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    /// Decision-point probes (one per probed subtask/call, regardless of
    /// how many side-keys the probe tried).
    pub lookups: u64,
    pub hits: u64,
    /// Subset of `hits` served from the shared global tier.
    pub shared_hits: u64,
    pub insertions: u64,
    pub evictions: u64,
    pub expirations: u64,
    /// Cloud tokens whose transmission a hit avoided — the transmitted
    /// payload `tok(x_i)` (input tokens), the same App. D.1 proxy as
    /// `metrics::exposure` and `fleet_cloud_tokens`, so saved and
    /// transmitted columns are directly comparable.
    pub tokens_saved: f64,
    /// Cloud dollars a hit avoided (budget that was never spent).
    pub dollars_saved: f64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn misses(&self) -> u64 {
        self.lookups.saturating_sub(self.hits)
    }

    /// Canonical one-line rendering of the counters, shared by
    /// `FleetReport::render`, `ServeReport::render`, and
    /// [`SubtaskCache::render_stats`] so the reports cannot drift apart.
    pub fn render_line(&self) -> String {
        format!(
            "cache: hit rate {:.1}% ({}/{} lookups, {} shared), {:.0} cloud tokens saved, \
             ${:.4} budget avoided, {} evicted, {} expired",
            self.hit_rate() * 100.0,
            self.hits,
            self.lookups,
            self.shared_hits,
            self.tokens_saved,
            self.dollars_saved,
            self.evictions,
            self.expirations,
        )
    }
}

struct Entry {
    result: CachedResult,
    /// Caller-clock instant the producing execution finishes. Within the
    /// same session epoch, probes before this instant miss: the fleet's
    /// virtual clock must never serve a result before it exists.
    ready_at: f64,
    /// Session epoch the entry was inserted in (see
    /// [`SubtaskCache::begin_session`]). Entries from earlier epochs are
    /// unconditionally available — their producing run already completed
    /// in wall order, even though the caller's clock restarted.
    epoch: u64,
    meta: EntryMeta,
}

#[derive(Default)]
struct Partition {
    /// Keyed on the raw fingerprint: BTreeMap gives O(log n) lookups and
    /// a deterministic iteration order.
    entries: BTreeMap<u64, Entry>,
    /// Eviction index: `(policy rank, fingerprint)`, kept in lockstep
    /// with `entries` — the minimum element is the next victim, so
    /// insert-at-capacity is O(log n) instead of the historical
    /// O(capacity) scan (ROADMAP "eviction index"; `benches/cache.rs`
    /// tracks the win). Ranks embed the per-entry `seq`, so keys are
    /// unique and victim selection is deterministic.
    evict_index: BTreeSet<(EvictionRank, u64)>,
    /// Expiry index: `(ordered insertion time, fingerprint)`, maintained
    /// only for policies with expiry. Because expiry is monotone in the
    /// insertion time, stale entries are exactly a prefix of this index.
    expiry_index: BTreeSet<(u64, u64)>,
    seq: u64,
    /// Monotone operation stamp feeding LRU/LFU recency (exact under any
    /// caller clock, including per-query restarting ones).
    op: u64,
}

impl Partition {
    /// Remove one entry and its index keys.
    fn remove(&mut self, fp: u64, policy: &dyn CachePolicy) -> Option<Entry> {
        let e = self.entries.remove(&fp)?;
        self.evict_index.remove(&(policy.rank(&e.meta), fp));
        if policy.has_expiry() {
            self.expiry_index.remove(&(ordered_bits(e.meta.inserted), fp));
        }
        Some(e)
    }

    /// Apply a metadata update to one entry, re-ranking it in the
    /// eviction index only when the policy's rank actually changed (a
    /// no-op for rank-insensitive updates, e.g. recency bumps under TTL,
    /// whose rank depends only on the immutable insertion time). Returns
    /// the entry's stored result so hit paths need no second map lookup.
    fn update_meta(
        &mut self,
        fp: u64,
        policy: &dyn CachePolicy,
        f: impl FnOnce(&mut EntryMeta),
    ) -> CachedResult {
        let e = self.entries.get_mut(&fp).expect("entry checked present");
        let old = policy.rank(&e.meta);
        f(&mut e.meta);
        let new = policy.rank(&e.meta);
        let result = e.result;
        if new != old {
            self.evict_index.remove(&(old, fp));
            self.evict_index.insert((new, fp));
        }
        result
    }

    /// Probe one key at session `epoch`; updates recency metadata on a
    /// hit, drops expired entries, and treats same-epoch entries whose
    /// producing execution has not finished yet (`now < ready_at`) as
    /// misses. Returns the hit and whether an expiration occurred.
    fn probe(
        &mut self,
        fp: Fingerprint,
        now: f64,
        epoch: u64,
        policy: &dyn CachePolicy,
    ) -> (Option<CachedResult>, bool) {
        let stale = match self.entries.get(&fp.0) {
            None => return (None, false),
            Some(e) => {
                if e.epoch == epoch && now + 1e-9 < e.ready_at {
                    // Result not available yet on this clock: miss, but
                    // the entry stays (it becomes valid at ready_at).
                    return (None, false);
                }
                policy.expired(&e.meta, now)
            }
        };
        if stale {
            self.remove(fp.0, policy);
            return (None, true);
        }
        self.op += 1;
        let op = self.op;
        let result = self.update_meta(fp.0, policy, |m| {
            m.hits += 1;
            m.last_used = op;
        });
        (Some(result), false)
    }

    /// Insert (or refresh) a key, evicting per policy when full. Returns
    /// `(evictions, expirations, inserted)`.
    #[allow(clippy::too_many_arguments)]
    fn insert(
        &mut self,
        fp: Fingerprint,
        result: CachedResult,
        now: f64,
        ready_at: f64,
        epoch: u64,
        capacity: usize,
        policy: &dyn CachePolicy,
    ) -> (u64, u64, bool) {
        if capacity == 0 {
            return (0, 0, false);
        }
        self.op += 1;
        let op = self.op;
        if self.entries.contains_key(&fp.0) {
            // Refresh: keep the first-stored result (hit bit-identity to
            // the first execution), bump recency.
            let _ = self.update_meta(fp.0, policy, |m| m.last_used = op);
            return (0, 0, false);
        }
        let mut expired = 0u64;
        let mut evicted = 0u64;
        if self.entries.len() >= capacity && policy.has_expiry() {
            // Purge stale entries first; they are free victims. Expiry is
            // monotone in insertion time, so the stale set is a prefix of
            // the expiry index — O(k log n) for k expired entries.
            // Skipped entirely for LRU/LFU, whose entries never expire.
            while let Some(&(_, victim)) = self.expiry_index.iter().next() {
                let meta = self.entries[&victim].meta;
                if !policy.expired(&meta, now) {
                    break;
                }
                self.remove(victim, policy);
                expired += 1;
            }
        }
        // O(log n) eviction: the index minimum is the policy's victim.
        while self.entries.len() >= capacity {
            let &(_, victim) = self
                .evict_index
                .iter()
                .next()
                .expect("non-empty partition must yield an eviction victim");
            self.remove(victim, policy);
            evicted += 1;
        }
        self.seq += 1;
        let meta = EntryMeta { inserted: now, last_used: op, hits: 0, seq: self.seq };
        self.evict_index.insert((policy.rank(&meta), fp.0));
        if policy.has_expiry() {
            self.expiry_index.insert((ordered_bits(now), fp.0));
        }
        self.entries.insert(fp.0, Entry { result, ready_at, epoch, meta });
        (evicted, expired, true)
    }
}

#[derive(Default)]
struct Inner {
    tenants: Vec<Partition>,
    shared: Partition,
    stats: CacheStats,
    /// Current session epoch (bumped by [`SubtaskCache::begin_session`]).
    epoch: u64,
}

impl Inner {
    fn tenant(&mut self, idx: usize) -> &mut Partition {
        if self.tenants.len() <= idx {
            self.tenants.resize_with(idx + 1, Partition::default);
        }
        &mut self.tenants[idx]
    }
}

fn credit_savings(stats: &mut CacheStats, r: &CachedResult) {
    if r.cloud {
        // Transmission proxy = input tokens (Eq. 30's tok(x_i)), matching
        // the exposure metric so saved vs transmitted columns reconcile.
        stats.tokens_saved += r.rec.in_tokens;
        stats.dollars_saved += r.rec.api_cost;
    }
}

/// Deterministic cross-query subtask result cache: per-tenant partitions
/// (auto-vivified by tenant index) plus an optional shared global tier,
/// each holding at most `capacity` entries under the configured eviction
/// policy. `capacity == 0` disables the cache entirely (every path is a
/// no-op), which is what the CLI's `--cache 0` maps to.
///
/// All methods take `&self` (internal mutex) so one `Arc<SubtaskCache>`
/// can be shared through `ScheduleConfig`; the virtual-clock event loops
/// are single-threaded, so fleet runs stay byte-reproducible.
pub struct SubtaskCache {
    capacity: usize,
    kind: CachePolicyKind,
    policy: Box<dyn CachePolicy>,
    shared_tier: bool,
    hit_latency: f64,
    inner: Mutex<Inner>,
}

impl SubtaskCache {
    /// Virtual seconds a cache hit takes on the sim clock (coordinator
    /// table lookup — near-zero, but strictly positive so event ordering
    /// and `finish > start` invariants hold).
    pub const DEFAULT_HIT_LATENCY: f64 = 1e-3;

    pub fn new(capacity: usize, kind: CachePolicyKind) -> SubtaskCache {
        SubtaskCache {
            capacity,
            kind,
            policy: kind.build(),
            shared_tier: false,
            hit_latency: Self::DEFAULT_HIT_LATENCY,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Enable the fleet-wide shared tier: inserts replicate into a global
    /// partition that lookups fall back to when the tenant partition
    /// misses (tenant isolation is the default; this opts out of it).
    pub fn with_shared_tier(mut self) -> SubtaskCache {
        self.shared_tier = true;
        self
    }

    /// Override the virtual-clock latency of a hit. Floored at a strictly
    /// positive value: `finish > start` must hold for cached events, and
    /// zero-duration completions would interleave with same-instant
    /// control events in heap orders the engine never exercises.
    pub fn with_hit_latency(mut self, latency: f64) -> SubtaskCache {
        self.hit_latency = latency.max(1e-9);
        self
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hit_latency(&self) -> f64 {
        self.hit_latency
    }

    pub fn has_shared_tier(&self) -> bool {
        self.shared_tier
    }

    pub fn policy_label(&self) -> String {
        self.kind.label()
    }

    /// Drop every entry and zero the counters (each fleet run starts
    /// cold; see `scheduler::fleet::run_fleet`).
    pub fn reset(&self) {
        *self.inner.lock().expect("cache poisoned") = Inner::default();
    }

    /// Start a new session epoch. Callers whose clock *restarts* (the
    /// single-query scheduler: every `execute_query` begins its virtual
    /// clock near zero) bump the epoch per run so earlier runs' entries
    /// are unconditionally available, while same-epoch entries stay gated
    /// on their `ready_at` instant. The fleet runs one global clock and
    /// never bumps mid-run.
    pub fn begin_session(&self) {
        self.inner.lock().expect("cache poisoned").epoch += 1;
    }

    /// Probe one key in one tenant partition (falling back to the shared
    /// tier). Counts one lookup.
    pub fn lookup(&self, tenant: usize, fp: Fingerprint, now: f64) -> Option<CachedResult> {
        self.lookup_any(tenant, &[fp], now)
    }

    /// Probe several alternative keys (e.g. the edge- and cloud-side
    /// fingerprints of one subtask) as **one** decision-point lookup:
    /// exactly one lookup is counted however many keys are tried, and the
    /// first hit wins. Order: all keys against the tenant partition, then
    /// all keys against the shared tier.
    pub fn lookup_any(
        &self,
        tenant: usize,
        fps: &[Fingerprint],
        now: f64,
    ) -> Option<CachedResult> {
        if !self.enabled() {
            return None;
        }
        let mut g = self.inner.lock().expect("cache poisoned");
        let epoch = g.epoch;
        g.stats.lookups += 1;
        for &fp in fps {
            let (hit, expired) = g.tenant(tenant).probe(fp, now, epoch, self.policy.as_ref());
            if expired {
                g.stats.expirations += 1;
            }
            if let Some(r) = hit {
                g.stats.hits += 1;
                credit_savings(&mut g.stats, &r);
                return Some(r);
            }
        }
        if self.shared_tier {
            for &fp in fps {
                let (hit, expired) = g.shared.probe(fp, now, epoch, self.policy.as_ref());
                if expired {
                    g.stats.expirations += 1;
                }
                if let Some(r) = hit {
                    g.stats.hits += 1;
                    g.stats.shared_hits += 1;
                    credit_savings(&mut g.stats, &r);
                    return Some(r);
                }
            }
        }
        None
    }

    /// Store one result under `fp` in the tenant partition (and the
    /// shared tier when enabled). `now` is the insert instant (recency /
    /// TTL origin); `ready_at` is when the producing execution *finishes*
    /// on the caller's clock — same-epoch probes before that instant miss
    /// (a result must not be served before it exists). Existing entries
    /// are never overwritten — a hit stays bit-identical to the *first*
    /// execution.
    pub fn insert(
        &self,
        tenant: usize,
        fp: Fingerprint,
        result: CachedResult,
        now: f64,
        ready_at: f64,
    ) {
        if !self.enabled() {
            return;
        }
        let mut g = self.inner.lock().expect("cache poisoned");
        let epoch = g.epoch;
        let cap = self.capacity;
        let (ev, ex, ins) =
            g.tenant(tenant).insert(fp, result, now, ready_at, epoch, cap, self.policy.as_ref());
        g.stats.evictions += ev;
        g.stats.expirations += ex;
        g.stats.insertions += u64::from(ins);
        if self.shared_tier {
            let (ev, ex, _) =
                g.shared.insert(fp, result, now, ready_at, epoch, cap, self.policy.as_ref());
            g.stats.evictions += ev;
            g.stats.expirations += ex;
        }
    }

    /// Entries currently held by one tenant partition.
    pub fn len(&self, tenant: usize) -> usize {
        let g = self.inner.lock().expect("cache poisoned");
        g.tenants.get(tenant).map_or(0, |p| p.entries.len())
    }

    pub fn is_empty(&self) -> bool {
        self.total_entries() == 0
    }

    /// Entries in the shared global tier.
    pub fn shared_len(&self) -> usize {
        self.inner.lock().expect("cache poisoned").shared.entries.len()
    }

    /// Entries across every partition (tenants + shared tier).
    pub fn total_entries(&self) -> usize {
        let g = self.inner.lock().expect("cache poisoned");
        g.tenants.iter().map(|p| p.entries.len()).sum::<usize>() + g.shared.entries.len()
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().expect("cache poisoned").stats.clone()
    }

    /// One-line render of the counters with this cache's configuration
    /// prefix (CLI); the counter half is [`CacheStats::render_line`].
    pub fn render_stats(&self) -> String {
        format!(
            "[{} cap {}{}] {}",
            self.policy_label(),
            self.capacity,
            if self.shared_tier { ", shared tier" } else { "" },
            self.stats().render_line(),
        )
    }
}

// Manual Debug: the boxed policy is not derivable, and `ScheduleConfig`
// (which embeds an `Option<Arc<SubtaskCache>>`) derives Debug.
impl fmt::Debug for SubtaskCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SubtaskCache")
            .field("capacity", &self.capacity)
            .field("policy", &self.policy_label())
            .field("shared_tier", &self.shared_tier)
            .field("entries", &self.total_entries())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_queries, Benchmark};

    fn rec(latency: f64, cost: f64, out: f64) -> ExecRecord {
        ExecRecord { correct: true, latency, api_cost: cost, in_tokens: 40.0, out_tokens: out }
    }

    fn cloud_result(cost: f64) -> CachedResult {
        CachedResult { cloud: true, rec: rec(2.0, cost, 90.0) }
    }

    /// Insert immediately available at `t` (ready_at == insert instant).
    fn put(c: &SubtaskCache, tenant: usize, fp: Fingerprint, r: CachedResult, t: f64) {
        c.insert(tenant, fp, r, t, t);
    }

    #[test]
    fn node_fingerprint_normalizes_query_id_and_splits_sides() {
        let qs = generate_queries(Benchmark::Gpqa, 2, 5);
        let mut twin = qs[0].clone();
        twin.id = 999; // same content, different id
        let a = Fingerprint::of_node(&qs[0], 2, Role::Analyze, false);
        assert_eq!(a, Fingerprint::of_node(&twin, 2, Role::Analyze, false));
        assert_ne!(a, Fingerprint::of_node(&qs[0], 2, Role::Analyze, true), "side splits");
        assert_ne!(a, Fingerprint::of_node(&qs[0], 3, Role::Analyze, false), "index splits");
        assert_ne!(a, Fingerprint::of_node(&qs[0], 2, Role::Generate, false), "role splits");
        assert_ne!(a, Fingerprint::of_node(&qs[1], 2, Role::Analyze, false), "content splits");
    }

    #[test]
    fn call_fingerprint_is_exact_match() {
        let l = SubtaskLatent { difficulty: 0.5, criticality: 0.4, out_tokens: 80.0 };
        let a = Fingerprint::of_call(1, &l, 120.0, true, false);
        assert_eq!(a, Fingerprint::of_call(1, &l, 120.0, true, false));
        assert_ne!(a, Fingerprint::of_call(1, &l, 120.0, false, false));
        assert_ne!(a, Fingerprint::of_call(1, &l, 120.0, true, true));
        assert_ne!(a, Fingerprint::of_call(2, &l, 120.0, true, false));
        let l2 = SubtaskLatent { difficulty: 0.5000001, ..l };
        assert_ne!(a, Fingerprint::of_call(1, &l2, 120.0, true, false));
    }

    #[test]
    fn lookup_hit_is_bit_identical_to_first_insert() {
        let c = SubtaskCache::new(8, CachePolicyKind::Lru);
        let fp = Fingerprint(42);
        let first = CachedResult {
            cloud: true,
            rec: ExecRecord {
                correct: false,
                latency: 1.234567891234,
                api_cost: 0.00123456789,
                in_tokens: 333.3,
                out_tokens: 777.7,
            },
        };
        put(&c, 0, fp, first, 1.0);
        // A second insert under the same key must NOT overwrite.
        put(&c, 0, fp, cloud_result(9.9), 2.0);
        let got = c.lookup(0, fp, 3.0).expect("hit");
        assert_eq!(got.rec.latency.to_bits(), first.rec.latency.to_bits());
        assert_eq!(got.rec.api_cost.to_bits(), first.rec.api_cost.to_bits());
        assert_eq!(got.rec.in_tokens.to_bits(), first.rec.in_tokens.to_bits());
        assert_eq!(got.rec.out_tokens.to_bits(), first.rec.out_tokens.to_bits());
        assert_eq!(got.rec.correct, first.rec.correct);
        assert_eq!(got.cloud, first.cloud);
    }

    #[test]
    fn capacity_enforced_with_lru_eviction() {
        let c = SubtaskCache::new(2, CachePolicyKind::Lru);
        put(&c, 0, Fingerprint(1), cloud_result(0.1), 1.0);
        put(&c, 0, Fingerprint(2), cloud_result(0.2), 2.0);
        // Touch key 1 so key 2 becomes the LRU victim.
        assert!(c.lookup(0, Fingerprint(1), 3.0).is_some());
        put(&c, 0, Fingerprint(3), cloud_result(0.3), 4.0);
        assert_eq!(c.len(0), 2);
        assert!(c.lookup(0, Fingerprint(1), 5.0).is_some());
        assert!(c.lookup(0, Fingerprint(2), 5.0).is_none(), "LRU victim evicted");
        assert!(c.lookup(0, Fingerprint(3), 5.0).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn lfu_keeps_hot_entries() {
        let c = SubtaskCache::new(2, CachePolicyKind::Lfu);
        put(&c, 0, Fingerprint(1), cloud_result(0.1), 1.0);
        put(&c, 0, Fingerprint(2), cloud_result(0.2), 2.0);
        for t in 0..3 {
            assert!(c.lookup(0, Fingerprint(1), 3.0 + t as f64).is_some());
        }
        put(&c, 0, Fingerprint(3), cloud_result(0.3), 10.0);
        assert!(c.lookup(0, Fingerprint(1), 11.0).is_some(), "hot entry survives");
        assert!(c.lookup(0, Fingerprint(2), 11.0).is_none(), "cold entry evicted");
    }

    #[test]
    fn ttl_expires_on_lookup() {
        let c = SubtaskCache::new(8, CachePolicyKind::Ttl(5.0));
        put(&c, 0, Fingerprint(1), cloud_result(0.1), 0.0);
        assert!(c.lookup(0, Fingerprint(1), 4.9).is_some());
        assert!(c.lookup(0, Fingerprint(1), 5.1).is_none(), "expired");
        assert_eq!(c.stats().expirations, 1);
        assert_eq!(c.len(0), 0);
    }

    #[test]
    fn same_session_entries_unavailable_before_ready_at() {
        // Temporal fidelity on one virtual clock (the fleet): an entry
        // inserted at dispatch time must not be servable before the
        // producing execution's finish instant.
        let c = SubtaskCache::new(8, CachePolicyKind::Lru);
        c.insert(0, Fingerprint(1), cloud_result(0.1), 0.0, 20.0);
        assert!(c.lookup(0, Fingerprint(1), 5.0).is_none(), "result does not exist yet");
        assert!(c.lookup(0, Fingerprint(1), 19.9).is_none());
        assert!(c.lookup(0, Fingerprint(1), 20.0).is_some(), "available from finish");
        let s = c.stats();
        assert_eq!(s.lookups, 3);
        assert_eq!(s.hits, 1, "pre-finish probes are misses");
        // The not-yet-ready probes did not drop the entry.
        assert_eq!(c.len(0), 1);
    }

    #[test]
    fn new_session_makes_prior_entries_available_despite_clock_restart() {
        // The single-query scheduler restarts its virtual clock per query;
        // begin_session marks earlier entries as completed-in-wall-order,
        // so a probe at t=2.0 may hit an entry that finished at t=25.0 of
        // the *previous* query's clock.
        let c = SubtaskCache::new(8, CachePolicyKind::Lru);
        c.insert(0, Fingerprint(1), cloud_result(0.1), 10.0, 25.0);
        assert!(c.lookup(0, Fingerprint(1), 2.0).is_none(), "same session, pre-finish");
        c.begin_session();
        assert!(
            c.lookup(0, Fingerprint(1), 2.0).is_some(),
            "prior-session entry is unconditionally available"
        );
    }

    #[test]
    fn tenant_partitions_isolate_unless_shared() {
        let isolated = SubtaskCache::new(8, CachePolicyKind::Lru);
        put(&isolated, 0, Fingerprint(7), cloud_result(0.5), 1.0);
        assert!(isolated.lookup(0, Fingerprint(7), 2.0).is_some());
        assert!(isolated.lookup(1, Fingerprint(7), 2.0).is_none(), "tenant isolation");
        assert_eq!(isolated.shared_len(), 0);

        let shared = SubtaskCache::new(8, CachePolicyKind::Lru).with_shared_tier();
        put(&shared, 0, Fingerprint(7), cloud_result(0.5), 1.0);
        let hit = shared.lookup(1, Fingerprint(7), 2.0);
        assert!(hit.is_some(), "shared tier crosses tenants");
        assert_eq!(shared.stats().shared_hits, 1);
        assert_eq!(shared.shared_len(), 1);
    }

    #[test]
    fn lookup_any_counts_one_lookup_for_multi_key_probes() {
        let c = SubtaskCache::new(8, CachePolicyKind::Lru);
        put(&c, 0, Fingerprint(2), cloud_result(0.2), 1.0);
        // Miss on key 1, hit on key 2: one lookup, one hit.
        let hit = c.lookup_any(0, &[Fingerprint(1), Fingerprint(2)], 2.0);
        assert!(hit.is_some());
        let miss = c.lookup_any(0, &[Fingerprint(8), Fingerprint(9)], 3.0);
        assert!(miss.is_none());
        let s = c.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses(), 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn savings_credit_cloud_results_only() {
        let c = SubtaskCache::new(8, CachePolicyKind::Lru);
        put(&c, 0, Fingerprint(1), CachedResult { cloud: false, rec: rec(1.0, 0.0, 50.0) }, 0.0);
        put(&c, 0, Fingerprint(2), cloud_result(0.25), 0.0);
        c.lookup(0, Fingerprint(1), 1.0);
        let s = c.stats();
        assert_eq!(s.tokens_saved, 0.0, "edge hits save no cloud tokens");
        assert_eq!(s.dollars_saved, 0.0);
        c.lookup(0, Fingerprint(2), 2.0);
        let s = c.stats();
        // Transmission proxy: input tokens only (same rule as exposure).
        assert!((s.tokens_saved - 40.0).abs() < 1e-12);
        assert!((s.dollars_saved - 0.25).abs() < 1e-12);
    }

    #[test]
    fn zero_capacity_cache_is_fully_inert() {
        let c = SubtaskCache::new(0, CachePolicyKind::Lru);
        assert!(!c.enabled());
        put(&c, 0, Fingerprint(1), cloud_result(0.1), 0.0);
        assert!(c.lookup(0, Fingerprint(1), 1.0).is_none());
        let s = c.stats();
        assert_eq!(s.lookups, 0, "disabled cache counts nothing");
        assert_eq!(s.insertions, 0);
        assert_eq!(c.total_entries(), 0);
    }

    #[test]
    fn reset_clears_entries_and_counters() {
        let c = SubtaskCache::new(8, CachePolicyKind::Lru).with_shared_tier();
        put(&c, 0, Fingerprint(1), cloud_result(0.1), 0.0);
        c.lookup(0, Fingerprint(1), 1.0);
        assert!(c.total_entries() > 0);
        c.reset();
        assert_eq!(c.total_entries(), 0);
        let s = c.stats();
        assert_eq!(s.lookups, 0);
        assert_eq!(s.hits, 0);
        assert_eq!(s.insertions, 0);
    }

    #[test]
    fn eviction_index_matches_linear_scan_reference() {
        // The O(log n) index must pick exactly the victims the historical
        // O(capacity) scan (select_victim) would: replay a scripted churn
        // against a naive reference model and compare surviving key sets.
        for kind in [CachePolicyKind::Lru, CachePolicyKind::Lfu, CachePolicyKind::Ttl(40.0)] {
            let policy = kind.build();
            let capacity = 8usize;
            let cache = SubtaskCache::new(capacity, kind);
            let mut reference: std::collections::BTreeMap<u64, EntryMeta> =
                Default::default();
            let (mut seq, mut op) = (0u64, 0u64);
            let mut clock = 0.0f64;
            for i in 0..200u64 {
                clock += 1.0;
                let key = (i * 7) % 23; // colliding keys force hits + refreshes
                if i % 3 == 0 {
                    // Lookup path (recency bump on the reference model too).
                    let hit = cache.lookup(0, Fingerprint(key), clock).is_some();
                    let mut expired = false;
                    if let Some(m) = reference.get_mut(&key) {
                        if policy.expired(m, clock) {
                            expired = true;
                        } else {
                            op += 1;
                            m.hits += 1;
                            m.last_used = op;
                        }
                    }
                    if expired {
                        reference.remove(&key);
                    }
                    assert_eq!(hit, reference.contains_key(&key) && !expired, "op {i}");
                } else {
                    put(&cache, 0, Fingerprint(key), cloud_result(0.01), clock);
                    op += 1;
                    if let Some(m) = reference.get_mut(&key) {
                        m.last_used = op;
                    } else {
                        if reference.len() >= capacity && policy.has_expiry() {
                            reference.retain(|_, m| !policy.expired(m, clock));
                        }
                        while reference.len() >= capacity {
                            let victim = select_victim(
                                policy.as_ref(),
                                &mut reference.iter().map(|(&k, &m)| (k, m)),
                            )
                            .unwrap();
                            reference.remove(&victim);
                        }
                        seq += 1;
                        reference.insert(
                            key,
                            EntryMeta { inserted: clock, last_used: op, hits: 0, seq },
                        );
                    }
                }
            }
            // Surviving key sets agree exactly. (Both models keep stale
            // TTL entries until a probe or purge touches them, so the raw
            // entry counts must match; the final probes then hit iff the
            // entry is still unexpired at the probe instant.)
            assert_eq!(cache.len(0), reference.len(), "{}", kind.label());
            for (&k, m) in &reference {
                let hit = cache.lookup(0, Fingerprint(k), clock).is_some();
                assert_eq!(
                    hit,
                    !policy.expired(m, clock),
                    "{}: key {k} survivor state diverged",
                    kind.label()
                );
            }
        }
    }

    #[test]
    fn empty_stats_report_zero_not_nan() {
        let s = CacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        assert_eq!(s.misses(), 0);
        let c = SubtaskCache::new(4, CachePolicyKind::Lru);
        assert!(c.render_stats().contains("hit rate 0.0%"));
        assert!(!c.render_stats().contains("NaN"));
    }

    #[test]
    fn hit_latency_floored_strictly_positive() {
        let c = SubtaskCache::new(4, CachePolicyKind::Lru).with_hit_latency(0.0);
        assert!(c.hit_latency() > 0.0, "finish > start must hold for cached events");
        let c = SubtaskCache::new(4, CachePolicyKind::Lru).with_hit_latency(-1.0);
        assert!(c.hit_latency() > 0.0);
    }

    #[test]
    fn render_and_debug_are_informative() {
        let c = SubtaskCache::new(4, CachePolicyKind::Ttl(60.0)).with_shared_tier();
        let dbg = format!("{c:?}");
        assert!(dbg.contains("SubtaskCache"));
        assert!(dbg.contains("ttl"));
        assert!(c.render_stats().contains("shared tier"));
    }
}

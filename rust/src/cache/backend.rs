//! [`CachedBackend`]: a result-caching [`Backend`] wrapper.
//!
//! Wraps any inner backend (simulation, replay, recording, a future
//! network endpoint) and memoizes execution results under the exact-match
//! call fingerprint ([`Fingerprint::of_call`]). A hit replays the stored
//! [`ExecRecord`] with **zero RNG consumption** — the caller's stream is
//! untouched, exactly like [`crate::engine::ReplayBackend`] — so a
//! cache-heavy workload spends neither simulated model time nor random
//! draws on repeated calls.
//!
//! The backend has no view of the virtual clock (the [`Backend`] surface
//! carries none), so recency/TTL run on a logical per-call tick: one unit
//! per `execute_*` invocation. A TTL policy therefore expresses "expire
//! after N calls" at this layer, vs "expire after N virtual seconds" in
//! the scheduler integration.

use super::{CachePolicyKind, CacheStats, CachedResult, Fingerprint, SubtaskCache};
use crate::config::simparams::SimParams;
use crate::engine::Backend;
use crate::models::{ExecRecord, ModelProfile};
use crate::util::rng::Rng;
use crate::workload::SubtaskLatent;
use std::sync::atomic::{AtomicU64, Ordering};

/// A [`Backend`] that serves repeated calls from a [`SubtaskCache`].
pub struct CachedBackend<B: Backend> {
    inner: B,
    cache: SubtaskCache,
    /// Logical clock: one tick per execute call (recency/TTL unit).
    tick: AtomicU64,
}

impl<B: Backend> CachedBackend<B> {
    pub fn new(inner: B, capacity: usize, kind: CachePolicyKind) -> CachedBackend<B> {
        CachedBackend { inner, cache: SubtaskCache::new(capacity, kind), tick: AtomicU64::new(0) }
    }

    pub fn stats(&self) -> CacheStats {
        self.cache.stats()
    }

    pub fn cache(&self) -> &SubtaskCache {
        &self.cache
    }

    pub fn into_inner(self) -> B {
        self.inner
    }

    fn next_tick(&self) -> f64 {
        self.tick.fetch_add(1, Ordering::Relaxed) as f64
    }

    fn cached_exec(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        direct: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        let fp = Fingerprint::of_call(domain, latent, in_tokens, cloud, direct);
        let now = self.next_tick();
        if let Some(hit) = self.cache.lookup(0, fp, now) {
            // Zero RNG consumption: the stored record IS the outcome.
            return hit.rec;
        }
        let rec = if direct {
            self.inner.execute_direct(domain, latent, in_tokens, cloud, rng)
        } else {
            self.inner.execute_subtask(domain, latent, in_tokens, cloud, rng)
        };
        // A backend call blocks until completion, so the result is
        // available from its own tick onward (ready_at == now).
        self.cache.insert(0, fp, CachedResult { cloud, rec }, now, now);
        rec
    }
}

impl<B: Backend> Backend for CachedBackend<B> {
    fn name(&self) -> &'static str {
        "cached"
    }

    fn sp(&self) -> &SimParams {
        self.inner.sp()
    }

    fn profile(&self, cloud: bool) -> &ModelProfile {
        self.inner.profile(cloud)
    }

    fn execute_subtask(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        self.cached_exec(domain, latent, in_tokens, cloud, false, rng)
    }

    fn execute_direct(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        self.cached_exec(domain, latent, in_tokens, cloud, true, rng)
    }

    fn final_answer_correct(
        &self,
        latents: &[SubtaskLatent],
        subtask_correct: &[bool],
        rng: &mut Rng,
    ) -> bool {
        // Never cached: the aggregation draw is query-level randomness.
        self.inner.final_answer_correct(latents, subtask_correct, rng)
    }

    fn true_dq(&self, domain: usize, latents: &[SubtaskLatent], i: usize) -> f64 {
        self.inner.true_dq(domain, latents, i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SimExecutor;

    fn latent(d: f64, w: f64, toks: f64) -> SubtaskLatent {
        SubtaskLatent { difficulty: d, criticality: w, out_tokens: toks }
    }

    #[test]
    fn repeated_call_hits_and_replays_bit_identically() {
        let b = CachedBackend::new(SimExecutor::paper_pair(), 64, CachePolicyKind::Lru);
        let l = latent(0.5, 0.5, 100.0);
        let mut rng = Rng::new(7);
        let first = b.execute_subtask(1, &l, 200.0, true, &mut rng);
        let again = b.execute_subtask(1, &l, 200.0, true, &mut rng);
        assert_eq!(first.latency.to_bits(), again.latency.to_bits());
        assert_eq!(first.api_cost.to_bits(), again.api_cost.to_bits());
        assert_eq!(first.out_tokens.to_bits(), again.out_tokens.to_bits());
        assert_eq!(first.correct, again.correct);
        let s = b.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.insertions, 1);
    }

    #[test]
    fn hit_consumes_zero_rng() {
        let b = CachedBackend::new(SimExecutor::paper_pair(), 64, CachePolicyKind::Lru);
        let l = latent(0.4, 0.6, 80.0);
        let mut warm = Rng::new(3);
        b.execute_subtask(2, &l, 150.0, true, &mut warm);
        // Two clones of one stream: one serves a hit, the other is idle.
        let mut rng_a = Rng::new(99);
        let mut rng_b = Rng::new(99);
        let _ = b.execute_subtask(2, &l, 150.0, true, &mut rng_a);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "hit must not touch the stream");
    }

    #[test]
    fn sides_and_direct_calls_are_keyed_apart() {
        let b = CachedBackend::new(SimExecutor::paper_pair(), 64, CachePolicyKind::Lru);
        let l = latent(0.5, 0.5, 100.0);
        let mut rng = Rng::new(11);
        b.execute_subtask(1, &l, 200.0, false, &mut rng);
        b.execute_subtask(1, &l, 200.0, true, &mut rng);
        b.execute_direct(1, &l, 200.0, true, &mut rng);
        let s = b.stats();
        assert_eq!(s.hits, 0, "edge/cloud/direct are distinct keys");
        assert_eq!(s.insertions, 3);
    }

    #[test]
    fn delegates_profiles_and_dq() {
        let inner = SimExecutor::paper_pair();
        let sp_tau0 = inner.sp.tau0;
        let b = CachedBackend::new(inner, 8, CachePolicyKind::Lfu);
        assert_eq!(b.name(), "cached");
        assert_eq!(b.sp().tau0, sp_tau0);
        let lat = vec![latent(0.4, 0.4, 80.0), latent(0.6, 0.6, 120.0)];
        let via: &dyn Backend = &b;
        let dq = via.true_dq(1, &lat, 0);
        assert!(dq > 0.0 && dq < 1.0);
        assert!(via.profile(true).kind.is_cloud());
    }

    #[test]
    fn final_answer_always_delegates_with_rng() {
        let b = CachedBackend::new(SimExecutor::paper_pair(), 8, CachePolicyKind::Lru);
        let lat = vec![latent(0.5, 0.7, 100.0)];
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = b.final_answer_correct(&lat, &[false], &mut r1);
        let c = SimExecutor::paper_pair().final_answer_correct(&lat, &[false], &mut r2);
        assert_eq!(a, c);
    }
}

//! Pluggable eviction policies for [`super::SubtaskCache`].
//!
//! A policy never owns entry state: every cached entry carries an
//! [`EntryMeta`] (insert time, last-use time, hit count, insertion
//! sequence number) maintained by the cache itself, and the policy is a
//! *stateless selector* over that metadata — it decides which entries have
//! expired and which entry to evict when a partition is full. Keeping the
//! policy stateless makes one boxed policy safely shareable across every
//! tenant partition and the shared tier, and keeps victim selection
//! deterministic: candidates are iterated in fingerprint order and every
//! comparison falls back to the insertion sequence number as the final
//! tie-break.
//!
//! All times are the caller's clock — the virtual sim clock in the
//! scheduler integration, a logical call counter in
//! [`super::CachedBackend`] — so TTLs are expressed in whichever unit the
//! caller advances.

/// Bookkeeping the cache maintains per entry; the raw material policies
/// select on.
#[derive(Debug, Clone, Copy)]
pub struct EntryMeta {
    /// Caller-clock value when the entry was first inserted (the TTL
    /// input; in the fleet this is virtual seconds).
    pub inserted: f64,
    /// Monotone per-partition *operation* stamp of the most recent hit or
    /// insert — the LRU/LFU recency input. An operation counter (rather
    /// than the caller clock) keeps recency exact even when the caller's
    /// clock restarts, as the single-query CLI loop's per-query virtual
    /// clock does.
    pub last_used: u64,
    /// Lookup hits served by this entry.
    pub hits: u64,
    /// Monotone insertion sequence within the partition (final tie-break).
    pub seq: u64,
}

/// An eviction policy: expiry predicate + victim selector.
pub trait CachePolicy: Send + Sync {
    /// Short label ("lru", "lfu", ...).
    fn name(&self) -> &'static str;

    /// Whether an entry is stale at clock `now` (TTL policies). Expired
    /// entries are dropped on lookup (counted as misses) and purged before
    /// any eviction. Default: entries never expire.
    fn expired(&self, _meta: &EntryMeta, _now: f64) -> bool {
        false
    }

    /// Whether `expired` can ever return true. Policies without expiry
    /// (LRU/LFU) return false so the cache skips the full-partition stale
    /// purge on the insert-at-capacity path. Default: no expiry.
    fn has_expiry(&self) -> bool {
        false
    }

    /// Pick the eviction victim among `(fingerprint, meta)` candidates.
    /// Candidates arrive in ascending fingerprint order; implementations
    /// must be deterministic (tie-break on `meta.seq`). Returns `None`
    /// only for an empty candidate set.
    fn victim(&self, candidates: &mut dyn Iterator<Item = (u64, EntryMeta)>) -> Option<u64>;
}

/// Evict the least-recently-used entry.
pub struct LruPolicy;

impl CachePolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn victim(&self, candidates: &mut dyn Iterator<Item = (u64, EntryMeta)>) -> Option<u64> {
        candidates
            .min_by_key(|&(_, m)| (m.last_used, m.seq))
            .map(|(k, _)| k)
    }
}

/// Evict the least-frequently-used entry (ties: least recent, then oldest
/// insertion).
pub struct LfuPolicy;

impl CachePolicy for LfuPolicy {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn victim(&self, candidates: &mut dyn Iterator<Item = (u64, EntryMeta)>) -> Option<u64> {
        candidates
            .min_by_key(|&(_, m)| (m.hits, m.last_used, m.seq))
            .map(|(k, _)| k)
    }
}

/// Entries expire `ttl` clock units after insertion; eviction (when the
/// partition is full of fresh entries) drops the oldest insertion.
///
/// TTL ages on the *caller's* clock domain: one global virtual clock in
/// the fleet (ages are real virtual seconds), a logical call tick in
/// `CachedBackend` (ages are call counts). In the single-query CLI loop
/// the virtual clock restarts per query, so ages only accumulate within
/// a query — use LRU/LFU there, or the fleet path for true time-based
/// expiry.
pub struct TtlPolicy {
    pub ttl: f64,
}

impl CachePolicy for TtlPolicy {
    fn name(&self) -> &'static str {
        "ttl"
    }

    fn expired(&self, meta: &EntryMeta, now: f64) -> bool {
        now - meta.inserted > self.ttl
    }

    fn has_expiry(&self) -> bool {
        true
    }

    fn victim(&self, candidates: &mut dyn Iterator<Item = (u64, EntryMeta)>) -> Option<u64> {
        candidates
            .min_by(|a, b| a.1.inserted.total_cmp(&b.1.inserted).then(a.1.seq.cmp(&b.1.seq)))
            .map(|(k, _)| k)
    }
}

/// Declarative policy selection (CLI / config layer), resolved by
/// [`CachePolicyKind::build`]. The size cap itself is a cache-level knob
/// ([`super::SubtaskCache::new`]'s `capacity`) that applies under every
/// policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CachePolicyKind {
    Lru,
    Lfu,
    /// TTL in caller clock units (virtual seconds in the scheduler).
    Ttl(f64),
}

impl CachePolicyKind {
    /// Default TTL horizon when `--cache-policy ttl` gives no duration.
    pub const DEFAULT_TTL: f64 = 300.0;

    /// Parse `lru | lfu | ttl | ttl:<seconds>`.
    pub fn parse(s: &str) -> Option<CachePolicyKind> {
        let lower = s.trim().to_ascii_lowercase();
        match lower.as_str() {
            "lru" => Some(CachePolicyKind::Lru),
            "lfu" => Some(CachePolicyKind::Lfu),
            "ttl" => Some(CachePolicyKind::Ttl(Self::DEFAULT_TTL)),
            other => {
                let secs = other.strip_prefix("ttl:")?.parse::<f64>().ok()?;
                (secs > 0.0).then_some(CachePolicyKind::Ttl(secs))
            }
        }
    }

    pub fn build(&self) -> Box<dyn CachePolicy> {
        match self {
            CachePolicyKind::Lru => Box::new(LruPolicy),
            CachePolicyKind::Lfu => Box::new(LfuPolicy),
            CachePolicyKind::Ttl(ttl) => Box::new(TtlPolicy { ttl: *ttl }),
        }
    }

    pub fn label(&self) -> String {
        match self {
            CachePolicyKind::Lru => "lru".into(),
            CachePolicyKind::Lfu => "lfu".into(),
            CachePolicyKind::Ttl(ttl) => format!("ttl({ttl})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(inserted: f64, last_used: u64, hits: u64, seq: u64) -> EntryMeta {
        EntryMeta { inserted, last_used, hits, seq }
    }

    #[test]
    fn lru_picks_least_recent_with_seq_tiebreak() {
        let entries = vec![
            (1u64, meta(0.0, 5, 3, 0)),
            (2u64, meta(0.0, 2, 9, 1)),
            (3u64, meta(0.0, 2, 1, 2)),
        ];
        let v = LruPolicy.victim(&mut entries.clone().into_iter());
        assert_eq!(v, Some(2), "earliest last_used wins; seq breaks the op-2 tie");
        let empty: Vec<(u64, EntryMeta)> = Vec::new();
        assert_eq!(LruPolicy.victim(&mut empty.into_iter()), None);
    }

    #[test]
    fn lfu_picks_fewest_hits() {
        let entries = vec![
            (1u64, meta(0.0, 9, 2, 0)),
            (2u64, meta(0.0, 1, 7, 1)),
            (3u64, meta(0.0, 8, 2, 2)),
        ];
        // hits tie between 1 and 3: the least-recent of the tied set (op
        // stamp 8 vs 9) is evicted, so 3 goes.
        let v = LfuPolicy.victim(&mut entries.into_iter());
        assert_eq!(v, Some(3));
    }

    #[test]
    fn ttl_expires_and_evicts_oldest() {
        let p = TtlPolicy { ttl: 10.0 };
        assert!(!p.expired(&meta(0.0, 0, 0, 0), 10.0));
        assert!(p.expired(&meta(0.0, 0, 0, 0), 10.1));
        let entries = vec![(1u64, meta(4.0, 9, 0, 0)), (2u64, meta(1.0, 9, 5, 1))];
        assert_eq!(p.victim(&mut entries.into_iter()), Some(2));
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(CachePolicyKind::parse("lru"), Some(CachePolicyKind::Lru));
        assert_eq!(CachePolicyKind::parse("LFU"), Some(CachePolicyKind::Lfu));
        assert_eq!(
            CachePolicyKind::parse("ttl"),
            Some(CachePolicyKind::Ttl(CachePolicyKind::DEFAULT_TTL))
        );
        assert_eq!(CachePolicyKind::parse("ttl:45"), Some(CachePolicyKind::Ttl(45.0)));
        assert_eq!(CachePolicyKind::parse("ttl:-1"), None);
        assert_eq!(CachePolicyKind::parse("arc"), None);
        for kind in [CachePolicyKind::Lru, CachePolicyKind::Lfu, CachePolicyKind::Ttl(9.0)] {
            let built = kind.build();
            assert!(kind.label().starts_with(built.name()));
        }
    }
}

//! Pluggable eviction policies for [`super::SubtaskCache`].
//!
//! A policy never owns entry state: every cached entry carries an
//! [`EntryMeta`] (insert time, last-use time, hit count, insertion
//! sequence number) maintained by the cache itself, and the policy is a
//! *stateless selector* over that metadata — it decides which entries
//! have expired and assigns each entry a total-order eviction
//! [`rank`](CachePolicy::rank). The cache maintains a `BTreeSet` index on
//! `(rank, fingerprint)` per partition, so the eviction victim (the
//! minimum) is found in O(log n) instead of the historical O(capacity)
//! scan — see the `insert+evict` cases of `benches/cache.rs`. Victim
//! selection stays deterministic because every rank embeds the insertion
//! sequence number, which is unique within a partition.
//!
//! All times are the caller's clock — the virtual sim clock in the
//! scheduler integration, a logical call counter in
//! [`super::CachedBackend`] — so TTLs are expressed in whichever unit the
//! caller advances.

/// Bookkeeping the cache maintains per entry; the raw material policies
/// select on.
#[derive(Debug, Clone, Copy)]
pub struct EntryMeta {
    /// Caller-clock value when the entry was first inserted (the TTL
    /// input; in the fleet this is virtual seconds).
    pub inserted: f64,
    /// Monotone per-partition *operation* stamp of the most recent hit or
    /// insert — the LRU/LFU recency input. An operation counter (rather
    /// than the caller clock) keeps recency exact even when the caller's
    /// clock restarts, as the single-query CLI loop's per-query virtual
    /// clock does.
    pub last_used: u64,
    /// Lookup hits served by this entry.
    pub hits: u64,
    /// Monotone insertion sequence within the partition (final tie-break).
    pub seq: u64,
}

/// Total-order eviction key (see [`CachePolicy::rank`]): the entry with
/// the smallest rank is the eviction victim.
pub type EvictionRank = [u64; 3];

/// Map an `f64` clock value onto `u64`s whose unsigned ordering matches
/// `f64::total_cmp` (standard sign-flip trick), so clock-ranked policies
/// (TTL) can participate in the integer eviction index.
pub fn ordered_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b & (1 << 63) != 0 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// An eviction policy: expiry predicate + eviction-order key.
pub trait CachePolicy: Send + Sync {
    /// Short label ("lru", "lfu", ...).
    fn name(&self) -> &'static str;

    /// Whether an entry is stale at clock `now` (TTL policies). Expired
    /// entries are dropped on lookup (counted as misses) and purged before
    /// any eviction. Implementations must be monotone in `meta.inserted`
    /// (an older insertion can never outlive a newer one), which lets the
    /// cache purge stale entries from the front of an insertion-ordered
    /// index. Default: entries never expire.
    fn expired(&self, _meta: &EntryMeta, _now: f64) -> bool {
        false
    }

    /// Whether `expired` can ever return true. Policies without expiry
    /// (LRU/LFU) return false so the cache skips the stale purge on the
    /// insert-at-capacity path. Default: no expiry.
    fn has_expiry(&self) -> bool {
        false
    }

    /// The entry's eviction rank: among live entries, the one with the
    /// smallest `(rank, fingerprint)` is evicted first. Must embed
    /// `meta.seq` (unique within a partition) so ranks are distinct and
    /// victim selection is deterministic. The cache keeps a sorted index
    /// on this key, so eviction is O(log n); the rank must therefore be a
    /// pure function of `meta` (it is recomputed whenever the cache
    /// updates an entry's metadata).
    fn rank(&self, meta: &EntryMeta) -> EvictionRank;
}

/// Deterministic victim among `(fingerprint, meta)` candidates: smallest
/// `(rank, fingerprint)`. This is the linear-scan reference semantics of
/// the cache's O(log n) eviction index (tests and benches compare against
/// it; the cache itself uses the index).
pub fn select_victim(
    policy: &dyn CachePolicy,
    candidates: &mut dyn Iterator<Item = (u64, EntryMeta)>,
) -> Option<u64> {
    candidates.min_by_key(|&(k, m)| (policy.rank(&m), k)).map(|(k, _)| k)
}

/// Evict the least-recently-used entry.
pub struct LruPolicy;

impl CachePolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn rank(&self, meta: &EntryMeta) -> EvictionRank {
        [meta.last_used, meta.seq, 0]
    }
}

/// Evict the least-frequently-used entry (ties: least recent, then oldest
/// insertion).
pub struct LfuPolicy;

impl CachePolicy for LfuPolicy {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn rank(&self, meta: &EntryMeta) -> EvictionRank {
        [meta.hits, meta.last_used, meta.seq]
    }
}

/// Entries expire `ttl` clock units after insertion; eviction (when the
/// partition is full of fresh entries) drops the oldest insertion.
///
/// TTL ages on the *caller's* clock domain: one global virtual clock in
/// the fleet (ages are real virtual seconds), a logical call tick in
/// `CachedBackend` (ages are call counts). In the single-query CLI loop
/// the virtual clock restarts per query, so ages only accumulate within
/// a query — use LRU/LFU there, or the fleet path for true time-based
/// expiry.
pub struct TtlPolicy {
    pub ttl: f64,
}

impl CachePolicy for TtlPolicy {
    fn name(&self) -> &'static str {
        "ttl"
    }

    fn expired(&self, meta: &EntryMeta, now: f64) -> bool {
        now - meta.inserted > self.ttl
    }

    fn has_expiry(&self) -> bool {
        true
    }

    fn rank(&self, meta: &EntryMeta) -> EvictionRank {
        [ordered_bits(meta.inserted), meta.seq, 0]
    }
}

/// Declarative policy selection (CLI / config layer), resolved by
/// [`CachePolicyKind::build`]. The size cap itself is a cache-level knob
/// ([`super::SubtaskCache::new`]'s `capacity`) that applies under every
/// policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CachePolicyKind {
    Lru,
    Lfu,
    /// TTL in caller clock units (virtual seconds in the scheduler).
    Ttl(f64),
}

impl CachePolicyKind {
    /// Default TTL horizon when `--cache-policy ttl` gives no duration.
    pub const DEFAULT_TTL: f64 = 300.0;

    /// Parse `lru | lfu | ttl | ttl:<seconds>`.
    pub fn parse(s: &str) -> Option<CachePolicyKind> {
        let lower = s.trim().to_ascii_lowercase();
        match lower.as_str() {
            "lru" => Some(CachePolicyKind::Lru),
            "lfu" => Some(CachePolicyKind::Lfu),
            "ttl" => Some(CachePolicyKind::Ttl(Self::DEFAULT_TTL)),
            other => {
                let secs = other.strip_prefix("ttl:")?.parse::<f64>().ok()?;
                (secs > 0.0).then_some(CachePolicyKind::Ttl(secs))
            }
        }
    }

    pub fn build(&self) -> Box<dyn CachePolicy> {
        match self {
            CachePolicyKind::Lru => Box::new(LruPolicy),
            CachePolicyKind::Lfu => Box::new(LfuPolicy),
            CachePolicyKind::Ttl(ttl) => Box::new(TtlPolicy { ttl: *ttl }),
        }
    }

    pub fn label(&self) -> String {
        match self {
            CachePolicyKind::Lru => "lru".into(),
            CachePolicyKind::Lfu => "lfu".into(),
            CachePolicyKind::Ttl(ttl) => format!("ttl({ttl})"),
        }
    }

    /// Canonical [`parse`](Self::parse)-compatible string form
    /// (`lru | lfu | ttl:<secs>`), used by scenario-spec serialization so
    /// policies round-trip through JSON.
    pub fn spec_label(&self) -> String {
        match self {
            CachePolicyKind::Lru => "lru".into(),
            CachePolicyKind::Lfu => "lfu".into(),
            CachePolicyKind::Ttl(ttl) => format!("ttl:{ttl}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(inserted: f64, last_used: u64, hits: u64, seq: u64) -> EntryMeta {
        EntryMeta { inserted, last_used, hits, seq }
    }

    #[test]
    fn lru_picks_least_recent_with_seq_tiebreak() {
        let entries = vec![
            (1u64, meta(0.0, 5, 3, 0)),
            (2u64, meta(0.0, 2, 9, 1)),
            (3u64, meta(0.0, 2, 1, 2)),
        ];
        let v = select_victim(&LruPolicy, &mut entries.clone().into_iter());
        assert_eq!(v, Some(2), "earliest last_used wins; seq breaks the op-2 tie");
        let empty: Vec<(u64, EntryMeta)> = Vec::new();
        assert_eq!(select_victim(&LruPolicy, &mut empty.into_iter()), None);
    }

    #[test]
    fn lfu_picks_fewest_hits() {
        let entries = vec![
            (1u64, meta(0.0, 9, 2, 0)),
            (2u64, meta(0.0, 1, 7, 1)),
            (3u64, meta(0.0, 8, 2, 2)),
        ];
        // hits tie between 1 and 3: the least-recent of the tied set (op
        // stamp 8 vs 9) is evicted, so 3 goes.
        let v = select_victim(&LfuPolicy, &mut entries.into_iter());
        assert_eq!(v, Some(3));
    }

    #[test]
    fn ttl_expires_and_evicts_oldest() {
        let p = TtlPolicy { ttl: 10.0 };
        assert!(!p.expired(&meta(0.0, 0, 0, 0), 10.0));
        assert!(p.expired(&meta(0.0, 0, 0, 0), 10.1));
        let entries = vec![(1u64, meta(4.0, 9, 0, 0)), (2u64, meta(1.0, 9, 5, 1))];
        assert_eq!(select_victim(&p, &mut entries.into_iter()), Some(2));
    }

    #[test]
    fn ranks_are_unique_and_policy_ordered() {
        // Ranks embed seq, so two distinct entries never tie — the
        // eviction index needs strict total order.
        let a = meta(1.0, 4, 2, 0);
        let b = meta(1.0, 4, 2, 1);
        for p in [&LruPolicy as &dyn CachePolicy, &LfuPolicy, &TtlPolicy { ttl: 5.0 }] {
            assert_ne!(p.rank(&a), p.rank(&b), "{} rank must embed seq", p.name());
        }
    }

    #[test]
    fn ordered_bits_matches_total_cmp() {
        let xs = [-10.0f64, -1.5, -0.0, 0.0, 1e-9, 1.0, 1e9];
        for &x in &xs {
            for &y in &xs {
                assert_eq!(
                    ordered_bits(x).cmp(&ordered_bits(y)),
                    x.total_cmp(&y),
                    "{x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(CachePolicyKind::parse("lru"), Some(CachePolicyKind::Lru));
        assert_eq!(CachePolicyKind::parse("LFU"), Some(CachePolicyKind::Lfu));
        assert_eq!(
            CachePolicyKind::parse("ttl"),
            Some(CachePolicyKind::Ttl(CachePolicyKind::DEFAULT_TTL))
        );
        assert_eq!(CachePolicyKind::parse("ttl:45"), Some(CachePolicyKind::Ttl(45.0)));
        assert_eq!(CachePolicyKind::parse("ttl:-1"), None);
        assert_eq!(CachePolicyKind::parse("arc"), None);
        for kind in [CachePolicyKind::Lru, CachePolicyKind::Lfu, CachePolicyKind::Ttl(9.0)] {
            let built = kind.build();
            assert!(kind.label().starts_with(built.name()));
            // spec_label is the parse-compatible canonical form.
            assert_eq!(CachePolicyKind::parse(&kind.spec_label()), Some(kind));
        }
    }
}

//! Simulated model endpoints (the substitution for GPT-4.1 / Llama3.2-3B /
//! Qwen2.5-7B / DeepSeek-V3 — see DESIGN.md section 3).
//!
//! A [`ModelProfile`] combines per-domain capability curves with a serving
//! profile (decode/prefill speed, network RTT distribution, pricing). The
//! [`SimExecutor`] turns (latent subtask, assignment) into an observed
//! [`ExecRecord`] — correctness draw, latency, API cost — which is all the
//! coordinator ever sees, exactly like a real endpoint.

use crate::config::simparams::{model_params, ModelParams, SimParams};
use crate::util::rng::Rng;
use crate::workload::SubtaskLatent;

/// Known model endpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// Llama3.2-3B (edge, main pair).
    Llama3B,
    /// GPT-4.1 (cloud, main pair).
    Gpt41,
    /// Qwen2.5-7B (edge, swap pair of Table 8).
    Qwen7B,
    /// DeepSeek-V3 (cloud, swap pair of Table 8).
    DeepSeekV3,
}

impl ModelKind {
    pub fn zoo_name(&self) -> &'static str {
        match self {
            ModelKind::Llama3B => "llama3.2-3b",
            ModelKind::Gpt41 => "gpt-4.1",
            ModelKind::Qwen7B => "qwen2.5-7b",
            ModelKind::DeepSeekV3 => "deepseek-v3",
        }
    }

    /// Short label used in tables ("L3B", "G4.1", ...).
    pub fn label(&self) -> &'static str {
        match self {
            ModelKind::Llama3B => "L3B",
            ModelKind::Gpt41 => "G4.1",
            ModelKind::Qwen7B => "Q7B",
            ModelKind::DeepSeekV3 => "DSV3",
        }
    }

    pub fn is_cloud(&self) -> bool {
        matches!(self, ModelKind::Gpt41 | ModelKind::DeepSeekV3)
    }
}

/// Resolved profile (capabilities + serving characteristics).
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub kind: ModelKind,
    pub params: ModelParams,
}

impl ModelProfile {
    pub fn of(kind: ModelKind) -> ModelProfile {
        ModelProfile { kind, params: model_params(kind.zoo_name()).expect("model in zoo") }
    }

    /// Probability this model solves a subtask of difficulty `d` in `domain`.
    pub fn p_solve(&self, domain: usize, d: f64, sp: &SimParams) -> f64 {
        let cap = self.params.caps[domain];
        sigmoid((cap - d) / sp.cap_temp)
    }

    /// Simulated wall-clock latency of one call.
    pub fn latency(&self, in_tokens: f64, out_tokens: f64, rng: &mut Rng) -> f64 {
        let s = &self.params.serving;
        let rtt = if s.rtt_mean > 0.0 { s.rtt_mean * rng.lognormal(0.0, s.rtt_sigma) } else { 0.0 };
        rtt + in_tokens / s.prefill_tps + out_tokens / s.tps
    }

    /// Mean latency (no jitter) — used for profiling targets and oracles.
    pub fn latency_mean(&self, in_tokens: f64, out_tokens: f64) -> f64 {
        let s = &self.params.serving;
        s.rtt_mean + in_tokens / s.prefill_tps + out_tokens / s.tps
    }

    /// API cost of one call ($); zero for on-device models.
    pub fn api_cost(&self, in_tokens: f64, out_tokens: f64) -> f64 {
        let s = &self.params.serving;
        in_tokens * s.price_in + out_tokens * s.price_out
    }
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Observed outcome of one model call — everything downstream components
/// (budget, metrics, bandit feedback) are allowed to see.
#[derive(Debug, Clone, Copy)]
pub struct ExecRecord {
    /// Whether the subtask's local output is correct (latent; revealed to
    /// metrics only through the final-answer draw).
    pub correct: bool,
    pub latency: f64,
    pub api_cost: f64,
    pub in_tokens: f64,
    pub out_tokens: f64,
}

/// An optional compute hook run inside every *edge* execution; the runtime
/// module installs the PJRT edge-LM forward here so on-device work burns
/// real cycles through the AOT artifact (serving-path realism).
pub type ComputeHook = std::sync::Arc<dyn Fn(usize) + Send + Sync>;

/// Simulated execution engine over a fixed (edge, cloud) model pair.
pub struct SimExecutor {
    pub sp: SimParams,
    pub edge: ModelProfile,
    pub cloud: ModelProfile,
    /// Called with the chunk count for edge executions (PJRT burn hook).
    pub edge_compute: Option<ComputeHook>,
}

impl SimExecutor {
    pub fn new(edge: ModelKind, cloud: ModelKind) -> SimExecutor {
        SimExecutor {
            sp: SimParams::default(),
            edge: ModelProfile::of(edge),
            cloud: ModelProfile::of(cloud),
            edge_compute: None,
        }
    }

    /// Main paper pair: Llama3.2-3B on edge, GPT-4.1 on cloud.
    pub fn paper_pair() -> SimExecutor {
        SimExecutor::new(ModelKind::Llama3B, ModelKind::Gpt41)
    }

    /// Table 8 swapped pair.
    pub fn swap_pair() -> SimExecutor {
        SimExecutor::new(ModelKind::Qwen7B, ModelKind::DeepSeekV3)
    }

    pub fn with_edge_compute(mut self, hook: ComputeHook) -> SimExecutor {
        self.edge_compute = Some(hook);
        self
    }

    pub fn profile(&self, cloud: bool) -> &ModelProfile {
        if cloud {
            &self.cloud
        } else {
            &self.edge
        }
    }

    /// Execute one decomposed subtask on the chosen side.
    ///
    /// `in_tokens` must include the query prompt plus dependency outputs
    /// (the scheduler accumulates this). Cloud executions multiply output
    /// tokens by the verbosity factor, as profiled.
    pub fn execute_subtask(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        let profile = self.profile(cloud);
        let out_tokens =
            if cloud { latent.out_tokens * self.sp.cloud_verbosity } else { latent.out_tokens };
        let p = profile.p_solve(domain, latent.difficulty, &self.sp);
        let correct = rng.bernoulli(p);
        let latency = profile.latency(in_tokens, out_tokens, rng);
        let api_cost = profile.api_cost(in_tokens, out_tokens);
        if !cloud {
            if let Some(hook) = &self.edge_compute {
                // One PJRT chunk per EDGE_LM_T(=32)-token block, capped to
                // bound wall-clock in large sweeps.
                let chunks = ((out_tokens / 32.0).ceil() as usize).clamp(1, 4);
                hook(chunks);
            }
        }
        ExecRecord { correct, latency, api_cost, in_tokens, out_tokens }
    }

    /// Execute the whole query as a single (direct or CoT) call.
    pub fn execute_direct(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        let profile = self.profile(cloud);
        // Direct latents already encode model-family token counts; no
        // verbosity multiplier on top.
        let p = profile.p_solve(domain, latent.difficulty, &self.sp);
        let correct = rng.bernoulli(p);
        let latency = profile.latency(in_tokens, latent.out_tokens, rng);
        let api_cost = profile.api_cost(in_tokens, latent.out_tokens);
        if !cloud {
            if let Some(hook) = &self.edge_compute {
                let chunks = ((latent.out_tokens / 32.0).ceil() as usize).clamp(1, 4);
                hook(chunks);
            }
        }
        ExecRecord { correct, latency, api_cost, in_tokens, out_tokens: latent.out_tokens }
    }

    /// Final-answer correctness draw: `P(correct) = prod_i (1 - w_i (1 - s_i))`
    /// over per-subtask success indicators `s_i` (DESIGN.md / simparams).
    pub fn final_answer_correct(
        &self,
        latents: &[SubtaskLatent],
        subtask_correct: &[bool],
        rng: &mut Rng,
    ) -> bool {
        let mut p = 1.0;
        for (l, &ok) in latents.iter().zip(subtask_correct) {
            if !ok {
                p *= 1.0 - l.criticality;
            }
        }
        rng.bernoulli(p)
    }

    /// Expected accuracy gain of offloading one subtask, with the rest of
    /// the pipeline mixed (the profiling ground truth of App. C).
    pub fn true_dq(
        &self,
        domain: usize,
        latents: &[SubtaskLatent],
        i: usize,
    ) -> f64 {
        let sp = &self.sp;
        let p_e = self.edge.p_solve(domain, latents[i].difficulty, sp);
        let p_c = self.cloud.p_solve(domain, latents[i].difficulty, sp);
        let mut pipeline = 1.0;
        for (j, l) in latents.iter().enumerate() {
            if j != i {
                let p_avg = 0.5
                    * (self.edge.p_solve(domain, l.difficulty, sp)
                        + self.cloud.p_solve(domain, l.difficulty, sp));
                pipeline *= 1.0 - l.criticality * (1.0 - p_avg);
            }
        }
        (p_c - p_e) * latents[i].criticality * pipeline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn latent(d: f64, w: f64, toks: f64) -> SubtaskLatent {
        SubtaskLatent { difficulty: d, criticality: w, out_tokens: toks }
    }

    #[test]
    fn cloud_beats_edge_on_solve_probability() {
        let ex = SimExecutor::paper_pair();
        for domain in 0..4 {
            for d in [0.2, 0.5, 0.8] {
                let pe = ex.edge.p_solve(domain, d, &ex.sp);
                let pc = ex.cloud.p_solve(domain, d, &ex.sp);
                assert!(pc > pe, "domain {domain} d {d}");
            }
        }
    }

    #[test]
    fn p_solve_monotone_in_difficulty() {
        let ex = SimExecutor::paper_pair();
        let p1 = ex.edge.p_solve(1, 0.2, &ex.sp);
        let p2 = ex.edge.p_solve(1, 0.6, &ex.sp);
        let p3 = ex.edge.p_solve(1, 0.9, &ex.sp);
        assert!(p1 > p2 && p2 > p3);
    }

    #[test]
    fn edge_is_free_cloud_costs() {
        let ex = SimExecutor::paper_pair();
        let mut rng = Rng::new(0);
        let l = latent(0.5, 0.5, 100.0);
        let e = ex.execute_subtask(1, &l, 200.0, false, &mut rng);
        let c = ex.execute_subtask(1, &l, 200.0, true, &mut rng);
        assert_eq!(e.api_cost, 0.0);
        assert!(c.api_cost > 0.0);
        // Cloud verbosity inflates output tokens.
        assert!((c.out_tokens / e.out_tokens - ex.sp.cloud_verbosity).abs() < 1e-9);
    }

    #[test]
    fn cloud_call_is_slower_per_subtask() {
        // With verbosity + RTT, per-subtask cloud latency exceeds edge
        // latency in expectation at typical token counts.
        let ex = SimExecutor::paper_pair();
        let l = latent(0.5, 0.5, 120.0);
        let el = ex.edge.latency_mean(200.0, l.out_tokens);
        let cl = ex.cloud.latency_mean(200.0, l.out_tokens * ex.sp.cloud_verbosity);
        assert!(cl > el, "cloud {cl} edge {el}");
    }

    #[test]
    fn correctness_rate_tracks_p_solve() {
        let ex = SimExecutor::paper_pair();
        let mut rng = Rng::new(42);
        let l = latent(0.5, 0.5, 100.0);
        let p = ex.cloud.p_solve(1, 0.5, &ex.sp);
        let n = 4000;
        let hits = (0..n)
            .filter(|_| ex.execute_subtask(1, &l, 100.0, true, &mut rng).correct)
            .count();
        let rate = hits as f64 / n as f64;
        assert!((rate - p).abs() < 0.03, "rate {rate} vs p {p}");
    }

    #[test]
    fn final_answer_model() {
        let ex = SimExecutor::paper_pair();
        let mut rng = Rng::new(1);
        let lat = vec![latent(0.5, 0.4, 100.0), latent(0.5, 0.7, 100.0)];
        // All correct -> always correct.
        let all = (0..2000)
            .filter(|_| ex.final_answer_correct(&lat, &[true, true], &mut rng))
            .count();
        assert_eq!(all, 2000);
        // One failure with w=0.7 -> ~30% survive.
        let some = (0..4000)
            .filter(|_| ex.final_answer_correct(&lat, &[true, false], &mut rng))
            .count();
        let rate = some as f64 / 4000.0;
        assert!((rate - 0.3).abs() < 0.03, "rate {rate}");
    }

    #[test]
    fn true_dq_positive_and_bounded() {
        let ex = SimExecutor::paper_pair();
        let lat =
            vec![latent(0.4, 0.4, 80.0), latent(0.6, 0.6, 120.0), latent(0.55, 0.7, 100.0)];
        for i in 0..3 {
            let dq = ex.true_dq(1, &lat, i);
            assert!(dq > 0.0 && dq < 1.0, "dq {dq}");
        }
    }

    #[test]
    fn edge_compute_hook_fires_for_edge_only() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&count);
        let ex = SimExecutor::paper_pair()
            .with_edge_compute(Arc::new(move |chunks| {
                c2.fetch_add(chunks, Ordering::SeqCst);
            }));
        let mut rng = Rng::new(0);
        let l = latent(0.5, 0.5, 64.0);
        ex.execute_subtask(1, &l, 100.0, true, &mut rng);
        assert_eq!(count.load(Ordering::SeqCst), 0);
        ex.execute_subtask(1, &l, 100.0, false, &mut rng);
        assert!(count.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn swap_pair_profiles() {
        let ex = SimExecutor::swap_pair();
        assert_eq!(ex.edge.kind, ModelKind::Qwen7B);
        assert_eq!(ex.cloud.kind, ModelKind::DeepSeekV3);
        assert!(ex.cloud.params.serving.price_out < 8.0e-6); // cheaper than GPT-4.1
        assert!(ModelKind::DeepSeekV3.is_cloud() && !ModelKind::Qwen7B.is_cloud());
    }
}

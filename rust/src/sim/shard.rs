//! Sharded fleet execution: partition one fleet across per-shard
//! [`Kernel`](super::Kernel) instances fanned over
//! [`ThreadPool`](crate::util::pool::ThreadPool), then deterministically
//! merge the per-shard runs back into a single [`FleetReport`] and trace.
//!
//! The single-heap kernel tops out around 10k-query sweeps: one
//! `BinaryHeap` carries every in-flight event, and nothing runs
//! concurrently. Sharding models the scale-out deployment instead — the
//! fleet is split into `S` independent slices, each with its **own**
//! worker pools, result cache, admission queue, and `1/S` of every
//! dollar cap, exactly as a row of replicated serving cells would divide
//! traffic (EdgeShard-style collaborative serving). Shards share nothing,
//! so they run embarrassingly parallel and a 1M-query fleet becomes `S`
//! tractable event loops.
//!
//! Determinism contract (pinned by `rust/tests/scenario.rs` and the fuzz
//! invariants in `testing::fuzz`):
//!
//! * **Shard assignment** hashes the query id through the same PHI64
//!   multiplicative mix the engine uses for seed forking —
//!   `(id · PHI64) >> 32 mod S` — so the partition depends only on the
//!   workload, never on threads or arrival interleaving. Arrival order is
//!   preserved within each shard (stable partition).
//! * **Per-query RNG streams** are forked from `(seed, global job
//!   index)` via [`fleet_job`] — identical to the unsharded kernel — so a
//!   query's decomposition and latents do not depend on the shard count;
//!   only infrastructure effects (contention, budget pressure, cache
//!   locality) do.
//! * **The merge is a pure function** of the ordered per-shard outputs:
//!   report bytes and trace bytes are independent of the worker-thread
//!   count, and `shards = 1` reproduces the unsharded kernel — report and
//!   golden fleet trace — byte for byte.
//!
//! The merged trace interleaves shard traces by virtual-clock timestamp
//! with the shard index as tie-break, and rewrites each line's
//! kernel-local `q=` index back to the fleet-global job index.

use crate::budget::{GlobalBudget, TenantPool};
use crate::cache::CacheStats;
use crate::obs::{CriticalPathSummary, ObsData};
use crate::pipeline::HybridFlowPipeline;
use crate::util::pool::ThreadPool;
use crate::util::stats::Summary;
use std::sync::Arc;

use super::{fleet_job, run_fleet_jobs, FleetArrival, FleetConfig, FleetReport, Job, RunStats};

/// Same multiplicative mix as the kernel's per-job seed fork.
const PHI64: u64 = 0x9E3779B97f4A7C15;

/// Deterministic shard assignment: hash of the query id, independent of
/// arrival order, tenant, thread count, and seed.
pub(crate) fn shard_of(query_id: u64, shards: usize) -> usize {
    ((query_id.wrapping_mul(PHI64)) >> 32) as usize % shards.max(1)
}

/// Split a dollar cap evenly across shards (`inf` stays unlimited; at
/// `shards = 1` the division is exact, preserving byte-identity).
fn split_cap(cap: f64, shards: usize) -> f64 {
    cap / shards as f64
}

/// Run a fleet partitioned across `shards` independent kernel instances
/// on up to `threads` worker threads (`threads <= 1` runs the shards
/// serially — byte-identical output either way).
///
/// `make_pipeline` builds one pipeline per shard, so per-shard state the
/// pipeline owns (notably the result cache) is modeled per shard; it must
/// be deterministic (build the same pipeline every call). Tenant and
/// global dollar caps are split `1/shards` per shard and re-aggregated in
/// the merged report under their original caps; the admission limit
/// applies per shard.
pub fn run_fleet_sharded<F>(
    make_pipeline: F,
    cfg: &FleetConfig,
    tenants: Vec<TenantPool>,
    arrivals: Vec<FleetArrival>,
    seed: u64,
    shards: usize,
    threads: usize,
) -> FleetReport
where
    F: Fn() -> HybridFlowPipeline + Send + Sync + 'static,
{
    let shards = shards.max(1);
    let make_pipeline = Arc::new(make_pipeline);
    // One probe pipeline for the schedule the merge needs (worker counts,
    // chain mode); dropped before any shard runs.
    let schedule = (*make_pipeline)().config.schedule.clone();

    // Stable hash-of-query partition. `globals[s][j]` is the fleet-global
    // job index of shard `s`'s `j`-th query (the q= rewrite map).
    let n_total = arrivals.len();
    let mut inputs: Vec<Vec<(usize, FleetArrival)>> = (0..shards).map(|_| Vec::new()).collect();
    let mut globals: Vec<Vec<usize>> = (0..shards).map(|_| Vec::new()).collect();
    for (i, a) in arrivals.into_iter().enumerate() {
        let s = shard_of(a.query.id, shards);
        globals[s].push(i);
        inputs[s].push((i, a));
    }

    // Each shard models its slice of the infrastructure: split caps,
    // per-shard admission, fresh tenant pools.
    let shard_cfg = FleetConfig {
        admission_limit: cfg.admission_limit,
        global_k_cap: split_cap(cfg.global_k_cap, shards),
        record_trace: cfg.record_trace,
        tenant_policies: cfg.tenant_policies.clone(),
        observe: cfg.observe.clone(),
        // Fault realizations fork from the global (query, node, attempt)
        // index, so an identical config per shard reproduces the same
        // faults no matter the partition.
        faults: cfg.faults.clone(),
        resilience: cfg.resilience.clone(),
    };
    let shard_tenants: Vec<TenantPool> =
        tenants.iter().map(|t| TenantPool::new(&t.name, split_cap(t.k_cap, shards))).collect();

    let worker = {
        let make_pipeline = Arc::clone(&make_pipeline);
        let shard_cfg = shard_cfg.clone();
        let shard_tenants = shard_tenants.clone();
        move |items: Vec<(usize, FleetArrival)>| -> (FleetReport, RunStats) {
            let pipeline = (*make_pipeline)();
            let n_tenants = shard_tenants.len();
            let jobs: Vec<Job> = items
                .into_iter()
                .map(|(gi, a)| fleet_job(&pipeline, &shard_cfg, n_tenants, gi, a, seed))
                .collect();
            let run = run_fleet_jobs(&pipeline, &shard_cfg, shard_tenants.clone(), jobs);
            (run.report, run.stats)
        }
    };

    // Shards are fully independent and `ThreadPool::map` preserves input
    // order, so the outcome vector — and everything merged from it — is
    // identical no matter how many threads execute it.
    let outcomes: Vec<(FleetReport, RunStats)> = if threads <= 1 || shards == 1 {
        inputs.into_iter().map(&worker).collect()
    } else {
        ThreadPool::new(threads.min(shards)).map(inputs, worker)
    };

    merge_shard_runs(outcomes, &globals, n_total, &tenants, cfg, &schedule, shards)
}

/// Deterministically reassemble per-shard kernel runs into one fleet
/// report. Pure function of the ordered shard outputs; at `shards = 1`
/// every aggregation below reduces to the unsharded kernel's own report
/// assembly, bit for bit.
fn merge_shard_runs(
    outcomes: Vec<(FleetReport, RunStats)>,
    globals: &[Vec<usize>],
    n_total: usize,
    tenants: &[TenantPool],
    cfg: &FleetConfig,
    schedule: &crate::scheduler::ScheduleConfig,
    shards: usize,
) -> FleetReport {
    // Tenant ledgers: spends and decision counts sum across shards; the
    // report carries the original (pre-split) caps. `l_used` is a max —
    // it tracks the worst realized latency, not a consumable budget.
    let mut merged_tenants: Vec<TenantPool> =
        tenants.iter().map(|t| TenantPool::new(&t.name, t.k_cap)).collect();
    for (report, _) in &outcomes {
        for (mt, st) in merged_tenants.iter_mut().zip(&report.tenants) {
            mt.state.k_used += st.state.k_used;
            mt.state.c_used += st.state.c_used;
            mt.state.l_used = mt.state.l_used.max(st.state.l_used);
            mt.state.n_offloaded += st.state.n_offloaded;
            mt.state.n_decided += st.state.n_decided;
        }
    }
    let mut global = GlobalBudget::new(cfg.global_k_cap);
    for (report, _) in &outcomes {
        global.k_spent += report.global.k_spent;
    }

    // Fleet summaries over the concatenated raw samples (shard order):
    // quantiles cannot be merged from per-shard digests.
    let mut admission_delays = Vec::new();
    let mut queue_waits = Vec::new();
    let mut sojourns = Vec::new();
    let mut hedge_cancelled = 0usize;
    let mut hedge_refund = 0.0f64;
    let (mut edge_busy, mut cloud_busy) = (0.0f64, 0.0f64);
    let mut clock_monotone = true;
    let mut fault = crate::fault::FaultStats::default();
    for (_, stats) in &outcomes {
        admission_delays.extend_from_slice(&stats.admission_delays);
        queue_waits.extend_from_slice(&stats.queue_waits);
        sojourns.extend_from_slice(&stats.sojourns);
        hedge_cancelled += stats.hedge_cancelled;
        hedge_refund += stats.hedge_refund;
        edge_busy += stats.hedge_loser_busy[0];
        cloud_busy += stats.hedge_loser_busy[1];
        clock_monotone &= stats.clock_monotone;
        fault.merge(&stats.fault);
    }

    // Cache counters are per-shard caches of the same configuration:
    // field-wise sums (None when no shard had a cache attached).
    let mut cache: Option<CacheStats> = None;
    for (report, _) in &outcomes {
        if let Some(cs) = &report.cache {
            let acc = cache.get_or_insert_with(CacheStats::default);
            acc.lookups += cs.lookups;
            acc.hits += cs.hits;
            acc.shared_hits += cs.shared_hits;
            acc.insertions += cs.insertions;
            acc.evictions += cs.evictions;
            acc.expirations += cs.expirations;
            acc.tokens_saved += cs.tokens_saved;
            acc.dollars_saved += cs.dollars_saved;
        }
    }

    // Merged trace: k-way interleave by virtual-clock timestamp, shard
    // index as tie-break, stable within each shard; kernel-local `q=`
    // indices rewritten to fleet-global job indices.
    let trace = if cfg.record_trace {
        merge_traces(&outcomes, globals)
    } else {
        Vec::new()
    };

    // Scatter per-query results back to fleet-global job order, folding
    // each shard's observability artifacts in as it is consumed: spans
    // concatenate in shard order with shard-local query indices rewritten
    // to global job indices and the shard id stamped (one trace `pid` per
    // shard); snapshots and paths are canonicalized below. At `shards = 1`
    // every rewrite is the identity, reproducing the unsharded artifacts
    // byte for byte.
    let mut slots: Vec<Option<super::FleetQueryResult>> = (0..n_total).map(|_| None).collect();
    let mut obs: Option<ObsData> = None;
    for (s, (mut report, _)) in outcomes.into_iter().enumerate() {
        if let Some(mut o) = report.obs.take() {
            let acc = obs.get_or_insert_with(ObsData::default);
            for sp in &mut o.spans {
                sp.q = globals[s][sp.q];
                sp.shard = s;
            }
            for snap in &mut o.snapshots {
                snap.shard = s;
            }
            for p in &mut o.paths {
                p.q = globals[s][p.q];
            }
            acc.spans.append(&mut o.spans);
            acc.snapshots.append(&mut o.snapshots);
            acc.paths.append(&mut o.paths);
            acc.unclosed_spans += o.unclosed_spans;
        }
        for (j, r) in report.results.into_iter().enumerate() {
            slots[globals[s][j]] = Some(r);
        }
    }
    // Canonical artifact order: snapshots by (time, shard), paths by
    // global query index — the same order the unsharded kernel emits, so
    // downstream aggregation (and the critical-path summary's f64 sums)
    // is shard-layout invariant.
    let critical_path = obs.as_mut().and_then(|o| {
        o.snapshots.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.shard.cmp(&b.shard)));
        o.paths.sort_by_key(|p| p.q);
        CriticalPathSummary::from_paths(&o.paths)
    });
    let results: Vec<super::FleetQueryResult> = slots
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.unwrap_or_else(|| panic!("job {i} missing from every shard")))
        .collect();

    let horizon = results.iter().map(|r| r.completed_at).fold(0.0f64, f64::max);
    let n_decided: usize = merged_tenants.iter().map(|t| t.state.n_decided).sum();
    let n_offloaded: usize = merged_tenants.iter().map(|t| t.state.n_offloaded).sum();
    let forced_edge: usize = results.iter().map(|r| r.forced_edge).sum();
    // Same busy-time accounting as the kernel's report assembly; the
    // configured capacity is `shards` pools per side.
    if !schedule.chain_mode {
        for r in &results {
            for e in &r.exec.events {
                if e.cached {
                    continue;
                }
                if e.cloud {
                    cloud_busy += e.finish - e.start;
                } else {
                    edge_busy += e.finish - e.start;
                }
            }
        }
    }
    let span = horizon.max(1e-9);
    FleetReport {
        admission_delay: Summary::of_or_zero(&admission_delays),
        queue_wait: Summary::of_or_zero(&queue_waits),
        sojourn: Summary::of_or_zero(&sojourns),
        throughput_qps: results.len() as f64 / span,
        offload_rate: if n_decided == 0 { 0.0 } else { n_offloaded as f64 / n_decided as f64 },
        total_api_cost: global.k_spent,
        forced_edge,
        hedge_cancelled,
        hedge_refund,
        cache,
        edge_utilization: if schedule.edge_workers == 0 {
            0.0
        } else {
            edge_busy / (span * (shards * schedule.edge_workers) as f64)
        },
        cloud_utilization: if schedule.cloud_workers == 0 {
            0.0
        } else {
            cloud_busy / (span * (shards * schedule.cloud_workers) as f64)
        },
        clock_monotone,
        horizon,
        results,
        tenants: merged_tenants,
        global,
        trace,
        obs,
        critical_path,
        // Same presence rule as the kernel: the roll-up appears iff the
        // fault layer was configured.
        faults: (cfg.faults.is_some() || cfg.resilience.is_some()).then_some(fault),
    }
}

/// K-way merge of per-shard traces: each shard's trace is already
/// non-decreasing in time (clock monotone), so repeatedly taking the
/// earliest head — lowest shard index on ties — yields one globally
/// time-ordered, deterministic interleaving.
fn merge_traces(outcomes: &[(FleetReport, RunStats)], globals: &[Vec<usize>]) -> Vec<String> {
    let total: usize = outcomes.iter().map(|(r, _)| r.trace.len()).sum();
    let mut merged = Vec::with_capacity(total);
    let mut cursors = vec![0usize; outcomes.len()];
    loop {
        let mut best: Option<(f64, usize)> = None;
        for (s, (report, _)) in outcomes.iter().enumerate() {
            if cursors[s] < report.trace.len() {
                let t = trace_time(&report.trace[cursors[s]]);
                if best.map_or(true, |(bt, _)| t < bt) {
                    best = Some((t, s));
                }
            }
        }
        let Some((_, s)) = best else { break };
        merged.push(rewrite_q(&outcomes[s].0.trace[cursors[s]], &globals[s]));
        cursors[s] += 1;
    }
    merged
}

/// Parse the leading `t=<seconds>` field of a trace line.
fn trace_time(line: &str) -> f64 {
    debug_assert!(line.starts_with("t="), "malformed trace line: {line}");
    let rest = line.get(2..).unwrap_or("");
    let end = rest.find(' ').unwrap_or(rest.len());
    rest[..end].parse().unwrap_or(0.0)
}

/// Rewrite the single ` q=<idx>` token from the shard-local query index
/// to the fleet-global job index. Identity when the map is the identity
/// (the `shards = 1` byte-parity path).
fn rewrite_q(line: &str, to_global: &[usize]) -> String {
    let Some(pos) = line.find(" q=") else {
        return line.to_string();
    };
    let start = pos + 3;
    let end = line[start..].find(' ').map_or(line.len(), |k| start + k);
    let Ok(local) = line[start..end].parse::<usize>() else {
        return line.to_string();
    };
    let global = to_global.get(local).copied().unwrap_or(local);
    format!("{}{}{}", &line[..start], global, &line[end..])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::simparams::SimParams;
    use crate::models::SimExecutor;
    use crate::pipeline::PipelineConfig;
    use crate::planner::synthetic::SyntheticPlanner;
    use crate::router::{MirrorPredictor, RoutePolicy};
    use crate::sim::run_fleet;
    use crate::workload::{generate_queries, Benchmark};

    fn make_pipeline() -> HybridFlowPipeline {
        let sp = SimParams::default();
        let cfg = PipelineConfig::paper_default(&sp);
        HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        )
    }

    fn arrivals(n: usize, gap: f64, tenants: usize, seed: u64) -> Vec<FleetArrival> {
        generate_queries(Benchmark::Gpqa, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| FleetArrival { time: i as f64 * gap, tenant: i % tenants, query })
            .collect()
    }

    fn tenants() -> Vec<TenantPool> {
        vec![TenantPool::unlimited("a"), TenantPool::new("b", 0.05)]
    }

    #[test]
    fn shard_assignment_is_stable_and_in_range() {
        for id in [0u64, 1, 2, 17, u64::MAX] {
            for shards in [1usize, 2, 4, 8] {
                let s = shard_of(id, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(id, shards), "stable");
            }
        }
        assert_eq!(shard_of(42, 1), 0, "single shard takes everything");
    }

    #[test]
    fn one_shard_is_byte_identical_to_unsharded() {
        let cfg = FleetConfig::default();
        let plain = run_fleet(&make_pipeline(), &cfg, tenants(), arrivals(12, 1.0, 2, 9), 33);
        let sharded =
            run_fleet_sharded(make_pipeline, &cfg, tenants(), arrivals(12, 1.0, 2, 9), 33, 1, 4);
        assert_eq!(plain.trace_text(), sharded.trace_text(), "trace bytes");
        assert_eq!(
            plain.to_json().to_string_pretty(),
            sharded.to_json().to_string_pretty(),
            "report bytes"
        );
    }

    #[test]
    fn sharded_run_is_thread_count_invariant() {
        let cfg = FleetConfig { global_k_cap: 0.08, ..Default::default() };
        let runs: Vec<FleetReport> = [1usize, 2, 4, 8]
            .into_iter()
            .map(|threads| {
                run_fleet_sharded(
                    make_pipeline,
                    &cfg,
                    tenants(),
                    arrivals(16, 0.5, 2, 5),
                    7,
                    4,
                    threads,
                )
            })
            .collect();
        for r in &runs[1..] {
            assert_eq!(runs[0].trace_text(), r.trace_text(), "trace bytes");
            assert_eq!(
                runs[0].to_json().to_string_pretty(),
                r.to_json().to_string_pretty(),
                "report bytes"
            );
        }
    }

    #[test]
    fn merge_preserves_per_query_results_and_ledgers() {
        let cfg = FleetConfig::default();
        let arr = arrivals(20, 0.25, 2, 21);
        let plain = run_fleet(&make_pipeline(), &cfg, tenants(), arr.clone(), 11);
        let sharded = run_fleet_sharded(make_pipeline, &cfg, tenants(), arr, 11, 4, 2);
        assert_eq!(sharded.results.len(), plain.results.len());
        // Global arrival order is restored: result i is job i.
        for (i, r) in sharded.results.iter().enumerate() {
            assert_eq!(r.query_id, plain.results[i].query_id, "job {i} out of place");
            assert_eq!(r.tenant, plain.results[i].tenant);
            assert_eq!(r.arrival, plain.results[i].arrival);
        }
        // Ledger conservation across the merge.
        let tenant_sum: f64 = sharded.tenants.iter().map(|t| t.state.k_used).sum();
        assert!((sharded.global.k_spent - tenant_sum).abs() < 1e-9);
        assert_eq!(sharded.total_api_cost, sharded.global.k_spent);
        assert_eq!(sharded.tenants[1].k_cap, 0.05, "original caps restored");
        assert!(sharded.clock_monotone);
        // Trace is globally time-ordered after the k-way merge.
        let times: Vec<f64> = sharded.trace.iter().map(|l| trace_time(l)).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "merged trace out of order");
    }

    #[test]
    fn q_rewrite_maps_local_to_global() {
        let line = "t=1.500000 tenant=0 q=2 exec node=1 side=edge start=1.500000 finish=2.000000 wait=0.000000";
        let out = rewrite_q(line, &[5, 9, 14]);
        assert_eq!(
            out,
            "t=1.500000 tenant=0 q=14 exec node=1 side=edge start=1.500000 finish=2.000000 wait=0.000000"
        );
        // Identity map reproduces the input bytes.
        assert_eq!(rewrite_q(line, &[0, 1, 2]), line);
        assert_eq!(trace_time(line), 1.5);
    }
}

//! The unified simulation kernel: **one** event-heap loop drives every
//! execution mode of the engine.
//!
//! Historically the repo carried two near-duplicate event loops — a
//! single-query scheduler (`scheduler::execute_query`) and a fleet
//! simulator (`scheduler::fleet::run_fleet`) — and every engine feature
//! (hedged dispatch, `Cancel` events, cache short-circuits) had to be
//! implemented twice. [`Kernel`] collapses them: a single tagged event
//! heap (keyed by [`crate::scheduler::events::EventKey`]) orders
//! **arrivals**, **planner completions**, **ready-frontier markers**,
//! **subtask finishes**, and **hedge cancellations** across all queries,
//! and the two old entrypoints are thin wrappers:
//!
//! * [`run_fleet`] — fleet mode: shared worker pools, tenant/global
//!   dollar scopes, admission queueing, cold cache per run;
//! * `scheduler::execute_query` — literally the kernel with one tenant
//!   and one (pre-planned) arrival: query-local budget scope, no
//!   admission limit, cache sessions advanced per run instead of reset.
//!
//! Event semantics (unchanged from the pre-unification engine, pinned by
//! the golden fleet trace and the `fleet(N=1) == execute_query`
//! equivalence suite):
//!
//! * worker pools are shared: a subtask decided at `t` starts at
//!   `max(t, earliest_free_worker)`, so load shows up as per-subtask
//!   queueing delay;
//! * in fleet scope, routing decisions see the **tenant's aggregated**
//!   [`BudgetState`](crate::budget::BudgetState) (fleet-level `C_used(t)`
//!   in Eq. 8's sense) and a dry tenant or global dollar pool forces
//!   subtasks back to the edge; in query-local scope the router sees the
//!   query's own budget (the paper's per-query semantics);
//! * per-tenant policy overrides build each query's router from its
//!   tenant's policy (falling back to the pipeline default);
//! * an admission limit bounds in-service queries; excess arrivals wait
//!   FIFO and their admission delay is reported;
//! * with hedging on, the losing replica's `Cancel` event releases its
//!   worker slot and refunds the unconsumed cloud spend at every budget
//!   scope the dispatch charged;
//! * `chain_mode` queries execute strictly sequentially on the virtual
//!   clock without occupying shared pools; their admission slot is held
//!   until the chain's virtual makespan.
//!
//! Determinism: every fleet query gets an RNG forked from `(seed, job
//! index)` — never from arrival interleaving — and all state lives in
//! vectors and binary heaps with total orderings, so a fixed
//! `(workload, seed)` pair reproduces the event trace byte-for-byte.

use crate::budget::{GlobalBudget, TenantPool};
use crate::cache::CacheStats;
use crate::embed::FeatureContext;
use crate::engine::Backend;
use crate::fault::{FaultConfig, FaultMark, FaultModel, FaultStats, ResilienceConfig};
use crate::obs::{
    CriticalPathSummary, Histogram, MetricsSnapshot, ObsData, ObserveConfig, QueryPath, Span,
    MAX_METRIC_SNAPSHOTS,
};
use crate::pipeline::HybridFlowPipeline;
use crate::planner::synthetic::SyntheticPlanner;
use crate::planner::Planner;
use crate::report::ReportRenderer;
use crate::router::predictor::UtilityPredictor;
use crate::router::{RoutePolicy, RouterState};
use crate::scheduler::events::EventKey;
use crate::scheduler::pool::WorkerPool;
use crate::scheduler::{
    apply_cancel, run_group, CancelTicket, Dispatch, DispatchOutcome, FaultCtx, FleetRouteCtx,
    GroupCtx, QueryExecState, QueryExecution, ScheduleConfig,
};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::{sample_latents, Query, SubtaskLatent};
use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::sync::Arc;

pub mod shard;

pub use shard::run_fleet_sharded;

/// Fleet-level knobs (per-query scheduling semantics come from the
/// pipeline's [`ScheduleConfig`]).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Maximum queries in service at once; 0 = unlimited. Arrivals beyond
    /// the limit queue FIFO and are admitted as earlier queries complete.
    pub admission_limit: usize,
    /// Fleet-wide cloud-dollar ceiling shared by every tenant pool.
    pub global_k_cap: f64,
    /// Record the human-readable event trace (golden-trace tests, debug).
    pub record_trace: bool,
    /// Per-tenant routing-policy overrides, indexed like the tenant list.
    /// `None` (or an index beyond the vector) falls back to the pipeline's
    /// default policy, so an empty vector reproduces a homogeneous fleet.
    pub tenant_policies: Vec<Option<RoutePolicy>>,
    /// Structured observability (spans + metrics time series + critical
    /// paths). `None` is fully off: the kernel takes the exact
    /// uninstrumented code path (byte-identity pinned by the golden fleet
    /// trace).
    pub observe: Option<ObserveConfig>,
    /// Deterministic fault injection (transient failures, outage windows,
    /// stragglers). `None` with `resilience: None` is fully off: the
    /// kernel takes the exact pre-fault code path (byte-identity pinned by
    /// the golden fleet trace).
    pub faults: Option<FaultConfig>,
    /// Resilience policies (timeout, retries with backoff, failover,
    /// graceful degradation). Activating either block activates the fault
    /// layer; the missing half takes its defaults.
    pub resilience: Option<ResilienceConfig>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            admission_limit: 0,
            global_k_cap: f64::INFINITY,
            record_trace: true,
            tenant_policies: Vec::new(),
            observe: None,
            faults: None,
            resilience: None,
        }
    }
}

/// One query arriving at the kernel.
#[derive(Debug, Clone)]
pub struct FleetArrival {
    pub time: f64,
    /// Index into the tenant pool list.
    pub tenant: usize,
    pub query: Query,
}

/// Per-query outcome with fleet timing attached.
#[derive(Debug, Clone)]
pub struct FleetQueryResult {
    pub tenant: usize,
    pub query_id: u64,
    pub arrival: f64,
    pub admitted: f64,
    pub plan_done: f64,
    pub completed_at: f64,
    /// Decisions overridden to edge because a dollar pool was exhausted.
    pub forced_edge: usize,
    /// `latency` is the sojourn time (arrival to completion, planning and
    /// admission queueing included); for an uncontended single query this
    /// equals `execute_query`'s latency exactly.
    pub exec: QueryExecution,
}

/// Aggregate outcome of one kernel run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-query results in job (arrival-list) order.
    pub results: Vec<FleetQueryResult>,
    /// Final tenant pools (aggregated budget state, spend vs cap).
    pub tenants: Vec<TenantPool>,
    pub global: GlobalBudget,
    /// Virtual time of the last completion.
    pub horizon: f64,
    /// Queries per virtual second over the horizon.
    pub throughput_qps: f64,
    /// Admission-queue delay per query (seconds).
    pub admission_delay: Summary,
    /// Per-subtask wait between routing decision and worker start.
    pub queue_wait: Summary,
    /// Arrival-to-completion time per query.
    pub sojourn: Summary,
    pub offload_rate: f64,
    pub total_api_cost: f64,
    pub forced_edge: usize,
    /// Hedged replicas cancelled (losing side of speculative dispatch).
    pub hedge_cancelled: usize,
    /// Dollars refunded for the unconsumed share of cancelled replicas.
    pub hedge_refund: f64,
    /// Cross-query result-cache counters for this run (`None` when no
    /// enabled cache was attached): hit rate, cloud tokens saved, budget
    /// avoided, evictions. The cache is reset at run start, so these are
    /// exactly this run's numbers.
    pub cache: Option<CacheStats>,
    pub edge_utilization: f64,
    pub cloud_utilization: f64,
    /// True unless the event heap ever popped times out of order.
    pub clock_monotone: bool,
    /// Human-readable event log (empty unless `record_trace`).
    pub trace: Vec<String>,
    /// Structured observability artifacts (spans, metrics snapshots,
    /// per-query critical paths) — `None` unless the run carried an
    /// [`ObserveConfig`].
    pub obs: Option<ObsData>,
    /// Fault/resilience roll-up (attempts, failures, timeouts, retries,
    /// failovers, degraded queries, refunds) — `None` unless the run
    /// carried a fault layer, so fault-free reports render and serialize
    /// byte-identically to pre-fault-injection ones.
    pub faults: Option<FaultStats>,
    /// Fleet-level critical-path aggregate, derived from `obs` paths
    /// (`None` whenever `obs` is, so observe-off reports render and
    /// serialize byte-identically to pre-observability ones).
    pub critical_path: Option<CriticalPathSummary>,
}

impl FleetReport {
    /// The serialized event trace (golden-file format): one event per
    /// line, newline-terminated.
    pub fn trace_text(&self) -> String {
        let mut out = self.trace.join("\n");
        out.push('\n');
        out
    }

    pub fn render(&self) -> String {
        let mut r = ReportRenderer::new(format!(
            "fleet: {} queries over {:.1}s virtual ({:.3} q/s)",
            self.results.len(),
            self.horizon,
            self.throughput_qps,
        ));
        r.line(format!(
            "admission delay: mean {:.2}s  p99 {:.2}s",
            self.admission_delay.mean, self.admission_delay.p99
        ));
        r.line(format!(
            "subtask queue wait: mean {:.2}s  p99 {:.2}s",
            self.queue_wait.mean, self.queue_wait.p99
        ));
        r.line(crate::report::quantiles_s("sojourn", &self.sojourn));
        r.line(format!(
            "offload {:.1}%  C_API ${:.4}  forced-to-edge {}",
            self.offload_rate * 100.0,
            self.total_api_cost,
            self.forced_edge,
        ));
        r.line(format!(
            "utilization: edge {:.1}%  cloud {:.1}%",
            self.edge_utilization * 100.0,
            self.cloud_utilization * 100.0,
        ));
        r.hedge(self.hedge_cancelled, self.hedge_refund);
        r.cache(self.cache.as_ref());
        r.critical_path(self.critical_path.as_ref());
        r.faults(self.faults.as_ref());
        r.finish()
    }

    /// Machine-readable report (`util::json`): aggregate serving metrics,
    /// tenant ledgers, and cache counters — the plotting surface behind
    /// the CLI's `--json` flag and the sweep engine's cell tables. The
    /// per-event trace is deliberately omitted (use
    /// [`trace_text`](Self::trace_text) for golden-file comparison).
    pub fn to_json(&self) -> Json {
        use crate::report::{cache_stats_json, summary_json};
        let n = self.results.len();
        let correct = self.results.iter().filter(|r| r.exec.correct).count();
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::Str(t.name.clone())),
                    // Unlimited caps serialize as null (same convention
                    // as scenario specs).
                    (
                        "k_cap",
                        if t.k_cap.is_finite() { Json::Num(t.k_cap) } else { Json::Null },
                    ),
                    ("k_used", Json::Num(t.state.k_used)),
                    ("c_used", Json::Num(t.state.c_used)),
                    ("n_decided", Json::Num(t.state.n_decided as f64)),
                    ("n_offloaded", Json::Num(t.state.n_offloaded as f64)),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("n_queries", Json::Num(n as f64)),
            (
                "accuracy_pct",
                Json::Num(if n == 0 { 0.0 } else { correct as f64 / n as f64 * 100.0 }),
            ),
            ("horizon", Json::Num(self.horizon)),
            ("throughput_qps", Json::Num(self.throughput_qps)),
            ("admission_delay", summary_json(&self.admission_delay)),
            ("queue_wait", summary_json(&self.queue_wait)),
            ("sojourn", summary_json(&self.sojourn)),
            ("offload_rate", Json::Num(self.offload_rate)),
            ("total_api_cost", Json::Num(self.total_api_cost)),
            ("forced_edge", Json::Num(self.forced_edge as f64)),
            ("hedge_cancelled", Json::Num(self.hedge_cancelled as f64)),
            ("hedge_refund", Json::Num(self.hedge_refund)),
            ("edge_utilization", Json::Num(self.edge_utilization)),
            ("cloud_utilization", Json::Num(self.cloud_utilization)),
            ("clock_monotone", Json::Bool(self.clock_monotone)),
            ("cache", self.cache.as_ref().map_or(Json::Null, cache_stats_json)),
            ("tenants", Json::Arr(tenants)),
        ];
        // Emitted only when observability ran, so observe-off JSON stays
        // byte-identical to the pre-observability report.
        if let Some(cp) = &self.critical_path {
            pairs.push(("critical_path", cp.to_json()));
        }
        // Same convention: the fault roll-up appears only when the fault
        // layer was active.
        if let Some(f) = &self.faults {
            pairs.push(("faults", f.to_json()));
        }
        Json::obj(pairs)
    }
}

// Event-kind priorities: at equal times, control events (arrival/planner/
// cancel) run first, then ready-frontier markers, then subtask finishes —
// the marker-before-finish order reproduces the classic "ready first"
// tie-break, and cancel-before-marker makes freed workers and refunds
// visible to decisions at the same instant.
const PRI_CTRL: u8 = 0;
const PRI_MARKER: u8 = 1;
const PRI_DONE: u8 = 2;

#[derive(Debug, Clone, Copy, PartialEq)]
enum EvKind {
    Arrival,
    PlanDone,
    Marker,
    Done,
    /// Cancellation of a hedged dispatch's losing replica.
    Cancel,
    /// Completion of a chain-mode query: its subtasks executed
    /// synchronously at PlanDone, but the service slot is held until the
    /// chain's virtual makespan. (Also used for degenerate zero-node
    /// plans, which complete at their planning instant.)
    ChainDone,
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Ev {
    key: EventKey,
    kind: EvKind,
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Single shared ordering rule: scheduler::events::EventKey.
        self.key.cmp(&other.key)
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// How the kernel treats an attached result cache at run start.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum CacheSessions {
    /// Fleet mode: drop entries + counters so a fixed `(workload, seed)`
    /// pair reproduces the same hit/miss/eviction sequence byte-for-byte.
    ResetCold,
    /// Single-query mode: keep warm state from earlier runs but bump the
    /// session epoch (the per-query virtual clock restarts, so earlier
    /// runs' entries become unconditionally available).
    EpochPerRun,
}

/// A query job entering the kernel: its RNG stream and router state, plus
/// optionally a pre-planned decomposition (single-query mode, where the
/// caller already ran the planner on the same RNG).
pub(crate) struct Job {
    pub tenant: usize,
    /// Shared, never deep-copied: job construction moves the caller's
    /// query behind an `Arc` (zero-copy job contract).
    pub query: Arc<Query>,
    pub arrival: f64,
    /// Position in the *full* (unsharded) arrival list — the fault layer's
    /// attempt streams fork from this global index, so fault realizations
    /// are invariant to shard assignment and thread count.
    pub global_index: usize,
    pub rng: Rng,
    pub router: RouterState,
    pub preplanned: Option<Preplanned>,
}

/// Pre-planned decomposition for a [`Job`] (skips the admission-time
/// planner call; `plan_done = arrival + planning_latency`). The DAG is
/// `Arc`-shared so handing a plan to the kernel never copies subtask
/// text.
pub(crate) struct Preplanned {
    pub dag: Arc<crate::dag::TaskDag>,
    pub latents: Vec<SubtaskLatent>,
    pub planning_latency: f64,
}

/// Kernel configuration: the model/planner seams plus the knobs that
/// distinguish fleet mode from single-query mode.
pub(crate) struct KernelSpec<'a> {
    /// Planner for jobs without a pre-planned decomposition. `None` is
    /// only valid when every job is pre-planned.
    pub planner: Option<&'a SyntheticPlanner>,
    pub executor: &'a dyn Backend,
    pub predictor: &'a dyn UtilityPredictor,
    pub schedule: &'a ScheduleConfig,
    /// Planner subtask cap (unused when every job is pre-planned).
    pub n_max: usize,
    pub admission_limit: usize,
    pub record_trace: bool,
    /// Query-local budget scope: `run_group` sees no tenant/global pools
    /// (single-query semantics — the router routes against the query's own
    /// budget and nothing can force-edge a decision).
    pub query_local: bool,
    pub global_k_cap: f64,
    pub cache_sessions: CacheSessions,
    /// Observability recorders; `None` takes the uninstrumented path.
    pub observe: Option<ObserveConfig>,
    /// Fault-injection + resilience model; `None` takes the exact
    /// pre-fault path.
    pub fault: Option<FaultModel>,
}

/// Everything a kernel run produces: the report plus each job's final
/// router state and RNG (handed back to single-query callers so
/// `execute_query`'s `&mut` contract holds across the kernel boundary)
/// and the raw sample streams behind the report's summaries (consumed by
/// the cross-shard merge).
pub(crate) struct KernelRun {
    pub report: FleetReport,
    pub routers: Vec<RouterState>,
    pub rngs: Vec<Rng>,
    pub stats: RunStats,
}

/// The unified simulation kernel: configuration + tenant pools + jobs,
/// consumed by [`Kernel::run`] — the one event-heap loop in the engine.
pub(crate) struct Kernel<'a> {
    pub spec: KernelSpec<'a>,
    pub tenants: Vec<TenantPool>,
    pub jobs: Vec<Job>,
}

/// Scheduling state built at admission (planning done lazily so queued
/// queries consume planner latency when they actually start).
struct PlanState {
    dag: Arc<crate::dag::TaskDag>,
    latents: Vec<SubtaskLatent>,
    fctx: FeatureContext,
    depths: Vec<usize>,
    max_depth: usize,
    /// Flattened children adjacency (CSR): built once at plan time, two
    /// allocations instead of one vector per node.
    children: crate::dag::CsrChildren,
    indeg: Vec<usize>,
    done: Vec<bool>,
    ready: BinaryHeap<EventKey>,
    st: QueryExecState,
    /// Outstanding hedge-cancel tickets, indexed by node.
    cancel_tickets: Vec<Option<CancelTicket>>,
    completed: usize,
}

struct QueryRun {
    tenant: usize,
    query: Arc<Query>,
    arrival: f64,
    global_index: usize,
    admitted: f64,
    plan_done: f64,
    rng: Rng,
    router: RouterState,
    forced_edge: usize,
    preplanned: Option<Preplanned>,
    plan: Option<PlanState>,
    outcome: Option<QueryExecution>,
    completed_at: f64,
}

/// Raw per-run sample streams behind the report's summaries, kept on
/// [`KernelRun`] so the sharded merge ([`shard::run_fleet_sharded`]) can
/// recompute fleet-level [`Summary`] values over the *concatenated*
/// per-shard samples instead of trying to merge pre-digested quantiles.
pub(crate) struct RunStats {
    pub(crate) admission_delays: Vec<f64>,
    pub(crate) queue_waits: Vec<f64>,
    pub(crate) sojourns: Vec<f64>,
    pub(crate) hedge_cancelled: usize,
    pub(crate) hedge_refund: f64,
    /// Worker-busy seconds consumed by hedged losing replicas before their
    /// cancellation, per side (edge, cloud) — counted into utilization so
    /// the report reflects real pool occupancy, not just winner events.
    pub(crate) hedge_loser_busy: [f64; 2],
    pub(crate) clock_monotone: bool,
    /// Fault/resilience roll-up across completed queries (zero when the
    /// fault layer is off).
    pub(crate) fault: FaultStats,
}

/// Per-run observability state, allocated only when the kernel spec
/// carries an [`ObserveConfig`]. Every touch point in the event loop sits
/// behind `if let Some`, so the observe-off kernel executes the exact
/// pre-observability instructions (byte-identity pinned by the golden
/// fleet trace). Pure read-side recording: nothing here feeds back into
/// routing, RNG draws, or event ordering.
struct ObsState {
    cfg: ObserveConfig,
    spans: Vec<Span>,
    /// Open hedge-loser spans awaiting their `Cancel` event:
    /// `(query, node)` -> index into `spans`.
    open: BTreeMap<(usize, usize), usize>,
    snapshots: Vec<MetricsSnapshot>,
    /// Next snapshot index; sample time is `next_snap * metrics_interval`
    /// (multiplied, not accumulated, so long series don't drift).
    next_snap: u64,
    /// Live count of ready-queue entries across all in-flight queries.
    ready_depth: usize,
    /// Completed-query sojourns feeding the snapshot latency columns —
    /// the shared [`Histogram`] the serving telemetry also uses.
    sojourn: Histogram,
    paths: Vec<QueryPath>,
}

impl ObsState {
    fn new(cfg: ObserveConfig) -> ObsState {
        ObsState {
            cfg,
            spans: Vec::new(),
            open: BTreeMap::new(),
            snapshots: Vec::new(),
            next_snap: 0,
            ready_depth: 0,
            sojourn: Histogram::new(),
            paths: Vec::new(),
        }
    }

    /// Virtual time of the next due metrics snapshot, or `None` when the
    /// metrics recorder is off or the per-shard cap is exhausted.
    fn snapshot_due(&self) -> Option<f64> {
        if !self.cfg.metrics || self.snapshots.len() >= MAX_METRIC_SNAPSHOTS {
            return None;
        }
        Some(self.next_snap as f64 * self.cfg.metrics_interval)
    }
}

/// Record one metrics-snapshot row at virtual time `t` (gauges read the
/// kernel state *before* any event at `t` is processed).
#[allow(clippy::too_many_arguments)]
fn obs_snapshot(
    o: &mut ObsState,
    t: f64,
    admission_backlog: usize,
    edge: &WorkerPool,
    cloud: &WorkerPool,
    tenants: &[TenantPool],
    global_spent: f64,
    cache_lookups: u64,
    cache_hits: u64,
) {
    let completed = o.sojourn.count();
    let (latency_mean, latency_p50, latency_p99) = if completed == 0 {
        (0.0, 0.0, 0.0)
    } else {
        (o.sojourn.mean_secs(), o.sojourn.quantile(0.5), o.sojourn.quantile(0.99))
    };
    o.snapshots.push(MetricsSnapshot {
        t,
        shard: 0,
        ready_depth: o.ready_depth,
        admission_backlog,
        edge_busy: edge.busy_at(t),
        cloud_busy: cloud.busy_at(t),
        global_spent,
        tenant_spent: tenants.iter().map(|tp| tp.state.k_used).collect(),
        cache_lookups,
        cache_hits,
        completed,
        latency_mean,
        latency_p50,
        latency_p99,
    });
    o.next_snap += 1;
}

/// Recover one completed query's realized critical path: walk back from
/// the last-finishing node through the latest-finishing parent at each
/// step (first maximum on ties — deterministic). `slacks[i]` is the gap
/// between the node becoming runnable (latest parent finish, or the plan
/// instant for the entry node) and its worker start. `None` for
/// degenerate zero-node plans.
fn critical_path_of(
    qi: usize,
    plan_done: f64,
    ps: &PlanState,
    makespan_abs: f64,
) -> Option<QueryPath> {
    let n = ps.dag.len();
    if n == 0 || ps.st.events.len() < n {
        return None;
    }
    let mut start = vec![0.0f64; n];
    let mut finish = vec![0.0f64; n];
    for e in &ps.st.events {
        start[e.node] = e.start;
        finish[e.node] = e.finish;
    }
    // Parent adjacency by inverting the children CSR.
    let mut parents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for p in 0..n {
        for &c in ps.children.children_of(p) {
            parents[c as usize].push(p);
        }
    }
    let mut exit = 0;
    for i in 1..n {
        if finish[i] > finish[exit] {
            exit = i;
        }
    }
    let mut rev = vec![exit];
    let mut cur = exit;
    while let Some(&first) = parents[cur].first() {
        let mut best = first;
        for &p in &parents[cur][1..] {
            if finish[p] > finish[best] {
                best = p;
            }
        }
        rev.push(best);
        cur = best;
    }
    rev.reverse();
    let nodes = rev;
    let mut slacks = Vec::with_capacity(nodes.len());
    let mut path_latency = 0.0;
    for (k, &i) in nodes.iter().enumerate() {
        let ready_at = if k == 0 {
            plan_done
        } else {
            parents[i].iter().map(|&p| finish[p]).fold(plan_done, f64::max)
        };
        slacks.push(start[i] - ready_at);
        path_latency += finish[i] - start[i];
    }
    Some(QueryPath { q: qi, nodes, slacks, path_latency, makespan: makespan_abs - plan_done })
}

#[allow(clippy::too_many_arguments)]
fn admit_query(
    qi: usize,
    now: f64,
    q: &mut QueryRun,
    planner: Option<&SyntheticPlanner>,
    executor: &dyn Backend,
    n_max: usize,
    heap: &mut BinaryHeap<Ev>,
    stats: &mut RunStats,
    trace: &mut Vec<String>,
    record_trace: bool,
) {
    q.admitted = now;
    stats.admission_delays.push(now - q.arrival);
    // Same call order as `HybridFlowPipeline::run_query_traced`: plan, then
    // latents, both on the query's own RNG stream — unless the caller
    // already planned on that stream and handed the result over.
    let (dag, latents, planning_latency) = match q.preplanned.take() {
        Some(p) => (p.dag, p.latents, p.planning_latency),
        None => {
            let planner = planner.expect("kernel jobs without a planner must be pre-planned");
            let plan = planner.plan(&q.query, n_max, &mut q.rng);
            let latents = sample_latents(&plan.dag, &q.query, executor.sp(), &mut q.rng);
            (Arc::new(plan.dag), latents, plan.planning_latency)
        }
    };
    let n = dag.len();
    let fctx = FeatureContext::new(&dag, &q.query);
    let depths = dag.depths().unwrap_or_else(|| vec![0; n]);
    let max_depth = depths.iter().copied().max().unwrap_or(0).max(1);
    let children = dag.children_csr();
    let indeg = dag.in_degrees();
    q.plan_done = now + planning_latency;
    q.plan = Some(PlanState {
        dag,
        latents,
        fctx,
        depths,
        max_depth,
        children,
        indeg,
        done: vec![false; n],
        ready: BinaryHeap::new(),
        st: QueryExecState::new(n),
        cancel_tickets: (0..n).map(|_| None).collect(),
        completed: 0,
    });
    heap.push(Ev {
        key: EventKey { time: q.plan_done, pri: PRI_CTRL, q: qi, node: 0 },
        kind: EvKind::PlanDone,
    });
    if record_trace {
        trace.push(format!(
            "t={:.6} tenant={} q={} admit wait={:.6}",
            now,
            q.tenant,
            qi,
            now - q.arrival
        ));
    }
}

#[allow(clippy::too_many_arguments)]
fn finalize_query(
    qi: usize,
    q: &mut QueryRun,
    tenant: Option<&mut TenantPool>,
    executor: &dyn Backend,
    stats: &mut RunStats,
    trace: &mut Vec<String>,
    record_trace: bool,
    obs: Option<&mut ObsState>,
) {
    let makespan_abs = {
        let ps = q.plan.as_mut().expect("finalize before planning");
        debug_assert!(
            ps.cancel_tickets.iter().all(Option::is_none),
            "outstanding hedge cancels at finalize"
        );
        let makespan_abs =
            ps.st.events.iter().map(|e| e.finish).fold(q.plan_done, f64::max);
        ps.st.budget.advance_latency(makespan_abs - q.plan_done);
        if let Some(t) = tenant {
            t.state.advance_latency(makespan_abs - q.plan_done);
        }
        makespan_abs
    };
    if let Some(o) = obs {
        if o.cfg.spans {
            let ps = q.plan.as_ref().expect("plan state");
            if let Some(path) = critical_path_of(qi, q.plan_done, ps, makespan_abs) {
                o.paths.push(path);
            }
        }
        if o.cfg.metrics {
            o.sojourn.record(makespan_abs - q.arrival);
        }
    }
    let final_correct = {
        let ps = q.plan.as_ref().expect("plan state");
        executor.final_answer_correct(&ps.latents, &ps.st.correct, &mut q.rng)
    };
    let ps = q.plan.take().expect("plan state");
    stats.fault.merge(&ps.st.fault);
    if ps.st.degraded {
        stats.fault.degraded_queries += 1;
    }
    let exec = QueryExecution {
        correct: final_correct,
        latency: makespan_abs - q.arrival,
        api_cost: ps.st.api_total,
        offload_rate: ps.st.budget.offload_rate(),
        n_subtasks: ps.dag.len(),
        degraded: ps.st.degraded,
        events: ps.st.events,
        budget: ps.st.budget,
    };
    stats.sojourns.push(makespan_abs - q.arrival);
    if record_trace {
        trace.push(format!(
            "t={:.6} tenant={} q={} complete correct={} latency={:.6} api={:.6} offload={:.6}{}",
            makespan_abs,
            q.tenant,
            qi,
            exec.correct,
            exec.latency,
            exec.api_cost,
            exec.offload_rate,
            if exec.degraded { " degraded=1" } else { "" }
        ));
    }
    q.completed_at = makespan_abs;
    q.outcome = Some(exec);
}

impl<'a> Kernel<'a> {
    /// Run every job to completion. This is the engine's only event loop.
    pub(crate) fn run(self) -> KernelRun {
        let Kernel { spec, mut tenants, jobs } = self;
        let schedule = spec.schedule;
        let record_trace = spec.record_trace;
        let hedge = schedule.hedge_gate();
        let cache = schedule.cache_gate();
        if let Some(c) = cache {
            match spec.cache_sessions {
                // Fleet runs start with a cold cache so a fixed
                // (workload, seed) pair reproduces the same hit/miss/
                // eviction sequence byte-for-byte.
                CacheSessions::ResetCold => c.reset(),
                // Single-query runs are fresh sessions on a *restarting*
                // virtual clock: entries from earlier runs become
                // unconditionally available, while this run's own inserts
                // stay gated on their finish time.
                CacheSessions::EpochPerRun => c.begin_session(),
            }
        }
        if !spec.query_local {
            assert!(!tenants.is_empty(), "fleet needs at least one tenant pool");
        }
        let mut global = GlobalBudget::new(spec.global_k_cap);

        // Shared worker pools: ordered next-free index per side, O(log W)
        // claim/release (see scheduler::pool).
        // `ScheduleConfig::linear_pool_reference` selects the retained
        // linear-scan reference — identical semantics, O(W) claims — so
        // parity tests and `benches/kernel.rs` can measure the index
        // against the baseline it replaced.
        let (mut edge, mut cloud) = if schedule.linear_pool_reference {
            (
                WorkerPool::linear_reference(schedule.edge_workers),
                WorkerPool::linear_reference(schedule.cloud_workers),
            )
        } else {
            (WorkerPool::new(schedule.edge_workers), WorkerPool::new(schedule.cloud_workers))
        };

        let mut queries: Vec<QueryRun> = jobs
            .into_iter()
            .map(|j| QueryRun {
                tenant: j.tenant,
                query: j.query,
                arrival: j.arrival,
                global_index: j.global_index,
                admitted: f64::NAN,
                plan_done: f64::NAN,
                rng: j.rng,
                router: j.router,
                forced_edge: 0,
                preplanned: j.preplanned,
                plan: None,
                outcome: None,
                completed_at: f64::NAN,
            })
            .collect();

        let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
        for (i, q) in queries.iter().enumerate() {
            heap.push(Ev {
                key: EventKey { time: q.arrival, pri: PRI_CTRL, q: i, node: 0 },
                kind: EvKind::Arrival,
            });
        }

        let mut stats = RunStats {
            admission_delays: Vec::new(),
            queue_waits: Vec::new(),
            sojourns: Vec::new(),
            hedge_cancelled: 0,
            hedge_refund: 0.0,
            hedge_loser_busy: [0.0, 0.0],
            clock_monotone: true,
            fault: FaultStats::default(),
        };
        let mut trace: Vec<String> = Vec::new();
        let mut waitq: VecDeque<usize> = VecDeque::new();
        let mut active = 0usize;
        let mut dispatched: Vec<Dispatch> = Vec::new();
        let mut last_time = f64::NEG_INFINITY;
        // Observability state: `None` keeps every obs touch point below a
        // dead branch, so the observe-off loop is the uninstrumented loop.
        let mut obs: Option<ObsState> = spec.observe.clone().map(ObsState::new);

        while let Some(ev) = heap.pop() {
            if ev.key.time < last_time - 1e-9 {
                stats.clock_monotone = false;
                debug_assert!(
                    false,
                    "virtual clock moved backwards: {} < {}",
                    ev.key.time, last_time
                );
            }
            last_time = last_time.max(ev.key.time);

            // Emit every metrics snapshot due at or before this event's
            // instant, reading the state *before* the event applies.
            if let Some(o) = obs.as_mut() {
                while let Some(t) = o.snapshot_due() {
                    if t > ev.key.time {
                        break;
                    }
                    let (lookups, hits) = cache.map_or((0, 0), |c| {
                        let s = c.stats();
                        (s.lookups, s.hits)
                    });
                    obs_snapshot(
                        o,
                        t,
                        waitq.len(),
                        &edge,
                        &cloud,
                        &tenants,
                        global.k_spent,
                        lookups,
                        hits,
                    );
                }
            }

            match ev.kind {
                EvKind::Arrival => {
                    let qi = ev.key.q;
                    if record_trace {
                        trace.push(format!(
                            "t={:.6} tenant={} q={} arrive",
                            ev.key.time, queries[qi].tenant, qi
                        ));
                    }
                    if spec.admission_limit == 0 || active < spec.admission_limit {
                        active += 1;
                        admit_query(
                            qi,
                            ev.key.time,
                            &mut queries[qi],
                            spec.planner,
                            spec.executor,
                            spec.n_max,
                            &mut heap,
                            &mut stats,
                            &mut trace,
                            record_trace,
                        );
                    } else {
                        waitq.push_back(qi);
                    }
                }

                EvKind::PlanDone => {
                    let qi = ev.key.q;
                    {
                        let q = &mut queries[qi];
                        let ti = q.tenant;
                        let ps = q.plan.as_mut().expect("plan state exists after admission");
                        if record_trace {
                            trace.push(format!(
                                "t={:.6} tenant={} q={} plan nodes={}",
                                ev.key.time,
                                ti,
                                qi,
                                ps.dag.len()
                            ));
                        }
                        let chain_order =
                            if schedule.chain_mode { ps.dag.topo_order() } else { None };
                        if let Some(order) = chain_order {
                            // Chain ablation: the whole query runs sequentially
                            // on the virtual clock, bypassing shared pools
                            // (single-query semantics preserved exactly).
                            let mut chain_clock = q.plan_done;
                            for &node in &order {
                                // Fault layer: a failed attempt advances the
                                // chain clock by (consumed service + backoff)
                                // and the node re-dispatches immediately —
                                // the loop exits on the guaranteed `Done`
                                // (bounded by degradation).
                                loop {
                                    let now = chain_clock;
                                    let gctx = GroupCtx {
                                        dag: &ps.dag,
                                        latents: &ps.latents,
                                        query: &q.query,
                                        executor: spec.executor,
                                        predictor: spec.predictor,
                                        ctx: &ps.fctx,
                                        depths: &ps.depths,
                                        max_depth: ps.max_depth,
                                    };
                                    let mut route = if spec.query_local {
                                        None
                                    } else {
                                        Some(FleetRouteCtx {
                                            tenant: &mut tenants[ti],
                                            tenant_idx: ti,
                                            global: &mut global,
                                            forced_edge: &mut q.forced_edge,
                                        })
                                    };
                                    let fctx = spec.fault.as_ref().map(|m| FaultCtx {
                                        model: m,
                                        q_global: q.global_index as u64,
                                    });
                                    dispatched.clear();
                                    run_group(
                                        &gctx,
                                        now,
                                        &[node],
                                        q.plan_done,
                                        &mut ps.st,
                                        &mut q.router,
                                        &mut q.rng,
                                        &mut edge,
                                        &mut cloud,
                                        Some(&mut chain_clock),
                                        route.as_mut(),
                                        hedge,
                                        cache,
                                        fctx.as_ref(),
                                        &mut dispatched,
                                    );
                                    // Chain subtasks bypass the pools: zero wait by
                                    // construction (keeps the queue-wait summary
                                    // well-defined for chain fleets).
                                    for _ in &dispatched {
                                        stats.queue_waits.push(0.0);
                                    }
                                    if record_trace {
                                        let tail = ps.st.events.len() - dispatched.len();
                                        for (k, d) in dispatched.iter().enumerate() {
                                            let e = &ps.st.events[tail + k];
                                            let side = if e.cached {
                                                "cache"
                                            } else if e.cloud {
                                                "cloud"
                                            } else {
                                                "edge"
                                            };
                                            trace.push(format!(
                                                "t={:.6} tenant={} q={} exec node={} side={} start={:.6} finish={:.6} wait={:.6}{}",
                                                now, ti, qi, d.node, side, d.start, d.finish, 0.0,
                                                e.fault.trace_suffix()
                                            ));
                                        }
                                    }
                                    if let Some(o) = obs.as_mut() {
                                        if o.cfg.spans {
                                            let tail = ps.st.events.len() - dispatched.len();
                                            for (k, d) in dispatched.iter().enumerate() {
                                                let e = &ps.st.events[tail + k];
                                                o.spans.push(Span {
                                                    q: qi,
                                                    node: d.node,
                                                    shard: 0,
                                                    tenant: ti,
                                                    cloud: e.cloud,
                                                    worker: e.worker,
                                                    planned: q.plan_done,
                                                    queued: now,
                                                    dispatched: d.start,
                                                    finished: d.finish,
                                                    tokens: e.in_tokens,
                                                    dollars: e.api_cost,
                                                    hedged: e.hedged,
                                                    cancelled: false,
                                                    cached: e.cached,
                                                    refund: 0.0,
                                                    fault: e.fault,
                                                });
                                            }
                                        }
                                    }
                                    if !matches!(
                                        dispatched.last().map(|d| d.outcome),
                                        Some(DispatchOutcome::Retry { .. })
                                    ) {
                                        break;
                                    }
                                }
                            }
                            for d in ps.done.iter_mut() {
                                *d = true;
                            }
                            ps.completed = ps.dag.len();
                            // Hold the service slot until the chain's virtual
                            // makespan; finalization happens at that instant so
                            // admission limits see the query as in-service.
                            heap.push(Ev {
                                key: EventKey {
                                    time: chain_clock,
                                    pri: PRI_DONE,
                                    q: qi,
                                    node: 0,
                                },
                                kind: EvKind::ChainDone,
                            });
                        } else {
                            // Dependency-triggered path: seed the ready frontier.
                            let n = ps.dag.len();
                            for i in 0..n {
                                if ps.indeg[i] == 0 {
                                    ps.ready.push(EventKey::ready(q.plan_done, i));
                                    if let Some(o) = obs.as_mut() {
                                        o.ready_depth += 1;
                                    }
                                    heap.push(Ev {
                                        key: EventKey {
                                            time: q.plan_done,
                                            pri: PRI_MARKER,
                                            q: qi,
                                            node: i,
                                        },
                                        kind: EvKind::Marker,
                                    });
                                }
                            }
                            if n == 0 {
                                // Degenerate empty plan: nothing to execute;
                                // the query completes at its planning instant.
                                heap.push(Ev {
                                    key: EventKey {
                                        time: q.plan_done,
                                        pri: PRI_DONE,
                                        q: qi,
                                        node: 0,
                                    },
                                    kind: EvKind::ChainDone,
                                });
                            }
                        }
                    }
                }

                EvKind::ChainDone => {
                    let qi = ev.key.q;
                    let ti = queries[qi].tenant;
                    finalize_query(
                        qi,
                        &mut queries[qi],
                        if spec.query_local { None } else { Some(&mut tenants[ti]) },
                        spec.executor,
                        &mut stats,
                        &mut trace,
                        record_trace,
                        obs.as_mut(),
                    );
                    if let Some(next) = waitq.pop_front() {
                        admit_query(
                            next,
                            ev.key.time,
                            &mut queries[next],
                            spec.planner,
                            spec.executor,
                            spec.n_max,
                            &mut heap,
                            &mut stats,
                            &mut trace,
                            record_trace,
                        );
                    } else {
                        active -= 1;
                    }
                }

                EvKind::Cancel => {
                    let qi = ev.key.q;
                    let q = &mut queries[qi];
                    let ti = q.tenant;
                    if let Some(ps) = q.plan.as_mut() {
                        if let Some(ticket) = ps.cancel_tickets[ev.key.node].take() {
                            let mut route = if spec.query_local {
                                None
                            } else {
                                Some(FleetRouteCtx {
                                    tenant: &mut tenants[ti],
                                    tenant_idx: ti,
                                    global: &mut global,
                                    forced_edge: &mut q.forced_edge,
                                })
                            };
                            apply_cancel(
                                &ticket,
                                ev.key.time,
                                &mut ps.st,
                                &mut edge,
                                &mut cloud,
                                route.as_mut(),
                            );
                            if ticket.timeout {
                                // Fault-layer timeout: the deadline released
                                // the worker and refunded the unconsumed cost
                                // share; this is not a hedge loser, so the
                                // hedge counters and loser-busy accounting
                                // stay untouched (the attempt's own trace
                                // event already covers its busy window).
                                if record_trace {
                                    trace.push(format!(
                                        "t={:.6} tenant={} q={} timeout node={} side={} refund={:.6}",
                                        ev.key.time,
                                        ti,
                                        qi,
                                        ticket.node,
                                        if ticket.cloud { "cloud" } else { "edge" },
                                        ticket.refund_k
                                    ));
                                }
                            } else {
                                stats.hedge_cancelled += 1;
                                stats.hedge_refund += ticket.refund_k;
                                // The loser occupied its worker from start
                                // until the cancel instant (zero if cancelled
                                // pre-start).
                                let release =
                                    ev.key.time.clamp(ticket.start, ticket.reserved_until);
                                stats.hedge_loser_busy[usize::from(ticket.cloud)] +=
                                    release - ticket.start;
                                if let Some(o) = obs.as_mut() {
                                    if let Some(idx) = o.open.remove(&(qi, ev.key.node)) {
                                        o.spans[idx].finished = release;
                                        o.spans[idx].refund = ticket.refund_k;
                                    }
                                }
                                if record_trace {
                                    trace.push(format!(
                                        "t={:.6} tenant={} q={} cancel node={} side={} refund={:.6}",
                                        ev.key.time,
                                        ti,
                                        qi,
                                        ticket.node,
                                        if ticket.cloud { "cloud" } else { "edge" },
                                        ticket.refund_k
                                    ));
                                }
                            }
                        }
                    }
                }

                EvKind::Marker => {
                    let qi = ev.key.q;
                    let q = &mut queries[qi];
                    let ti = q.tenant;
                    let ps = match q.plan.as_mut() {
                        Some(p) => p,
                        None => continue, // query already finalized
                    };
                    // Stale marker: its ready entry was consumed by an earlier
                    // group at the same instant.
                    let first_time = match ps.ready.peek() {
                        Some(f) => f.time,
                        None => continue,
                    };
                    if first_time > ev.key.time + 1e-12 {
                        continue;
                    }
                    let f0 = ps.ready.pop().unwrap();
                    let mut group = vec![f0.node];
                    if schedule.batch_frontier {
                        while let Some(peek) = ps.ready.peek() {
                            if peek.time <= f0.time + 1e-12 {
                                group.push(ps.ready.pop().unwrap().node);
                            } else {
                                break;
                            }
                        }
                    }
                    if let Some(o) = obs.as_mut() {
                        o.ready_depth -= group.len();
                    }
                    let now = f0.time;
                    let gctx = GroupCtx {
                        dag: &ps.dag,
                        latents: &ps.latents,
                        query: &q.query,
                        executor: spec.executor,
                        predictor: spec.predictor,
                        ctx: &ps.fctx,
                        depths: &ps.depths,
                        max_depth: ps.max_depth,
                    };
                    let mut route = if spec.query_local {
                        None
                    } else {
                        Some(FleetRouteCtx {
                            tenant: &mut tenants[ti],
                            tenant_idx: ti,
                            global: &mut global,
                            forced_edge: &mut q.forced_edge,
                        })
                    };
                    let fctx = spec.fault.as_ref().map(|m| FaultCtx {
                        model: m,
                        q_global: q.global_index as u64,
                    });
                    dispatched.clear();
                    run_group(
                        &gctx,
                        now,
                        &group,
                        q.plan_done,
                        &mut ps.st,
                        &mut q.router,
                        &mut q.rng,
                        &mut edge,
                        &mut cloud,
                        None,
                        route.as_mut(),
                        hedge,
                        cache,
                        fctx.as_ref(),
                        &mut dispatched,
                    );
                    for d in &dispatched {
                        stats.queue_waits.push(d.start - now);
                        match d.outcome {
                            DispatchOutcome::Done => {
                                heap.push(Ev {
                                    key: EventKey {
                                        time: d.finish,
                                        pri: PRI_DONE,
                                        q: qi,
                                        node: d.node,
                                    },
                                    kind: EvKind::Done,
                                });
                            }
                            // Failed attempt: the node goes back onto the
                            // ready frontier at the backoff-delayed instant
                            // instead of completing — no `Done` fires, so
                            // dependents stay blocked until a later attempt
                            // succeeds (or degrades).
                            DispatchOutcome::Retry { at } => {
                                ps.ready.push(EventKey::ready(at, d.node));
                                if let Some(o) = obs.as_mut() {
                                    o.ready_depth += 1;
                                }
                                heap.push(Ev {
                                    key: EventKey {
                                        time: at,
                                        pri: PRI_MARKER,
                                        q: qi,
                                        node: d.node,
                                    },
                                    kind: EvKind::Marker,
                                });
                            }
                        }
                        if let Some(ticket) = &d.cancel {
                            ps.cancel_tickets[d.node] = Some(ticket.clone());
                            heap.push(Ev {
                                key: EventKey {
                                    time: d.finish,
                                    pri: PRI_CTRL,
                                    q: qi,
                                    node: d.node,
                                },
                                kind: EvKind::Cancel,
                            });
                        }
                    }
                    if record_trace {
                        let tail = ps.st.events.len() - dispatched.len();
                        for (k, d) in dispatched.iter().enumerate() {
                            let e = &ps.st.events[tail + k];
                            let side = if e.cached {
                                "cache"
                            } else if e.cloud {
                                "cloud"
                            } else {
                                "edge"
                            };
                            trace.push(format!(
                                "t={:.6} tenant={} q={} exec node={} side={} start={:.6} finish={:.6} wait={:.6}{}",
                                now,
                                ti,
                                qi,
                                d.node,
                                side,
                                d.start,
                                d.finish,
                                d.start - now,
                                e.fault.trace_suffix()
                            ));
                        }
                    }
                    if let Some(o) = obs.as_mut() {
                        if o.cfg.spans {
                            let tail = ps.st.events.len() - dispatched.len();
                            for (k, d) in dispatched.iter().enumerate() {
                                let e = &ps.st.events[tail + k];
                                o.spans.push(Span {
                                    q: qi,
                                    node: d.node,
                                    shard: 0,
                                    tenant: ti,
                                    cloud: e.cloud,
                                    worker: e.worker,
                                    planned: q.plan_done,
                                    queued: now,
                                    dispatched: d.start,
                                    finished: d.finish,
                                    tokens: e.in_tokens,
                                    dollars: e.api_cost,
                                    hedged: e.hedged,
                                    cancelled: false,
                                    cached: e.cached,
                                    refund: 0.0,
                                    fault: e.fault,
                                });
                                if let Some(ticket) = &d.cancel {
                                    if !ticket.timeout {
                                        // Losing replica of a hedged
                                        // dispatch: opened now, closed
                                        // (finish + refund) by its `Cancel`
                                        // event. Its payload is accounted on
                                        // the winner span. A fault-layer
                                        // timeout ticket is *not* a replica —
                                        // its attempt span above already
                                        // carries the timeout marker.
                                        let idx = o.spans.len();
                                        o.spans.push(Span {
                                            q: qi,
                                            node: d.node,
                                            shard: 0,
                                            tenant: ti,
                                            cloud: ticket.cloud,
                                            worker: ticket.worker,
                                            planned: q.plan_done,
                                            queued: now,
                                            dispatched: ticket.start,
                                            finished: ticket.reserved_until,
                                            tokens: 0.0,
                                            dollars: 0.0,
                                            hedged: true,
                                            cancelled: true,
                                            cached: false,
                                            refund: 0.0,
                                            fault: FaultMark::default(),
                                        });
                                        o.open.insert((qi, d.node), idx);
                                    }
                                }
                            }
                        }
                    }
                }

                EvKind::Done => {
                    let qi = ev.key.q;
                    let mut completed_query = false;
                    {
                        let q = &mut queries[qi];
                        let ti = q.tenant;
                        let ps = q.plan.as_mut().expect("plan state exists");
                        let node = ev.key.node;
                        if !ps.done[node] {
                            ps.done[node] = true;
                            for &c in ps.children.children_of(node) {
                                let c = c as usize;
                                ps.indeg[c] -= 1;
                                if ps.indeg[c] == 0 {
                                    ps.ready.push(EventKey::ready(ev.key.time, c));
                                    if let Some(o) = obs.as_mut() {
                                        o.ready_depth += 1;
                                    }
                                    heap.push(Ev {
                                        key: EventKey {
                                            time: ev.key.time,
                                            pri: PRI_MARKER,
                                            q: qi,
                                            node: c,
                                        },
                                        kind: EvKind::Marker,
                                    });
                                }
                            }
                        }
                        ps.completed += 1;
                        if record_trace {
                            trace.push(format!(
                                "t={:.6} tenant={} q={} done node={}",
                                ev.key.time, ti, qi, node
                            ));
                        }
                        if ps.completed == ps.dag.len() {
                            completed_query = true;
                        }
                    }
                    if completed_query {
                        let ti = queries[qi].tenant;
                        finalize_query(
                            qi,
                            &mut queries[qi],
                            if spec.query_local { None } else { Some(&mut tenants[ti]) },
                            spec.executor,
                            &mut stats,
                            &mut trace,
                            record_trace,
                            obs.as_mut(),
                        );
                        if let Some(next) = waitq.pop_front() {
                            admit_query(
                                next,
                                ev.key.time,
                                &mut queries[next],
                                spec.planner,
                                spec.executor,
                                spec.n_max,
                                &mut heap,
                                &mut stats,
                                &mut trace,
                                record_trace,
                            );
                        } else {
                            active -= 1;
                        }
                    }
                }
            }
        }

        // ---- Report assembly ----------------------------------------------
        let mut routers = Vec::with_capacity(queries.len());
        let mut rngs = Vec::with_capacity(queries.len());
        let results: Vec<FleetQueryResult> = queries
            .into_iter()
            .enumerate()
            .map(|(qi, q)| {
                routers.push(q.router);
                rngs.push(q.rng);
                FleetQueryResult {
                    tenant: q.tenant,
                    query_id: q.query.id,
                    arrival: q.arrival,
                    admitted: q.admitted,
                    plan_done: q.plan_done,
                    completed_at: q.completed_at,
                    forced_edge: q.forced_edge,
                    exec: q.outcome.unwrap_or_else(|| {
                        panic!("kernel query {qi} never completed (engine invariant)")
                    }),
                }
            })
            .collect();

        let horizon = results.iter().map(|r| r.completed_at).fold(0.0f64, f64::max);
        // Trailing metrics snapshots: the heap drained before the series
        // reached the horizon (the last completions land between samples).
        if let Some(o) = obs.as_mut() {
            while let Some(t) = o.snapshot_due() {
                if t > horizon {
                    break;
                }
                let (lookups, hits) = cache.map_or((0, 0), |c| {
                    let s = c.stats();
                    (s.lookups, s.hits)
                });
                obs_snapshot(
                    o,
                    t,
                    waitq.len(),
                    &edge,
                    &cloud,
                    &tenants,
                    global.k_spent,
                    lookups,
                    hits,
                );
            }
        }
        let n_decided: usize = if spec.query_local {
            results.iter().map(|r| r.exec.budget.n_decided).sum()
        } else {
            tenants.iter().map(|t| t.state.n_decided).sum()
        };
        let n_offloaded: usize = if spec.query_local {
            results.iter().map(|r| r.exec.budget.n_offloaded).sum()
        } else {
            tenants.iter().map(|t| t.state.n_offloaded).sum()
        };
        let forced_edge: usize = results.iter().map(|r| r.forced_edge).sum();
        // Winner events plus the consumed share of hedged losing replicas.
        let (mut edge_busy, mut cloud_busy) =
            (stats.hedge_loser_busy[0], stats.hedge_loser_busy[1]);
        // Chain-mode queries bypass the shared pools, so their events are not
        // pool busy time; utilization reads 0 for the chain ablation. Cached
        // hits run on no worker at all, so they are never busy time either.
        if !schedule.chain_mode {
            for r in &results {
                for e in &r.exec.events {
                    if e.cached {
                        continue;
                    }
                    if e.cloud {
                        cloud_busy += e.finish - e.start;
                    } else {
                        edge_busy += e.finish - e.start;
                    }
                }
            }
        }
        let span = horizon.max(1e-9);
        // Package the observability artifacts. Paths are sorted by query
        // index so the summary's floating-point sums are byte-stable no
        // matter the completion (or shard) order that produced them.
        let (obs_data, critical_path) = match obs {
            Some(mut o) => {
                o.paths.sort_by_key(|p| p.q);
                let cp = CriticalPathSummary::from_paths(&o.paths);
                let unclosed_spans = o.open.len();
                (
                    Some(ObsData {
                        spans: o.spans,
                        snapshots: o.snapshots,
                        paths: o.paths,
                        unclosed_spans,
                    }),
                    cp,
                )
            }
            None => (None, None),
        };
        let report = FleetReport {
            admission_delay: Summary::of_or_zero(&stats.admission_delays),
            queue_wait: Summary::of_or_zero(&stats.queue_waits),
            sojourn: Summary::of_or_zero(&stats.sojourns),
            throughput_qps: results.len() as f64 / span,
            offload_rate: if n_decided == 0 {
                0.0
            } else {
                n_offloaded as f64 / n_decided as f64
            },
            total_api_cost: if spec.query_local {
                results.iter().map(|r| r.exec.api_cost).sum()
            } else {
                global.k_spent
            },
            forced_edge,
            hedge_cancelled: stats.hedge_cancelled,
            hedge_refund: stats.hedge_refund,
            cache: cache.map(|c| c.stats()),
            // Utilization is busy time over *configured* capacity. A
            // zero-worker side carries one phantom claim slot internally
            // (the engine's historical `max(1)` padding) but has no real
            // capacity, so it reports 0.0 instead of utilization against
            // a phantom worker.
            edge_utilization: if edge.configured() == 0 {
                0.0
            } else {
                edge_busy / (span * edge.configured() as f64)
            },
            cloud_utilization: if cloud.configured() == 0 {
                0.0
            } else {
                cloud_busy / (span * cloud.configured() as f64)
            },
            clock_monotone: stats.clock_monotone,
            horizon,
            results,
            tenants,
            global,
            trace,
            obs: obs_data,
            critical_path,
            // Present iff the fault layer ran, so fault-free reports keep
            // their pre-fault bytes.
            faults: spec.fault.as_ref().map(|_| stats.fault),
        };
        KernelRun { report, routers, rngs, stats }
    }
}

/// Run a multi-tenant fleet workload against shared resources.
///
/// Planner, executor, predictor, routing policy, and per-query scheduling
/// semantics all come from `pipeline` (so a fleet with one tenant and one
/// query is exactly `pipeline.run_query_traced` with the job's RNG).
/// `tenants` are the hierarchical dollar pools (see
/// [`crate::budget::split_evenly`]); `arrivals` reference tenants by
/// index. `cfg.tenant_policies` may override the routing policy per
/// tenant. Router state is per-query (the paper's evaluation protocol);
/// `persist_router` is ignored in fleet mode.
pub fn run_fleet(
    pipeline: &HybridFlowPipeline,
    cfg: &FleetConfig,
    tenants: Vec<TenantPool>,
    arrivals: Vec<FleetArrival>,
    seed: u64,
) -> FleetReport {
    let n_tenants = tenants.len();
    let jobs: Vec<Job> = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, a)| fleet_job(pipeline, cfg, n_tenants, i, a, seed))
        .collect();
    run_fleet_jobs(pipeline, cfg, tenants, jobs).report
}

/// Build one fleet [`Job`] from an arrival. `index` is the job's position
/// in the *full* arrival list: the RNG stream is forked from
/// `(seed, index)` — never from arrival interleaving or shard assignment —
/// so a query's planned decomposition and sampled latents are identical no
/// matter how the fleet is partitioned (the sharded-run invariant).
pub(crate) fn fleet_job(
    pipeline: &HybridFlowPipeline,
    cfg: &FleetConfig,
    n_tenants: usize,
    index: usize,
    a: FleetArrival,
    seed: u64,
) -> Job {
    assert!(a.tenant < n_tenants, "arrival references unknown tenant {}", a.tenant);
    // Seed by job index, not arrival interleaving, so results are
    // exactly reproducible (same scheme as `server::serve`).
    let rng = Rng::new(seed ^ (index as u64).wrapping_mul(0x9E3779B97f4A7C15));
    // Per-tenant policy override (heterogeneous fleets); absent or
    // None falls back to the pipeline default.
    let policy = cfg
        .tenant_policies
        .get(a.tenant)
        .and_then(|p| p.clone())
        .unwrap_or_else(|| pipeline.config.policy.clone());
    let mut router = RouterState::new(policy);
    router.begin_query(false);
    Job {
        tenant: a.tenant,
        // Moved behind an Arc, never deep-copied again.
        query: Arc::new(a.query),
        arrival: a.time,
        global_index: index,
        rng,
        router,
        preplanned: None,
    }
}

/// Run pre-built fleet jobs on the kernel (fleet scope, cold cache) and
/// hand back the full [`KernelRun`] — the shared tail of [`run_fleet`]
/// and the per-shard runs in [`shard::run_fleet_sharded`].
pub(crate) fn run_fleet_jobs(
    pipeline: &HybridFlowPipeline,
    cfg: &FleetConfig,
    tenants: Vec<TenantPool>,
    jobs: Vec<Job>,
) -> KernelRun {
    let schedule = pipeline.config.schedule.clone();
    let kernel = Kernel {
        spec: KernelSpec {
            planner: Some(&pipeline.planner),
            executor: pipeline.executor.as_ref(),
            predictor: pipeline.predictor.as_ref(),
            schedule: &schedule,
            n_max: pipeline.config.n_max,
            admission_limit: cfg.admission_limit,
            record_trace: cfg.record_trace,
            query_local: false,
            global_k_cap: cfg.global_k_cap,
            cache_sessions: CacheSessions::ResetCold,
            observe: cfg.observe.clone(),
            fault: FaultModel::from_parts(cfg.faults.clone(), cfg.resilience.clone()),
        },
        tenants,
        jobs,
    };
    kernel.run()
}

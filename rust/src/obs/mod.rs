//! Deterministic observability for the unified simulation kernel: span
//! lifecycles on the virtual clock, a fleet metrics time series, and
//! realized critical-path extraction.
//!
//! Everything here is **pure data recorded off the kernel's existing
//! decisions** — enabling observability must never change a routing
//! choice, an RNG draw, or an event ordering, so the observability-off
//! run stays byte-identical to the uninstrumented kernel (pinned by the
//! golden fleet trace) and the emitted artifacts are byte-identical
//! across thread counts (spans are collected per shard and merged in
//! shard order by the deterministic cross-shard merge).
//!
//! * [`ObserveConfig`] — the `observe` block of a scenario spec: which
//!   recorders are on and the metrics sampling interval.
//! * [`Span`] — one subtask's lifecycle (planned → queued → dispatched →
//!   finished) with tenant/side/worker/token/dollar annotations, exported
//!   as Chrome trace-event JSON ([`ObsData::chrome_trace`]) loadable in
//!   Perfetto or `chrome://tracing`: one lane per worker per side per
//!   shard, plus a cache lane for zero-duration hits.
//! * [`MetricsSnapshot`] / [`metrics_jsonl`] — queue depth, admission
//!   backlog, pool occupancy, budget spend, cache hit rate, and latency
//!   quantiles sampled every `metrics_interval` virtual seconds.
//! * [`QueryPath`] / [`CriticalPathSummary`] — each query's realized
//!   critical path recovered from its completed spans (per-node slack,
//!   path latency vs. makespan), aggregated into the fleet report.

pub mod metrics;

pub use metrics::{metrics_jsonl, Histogram, MetricsSnapshot, HIST_BUCKETS};

use crate::util::json::Json;
use std::collections::BTreeSet;

/// Hard cap on emitted metrics snapshots per shard, so a tiny interval on
/// a long-horizon fleet cannot balloon a run's memory; the series simply
/// stops once the cap is reached.
pub const MAX_METRIC_SNAPSHOTS: usize = 10_000;

/// The `observe` block of a scenario spec. Absent (`None` at the engine
/// level) means fully off: the kernel takes the exact uninstrumented code
/// path and the report carries no observability sections.
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveConfig {
    /// Record per-subtask spans (and derive critical paths from them).
    pub spans: bool,
    /// Sample the metrics time series.
    pub metrics: bool,
    /// Virtual-clock seconds between metrics snapshots.
    pub metrics_interval: f64,
}

impl Default for ObserveConfig {
    fn default() -> Self {
        ObserveConfig { spans: true, metrics: true, metrics_interval: 1.0 }
    }
}

/// Synthetic Chrome-trace lane ids: edge worker `w` maps to `1 + w`,
/// cloud worker `w` to `CLOUD_LANE_BASE + w`, cache hits to
/// [`CACHE_LANE`] — disjoint ranges so one `pid` (shard) holds every lane.
pub const CLOUD_LANE_BASE: usize = 1_000_001;
pub const CACHE_LANE: usize = 2_000_001;

/// One subtask's recorded lifecycle on the virtual clock. `queued` is the
/// instant the subtask's dependencies were satisfied and it was routed
/// (the kernel routes at the head of the ready queue, so route and queue
/// coincide); `dispatched` is when a worker started it; for a cache hit
/// all three collapse onto the hit instant. A hedged subtask produces two
/// spans — the winner and the `cancelled` loser replica on the opposite
/// side, closed at its cancel event with the refunded dollars.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Global query index (rewritten from shard-local by the merge).
    pub q: usize,
    /// Subtask index within the query's DAG.
    pub node: usize,
    /// Shard that executed the span (0 for the unsharded kernel).
    pub shard: usize,
    pub tenant: usize,
    /// Executed on the cloud side (false = edge).
    pub cloud: bool,
    /// Worker index within the side's pool (0 for cache hits and
    /// chain-mode virtual execution).
    pub worker: usize,
    /// When the query's plan finished (every node's earliest possible
    /// queue time).
    pub planned: f64,
    /// Dependencies satisfied + routed.
    pub queued: f64,
    /// Worker claim start.
    pub dispatched: f64,
    /// Worker claim end (for a cancelled loser: the cancel-release time).
    pub finished: f64,
    /// Transmitted input tokens.
    pub tokens: f64,
    /// Cloud dollars charged.
    pub dollars: f64,
    pub hedged: bool,
    /// Hedge loser replica, cancelled before completion.
    pub cancelled: bool,
    /// Served from the result cache (zero-duration span on the cache
    /// lane).
    pub cached: bool,
    /// Dollars refunded on cancellation.
    pub refund: f64,
    /// Fault/resilience annotation of the attempt (`Default` = fault-free;
    /// renders no extra Chrome-trace args, so fault-off artifacts keep
    /// their pre-fault bytes).
    pub fault: crate::fault::FaultMark,
}

impl Span {
    /// Chrome-trace lane id for this span within its shard (`tid`).
    pub fn lane(&self) -> usize {
        if self.cached {
            CACHE_LANE
        } else if self.cloud {
            CLOUD_LANE_BASE + self.worker
        } else {
            1 + self.worker
        }
    }

    /// Human lane label for the `thread_name` metadata event.
    pub fn lane_name(tid: usize) -> String {
        if tid == CACHE_LANE {
            "cache".into()
        } else if tid >= CLOUD_LANE_BASE {
            format!("cloud-{}", tid - CLOUD_LANE_BASE)
        } else {
            format!("edge-{}", tid - 1)
        }
    }

    /// This span as a Chrome trace-event *complete* event (`ph: "X"`,
    /// timestamps in integer microseconds).
    fn trace_event(&self) -> Json {
        let ts = (self.dispatched * 1e6).round();
        let dur = ((self.finished - self.dispatched) * 1e6).round().max(0.0);
        let cat = if self.cached {
            "cache"
        } else if self.cloud {
            "cloud"
        } else {
            "edge"
        };
        // Fault markers are emitted only when non-default: `Json::obj`
        // sorts keys, and absent keys keep fault-free span args
        // byte-identical to the pre-fault exporter.
        let mut args = vec![
            ("cached", Json::Bool(self.cached)),
            ("cancelled", Json::Bool(self.cancelled)),
            ("dollars", Json::Num(self.dollars)),
            ("hedged", Json::Bool(self.hedged)),
            ("planned", Json::Num(self.planned)),
            ("queued", Json::Num(self.queued)),
            ("refund", Json::Num(self.refund)),
            ("tenant", Json::Num(self.tenant as f64)),
            ("tokens", Json::Num(self.tokens)),
        ];
        if !self.fault.is_default() {
            args.push(("attempt", Json::Num(f64::from(self.fault.attempt))));
            if self.fault.failed {
                args.push(("failed", Json::Bool(true)));
            }
            if self.fault.outage {
                args.push(("outage", Json::Bool(true)));
            }
            if self.fault.timeout {
                args.push(("timeout", Json::Bool(true)));
            }
            if self.fault.failed_over {
                args.push(("failover", Json::Bool(true)));
            }
            if self.fault.degraded {
                args.push(("degraded", Json::Bool(true)));
            }
        }
        Json::obj(vec![
            ("args", Json::obj(args)),
            ("cat", Json::Str(cat.into())),
            ("dur", Json::Num(dur)),
            ("name", Json::Str(format!("q{}:n{}", self.q, self.node))),
            ("ph", Json::Str("X".into())),
            ("pid", Json::Num(self.shard as f64)),
            ("tid", Json::Num(self.lane() as f64)),
            ("ts", Json::Num(ts)),
        ])
    }
}

/// One query's realized critical path, recovered from its completed spans
/// by walking back from the last-finishing node through its
/// latest-finishing parent. `slacks[i]` is how long `nodes[i]` waited
/// between becoming runnable (its predecessor's finish, or the plan
/// instant for the entry node) and being dispatched, so
/// `sum(slacks) ≈ makespan - path_latency`.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryPath {
    /// Global query index.
    pub q: usize,
    /// Critical-path node indices, entry to exit.
    pub nodes: Vec<usize>,
    /// Per-node wait (queueing + contention) along the path.
    pub slacks: Vec<f64>,
    /// Sum of service durations along the path.
    pub path_latency: f64,
    /// Last finish minus plan completion.
    pub makespan: f64,
}

/// Fleet-level aggregate of per-query critical paths, surfaced in the
/// report (`critical_path` JSON section + one render line).
#[derive(Debug, Clone, PartialEq)]
pub struct CriticalPathSummary {
    pub queries: usize,
    /// Mean critical-path length in nodes.
    pub mean_len: f64,
    pub mean_makespan: f64,
    pub mean_path_latency: f64,
    /// Mean total wait along the path (makespan minus busy time).
    pub mean_slack: f64,
    pub max_makespan: f64,
}

impl CriticalPathSummary {
    /// Aggregate a path set; `None` when no query completed with spans.
    /// Callers must pass paths in a canonical order (sorted by `q`) so
    /// the floating-point sums are byte-stable across shard layouts.
    pub fn from_paths(paths: &[QueryPath]) -> Option<CriticalPathSummary> {
        if paths.is_empty() {
            return None;
        }
        let n = paths.len() as f64;
        let mut len = 0.0;
        let mut makespan = 0.0;
        let mut latency = 0.0;
        let mut slack = 0.0;
        let mut max_makespan = 0.0f64;
        for p in paths {
            len += p.nodes.len() as f64;
            makespan += p.makespan;
            latency += p.path_latency;
            slack += p.makespan - p.path_latency;
            max_makespan = max_makespan.max(p.makespan);
        }
        Some(CriticalPathSummary {
            queries: paths.len(),
            mean_len: len / n,
            mean_makespan: makespan / n,
            mean_path_latency: latency / n,
            mean_slack: slack / n,
            max_makespan,
        })
    }

    pub fn render_line(&self) -> String {
        format!(
            "critical path: mean {:.1} nodes, busy {:.2}s of {:.2}s makespan \
             (slack {:.2}s), max makespan {:.2}s over {} queries",
            self.mean_len,
            self.mean_path_latency,
            self.mean_makespan,
            self.mean_slack,
            self.max_makespan,
            self.queries
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_makespan", Json::Num(self.max_makespan)),
            ("mean_len", Json::Num(self.mean_len)),
            ("mean_makespan", Json::Num(self.mean_makespan)),
            ("mean_path_latency", Json::Num(self.mean_path_latency)),
            ("mean_slack", Json::Num(self.mean_slack)),
            ("queries", Json::Num(self.queries as f64)),
        ])
    }
}

/// Everything the observability layer recorded during one run: spans,
/// metrics snapshots, per-query critical paths, and the open-span leak
/// counter (0 on a healthy run — every opened span closed exactly once).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObsData {
    pub spans: Vec<Span>,
    pub snapshots: Vec<MetricsSnapshot>,
    /// Sorted by `q` (the merge re-sorts after rewriting shard-local
    /// indices) so downstream aggregation is shard-layout invariant.
    pub paths: Vec<QueryPath>,
    /// Spans opened but never closed (hedge losers whose cancel event
    /// never fired); the fuzz harness pins this to 0.
    pub unclosed_spans: usize,
}

impl ObsData {
    /// The span set as a Chrome trace-event JSON document:
    /// `{"displayTimeUnit": .., "traceEvents": [..]}` with one
    /// `thread_name` metadata event (`ph: "M"`) per populated lane
    /// followed by the complete events (`ph: "X"`) sorted by dispatch
    /// time. Load the rendered text in Perfetto or `chrome://tracing`.
    pub fn chrome_trace(&self) -> Json {
        let mut lanes: BTreeSet<(usize, usize)> = BTreeSet::new();
        for s in &self.spans {
            lanes.insert((s.shard, s.lane()));
        }
        let mut events: Vec<Json> = Vec::with_capacity(lanes.len() + self.spans.len());
        for (pid, tid) in &lanes {
            events.push(Json::obj(vec![
                ("args", Json::obj(vec![("name", Json::Str(Span::lane_name(*tid)))])),
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(*pid as f64)),
                ("tid", Json::Num(*tid as f64)),
            ]));
        }
        let mut spans: Vec<&Span> = self.spans.iter().collect();
        spans.sort_by(|a, b| {
            a.dispatched
                .total_cmp(&b.dispatched)
                .then(a.shard.cmp(&b.shard))
                .then(a.q.cmp(&b.q))
                .then(a.node.cmp(&b.node))
                .then(a.cancelled.cmp(&b.cancelled))
        });
        for s in spans {
            events.push(s.trace_event());
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::Str("ms".into())),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// Pretty-printed [`ObsData::chrome_trace`] text with a trailing
    /// newline — the exact bytes `--trace-out` writes.
    pub fn chrome_trace_text(&self) -> String {
        let mut s = self.chrome_trace().to_string_pretty();
        s.push('\n');
        s
    }

    /// The metrics series as JSONL — the exact bytes `--metrics-out`
    /// writes.
    pub fn metrics_jsonl(&self) -> String {
        metrics_jsonl(&self.snapshots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(q: usize, node: usize, cloud: bool, worker: usize, t0: f64) -> Span {
        Span {
            q,
            node,
            shard: 0,
            tenant: q % 2,
            cloud,
            worker,
            planned: t0 - 0.5,
            queued: t0 - 0.25,
            dispatched: t0,
            finished: t0 + 1.0,
            tokens: 120.0,
            dollars: if cloud { 0.001 } else { 0.0 },
            hedged: false,
            cancelled: false,
            cached: false,
            refund: 0.0,
            fault: crate::fault::FaultMark::default(),
        }
    }

    #[test]
    fn lanes_are_disjoint_and_named() {
        let edge = span(0, 0, false, 3, 1.0);
        let cloud = span(0, 1, true, 3, 1.0);
        let mut hit = span(0, 2, false, 7, 1.0);
        hit.cached = true;
        assert_eq!(edge.lane(), 4);
        assert_eq!(cloud.lane(), CLOUD_LANE_BASE + 3);
        assert_eq!(hit.lane(), CACHE_LANE);
        assert_eq!(Span::lane_name(edge.lane()), "edge-3");
        assert_eq!(Span::lane_name(cloud.lane()), "cloud-3");
        assert_eq!(Span::lane_name(CACHE_LANE), "cache");
    }

    #[test]
    fn chrome_trace_shape_and_roundtrip() {
        let data = ObsData {
            spans: vec![span(1, 0, false, 0, 2.0), span(0, 0, true, 1, 1.0)],
            ..Default::default()
        };
        let text = data.chrome_trace_text();
        let j = Json::parse(&text).expect("trace parses");
        let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
        assert_eq!(events.len(), 4, "2 lane metadata + 2 complete events");
        // Metadata first, then X events sorted by ts.
        assert_eq!(events[0].get("ph").and_then(Json::as_str), Some("M"));
        assert_eq!(events[1].get("ph").and_then(Json::as_str), Some("M"));
        assert_eq!(events[2].get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(events[2].get("ts").and_then(Json::as_f64), Some(1e6));
        assert_eq!(events[2].get("dur").and_then(Json::as_f64), Some(1e6));
        assert_eq!(events[2].get("name").and_then(Json::as_str), Some("q0:n0"));
        assert_eq!(events[3].get("ts").and_then(Json::as_f64), Some(2e6));
        // Canonical writer: parse -> pretty-print is a byte fixpoint.
        let mut again = j.to_string_pretty();
        again.push('\n');
        assert_eq!(again, text, "trace text round-trips through util::json");
    }

    #[test]
    fn critical_path_summary_aggregates() {
        let paths = vec![
            QueryPath {
                q: 0,
                nodes: vec![0, 2],
                slacks: vec![0.0, 0.5],
                path_latency: 2.0,
                makespan: 2.5,
            },
            QueryPath {
                q: 1,
                nodes: vec![0, 1, 3],
                slacks: vec![0.0, 0.0, 1.5],
                path_latency: 3.0,
                makespan: 4.5,
            },
        ];
        let s = CriticalPathSummary::from_paths(&paths).unwrap();
        assert_eq!(s.queries, 2);
        assert!((s.mean_len - 2.5).abs() < 1e-12);
        assert!((s.mean_makespan - 3.5).abs() < 1e-12);
        assert!((s.mean_slack - 1.0).abs() < 1e-12);
        assert_eq!(s.max_makespan, 4.5);
        assert!(s.render_line().contains("over 2 queries"));
        assert!(CriticalPathSummary::from_paths(&[]).is_none());
    }
}

//! Shared metrics primitives: the repo's single log-spaced histogram
//! implementation and the fleet metrics time-series snapshot.
//!
//! [`Histogram`] used to live in `server::telemetry`; it moved here so the
//! wall-clock serving telemetry and the virtual-clock observability layer
//! record into the exact same buckets (`server::telemetry` re-exports it,
//! so the old path keeps working). [`MetricsSnapshot`] is one row of the
//! kernel's time series: the queue/pool/budget/cache gauges sampled at a
//! configurable virtual-clock interval, serialized one compact JSON object
//! per line by [`metrics_jsonl`].

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram: buckets at 0.1ms * 2^k, k in 0..=N.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum_micros: AtomicU64,
    count: AtomicU64,
}

pub const HIST_BUCKETS: usize = 20; // 0.1ms .. ~52s

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..=HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_micros: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn bucket_index(secs: f64) -> usize {
        let ratio = (secs / 1e-4).max(1.0);
        (ratio.log2().floor() as usize).min(HIST_BUCKETS)
    }

    pub fn record(&self, secs: f64) {
        self.buckets[Self::bucket_index(secs)].fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return f64::NAN;
        }
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6 / c as f64
    }

    /// Approximate quantile from bucket boundaries (upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (k, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1e-4 * 2f64.powi(k as i32 + 1);
            }
        }
        f64::INFINITY
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// One row of the kernel's metrics time series: the state of a shard's
/// queues, pools, budgets, cache, and completed-query latency histogram at
/// virtual time `t` (before any event at that instant is processed). The
/// latency columns come from the shared [`Histogram`] and guard the
/// zero-completion case to 0.0 so JSONL rows never carry `null`.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Virtual-clock sample time.
    pub t: f64,
    /// Shard that observed this row (0 for the unsharded kernel).
    pub shard: usize,
    /// Subtasks ready to dispatch across all in-flight queries.
    pub ready_depth: usize,
    /// Arrivals waiting for an admission slot.
    pub admission_backlog: usize,
    /// Edge workers busy at `t` (next-free strictly after `t`).
    pub edge_busy: usize,
    /// Cloud workers busy at `t`.
    pub cloud_busy: usize,
    /// Cumulative fleet-wide cloud dollars spent.
    pub global_spent: f64,
    /// Cumulative per-tenant cloud dollars spent (spec order).
    pub tenant_spent: Vec<f64>,
    /// Cumulative result-cache probes (0 when no cache is attached).
    pub cache_lookups: u64,
    /// Cumulative result-cache hits.
    pub cache_hits: u64,
    /// Queries finished so far.
    pub completed: u64,
    /// Mean / p50 / p99 of completed-query sojourn, 0.0 until the first
    /// completion.
    pub latency_mean: f64,
    pub latency_p50: f64,
    pub latency_p99: f64,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let hit_rate = if self.cache_lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.cache_lookups as f64
        };
        Json::obj(vec![
            ("admission_backlog", Json::Num(self.admission_backlog as f64)),
            ("cache_hit_rate", Json::Num(hit_rate)),
            ("cache_hits", Json::Num(self.cache_hits as f64)),
            ("cache_lookups", Json::Num(self.cache_lookups as f64)),
            ("cloud_busy", Json::Num(self.cloud_busy as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("edge_busy", Json::Num(self.edge_busy as f64)),
            ("global_spent", Json::Num(self.global_spent)),
            ("latency_mean", Json::Num(self.latency_mean)),
            ("latency_p50", Json::Num(self.latency_p50)),
            ("latency_p99", Json::Num(self.latency_p99)),
            ("ready_depth", Json::Num(self.ready_depth as f64)),
            ("shard", Json::Num(self.shard as f64)),
            ("t", Json::Num(self.t)),
            ("tenant_spent", Json::from_f64_slice(&self.tenant_spent)),
        ])
    }
}

/// Serialize a snapshot series as JSONL: one compact, sorted-key JSON
/// object per line, in series order. Byte-deterministic given the series.
pub fn metrics_jsonl(snapshots: &[MetricsSnapshot]) -> String {
    let mut out = String::new();
    for s in snapshots {
        out.push_str(&s.to_json().to_string());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(t: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            t,
            shard: 0,
            ready_depth: 3,
            admission_backlog: 1,
            edge_busy: 2,
            cloud_busy: 4,
            global_spent: 0.25,
            tenant_spent: vec![0.1, 0.15],
            cache_lookups: 0,
            cache_hits: 0,
            completed: 0,
            latency_mean: 0.0,
            latency_p50: 0.0,
            latency_p99: 0.0,
        }
    }

    #[test]
    fn snapshot_json_guards_zero_lookups() {
        let j = snap(2.0).to_json();
        assert_eq!(j.get("cache_hit_rate").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.get("latency_mean").and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.get("t").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn jsonl_is_one_parseable_object_per_line() {
        let text = metrics_jsonl(&[snap(0.0), snap(1.0)]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).expect("each line parses");
            assert!(j.get("ready_depth").is_some());
        }
        assert!(text.ends_with('\n'));
    }
}

//! Budget accounting at three scopes:
//! * per-query [`BudgetState`] — cumulative normalized cost `C_used(t)`
//!   (Eq. 1/24), raw API and latency consumption for the adaptive threshold
//!   of Eq. 27, and snapshots for trace events;
//! * per-tenant [`TenantPool`] — a dollar allotment plus the tenant's
//!   aggregated `BudgetState` across all of its in-flight queries (the
//!   fleet simulator routes against this state, so Eq. 8's `C_used(t)` is
//!   fleet-level rather than query-local);
//! * fleet-wide [`GlobalBudget`] — the shared dollar ceiling that tenant
//!   pools draw from.

use crate::config::simparams::SimParams;

/// Evolving resource state of one query's execution.
///
/// Plain-old-data (`Copy`): five machine words, no heap state. The
/// scheduler's decision path takes a [`snapshot`](BudgetState::snapshot)
/// of this state on every routing decision (the bandit's delayed feedback
/// needs the budget as seen at decision time), so staying `Copy` keeps
/// that per-decision capture a stack copy.
#[derive(Debug, Clone, Copy)]
pub struct BudgetState {
    /// Cumulative normalized cost `sum r_j c_j` (Eq. 8's second input).
    pub c_used: f64,
    /// Cumulative cloud API dollars (`k_used` of Eq. 27).
    pub k_used: f64,
    /// Cumulative latency seconds attributed so far (`l_used` of Eq. 27).
    /// Under the virtual clock this is the current makespan frontier.
    pub l_used: f64,
    /// Offload decisions so far (for offload-rate metrics).
    pub n_offloaded: usize,
    pub n_decided: usize,
}

impl BudgetState {
    pub fn new() -> BudgetState {
        BudgetState { c_used: 0.0, k_used: 0.0, l_used: 0.0, n_offloaded: 0, n_decided: 0 }
    }

    /// Normalized per-subtask offloading cost `c_i` (Eq. 1 / Eq. 24):
    /// `clip((dl / l_max_sub + dk / k_max_sub) / 2, 0, 1)`.
    pub fn normalized_cost(sp: &SimParams, dl: f64, dk: f64) -> f64 {
        (0.5 * dl / sp.l_max_sub + 0.5 * dk / sp.k_max_sub).clamp(0.0, 1.0)
    }

    /// Record an edge decision (free, but counted for offload rate).
    pub fn record_edge(&mut self) {
        self.n_decided += 1;
    }

    /// Record a cloud decision with its realized marginal costs.
    pub fn record_cloud(&mut self, sp: &SimParams, dl: f64, dk: f64) {
        let c = Self::normalized_cost(sp, dl, dk);
        self.c_used += c;
        self.k_used += dk;
        self.n_offloaded += 1;
        self.n_decided += 1;
    }

    /// Record speculative (hedged) cloud spend without counting a routing
    /// decision: the decision's offload/decided counters are attributed to
    /// the winning replica, but the speculative call's dollars and
    /// normalized cost burn from the moment it is dispatched.
    pub fn record_hedge_spend(&mut self, c: f64, dk: f64) {
        self.c_used += c;
        self.k_used += dk;
    }

    /// Refund the unconsumed part of a cancelled speculative call.
    /// Saturating at zero: a refund can never drive spend negative, even
    /// if accounting scopes disagree transiently.
    pub fn refund(&mut self, c: f64, dk: f64) {
        self.c_used = (self.c_used - c).max(0.0);
        self.k_used = (self.k_used - dk).max(0.0);
    }

    /// Advance the attributed latency frontier (virtual clock time).
    pub fn advance_latency(&mut self, t: f64) {
        self.l_used = self.l_used.max(t);
    }

    /// Cheap decision-time snapshot: a stack copy of this plain-old-data
    /// state (the routing hot path captures one per decision).
    pub fn snapshot(&self) -> BudgetState {
        *self
    }

    pub fn offload_rate(&self) -> f64 {
        if self.n_decided == 0 {
            0.0
        } else {
            self.n_offloaded as f64 / self.n_decided as f64
        }
    }
}

impl Default for BudgetState {
    fn default() -> Self {
        Self::new()
    }
}

/// One tenant's share of the fleet budget: a cloud-dollar allotment plus
/// the aggregated resource state of every query the tenant has run.
///
/// The spend check is a pre-decision gate (`k_used < k_cap`), so a single
/// cloud call may overshoot the cap by at most its own cost — the same
/// semantics as per-call API metering.
#[derive(Debug, Clone)]
pub struct TenantPool {
    pub name: String,
    /// Cloud-dollar allotment (`f64::INFINITY` = uncapped).
    pub k_cap: f64,
    /// Aggregated budget state across the tenant's queries.
    pub state: BudgetState,
}

impl TenantPool {
    pub fn new(name: &str, k_cap: f64) -> TenantPool {
        TenantPool { name: name.to_string(), k_cap, state: BudgetState::new() }
    }

    pub fn unlimited(name: &str) -> TenantPool {
        TenantPool::new(name, f64::INFINITY)
    }

    /// Whether another cloud call may start (pre-decision gate).
    pub fn can_spend(&self) -> bool {
        self.state.k_used < self.k_cap
    }

    pub fn remaining(&self) -> f64 {
        (self.k_cap - self.state.k_used).max(0.0)
    }
}

/// Fleet-wide dollar ceiling that tenant pools draw from.
#[derive(Debug, Clone)]
pub struct GlobalBudget {
    pub k_cap: f64,
    pub k_spent: f64,
}

impl GlobalBudget {
    pub fn new(k_cap: f64) -> GlobalBudget {
        GlobalBudget { k_cap, k_spent: 0.0 }
    }

    pub fn unlimited() -> GlobalBudget {
        GlobalBudget::new(f64::INFINITY)
    }

    pub fn can_spend(&self) -> bool {
        self.k_spent < self.k_cap
    }

    pub fn record(&mut self, dk: f64) {
        self.k_spent += dk;
    }

    /// Refund a cancelled speculative call (saturating at zero).
    pub fn refund(&mut self, dk: f64) {
        self.k_spent = (self.k_spent - dk).max(0.0);
    }

    pub fn remaining(&self) -> f64 {
        (self.k_cap - self.k_spent).max(0.0)
    }
}

/// Carve a global dollar budget into equal per-tenant pools (the simplest
/// hierarchical allotment; callers can also build pools by hand for
/// weighted shares).
pub fn split_evenly(global_k_cap: f64, names: &[&str]) -> Vec<TenantPool> {
    let n = names.len().max(1) as f64;
    names
        .iter()
        .map(|name| {
            let share = if global_k_cap.is_finite() { global_k_cap / n } else { f64::INFINITY };
            TenantPool::new(name, share)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_cost_formula() {
        let sp = SimParams::default();
        // dl = 5s of l_max 10 -> 0.25; dk = 0.01 of k_max 0.02 -> 0.25.
        let c = BudgetState::normalized_cost(&sp, 5.0, 0.01);
        assert!((c - 0.5).abs() < 1e-12);
        // Clipped at 1.
        assert_eq!(BudgetState::normalized_cost(&sp, 100.0, 1.0), 1.0);
        // Non-negative.
        assert_eq!(BudgetState::normalized_cost(&sp, -3.0, 0.0), 0.0);
    }

    #[test]
    fn accumulation_and_rates() {
        let sp = SimParams::default();
        let mut b = BudgetState::new();
        b.record_edge();
        b.record_cloud(&sp, 2.0, 0.004);
        b.record_cloud(&sp, 4.0, 0.002);
        assert_eq!(b.n_decided, 3);
        assert_eq!(b.n_offloaded, 2);
        assert!((b.offload_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((b.k_used - 0.006).abs() < 1e-12);
        let expect_c = BudgetState::normalized_cost(&sp, 2.0, 0.004)
            + BudgetState::normalized_cost(&sp, 4.0, 0.002);
        assert!((b.c_used - expect_c).abs() < 1e-12);
    }

    #[test]
    fn latency_frontier_is_monotone() {
        let mut b = BudgetState::new();
        b.advance_latency(3.0);
        b.advance_latency(1.5); // earlier event cannot move it back
        assert_eq!(b.l_used, 3.0);
        b.advance_latency(7.0);
        assert_eq!(b.l_used, 7.0);
    }

    #[test]
    fn empty_offload_rate_zero() {
        assert_eq!(BudgetState::new().offload_rate(), 0.0);
    }

    #[test]
    fn hedge_spend_and_refund_roundtrip() {
        let mut b = BudgetState::new();
        b.record_hedge_spend(0.3, 0.004);
        assert_eq!(b.n_decided, 0, "speculative spend is not a decision");
        assert_eq!(b.n_offloaded, 0);
        assert!((b.c_used - 0.3).abs() < 1e-12);
        assert!((b.k_used - 0.004).abs() < 1e-12);
        // Partial refund leaves the consumed share.
        b.refund(0.1, 0.001);
        assert!((b.c_used - 0.2).abs() < 1e-12);
        assert!((b.k_used - 0.003).abs() < 1e-12);
        // Over-refund saturates at zero instead of going negative.
        b.refund(10.0, 10.0);
        assert_eq!(b.c_used, 0.0);
        assert_eq!(b.k_used, 0.0);
    }

    #[test]
    fn global_refund_saturates() {
        let mut g = GlobalBudget::new(0.02);
        g.record(0.01);
        g.refund(0.004);
        assert!((g.k_spent - 0.006).abs() < 1e-12);
        g.refund(1.0);
        assert_eq!(g.k_spent, 0.0);
        assert!(g.can_spend());
    }

    #[test]
    fn tenant_pool_gates_on_cap() {
        let sp = SimParams::default();
        let mut t = TenantPool::new("acme", 0.01);
        assert!(t.can_spend());
        assert_eq!(t.remaining(), 0.01);
        t.state.record_cloud(&sp, 1.0, 0.008);
        assert!(t.can_spend());
        t.state.record_cloud(&sp, 1.0, 0.005); // overshoot allowed once
        assert!(!t.can_spend());
        assert_eq!(t.remaining(), 0.0);
        assert!(TenantPool::unlimited("free").can_spend());
    }

    #[test]
    fn global_budget_accumulates() {
        let mut g = GlobalBudget::new(0.02);
        assert!(g.can_spend());
        g.record(0.015);
        assert!(g.can_spend());
        assert!((g.remaining() - 0.005).abs() < 1e-12);
        g.record(0.01);
        assert!(!g.can_spend());
        assert_eq!(g.remaining(), 0.0);
        assert!(GlobalBudget::unlimited().can_spend());
    }

    #[test]
    fn split_evenly_partitions_global() {
        let pools = split_evenly(0.06, &["a", "b", "c"]);
        assert_eq!(pools.len(), 3);
        for p in &pools {
            assert!((p.k_cap - 0.02).abs() < 1e-12);
            assert_eq!(p.state.n_decided, 0);
        }
        let unlimited = split_evenly(f64::INFINITY, &["x"]);
        assert!(unlimited[0].k_cap.is_infinite());
    }
}

//! Per-query budget accounting: cumulative normalized cost `C_used(t)`
//! (Eq. 1/24), raw API and latency consumption for the adaptive threshold
//! of Eq. 27, and snapshots for trace events.

use crate::config::simparams::SimParams;

/// Evolving resource state of one query's execution.
#[derive(Debug, Clone)]
pub struct BudgetState {
    /// Cumulative normalized cost `sum r_j c_j` (Eq. 8's second input).
    pub c_used: f64,
    /// Cumulative cloud API dollars (`k_used` of Eq. 27).
    pub k_used: f64,
    /// Cumulative latency seconds attributed so far (`l_used` of Eq. 27).
    /// Under the virtual clock this is the current makespan frontier.
    pub l_used: f64,
    /// Offload decisions so far (for offload-rate metrics).
    pub n_offloaded: usize,
    pub n_decided: usize,
}

impl BudgetState {
    pub fn new() -> BudgetState {
        BudgetState { c_used: 0.0, k_used: 0.0, l_used: 0.0, n_offloaded: 0, n_decided: 0 }
    }

    /// Normalized per-subtask offloading cost `c_i` (Eq. 1 / Eq. 24):
    /// `clip((dl / l_max_sub + dk / k_max_sub) / 2, 0, 1)`.
    pub fn normalized_cost(sp: &SimParams, dl: f64, dk: f64) -> f64 {
        (0.5 * dl / sp.l_max_sub + 0.5 * dk / sp.k_max_sub).clamp(0.0, 1.0)
    }

    /// Record an edge decision (free, but counted for offload rate).
    pub fn record_edge(&mut self) {
        self.n_decided += 1;
    }

    /// Record a cloud decision with its realized marginal costs.
    pub fn record_cloud(&mut self, sp: &SimParams, dl: f64, dk: f64) {
        let c = Self::normalized_cost(sp, dl, dk);
        self.c_used += c;
        self.k_used += dk;
        self.n_offloaded += 1;
        self.n_decided += 1;
    }

    /// Advance the attributed latency frontier (virtual clock time).
    pub fn advance_latency(&mut self, t: f64) {
        self.l_used = self.l_used.max(t);
    }

    pub fn offload_rate(&self) -> f64 {
        if self.n_decided == 0 {
            0.0
        } else {
            self.n_offloaded as f64 / self.n_decided as f64
        }
    }
}

impl Default for BudgetState {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_cost_formula() {
        let sp = SimParams::default();
        // dl = 5s of l_max 10 -> 0.25; dk = 0.01 of k_max 0.02 -> 0.25.
        let c = BudgetState::normalized_cost(&sp, 5.0, 0.01);
        assert!((c - 0.5).abs() < 1e-12);
        // Clipped at 1.
        assert_eq!(BudgetState::normalized_cost(&sp, 100.0, 1.0), 1.0);
        // Non-negative.
        assert_eq!(BudgetState::normalized_cost(&sp, -3.0, 0.0), 0.0);
    }

    #[test]
    fn accumulation_and_rates() {
        let sp = SimParams::default();
        let mut b = BudgetState::new();
        b.record_edge();
        b.record_cloud(&sp, 2.0, 0.004);
        b.record_cloud(&sp, 4.0, 0.002);
        assert_eq!(b.n_decided, 3);
        assert_eq!(b.n_offloaded, 2);
        assert!((b.offload_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((b.k_used - 0.006).abs() < 1e-12);
        let expect_c = BudgetState::normalized_cost(&sp, 2.0, 0.004)
            + BudgetState::normalized_cost(&sp, 4.0, 0.002);
        assert!((b.c_used - expect_c).abs() < 1e-12);
    }

    #[test]
    fn latency_frontier_is_monotone() {
        let mut b = BudgetState::new();
        b.advance_latency(3.0);
        b.advance_latency(1.5); // earlier event cannot move it back
        assert_eq!(b.l_used, 3.0);
        b.advance_latency(7.0);
        assert_eq!(b.l_used, 7.0);
    }

    #[test]
    fn empty_offload_rate_zero() {
        assert_eq!(BudgetState::new().offload_rate(), 0.0);
    }
}

//! 0–1 knapsack oracle (App. B.1): the offline-optimal subtask allocation
//! `max sum r_i dq_i  s.t.  sum r_i c_i <= C_max`.
//!
//! Used as the evaluation upper bound for routing quality and to test the
//! Lagrangian-threshold structure (Eq. 6). Plans are small (n <= 7), so the
//! exact exponential enumeration is cheap; a discretized DP handles the
//! larger profiling sets; a greedy ratio heuristic provides the classic
//! approximation for comparison benches.

/// Exact solution by exhaustive enumeration (n <= 25 guarded).
pub fn solve_exact(values: &[f64], weights: &[f64], capacity: f64) -> (f64, Vec<bool>) {
    let n = values.len();
    assert_eq!(n, weights.len());
    assert!(n <= 25, "exhaustive knapsack limited to n<=25, got {n}");
    let mut best_val = 0.0;
    let mut best_mask = 0usize;
    for mask in 0..(1usize << n) {
        let mut v = 0.0;
        let mut w = 0.0;
        for i in 0..n {
            if mask & (1 << i) != 0 {
                v += values[i];
                w += weights[i];
            }
        }
        if w <= capacity + 1e-12 && v > best_val {
            best_val = v;
            best_mask = mask;
        }
    }
    let pick = (0..n).map(|i| best_mask & (1 << i) != 0).collect();
    (best_val, pick)
}

/// Discretized DP for larger instances: weights quantized to `resolution`
/// (conservative rounding up, so the returned set always fits the true
/// capacity).
pub fn solve_dp(values: &[f64], weights: &[f64], capacity: f64, resolution: f64) -> (f64, Vec<bool>) {
    let n = values.len();
    assert_eq!(n, weights.len());
    let cap_q = (capacity / resolution).floor() as usize;
    let wq: Vec<usize> = weights.iter().map(|w| (w / resolution).ceil() as usize).collect();
    // dp[w] = best value using weight exactly <= w; keep choice bits.
    let mut dp = vec![0.0f64; cap_q + 1];
    let mut choice = vec![vec![false; n]; cap_q + 1];
    for i in 0..n {
        if values[i] <= 0.0 {
            continue;
        }
        for w in (wq[i]..=cap_q).rev() {
            let cand = dp[w - wq[i]] + values[i];
            if cand > dp[w] {
                dp[w] = cand;
                choice[w] = choice[w - wq[i]].clone();
                choice[w][i] = true;
            }
        }
    }
    let best_w = (0..=cap_q)
        .max_by(|&a, &b| dp[a].total_cmp(&dp[b]))
        .unwrap_or(0);
    (dp[best_w], choice[best_w].clone())
}

/// Greedy benefit–cost ratio heuristic — exactly the Lagrangian threshold
/// family of Eq. 6: sort by `dq_i / c_i`, take while budget lasts.
pub fn solve_greedy_ratio(values: &[f64], weights: &[f64], capacity: f64) -> (f64, Vec<bool>) {
    let n = values.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        let ra = values[a] / weights[a].max(1e-12);
        let rb = values[b] / weights[b].max(1e-12);
        // total_cmp: a NaN utility must not abort the solve (it sorts to
        // the low-priority end of the descending ratio order).
        rb.total_cmp(&ra)
    });
    let mut pick = vec![false; n];
    let mut used = 0.0;
    let mut total = 0.0;
    for &i in &idx {
        if values[i] <= 0.0 {
            continue;
        }
        if used + weights[i] <= capacity + 1e-12 {
            pick[i] = true;
            used += weights[i];
            total += values[i];
        }
    }
    (total, pick)
}

/// The threshold rule of Eq. 6 for a fixed shadow price `lambda`:
/// offload iff `dq_i / c_i > lambda`.
pub fn threshold_allocation(values: &[f64], weights: &[f64], lambda: f64) -> Vec<bool> {
    values
        .iter()
        .zip(weights)
        .map(|(&v, &w)| v / w.max(1e-12) > lambda)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;

    #[test]
    fn exact_solves_textbook_instance() {
        let v = [60.0, 100.0, 120.0];
        let w = [0.10, 0.20, 0.30];
        let (best, pick) = solve_exact(&v, &w, 0.5);
        assert_eq!(best, 220.0);
        assert_eq!(pick, vec![false, true, true]);
    }

    #[test]
    fn zero_capacity_picks_nothing() {
        let (best, pick) = solve_exact(&[1.0, 2.0], &[0.5, 0.5], 0.0);
        assert_eq!(best, 0.0);
        assert!(pick.iter().all(|&p| !p));
    }

    #[test]
    fn dp_matches_exact_on_random_instances() {
        forall("dp == exact (fine grid)", 60, |g| {
            let n = g.usize_in(1..10);
            let v: Vec<f64> = (0..n).map(|_| g.f64_in(0.0..1.0)).collect();
            // Weights on the resolution grid so DP rounding is exact.
            let w: Vec<f64> = (0..n).map(|_| (g.usize_in(1..100) as f64) * 1e-3).collect();
            let cap = g.f64_in(0.0..2.0);
            let (ve, _) = solve_exact(&v, &w, cap);
            let (vd, pick) = solve_dp(&v, &w, cap, 1e-3);
            let wd: f64 = pick.iter().zip(&w).filter(|(p, _)| **p).map(|(_, w)| w).sum();
            (ve - vd).abs() < 1e-9 && wd <= cap + 1e-9
        });
    }

    #[test]
    fn greedy_never_beats_exact_and_respects_capacity() {
        forall("greedy <= exact", 80, |g| {
            let n = g.usize_in(1..12);
            let v: Vec<f64> = (0..n).map(|_| g.f64_in(0.0..1.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| g.f64_in(0.01..0.5)).collect();
            let cap = g.f64_in(0.0..1.5);
            let (ve, _) = solve_exact(&v, &w, cap);
            let (vg, pick) = solve_greedy_ratio(&v, &w, cap);
            let wg: f64 = pick.iter().zip(&w).filter(|(p, _)| **p).map(|(_, w)| w).sum();
            vg <= ve + 1e-9 && wg <= cap + 1e-9
        });
    }

    #[test]
    fn threshold_rule_monotone_in_lambda() {
        let v = [0.3, 0.1, 0.5, 0.05];
        let w = [0.2, 0.2, 0.25, 0.3];
        let count = |lam: f64| {
            threshold_allocation(&v, &w, lam).iter().filter(|&&b| b).count()
        };
        assert!(count(0.0) >= count(0.5));
        assert!(count(0.5) >= count(1.5));
        assert!(count(1.5) >= count(5.0));
        assert_eq!(count(1e9), 0);
    }

    #[test]
    fn lagrangian_threshold_achieves_exact_for_some_lambda() {
        // For instances where the LP relaxation is tight (no fractional
        // item), some lambda reproduces the exact optimum. Verify a sweep
        // finds a threshold allocation matching exact value on easy cases.
        let v = [0.6, 0.2, 0.15];
        let w = [0.3, 0.2, 0.15];
        let cap = 0.65;
        let (ve, _) = solve_exact(&v, &w, cap);
        let mut best = 0.0f64;
        for k in 0..200 {
            let lam = k as f64 * 0.02;
            let pick = threshold_allocation(&v, &w, lam);
            let wsum: f64 = pick.iter().zip(&w).filter(|(p, _)| **p).map(|(_, w)| w).sum();
            if wsum <= cap {
                let vsum: f64 = pick.iter().zip(&v).filter(|(p, _)| **p).map(|(_, v)| v).sum();
                best = best.max(vsum);
            }
        }
        assert!((best - ve).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "limited")]
    fn exact_guards_large_n() {
        let v = vec![1.0; 30];
        let w = vec![0.1; 30];
        let _ = solve_exact(&v, &w, 1.0);
    }
}

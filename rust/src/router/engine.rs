//! The `Router` seam: every routing policy is a [`Router`] implementation
//! behind one `route(ctx) -> Decision` surface, so the scheduler dispatches
//! through `dyn Router` and never matches on policy variants.
//!
//! [`crate::router::RoutePolicy`] stays the *declarative* layer — a
//! cloneable config that [`RoutePolicy::build`](crate::router::RoutePolicy::build)
//! resolves into a live router. This split is what lets `FleetConfig` carry
//! per-tenant policy overrides (heterogeneous tenants in one fleet) and
//! lets new policies ship without touching the scheduler.
//!
//! Determinism contract: `route` must consume the caller's RNG exactly as
//! many times as the policy semantics require (`Random` draws one
//! Bernoulli; every other built-in draws nothing), because the scheduler's
//! reproducibility guarantees depend on call-for-call stream alignment.

use super::bandit::LinUcb;
use super::threshold::Threshold;
use crate::budget::BudgetState;
use crate::config::simparams::SimParams;
use crate::util::rng::Rng;

/// Everything a router may observe at one decision point (Eq. 8's online
/// information set): the predicted utility, the subtask's normalized DAG
/// position, and whichever budget scope the caller routes against
/// (query-local in single-query mode, tenant-aggregated in fleet mode).
pub struct RouteCtx<'a> {
    pub sp: &'a SimParams,
    /// Predicted utility `u_hat` from the predictor.
    pub u_hat: f64,
    /// Topological position in [0, 1].
    pub position: f64,
    pub budget: &'a BudgetState,
    /// True benefit/cost ratio — supplied for the offline Oracle only.
    pub oracle_ratio: Option<f64>,
    /// Cache-lookup hook: `true` when the caller already holds a cached
    /// result for this subtask, so the decision is advisory — the cached
    /// record will be served at near-zero cost regardless of the returned
    /// side. Stateful routers should not spend resource-consumption state
    /// on cached decisions (the adaptive threshold does not step: a free
    /// completion exerts no budget pressure).
    pub cached: bool,
}

/// One routing decision.
#[derive(Debug, Clone, Copy)]
pub struct Decision {
    /// Offload to the cloud endpoint?
    pub cloud: bool,
    /// Threshold in force at decision time (Figure 3's line series).
    pub tau: f64,
}

/// A live routing policy. Implementations carry their own per-query state
/// (threshold dynamics, bandit head) and reset it in [`Router::begin_query`].
pub trait Router: Send {
    /// Row label for tables/diagnostics.
    fn label(&self) -> String;

    /// Decide one ready subtask.
    fn route(&mut self, ctx: &RouteCtx<'_>, rng: &mut Rng) -> Decision;

    /// Realized-outcome feedback for offloaded subtasks (the partial-
    /// feedback regime of Eq. 14). Default: ignore.
    fn observe_offloaded(
        &mut self,
        _sp: &SimParams,
        _u_hat: f64,
        _position: f64,
        _budget_at_decision: &BudgetState,
        _realized_dq: f64,
        _realized_c: f64,
    ) {
    }

    /// Start a new query; with `persist = false` all per-query state resets
    /// (the paper's evaluation protocol). Default: stateless.
    fn begin_query(&mut self, _persist: bool) {}

    /// Bandit observations consumed so far (0 for non-calibrated routers).
    fn bandit_updates(&self) -> usize {
        0
    }
}

/// Everything on the edge model.
pub struct AllEdgeRouter;

impl Router for AllEdgeRouter {
    fn label(&self) -> String {
        "Edge".into()
    }

    fn route(&mut self, _ctx: &RouteCtx<'_>, _rng: &mut Rng) -> Decision {
        Decision { cloud: false, tau: 1.0 }
    }
}

/// Everything on the cloud model.
pub struct AllCloudRouter;

impl Router for AllCloudRouter {
    fn label(&self) -> String {
        "Cloud".into()
    }

    fn route(&mut self, _ctx: &RouteCtx<'_>, _rng: &mut Rng) -> Decision {
        Decision { cloud: true, tau: 0.0 }
    }
}

/// Offload i.i.d. with probability `p` (Table 3's Random).
pub struct RandomRouter {
    pub p: f64,
}

impl Router for RandomRouter {
    fn label(&self) -> String {
        format!("Random({:.2})", self.p)
    }

    fn route(&mut self, _ctx: &RouteCtx<'_>, rng: &mut Rng) -> Decision {
        Decision { cloud: rng.bernoulli(self.p), tau: 1.0 - self.p }
    }
}

/// Learned utility vs. a fixed threshold tau0 (Table 6 sweep).
pub struct FixedThresholdRouter {
    pub tau0: f64,
}

impl Router for FixedThresholdRouter {
    fn label(&self) -> String {
        format!("Fixed(tau0={})", self.tau0)
    }

    fn route(&mut self, ctx: &RouteCtx<'_>, _rng: &mut Rng) -> Decision {
        Decision { cloud: ctx.u_hat > self.tau0, tau: self.tau0 }
    }
}

/// Full HybridFlow: learned utility + adaptive threshold, with an optional
/// LinUCB calibration head updated from partial feedback.
pub struct LearnedRouter {
    pub threshold: Threshold,
    pub calibrate: bool,
    pub bandit: LinUcb,
}

impl Router for LearnedRouter {
    fn label(&self) -> String {
        if self.calibrate {
            "HybridFlow+LinUCB".into()
        } else {
            "HybridFlow".into()
        }
    }

    fn route(&mut self, ctx: &RouteCtx<'_>, _rng: &mut Rng) -> Decision {
        let tau = self.threshold.tau(ctx.budget);
        let u_bar = if self.calibrate {
            let x = LinUcb::context(ctx.sp, ctx.u_hat, ctx.budget, ctx.position);
            self.bandit.calibrated(&x)
        } else {
            ctx.u_hat
        };
        let cloud = u_bar > tau;
        // Cache-aware: a cached subtask completes for free, so it exerts
        // no budget pressure and must not step the dual/threshold state.
        if !ctx.cached {
            self.threshold.update(ctx.budget);
        }
        Decision { cloud, tau }
    }

    fn observe_offloaded(
        &mut self,
        sp: &SimParams,
        u_hat: f64,
        position: f64,
        budget_at_decision: &BudgetState,
        realized_dq: f64,
        realized_c: f64,
    ) {
        if !self.calibrate {
            return;
        }
        let lambda = self.threshold.tau(budget_at_decision); // tau as shadow price
        let reward = (realized_dq - lambda * realized_c) / (realized_c + sp.eps_utility);
        let x = LinUcb::context(sp, u_hat, budget_at_decision, position);
        self.bandit.update(&x, reward.clamp(-1.0, 1.0));
    }

    fn begin_query(&mut self, persist: bool) {
        if !persist {
            self.threshold.reset();
            self.bandit = LinUcb::paper_default();
        }
    }

    fn bandit_updates(&self) -> usize {
        self.bandit.n_updates
    }
}

/// Offline knapsack oracle on the true (dq, c) ratio — evaluation upper
/// bound, not implementable online (App. B.5).
pub struct OracleRouter;

impl Router for OracleRouter {
    fn label(&self) -> String {
        "Oracle".into()
    }

    fn route(&mut self, ctx: &RouteCtx<'_>, _rng: &mut Rng) -> Decision {
        // Threshold at the budget-clearing shadow price; the price rises to
        // infinity once the budget is exhausted (certainty-equivalent rule).
        let lambda = if ctx.budget.c_used >= ctx.sp.c_max { f64::INFINITY } else { 0.35 };
        Decision { cloud: ctx.oracle_ratio.map_or(false, |r| r > lambda), tau: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(sp: &'a SimParams, budget: &'a BudgetState, u_hat: f64) -> RouteCtx<'a> {
        RouteCtx { sp, u_hat, position: 0.5, budget, oracle_ratio: None, cached: false }
    }

    #[test]
    fn constant_routers() {
        let sp = SimParams::default();
        let b = BudgetState::new();
        let mut rng = Rng::new(0);
        assert!(!AllEdgeRouter.route(&ctx(&sp, &b, 0.99), &mut rng).cloud);
        assert!(AllCloudRouter.route(&ctx(&sp, &b, 0.01), &mut rng).cloud);
        assert_eq!(AllEdgeRouter.route(&ctx(&sp, &b, 0.5), &mut rng).tau, 1.0);
        assert_eq!(AllCloudRouter.route(&ctx(&sp, &b, 0.5), &mut rng).tau, 0.0);
    }

    #[test]
    fn random_consumes_exactly_one_draw() {
        // Stream alignment contract: Random draws once per route() call.
        let sp = SimParams::default();
        let b = BudgetState::new();
        let mut r = RandomRouter { p: 0.5 };
        let mut rng_a = Rng::new(42);
        let mut rng_b = Rng::new(42);
        r.route(&ctx(&sp, &b, 0.5), &mut rng_a);
        let _ = rng_b.bernoulli(0.5);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn fixed_threshold_is_strict() {
        let sp = SimParams::default();
        let b = BudgetState::new();
        let mut rng = Rng::new(1);
        let mut r = FixedThresholdRouter { tau0: 0.5 };
        assert!(r.route(&ctx(&sp, &b, 0.7), &mut rng).cloud);
        assert!(!r.route(&ctx(&sp, &b, 0.5), &mut rng).cloud); // strict >
        assert!(!r.route(&ctx(&sp, &b, 0.3), &mut rng).cloud);
    }

    #[test]
    fn learned_updates_threshold_after_deciding() {
        let sp = SimParams::default();
        let mut rng = Rng::new(2);
        let mut r = LearnedRouter {
            threshold: Threshold::dual(&sp),
            calibrate: false,
            bandit: LinUcb::paper_default(),
        };
        // Overspent budget: dual variable rises across calls, so tau at the
        // second decision exceeds tau at the first.
        let mut burnt = BudgetState::new();
        burnt.c_used = sp.c_max + 1.0;
        let d1 = r.route(&ctx(&sp, &burnt, 0.5), &mut rng);
        let d2 = r.route(&ctx(&sp, &burnt, 0.5), &mut rng);
        assert!(d2.tau > d1.tau, "tau1 {} tau2 {}", d1.tau, d2.tau);
        r.begin_query(false);
        let d3 = r.route(&ctx(&sp, &BudgetState::new(), 0.5), &mut rng);
        assert!((d3.tau - sp.tau0).abs() < 1e-12, "reset restores tau0");
    }

    #[test]
    fn cached_decisions_do_not_step_the_threshold() {
        // Cache-aware hook: a cached (free) completion must leave the
        // adaptive threshold exactly where it was, while a real decision
        // under the same overspent budget steps it.
        let sp = SimParams::default();
        let mut rng = Rng::new(9);
        let mut r = LearnedRouter {
            threshold: Threshold::dual(&sp),
            calibrate: false,
            bandit: LinUcb::paper_default(),
        };
        let mut burnt = BudgetState::new();
        burnt.c_used = sp.c_max + 1.0;
        let cached_ctx = RouteCtx {
            sp: &sp,
            u_hat: 0.5,
            position: 0.5,
            budget: &burnt,
            oracle_ratio: None,
            cached: true,
        };
        let d1 = r.route(&cached_ctx, &mut rng);
        let d2 = r.route(&cached_ctx, &mut rng);
        assert_eq!(d1.tau, d2.tau, "cached decisions must not move tau");
        let real = RouteCtx { cached: false, ..cached_ctx };
        let d3 = r.route(&real, &mut rng);
        let d4 = r.route(&real, &mut rng);
        assert!(d4.tau > d3.tau, "real decisions under overspend step tau");
    }

    #[test]
    fn oracle_gates_on_ratio_and_budget() {
        let sp = SimParams::default();
        let b = BudgetState::new();
        let mut rng = Rng::new(3);
        let mut r = OracleRouter;
        let hit = RouteCtx {
            sp: &sp,
            u_hat: 0.0,
            position: 0.0,
            budget: &b,
            oracle_ratio: Some(5.0),
            cached: false,
        };
        let miss = RouteCtx {
            sp: &sp,
            u_hat: 1.0,
            position: 0.0,
            budget: &b,
            oracle_ratio: Some(0.01),
            cached: false,
        };
        assert!(r.route(&hit, &mut rng).cloud);
        assert!(!r.route(&miss, &mut rng).cloud);
        let mut burnt = BudgetState::new();
        burnt.c_used = sp.c_max + 0.1;
        let gated = RouteCtx {
            sp: &sp,
            u_hat: 1.0,
            position: 0.0,
            budget: &burnt,
            oracle_ratio: Some(100.0),
            cached: false,
        };
        assert!(!r.route(&gated, &mut rng).cloud);
    }
}

//! Declarative routing policies and the per-query router state.
//!
//! [`RoutePolicy`] is pure configuration: the learned HybridFlow router
//! plus every ablation baseline of Table 3 (Edge, Cloud, Random, Fixed
//! threshold) and the offline knapsack oracle. [`RoutePolicy::build`]
//! resolves it into a live [`Router`] implementation (see
//! [`super::engine`]); the scheduler only ever talks to the trait, so
//! policies are swappable per tenant and extensible without scheduler
//! edits.

use super::bandit::LinUcb;
use super::engine::{
    AllCloudRouter, AllEdgeRouter, FixedThresholdRouter, LearnedRouter, OracleRouter,
    RandomRouter, RouteCtx, Router,
};
use super::threshold::Threshold;
use crate::budget::BudgetState;
use crate::config::simparams::SimParams;
use crate::util::rng::Rng;

/// Declarative policy selection (resolved into a [`Router`] by `build`).
#[derive(Debug, Clone)]
pub enum RoutePolicy {
    /// Everything on the edge model.
    AllEdge,
    /// Everything on the cloud model.
    AllCloud,
    /// Offload i.i.d. with probability `p` (Table 3's Random, p ~ offload
    /// rate of the learned router).
    Random(f64),
    /// Learned utility vs. fixed threshold tau0 (Table 6 sweep).
    FixedThreshold(f64),
    /// Full HybridFlow: learned utility + adaptive threshold; optional
    /// LinUCB calibration.
    Learned { threshold: Threshold, calibrate: bool },
    /// Offline knapsack oracle on true (dq, c) — evaluation upper bound,
    /// not implementable online (App. B.5).
    Oracle,
}

impl RoutePolicy {
    /// Default HybridFlow configuration: projected dual ascent (Eq. 10/11)
    /// on the normalized budget. (The paper deploys the Eq. 27 resource-
    /// pressure form - available as [`RoutePolicy::hybridflow_eq27`] - but
    /// on our substrate its latency term over-penalizes deep pivotal
    /// subtasks; see EXPERIMENTS.md "Threshold form".)
    pub fn hybridflow(sp: &SimParams) -> RoutePolicy {
        RoutePolicy::Learned { threshold: Threshold::dual(sp), calibrate: false }
    }

    /// The paper's deployed Eq. 27 threshold variant.
    pub fn hybridflow_eq27(sp: &SimParams) -> RoutePolicy {
        RoutePolicy::Learned { threshold: Threshold::paper_default(sp), calibrate: false }
    }

    /// HybridFlow with the bandit calibration head enabled.
    pub fn hybridflow_calibrated(sp: &SimParams) -> RoutePolicy {
        RoutePolicy::Learned { threshold: Threshold::paper_default(sp), calibrate: true }
    }

    /// Resolve the declarative config into a live router (the Router seam).
    pub fn build(&self) -> Box<dyn Router> {
        match self {
            RoutePolicy::AllEdge => Box::new(AllEdgeRouter),
            RoutePolicy::AllCloud => Box::new(AllCloudRouter),
            RoutePolicy::Random(p) => Box::new(RandomRouter { p: *p }),
            RoutePolicy::FixedThreshold(t) => Box::new(FixedThresholdRouter { tau0: *t }),
            RoutePolicy::Learned { threshold, calibrate } => Box::new(LearnedRouter {
                threshold: threshold.clone(),
                calibrate: *calibrate,
                bandit: LinUcb::paper_default(),
            }),
            RoutePolicy::Oracle => Box::new(OracleRouter),
        }
    }

    /// Row label, matching the corresponding [`Router::label`] exactly
    /// (pinned by a test) without constructing the router.
    pub fn label(&self) -> String {
        match self {
            RoutePolicy::AllEdge => "Edge".into(),
            RoutePolicy::AllCloud => "Cloud".into(),
            RoutePolicy::Random(p) => format!("Random({p:.2})"),
            RoutePolicy::FixedThreshold(t) => format!("Fixed(tau0={t})"),
            RoutePolicy::Learned { calibrate, .. } => {
                if *calibrate {
                    "HybridFlow+LinUCB".into()
                } else {
                    "HybridFlow".into()
                }
            }
            RoutePolicy::Oracle => "Oracle".into(),
        }
    }
}

/// Mutable per-query routing state: the live router built from the
/// declarative policy, plus the decision-time threshold trace
/// (Figure 3's line series).
pub struct RouterState {
    /// The declarative config this state was built from (introspection /
    /// re-instantiation; behavior lives entirely in `router`).
    pub policy: RoutePolicy,
    router: Box<dyn Router>,
    pub tau_trace: Vec<f64>,
}

impl RouterState {
    pub fn new(policy: RoutePolicy) -> RouterState {
        let router = policy.build();
        RouterState { policy, router, tau_trace: Vec::new() }
    }

    /// Decide one ready subtask. `u_hat` from the predictor; `position` in
    /// [0,1]; `oracle_ratio` = true dq/c for the Oracle policy.
    pub fn decide(
        &mut self,
        sp: &SimParams,
        u_hat: f64,
        position: f64,
        budget: &BudgetState,
        oracle_ratio: Option<f64>,
        rng: &mut Rng,
    ) -> bool {
        self.decide_hinted(sp, u_hat, position, budget, oracle_ratio, false, rng)
    }

    /// [`decide`](Self::decide) with the cache-lookup hook: `cached = true`
    /// tells the router the scheduler already holds a cached result for
    /// this subtask, so the returned side is advisory (the cached record
    /// will be served either way) and resource-consumption state must not
    /// step — see [`RouteCtx::cached`]. The threshold trace still records
    /// the decision-time tau for the trace event.
    #[allow(clippy::too_many_arguments)]
    pub fn decide_hinted(
        &mut self,
        sp: &SimParams,
        u_hat: f64,
        position: f64,
        budget: &BudgetState,
        oracle_ratio: Option<f64>,
        cached: bool,
        rng: &mut Rng,
    ) -> bool {
        let decision = self
            .router
            .route(&RouteCtx { sp, u_hat, position, budget, oracle_ratio, cached }, rng);
        self.tau_trace.push(decision.tau);
        decision.cloud
    }

    /// Feed realized outcome back to the router (offloaded subtasks only —
    /// partial feedback, Eq. 14's `R = dq - lambda * c`).
    pub fn observe_offloaded(
        &mut self,
        sp: &SimParams,
        u_hat: f64,
        position: f64,
        budget_at_decision: &BudgetState,
        realized_dq: f64,
        realized_c: f64,
    ) {
        self.router.observe_offloaded(
            sp,
            u_hat,
            position,
            budget_at_decision,
            realized_dq,
            realized_c,
        );
    }

    pub fn reset_for_query(&mut self) {
        self.begin_query(false);
    }

    /// Start a new query. With `persist=true` the dual variable and the
    /// bandit head carry over (streaming deployment: the shadow price is
    /// learned across the query stream); with `persist=false` both reset
    /// (paper's per-query evaluation protocol).
    pub fn begin_query(&mut self, persist: bool) {
        self.router.begin_query(persist);
        self.tau_trace.clear();
    }

    /// Bandit observations consumed (0 unless the calibrated head is on).
    pub fn bandit_updates(&self) -> usize {
        self.router.bandit_updates()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> SimParams {
        SimParams::default()
    }

    #[test]
    fn all_edge_and_cloud_are_constant() {
        let s = sp();
        let b = BudgetState::new();
        let mut rng = Rng::new(0);
        let mut e = RouterState::new(RoutePolicy::AllEdge);
        let mut c = RouterState::new(RoutePolicy::AllCloud);
        for _ in 0..20 {
            assert!(!e.decide(&s, 0.99, 0.5, &b, None, &mut rng));
            assert!(c.decide(&s, 0.01, 0.5, &b, None, &mut rng));
        }
    }

    #[test]
    fn random_hits_target_rate() {
        let s = sp();
        let b = BudgetState::new();
        let mut rng = Rng::new(1);
        let mut r = RouterState::new(RoutePolicy::Random(0.42));
        let hits = (0..20000).filter(|_| r.decide(&s, 0.5, 0.5, &b, None, &mut rng)).count();
        let rate = hits as f64 / 20000.0;
        assert!((rate - 0.42).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn fixed_threshold_splits_on_u_hat() {
        let s = sp();
        let b = BudgetState::new();
        let mut rng = Rng::new(2);
        let mut r = RouterState::new(RoutePolicy::FixedThreshold(0.5));
        assert!(r.decide(&s, 0.7, 0.0, &b, None, &mut rng));
        assert!(!r.decide(&s, 0.3, 0.0, &b, None, &mut rng));
        assert!(!r.decide(&s, 0.5, 0.0, &b, None, &mut rng)); // strict >
    }

    #[test]
    fn learned_becomes_conservative_as_budget_burns() {
        let s = sp();
        let mut rng = Rng::new(3);
        // Eq. 27 variant: resource pressure comes from k_used/l_used.
        let mut r = RouterState::new(RoutePolicy::hybridflow_eq27(&s));
        let fresh = BudgetState::new();
        assert!(r.decide(&s, 0.45, 0.0, &fresh, None, &mut rng)); // above tau0
        let mut burnt = BudgetState::new();
        burnt.k_used = s.k_max_global; // +0.5 pressure
        burnt.l_used = s.l_max_global; // +0.5 pressure -> tau = 1.0
        assert!(!r.decide(&s, 0.45, 0.9, &burnt, None, &mut rng));
        assert!(!r.decide(&s, 0.99, 0.9, &burnt, None, &mut rng)); // tau clipped to 1, strict >
    }

    #[test]
    fn tau_trace_records_decisions() {
        let s = sp();
        let b = BudgetState::new();
        let mut rng = Rng::new(4);
        let mut r = RouterState::new(RoutePolicy::hybridflow(&s));
        for _ in 0..5 {
            r.decide(&s, 0.5, 0.2, &b, None, &mut rng);
        }
        assert_eq!(r.tau_trace.len(), 5);
        assert!(r.tau_trace.iter().all(|t| (0.0..=1.0).contains(t)));
        r.reset_for_query();
        assert!(r.tau_trace.is_empty());
    }

    #[test]
    fn oracle_uses_true_ratio() {
        let s = sp();
        let b = BudgetState::new();
        let mut rng = Rng::new(5);
        let mut r = RouterState::new(RoutePolicy::Oracle);
        assert!(r.decide(&s, 0.0, 0.0, &b, Some(5.0), &mut rng));
        assert!(!r.decide(&s, 1.0, 0.0, &b, Some(0.01), &mut rng));
        // Budget exhausted -> never offload.
        let mut burnt = BudgetState::new();
        burnt.c_used = s.c_max + 0.1;
        assert!(!r.decide(&s, 1.0, 0.0, &burnt, Some(100.0), &mut rng));
    }

    #[test]
    fn calibration_updates_only_when_enabled() {
        let s = sp();
        let b = BudgetState::new();
        let mut plain = RouterState::new(RoutePolicy::hybridflow(&s));
        plain.observe_offloaded(&s, 0.5, 0.2, &b, 0.3, 0.2);
        assert_eq!(plain.bandit_updates(), 0);
        let mut cal = RouterState::new(RoutePolicy::hybridflow_calibrated(&s));
        cal.observe_offloaded(&s, 0.5, 0.2, &b, 0.3, 0.2);
        assert_eq!(cal.bandit_updates(), 1);
    }

    #[test]
    fn build_produces_matching_labels() {
        let s = sp();
        let cases: Vec<(RoutePolicy, &str)> = vec![
            (RoutePolicy::AllEdge, "Edge"),
            (RoutePolicy::AllCloud, "Cloud"),
            (RoutePolicy::Random(0.25), "Random(0.25)"),
            (RoutePolicy::FixedThreshold(0.5), "Fixed(tau0=0.5)"),
            (RoutePolicy::hybridflow(&s), "HybridFlow"),
            (RoutePolicy::hybridflow_calibrated(&s), "HybridFlow+LinUCB"),
            (RoutePolicy::Oracle, "Oracle"),
        ];
        for (policy, want) in cases {
            assert_eq!(policy.label(), want);
            assert_eq!(policy.build().label(), want, "config/router label drift");
        }
    }
}

//! Utility predictors: the interface the scheduler calls to score a ready
//! frontier, plus the pure-rust mirror implementation.
//!
//! Two implementations exist:
//! * [`MirrorPredictor`] (here) — re-implements the trained MLP from
//!   `artifacts/router_meta.json` in plain rust. Used in artifact-free unit
//!   tests, as the cross-check oracle for the PJRT path, and as a fallback
//!   when artifacts are absent.
//! * `runtime::PjrtRouter` — loads `artifacts/router_b*.hlo.txt` and runs
//!   the AOT-compiled network through the PJRT CPU client (the production
//!   request path).

use crate::config::simparams::{FEAT_DIM, ROUTER_IN_DIM};
use crate::embed::Features;
use crate::util::json::Json;
use std::path::Path;

/// Batch utility scoring interface.
pub trait UtilityPredictor: Send + Sync {
    /// Predict `u_hat` for each subtask given the shared budget scalar
    /// `c_used` (Eq. 8).
    fn predict(&self, feats: &[Features], c_used: f64) -> Vec<f64>;

    /// Human-readable backend name (diagnostics).
    fn backend(&self) -> &'static str;
}

/// One dense layer.
#[derive(Debug, Clone)]
struct Layer {
    /// Row-major (in_dim x out_dim).
    w: Vec<f32>,
    b: Vec<f32>,
    in_dim: usize,
    out_dim: usize,
}

impl Layer {
    /// Batched forward: `x` is row-major (rows x in_dim), `out` becomes
    /// (rows x out_dim). Layer-major batching reuses the weight matrix
    /// across all rows while it is hot in cache (the SS`Perf "batched mirror"
    /// optimization: ~2x over per-row forwards at frontier batch sizes).
    fn forward_batch(&self, x: &[f32], rows: usize, out: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), rows * self.in_dim);
        out.clear();
        out.reserve(rows * self.out_dim);
        for r in 0..rows {
            out.extend_from_slice(&self.b);
            let xrow = &x[r * self.in_dim..(r + 1) * self.in_dim];
            let orow_start = r * self.out_dim;
            for (i, &xi) in xrow.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let wrow = &self.w[i * self.out_dim..(i + 1) * self.out_dim];
                let orow = &mut out[orow_start..orow_start + self.out_dim];
                for (o, wv) in orow.iter_mut().zip(wrow) {
                    *o += xi * wv;
                }
            }
        }
    }
}

/// Pure-rust mirror of the trained router network.
#[derive(Debug, Clone)]
pub struct MirrorPredictor {
    layers: Vec<Layer>,
}

/// jax.nn.gelu default (approximate=True).
fn gelu(x: f32) -> f32 {
    let x3 = x * x * x;
    0.5 * x * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044_715 * x3)).tanh())
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl MirrorPredictor {
    /// Load from the JSON exported by `train_router.export_router_meta`.
    pub fn from_meta_file(path: &Path) -> anyhow::Result<MirrorPredictor> {
        let j = Json::parse_file(path)?;
        Self::from_meta_json(&j)
    }

    pub fn from_meta_json(j: &Json) -> anyhow::Result<MirrorPredictor> {
        let dims: Vec<usize> = j
            .get("dims")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("router_meta missing dims"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        anyhow::ensure!(dims.len() >= 2, "router_meta dims too short");
        anyhow::ensure!(
            dims[0] == ROUTER_IN_DIM,
            "router_meta input dim {} != expected {ROUTER_IN_DIM}",
            dims[0]
        );
        let layers_json = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("router_meta missing layers"))?;
        anyhow::ensure!(layers_json.len() == dims.len() - 1, "layer count mismatch");

        let mut layers = Vec::new();
        for (li, lj) in layers_json.iter().enumerate() {
            let (in_dim, out_dim) = (dims[li], dims[li + 1]);
            let rows = lj
                .get("w")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("layer {li} missing w"))?;
            anyhow::ensure!(rows.len() == in_dim, "layer {li} w rows {} != {in_dim}", rows.len());
            let mut w = Vec::with_capacity(in_dim * out_dim);
            for row in rows {
                let vals = row
                    .f64_array()
                    .ok_or_else(|| anyhow::anyhow!("layer {li} w row not numeric"))?;
                anyhow::ensure!(vals.len() == out_dim, "layer {li} w cols mismatch");
                w.extend(vals.iter().map(|&v| v as f32));
            }
            let b: Vec<f32> = lj
                .get("b")
                .and_then(Json::f64_array)
                .ok_or_else(|| anyhow::anyhow!("layer {li} missing b"))?
                .iter()
                .map(|&v| v as f32)
                .collect();
            anyhow::ensure!(b.len() == out_dim, "layer {li} b mismatch");
            layers.push(Layer { w, b, in_dim, out_dim });
        }
        Ok(MirrorPredictor { layers })
    }

    /// Deterministic tiny network for artifact-free tests: hand-set weights
    /// making `u_hat` increase with the difficulty features.
    pub fn synthetic_for_tests() -> MirrorPredictor {
        let hidden = 8;
        let mut l1 = Layer {
            w: vec![0.0; ROUTER_IN_DIM * hidden],
            b: vec![0.0; hidden],
            in_dim: ROUTER_IN_DIM,
            out_dim: hidden,
        };
        // Wire difficulty (3) and criticality (15) into every hidden unit.
        for h in 0..hidden {
            l1.w[3 * hidden + h] = 1.2;
            l1.w[15 * hidden + h] = 0.8;
            l1.w[(ROUTER_IN_DIM - 1) * hidden + h] = -0.5; // c_used dampens
        }
        let l2 = Layer {
            w: vec![0.6; hidden],
            b: vec![-2.0],
            in_dim: hidden,
            out_dim: 1,
        };
        MirrorPredictor { layers: vec![l1, l2] }
    }

    fn forward_batch(&self, input: &[f32], rows: usize) -> Vec<f64> {
        let mut cur = input.to_vec();
        let mut next = Vec::new();
        let last = self.layers.len() - 1;
        for (li, layer) in self.layers.iter().enumerate() {
            layer.forward_batch(&cur, rows, &mut next);
            if li == last {
                for v in next.iter_mut() {
                    *v = sigmoid(*v);
                }
            } else {
                for v in next.iter_mut() {
                    *v = gelu(*v);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        }
        cur.iter().map(|&v| v as f64).collect()
    }
}

impl UtilityPredictor for MirrorPredictor {
    fn predict(&self, feats: &[Features], c_used: f64) -> Vec<f64> {
        let rows = feats.len();
        let mut input = Vec::with_capacity(rows * ROUTER_IN_DIM);
        for f in feats {
            input.extend_from_slice(f);
            input.push(c_used as f32);
        }
        self.forward_batch(&input, rows)
    }

    fn backend(&self) -> &'static str {
        "mirror"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feat_with(d: f32, crit: f32) -> Features {
        let mut f = [0.0f32; FEAT_DIM];
        f[0] = 1.0; // EXPLAIN
        f[3] = d;
        f[4] = d;
        f[6] = 1.0; // math
        f[15] = crit;
        f
    }

    #[test]
    fn synthetic_predictor_basic_shape() {
        let p = MirrorPredictor::synthetic_for_tests();
        let feats = vec![feat_with(0.1, 0.2), feat_with(0.9, 0.9)];
        let out = p.predict(&feats, 0.0);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|u| (0.0..=1.0).contains(u)));
        assert!(out[1] > out[0], "higher difficulty+crit must score higher");
    }

    #[test]
    fn synthetic_predictor_budget_dampens() {
        let p = MirrorPredictor::synthetic_for_tests();
        let feats = vec![feat_with(0.7, 0.7)];
        let fresh = p.predict(&feats, 0.0)[0];
        let spent = p.predict(&feats, 1.0)[0];
        assert!(spent < fresh);
    }

    #[test]
    fn from_meta_json_parses_and_validates() {
        // 17 -> 2 -> 1 tiny net.
        let mut w1_rows = Vec::new();
        for i in 0..ROUTER_IN_DIM {
            let v = if i == 3 { 1.0 } else { 0.0 };
            w1_rows.push(format!("[{v}, {v}]"));
        }
        let text = format!(
            r#"{{"dims": [{in_dim}, 2, 1], "layers": [
                {{"w": [{w1}], "b": [0.0, 0.0]}},
                {{"w": [[1.0],[1.0]], "b": [0.0]}}
            ]}}"#,
            in_dim = ROUTER_IN_DIM,
            w1 = w1_rows.join(",")
        );
        let p = MirrorPredictor::from_meta_json(&Json::parse(&text).unwrap()).unwrap();
        let lo = p.predict(&[feat_with(0.0, 0.0)], 0.0)[0];
        let hi = p.predict(&[feat_with(1.0, 0.0)], 0.0)[0];
        assert!(hi > lo);
        // sigmoid(2*gelu(1)) ~ sigmoid(1.68) ~ 0.84
        assert!((hi - 0.84).abs() < 0.02, "hi {hi}");
    }

    #[test]
    fn from_meta_json_rejects_bad_shapes() {
        let bad = r#"{"dims": [5, 2, 1], "layers": []}"#;
        assert!(MirrorPredictor::from_meta_json(&Json::parse(bad).unwrap()).is_err());
        let bad2 = format!(r#"{{"dims": [{ROUTER_IN_DIM}, 2, 1], "layers": []}}"#);
        assert!(MirrorPredictor::from_meta_json(&Json::parse(&bad2).unwrap()).is_err());
    }

    #[test]
    fn gelu_matches_jax_reference_values() {
        // Reference values from jax.nn.gelu (approximate=True).
        let cases = [(0.0f32, 0.0f32), (1.0, 0.841192), (-1.0, -0.158808), (2.0, 1.954598)];
        for (x, want) in cases {
            let got = gelu(x);
            assert!((got - want).abs() < 1e-4, "gelu({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn batch_equals_rowwise() {
        let p = MirrorPredictor::synthetic_for_tests();
        let feats = vec![feat_with(0.2, 0.3), feat_with(0.6, 0.1), feat_with(0.9, 0.9)];
        let batch = p.predict(&feats, 0.25);
        for (i, f) in feats.iter().enumerate() {
            let single = p.predict(std::slice::from_ref(f), 0.25)[0];
            assert!((batch[i] - single).abs() < 1e-12);
        }
    }
}

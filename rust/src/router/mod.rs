//! Utility-based subtask routing (Sec. 3.3): learned utility prediction,
//! adaptive thresholds, bandit calibration, the knapsack oracle, and the
//! policy zoo for ablations.

pub mod bandit;
pub mod engine;
pub mod knapsack;
pub mod policy;
pub mod predictor;
pub mod threshold;
pub mod utility;

pub use bandit::LinUcb;
pub use engine::{Decision, RouteCtx, Router};
pub use policy::{RoutePolicy, RouterState};
pub use predictor::{MirrorPredictor, UtilityPredictor};
pub use threshold::Threshold;

//! Adaptive routing thresholds.
//!
//! Two interchangeable mechanisms from the paper:
//! * [`DualAscent`] — the theory form (Eqs. 10/11): a shadow price
//!   `lambda_t` updated by projected subgradient on `C_used - C_max`,
//!   mapped to `tau_t = clip(tau0 + gamma * lambda_t, 0, 1)`.
//! * [`ResourcePressure`] — the implementation form (Eq. 27):
//!   `tau_t = clip(tau0 + k_used/(2 K_max) + l_used/(2 L_max), 0, 1)`,
//!   which App. B shows is an instance of the same primal-dual family.
//!
//! [`Threshold::Fixed`] disables adaptation for the tau0 sweep of
//! Table 6 / Figure 4.

use crate::budget::BudgetState;
use crate::config::simparams::SimParams;

/// Threshold mechanism selection.
#[derive(Debug, Clone)]
pub enum Threshold {
    /// Constant tau0 (Table 6 ablation).
    Fixed(f64),
    /// Eq. 10/11 projected dual ascent.
    DualAscent(DualAscent),
    /// Eq. 27 resource-pressure form (paper's deployed configuration).
    ResourcePressure(ResourcePressure),
}

impl Threshold {
    /// Paper default: Eq. 27 with simparams constants.
    pub fn paper_default(sp: &SimParams) -> Threshold {
        Threshold::ResourcePressure(ResourcePressure {
            tau0: sp.tau0,
            k_max: sp.k_max_global,
            l_max: sp.l_max_global,
        })
    }

    pub fn dual(sp: &SimParams) -> Threshold {
        Threshold::DualAscent(DualAscent {
            tau0: sp.tau0,
            lambda: 0.0,
            eta: sp.dual_eta,
            gamma: sp.dual_gamma,
            c_max: sp.c_max,
        })
    }

    /// Current threshold value given the budget state.
    pub fn tau(&self, budget: &BudgetState) -> f64 {
        match self {
            Threshold::Fixed(t) => *t,
            Threshold::DualAscent(d) => (d.tau0 + d.gamma * d.lambda).clamp(0.0, 1.0),
            Threshold::ResourcePressure(r) => {
                (r.tau0 + budget.k_used / (2.0 * r.k_max) + budget.l_used / (2.0 * r.l_max))
                    .clamp(0.0, 1.0)
            }
        }
    }

    /// Post-decision update (dual ascent needs the step; others are
    /// stateless in the budget).
    pub fn update(&mut self, budget: &BudgetState) {
        if let Threshold::DualAscent(d) = self {
            d.lambda = (d.lambda + d.eta * (budget.c_used - d.c_max)).max(0.0);
        }
    }

    /// Fresh per-query state (dual variable resets; the paper adapts within
    /// a query as dependencies resolve).
    pub fn reset(&mut self) {
        if let Threshold::DualAscent(d) = self {
            d.lambda = 0.0;
        }
    }
}

/// Eq. 10/11 state.
#[derive(Debug, Clone)]
pub struct DualAscent {
    pub tau0: f64,
    pub lambda: f64,
    pub eta: f64,
    pub gamma: f64,
    pub c_max: f64,
}

/// Eq. 27 parameters.
#[derive(Debug, Clone)]
pub struct ResourcePressure {
    pub tau0: f64,
    pub k_max: f64,
    pub l_max: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> SimParams {
        SimParams::default()
    }

    #[test]
    fn fixed_never_moves() {
        let mut t = Threshold::Fixed(0.5);
        let mut b = BudgetState::new();
        b.record_cloud(&sp(), 5.0, 0.01);
        b.advance_latency(10.0);
        t.update(&b);
        assert_eq!(t.tau(&b), 0.5);
    }

    #[test]
    fn resource_pressure_matches_eq27() {
        let s = sp();
        let t = Threshold::paper_default(&s);
        let mut b = BudgetState::new();
        b.k_used = s.k_max_global / 2.0; // -> +0.25
        b.l_used = s.l_max_global / 2.0; // -> +0.25
        let tau = t.tau(&b);
        assert!((tau - (s.tau0 + 0.25 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn resource_pressure_clips_at_one() {
        let s = sp();
        let t = Threshold::paper_default(&s);
        let mut b = BudgetState::new();
        b.k_used = 1.0;
        b.l_used = 100.0;
        assert_eq!(t.tau(&b), 1.0);
    }

    #[test]
    fn dual_ascent_increases_under_overspend() {
        let s = sp();
        let mut t = Threshold::dual(&s);
        let mut b = BudgetState::new();
        let tau_start = t.tau(&b);
        assert!((tau_start - s.tau0).abs() < 1e-12);
        // Overspend: C_used above C_max.
        b.c_used = s.c_max + 0.4;
        for _ in 0..5 {
            t.update(&b);
        }
        assert!(t.tau(&b) > tau_start);
    }

    #[test]
    fn dual_ascent_projects_at_zero() {
        let s = sp();
        let mut t = Threshold::dual(&s);
        let b = BudgetState::new(); // under budget: gradient negative
        for _ in 0..20 {
            t.update(&b);
        }
        // lambda stays at 0 (projection), tau at tau0.
        assert!((t.tau(&b) - s.tau0).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_dual_state() {
        let s = sp();
        let mut t = Threshold::dual(&s);
        let mut b = BudgetState::new();
        b.c_used = 2.0;
        t.update(&b);
        assert!(t.tau(&b) > s.tau0);
        t.reset();
        assert!((t.tau(&BudgetState::new()) - s.tau0).abs() < 1e-12);
    }

    #[test]
    fn threshold_always_in_unit_interval() {
        crate::testing::forall("tau in [0,1]", 300, |g| {
            let s = sp();
            let mut b = BudgetState::new();
            b.k_used = g.f64_in(0.0..0.2);
            b.l_used = g.f64_in(0.0..200.0);
            b.c_used = g.f64_in(0.0..5.0);
            let mut d = Threshold::dual(&s);
            for _ in 0..g.usize_in(0..10) {
                d.update(&b);
            }
            let taus = [
                Threshold::Fixed(g.unit_f64()).tau(&b),
                Threshold::paper_default(&s).tau(&b),
                d.tau(&b),
            ];
            taus.iter().all(|t| (0.0..=1.0).contains(t))
        });
    }
}

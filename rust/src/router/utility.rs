//! Utility definitions: the per-subtask offloading utility (Def. 3.2 /
//! Eq. 25) and the query-level *unified utility* metric of Table 3.
//!
//! The unified metric was reverse-engineered from Table 3's numbers:
//! `u = ((acc - acc_edge)/100) / c_query` with
//! `c_query = (dl_query / l_max + dk_query / k_max) / 2`, where deltas are
//! against the all-edge reference. Every row of Table 3 reproduces under
//! this formula to the printed precision (see tests).

use crate::config::simparams::SimParams;

/// Per-subtask utility target (Eq. 2 / Eq. 25): `clip(dq / (c + eps), 0, 1)`.
pub fn utility_target(sp: &SimParams, dq: f64, c: f64) -> f64 {
    (dq / (c + sp.eps_utility)).clamp(0.0, 1.0)
}

/// Query-level normalized cost (Table 3's `c` column): latency and API cost
/// deltas vs. the all-edge reference, normalized like Eq. 24.
pub fn query_norm_cost(sp: &SimParams, latency: f64, latency_edge: f64, api_cost: f64) -> f64 {
    let dl = (latency - latency_edge).max(0.0);
    0.5 * dl / sp.l_max_sub + 0.5 * api_cost / sp.k_max_sub
}

/// Table 3's unified utility: accuracy gain per unit normalized cost.
/// `acc` values in percent (as printed in the paper).
pub fn unified_utility(
    sp: &SimParams,
    acc: f64,
    acc_edge: f64,
    latency: f64,
    latency_edge: f64,
    api_cost: f64,
) -> Option<f64> {
    let c = query_norm_cost(sp, latency, latency_edge, api_cost);
    if c <= 0.0 {
        return None; // all-edge rows print "-" in the paper
    }
    Some(((acc - acc_edge) / 100.0) / c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp() -> SimParams {
        SimParams::default()
    }

    #[test]
    fn utility_target_clips() {
        let s = sp();
        assert_eq!(utility_target(&s, 0.5, 0.1), 1.0); // 5 -> clip
        assert!(utility_target(&s, 0.05, 0.2) < 0.26);
        assert_eq!(utility_target(&s, -0.1, 0.2), 0.0);
    }

    /// Reproduce Table 3 / Table 6 utility cells from their printed
    /// accuracy/latency/API columns — validates the reverse-engineered
    /// formula against the paper itself.
    #[test]
    fn reproduces_paper_table3_utilities() {
        let s = sp();
        let acc_edge = 25.54;
        let lat_edge = 11.99;
        // (acc, latency, api, expected_c, expected_u) from Table 3.
        let rows = [
            (57.28, 18.26, 0.0185, 0.7760, 0.4090), // Cloud
            (46.00, 15.15, 0.0075, 0.3455, 0.5922), // Random
            (51.62, 15.88, 0.0088, 0.4145, 0.6292), // Fixed tau=0.5
            (50.62, 16.12, 0.0082, 0.4115, 0.6095), // HybridFlow-Chain
            (53.33, 15.24, 0.0075, 0.3500, 0.7940), // HybridFlow
        ];
        for (acc, lat, api, want_c, want_u) in rows {
            let c = query_norm_cost(&s, lat, lat_edge, api);
            assert!((c - want_c).abs() < 0.002, "c {c} want {want_c}");
            let u = unified_utility(&s, acc, acc_edge, lat, lat_edge, api).unwrap();
            assert!((u - want_u).abs() < 0.005, "u {u} want {want_u}");
        }
    }

    #[test]
    fn reproduces_paper_table6_utilities() {
        let s = sp();
        let acc_edge = 25.54;
        let lat_edge = 11.99;
        // tau0 = 0.9 and 0.6 rows of Table 6.
        for (acc, lat, api, want_c, want_u) in [
            (35.51, 13.89, 0.0042, 0.2000, 0.4985),
            (47.85, 15.39, 0.0073, 0.3525, 0.6329),
        ] {
            let c = query_norm_cost(&s, lat, lat_edge, api);
            assert!((c - want_c).abs() < 0.003, "c {c} want {want_c}");
            let u = unified_utility(&s, acc, acc_edge, lat, lat_edge, api).unwrap();
            assert!((u - want_u).abs() < 0.01, "u {u} want {want_u}");
        }
    }

    #[test]
    fn all_edge_has_no_utility() {
        let s = sp();
        assert!(unified_utility(&s, 25.54, 25.54, 11.99, 11.99, 0.0).is_none());
    }
}

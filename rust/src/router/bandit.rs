//! Contextual-bandit calibration head (Sec. 3.3 "Contextual Bandit
//! Calibration", Eqs. 13–14).
//!
//! The offline utility `u_hat` can be miscalibrated under system shifts
//! (e.g. cloud RTT doubles) or task shifts. This LinUCB head refines it
//! online from *partial feedback*: the realized utility is observed only
//! when a subtask was offloaded (`r_i = 1`).
//!
//! Context vector: `x = [1, u_hat, remaining_k, remaining_l, position]`.
//! The calibrated score is `u_tilde = clip(theta^T x + alpha_ucb *
//! sqrt(x^T A^{-1} x), 0, 1)` — the affine `alpha*u_hat + beta + w^T s` of
//! Eq. 13 with an optimistic exploration bonus. `A^{-1}` is maintained
//! incrementally via Sherman–Morrison (no matrix inversion in the loop).

use crate::budget::BudgetState;
use crate::config::simparams::SimParams;

/// Context dimension: [bias, u_hat, remaining_k_frac, remaining_l_frac, pos].
pub const CTX_DIM: usize = 5;

/// LinUCB state with ridge prior `lambda_reg * I`.
#[derive(Debug, Clone)]
pub struct LinUcb {
    /// A^{-1} (row-major CTX_DIM x CTX_DIM).
    a_inv: [[f64; CTX_DIM]; CTX_DIM],
    /// b accumulator.
    b: [f64; CTX_DIM],
    /// theta = A^{-1} b (kept in sync).
    theta: [f64; CTX_DIM],
    /// Exploration strength.
    pub alpha_ucb: f64,
    /// Observations consumed.
    pub n_updates: usize,
}

impl LinUcb {
    pub fn new(alpha_ucb: f64, lambda_reg: f64) -> LinUcb {
        let mut a_inv = [[0.0; CTX_DIM]; CTX_DIM];
        for i in 0..CTX_DIM {
            a_inv[i][i] = 1.0 / lambda_reg;
        }
        let mut ucb = LinUcb { a_inv, b: [0.0; CTX_DIM], theta: [0.0; CTX_DIM], alpha_ucb, n_updates: 0 };
        // Prior: trust u_hat (theta = e_uhat) until data accumulates.
        ucb.b[1] = lambda_reg;
        ucb.refresh_theta();
        ucb
    }

    /// Paper-flavoured default: light exploration, unit ridge. (0.3 was
    /// over-optimistic: the per-query decision count is small, so a large
    /// UCB bonus routes everything cloud before the head has data.)
    pub fn paper_default() -> LinUcb {
        LinUcb::new(0.1, 1.0)
    }

    /// Build the context vector for one decision.
    pub fn context(sp: &SimParams, u_hat: f64, budget: &BudgetState, position: f64) -> [f64; CTX_DIM] {
        let rem_k = (1.0 - budget.k_used / sp.k_max_global).clamp(0.0, 1.0);
        let rem_l = (1.0 - budget.l_used / sp.l_max_global).clamp(0.0, 1.0);
        [1.0, u_hat, rem_k, rem_l, position.clamp(0.0, 1.0)]
    }

    /// Calibrated utility `u_tilde` (Eq. 13 + UCB bonus).
    pub fn calibrated(&self, x: &[f64; CTX_DIM]) -> f64 {
        let mean = dot(&self.theta, x);
        let bonus = self.alpha_ucb * self.mahalanobis(x).sqrt();
        (mean + bonus).clamp(0.0, 1.0)
    }

    /// Observe the realized cost-aware reward `R = dq - lambda * c`
    /// (Eq. 14), mapped into utility space by the caller. Only invoked for
    /// offloaded subtasks — the partial-feedback regime.
    pub fn update(&mut self, x: &[f64; CTX_DIM], reward: f64) {
        // Sherman–Morrison: (A + x x^T)^{-1} = A^{-1} - (A^{-1}x x^T A^{-1}) / (1 + x^T A^{-1} x)
        let ax = self.mat_vec(x);
        let denom = 1.0 + dot(&ax, x);
        for i in 0..CTX_DIM {
            for j in 0..CTX_DIM {
                self.a_inv[i][j] -= ax[i] * ax[j] / denom;
            }
        }
        for i in 0..CTX_DIM {
            self.b[i] += reward * x[i];
        }
        self.refresh_theta();
        self.n_updates += 1;
    }

    /// x^T A^{-1} x (>= 0 when A^{-1} stays PD).
    pub fn mahalanobis(&self, x: &[f64; CTX_DIM]) -> f64 {
        dot(&self.mat_vec(x), x).max(0.0)
    }

    fn mat_vec(&self, x: &[f64; CTX_DIM]) -> [f64; CTX_DIM] {
        let mut out = [0.0; CTX_DIM];
        for i in 0..CTX_DIM {
            for j in 0..CTX_DIM {
                out[i] += self.a_inv[i][j] * x[j];
            }
        }
        out
    }

    fn refresh_theta(&mut self) {
        self.theta = self.mat_vec(&self.b);
    }

    pub fn theta(&self) -> &[f64; CTX_DIM] {
        &self.theta
    }
}

fn dot(a: &[f64; CTX_DIM], b: &[f64; CTX_DIM]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn prior_trusts_u_hat() {
        let ucb = LinUcb::new(0.0, 1.0);
        for u in [0.1, 0.5, 0.9] {
            let x = [1.0, u, 1.0, 1.0, 0.0];
            let c = ucb.calibrated(&x);
            // theta prior = e_1 damped by the identity prior's own ridge.
            assert!((c - u).abs() < 0.6, "c {c} u {u}");
        }
        // Monotone in u_hat under the prior.
        let lo = ucb.calibrated(&[1.0, 0.1, 1.0, 1.0, 0.0]);
        let hi = ucb.calibrated(&[1.0, 0.9, 1.0, 1.0, 0.0]);
        assert!(hi > lo);
    }

    #[test]
    fn learns_affine_shift() {
        // True reward = 0.5 * u_hat + 0.2 (a miscalibration). After enough
        // updates the head should predict it closely.
        let mut ucb = LinUcb::new(0.0, 1.0);
        let mut rng = Rng::new(0);
        for _ in 0..3000 {
            let u = rng.f64();
            let x = [1.0, u, rng.f64(), rng.f64(), rng.f64()];
            ucb.update(&x, 0.5 * u + 0.2);
        }
        for u in [0.0, 0.3, 0.8] {
            let x = [1.0, u, 0.5, 0.5, 0.5];
            let got = ucb.calibrated(&x);
            let want = 0.5 * u + 0.2;
            assert!((got - want).abs() < 0.05, "u {u}: got {got} want {want}");
        }
    }

    #[test]
    fn exploration_bonus_shrinks_with_data() {
        let mut ucb = LinUcb::new(0.5, 1.0);
        let x = [1.0, 0.5, 0.5, 0.5, 0.5];
        let before = ucb.mahalanobis(&x);
        for _ in 0..100 {
            ucb.update(&x, 0.4);
        }
        let after = ucb.mahalanobis(&x);
        assert!(after < before * 0.05, "before {before} after {after}");
    }

    #[test]
    fn a_inv_stays_positive_definite() {
        crate::testing::forall("x^T A^-1 x >= 0", 100, |g| {
            let mut ucb = LinUcb::new(0.3, 1.0);
            for _ in 0..g.usize_in(0..50) {
                let x = [1.0, g.unit_f64(), g.unit_f64(), g.unit_f64(), g.unit_f64()];
                ucb.update(&x, g.f64_in(-1.0..1.0));
            }
            let probe = [1.0, g.unit_f64(), g.unit_f64(), g.unit_f64(), g.unit_f64()];
            ucb.mahalanobis(&probe) >= 0.0 && ucb.calibrated(&probe).is_finite()
        });
    }

    #[test]
    fn calibrated_clipped_to_unit() {
        let mut ucb = LinUcb::new(1.0, 0.1);
        // Push theta far positive.
        for _ in 0..50 {
            ucb.update(&[1.0, 1.0, 1.0, 1.0, 1.0], 10.0);
        }
        assert_eq!(ucb.calibrated(&[1.0, 1.0, 1.0, 1.0, 1.0]), 1.0);
        let mut ucb = LinUcb::new(0.0, 0.1);
        for _ in 0..50 {
            ucb.update(&[1.0, 1.0, 1.0, 1.0, 1.0], -10.0);
        }
        assert_eq!(ucb.calibrated(&[1.0, 1.0, 1.0, 1.0, 1.0]), 0.0);
    }

    #[test]
    fn context_reflects_remaining_budget() {
        let sp = SimParams::default();
        let mut b = BudgetState::new();
        let x0 = LinUcb::context(&sp, 0.5, &b, 0.2);
        assert_eq!(x0, [1.0, 0.5, 1.0, 1.0, 0.2]);
        b.k_used = sp.k_max_global; // exhausted
        b.l_used = sp.l_max_global / 2.0;
        let x1 = LinUcb::context(&sp, 0.5, &b, 0.2);
        assert_eq!(x1[2], 0.0);
        assert!((x1[3] - 0.5).abs() < 1e-12);
    }
}

//! Shared rendering for engine run reports.
//!
//! Both report types the unified kernel feeds — the virtual-clock
//! [`FleetReport`](crate::sim::FleetReport) and the wall-clock
//! [`ServeReport`](crate::server::ServeReport) — used to carry their own
//! copies of the hedge and cache summary lines. [`ReportRenderer`] is the
//! one place those sections are formatted, so the two reports (and any
//! future ones) cannot drift apart: a report renders its headline and
//! mode-specific lines, then appends the shared hedge/cache sections.

use crate::cache::CacheStats;
use crate::fault::FaultStats;
use crate::obs::CriticalPathSummary;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Line-oriented report builder with the shared sections every engine
/// report appends in the same order: mode-specific lines first, then the
/// hedge summary (only when speculation cancelled anything), then the
/// result-cache counters (only when a cache was attached).
pub struct ReportRenderer {
    out: String,
}

impl ReportRenderer {
    pub fn new(headline: String) -> ReportRenderer {
        ReportRenderer { out: headline }
    }

    /// Append one report line.
    pub fn line(&mut self, s: String) -> &mut Self {
        self.out.push('\n');
        self.out.push_str(&s);
        self
    }

    /// Shared hedge section: losers cancelled + dollars refunded. Silent
    /// when no speculative replica was cancelled, so hedge-off reports are
    /// byte-identical to pre-hedging ones.
    pub fn hedge(&mut self, cancelled: usize, refund: f64) -> &mut Self {
        if cancelled > 0 {
            self.line(format!(
                "hedge: {cancelled} losers cancelled, ${refund:.4} refunded"
            ));
        }
        self
    }

    /// Shared result-cache section ([`CacheStats::render_line`]). Silent
    /// when no cache was attached to the run.
    pub fn cache(&mut self, stats: Option<&CacheStats>) -> &mut Self {
        if let Some(c) = stats {
            self.line(c.render_line());
        }
        self
    }

    /// Shared critical-path section ([`CriticalPathSummary::render_line`]).
    /// Silent when observability was off, so uninstrumented reports are
    /// byte-identical to pre-observability ones.
    pub fn critical_path(&mut self, cp: Option<&CriticalPathSummary>) -> &mut Self {
        if let Some(cp) = cp {
            self.line(cp.render_line());
        }
        self
    }

    /// Shared fault/resilience section ([`FaultStats::render_line`]).
    /// Silent when the fault layer was off, so fault-free reports are
    /// byte-identical to pre-fault-injection ones.
    pub fn faults(&mut self, stats: Option<&FaultStats>) -> &mut Self {
        if let Some(f) = stats {
            self.line(f.render_line());
        }
        self
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Canonical `p50 / p95 / p99 / max` rendering of a latency summary in
/// seconds (sojourn-style lines).
pub fn quantiles_s(label: &str, s: &Summary) -> String {
    format!(
        "{label}: p50 {:.2}s  p95 {:.2}s  p99 {:.2}s  max {:.2}s",
        s.p50, s.p95, s.p99, s.max
    )
}

// ---------------------------------------------------------------------------
// Machine-readable report sections (util::json) — the shared vocabulary
// every engine report's `to_json` composes (ROADMAP's "JSON-out of Report
// for plotting"). NaN quantiles of empty summaries serialize as `null`
// (the writer's convention for non-finite numbers).
// ---------------------------------------------------------------------------

/// A latency [`Summary`] as a JSON object (count, mean/std, min/max,
/// p50/p90/p95/p99).
pub fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("n", Json::Num(s.n as f64)),
        ("mean", Json::Num(s.mean)),
        ("std", Json::Num(s.std)),
        ("min", Json::Num(s.min)),
        ("max", Json::Num(s.max)),
        ("p50", Json::Num(s.p50)),
        ("p90", Json::Num(s.p90)),
        ("p95", Json::Num(s.p95)),
        ("p99", Json::Num(s.p99)),
    ])
}

/// Result-cache counters as a JSON object (the same numbers
/// [`CacheStats::render_line`] prints).
pub fn cache_stats_json(c: &CacheStats) -> Json {
    Json::obj(vec![
        ("lookups", Json::Num(c.lookups as f64)),
        ("hits", Json::Num(c.hits as f64)),
        ("hit_rate", Json::Num(c.hit_rate())),
        ("shared_hits", Json::Num(c.shared_hits as f64)),
        ("insertions", Json::Num(c.insertions as f64)),
        ("evictions", Json::Num(c.evictions as f64)),
        ("expirations", Json::Num(c.expirations as f64)),
        ("tokens_saved", Json::Num(c.tokens_saved)),
        ("dollars_saved", Json::Num(c.dollars_saved)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renderer_appends_sections_in_order() {
        let mut r = ReportRenderer::new("head".into());
        r.line("body".into());
        r.hedge(0, 0.0); // silent
        r.hedge(3, 0.125);
        r.cache(None); // silent
        let got = r.finish();
        assert_eq!(got, "head\nbody\nhedge: 3 losers cancelled, $0.1250 refunded");
    }

    #[test]
    fn critical_path_section_is_silent_when_absent() {
        let mut r = ReportRenderer::new("head".into());
        r.critical_path(None);
        assert_eq!(r.finish(), "head", "no observability, no section");
        let cp = CriticalPathSummary {
            queries: 3,
            mean_len: 2.0,
            mean_makespan: 4.0,
            mean_path_latency: 3.0,
            mean_slack: 1.0,
            max_makespan: 6.0,
        };
        let mut r = ReportRenderer::new("head".into());
        r.critical_path(Some(&cp));
        let got = r.finish();
        assert!(got.contains("critical path:"), "{got}");
        assert!(got.contains("over 3 queries"), "{got}");
    }

    #[test]
    fn cache_section_uses_shared_line() {
        let stats = CacheStats { lookups: 4, hits: 2, ..Default::default() };
        let mut r = ReportRenderer::new("x".into());
        r.cache(Some(&stats));
        let got = r.finish();
        assert!(got.contains("cache: hit rate 50.0%"), "{got}");
    }

    #[test]
    fn quantile_line_formats_seconds() {
        let s = Summary::of_or_zero(&[1.0, 2.0, 3.0, 4.0]);
        let line = quantiles_s("sojourn", &s);
        assert!(line.starts_with("sojourn: p50 "));
        assert!(line.contains("max 4.00s"));
    }
}

//! Shared rendering for engine run reports.
//!
//! Both report types the unified kernel feeds — the virtual-clock
//! [`FleetReport`](crate::sim::FleetReport) and the wall-clock
//! [`ServeReport`](crate::server::ServeReport) — used to carry their own
//! copies of the hedge and cache summary lines. [`ReportRenderer`] is the
//! one place those sections are formatted, so the two reports (and any
//! future ones) cannot drift apart: a report renders its headline and
//! mode-specific lines, then appends the shared hedge/cache sections.

use crate::cache::CacheStats;
use crate::util::stats::Summary;

/// Line-oriented report builder with the shared sections every engine
/// report appends in the same order: mode-specific lines first, then the
/// hedge summary (only when speculation cancelled anything), then the
/// result-cache counters (only when a cache was attached).
pub struct ReportRenderer {
    out: String,
}

impl ReportRenderer {
    pub fn new(headline: String) -> ReportRenderer {
        ReportRenderer { out: headline }
    }

    /// Append one report line.
    pub fn line(&mut self, s: String) -> &mut Self {
        self.out.push('\n');
        self.out.push_str(&s);
        self
    }

    /// Shared hedge section: losers cancelled + dollars refunded. Silent
    /// when no speculative replica was cancelled, so hedge-off reports are
    /// byte-identical to pre-hedging ones.
    pub fn hedge(&mut self, cancelled: usize, refund: f64) -> &mut Self {
        if cancelled > 0 {
            self.line(format!(
                "hedge: {cancelled} losers cancelled, ${refund:.4} refunded"
            ));
        }
        self
    }

    /// Shared result-cache section ([`CacheStats::render_line`]). Silent
    /// when no cache was attached to the run.
    pub fn cache(&mut self, stats: Option<&CacheStats>) -> &mut Self {
        if let Some(c) = stats {
            self.line(c.render_line());
        }
        self
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Canonical `p50 / p95 / p99 / max` rendering of a latency summary in
/// seconds (sojourn-style lines).
pub fn quantiles_s(label: &str, s: &Summary) -> String {
    format!(
        "{label}: p50 {:.2}s  p95 {:.2}s  p99 {:.2}s  max {:.2}s",
        s.p50, s.p95, s.p99, s.max
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renderer_appends_sections_in_order() {
        let mut r = ReportRenderer::new("head".into());
        r.line("body".into());
        r.hedge(0, 0.0); // silent
        r.hedge(3, 0.125);
        r.cache(None); // silent
        let got = r.finish();
        assert_eq!(got, "head\nbody\nhedge: 3 losers cancelled, $0.1250 refunded");
    }

    #[test]
    fn cache_section_uses_shared_line() {
        let stats = CacheStats { lookups: 4, hits: 2, ..Default::default() };
        let mut r = ReportRenderer::new("x".into());
        r.cache(Some(&stats));
        let got = r.finish();
        assert!(got.contains("cache: hit rate 50.0%"), "{got}");
    }

    #[test]
    fn quantile_line_formats_seconds() {
        let s = Summary::of_or_zero(&[1.0, 2.0, 3.0, 4.0]);
        let line = quantiles_s("sojourn", &s);
        assert!(line.starts_with("sojourn: p50 "));
        assert!(line.contains("max 4.00s"));
    }
}

//! Subtask feature packing — the rust half of the embedding interface.
//!
//! The paper encodes each subtask with qwen3-embedding-0.6b; our substitute
//! exposes the same information channel as a fixed 16-dim feature vector
//! (layout shared with `python/compile/simparams.py`, version-checked via
//! the artifact manifest). The learned embedder lives *inside* the router
//! HLO artifact; this module only packs the raw features the network
//! consumes, including the *noisy* difficulty/criticality observations —
//! the router never sees latent ground truth.

use crate::config::simparams::{
    SimParams, FAN_NORM, FEAT_CRIT, FEAT_DIFF1, FEAT_DIFF2, FEAT_DIM, FEAT_DOMAIN, FEAT_FANIN,
    FEAT_FANOUT, FEAT_NSUB, FEAT_POS, FEAT_ROLE, FEAT_SINK, FEAT_TOKENS, TOKEN_NORM,
};
use crate::dag::{Role, TaskDag};
use crate::util::rng::Rng;
use crate::workload::{Query, SubtaskLatent};

/// Packed feature vector for one subtask.
pub type Features = [f32; FEAT_DIM];

/// Observation context: per-query DAG structure needed for packing.
pub struct FeatureContext {
    depths: Vec<usize>,
    out_degrees: Vec<usize>,
    n: usize,
    max_depth: usize,
    domain: usize,
}

impl FeatureContext {
    pub fn new(dag: &TaskDag, query: &Query) -> FeatureContext {
        let depths = dag.depths().unwrap_or_else(|| vec![0; dag.len()]);
        let max_depth = depths.iter().copied().max().unwrap_or(0);
        FeatureContext {
            depths,
            out_degrees: dag.out_degrees(),
            n: dag.len(),
            max_depth,
            domain: query.domain,
        }
    }

    /// Pack the feature vector for node `i`.
    ///
    /// The two difficulty observations and the criticality hint are noisy
    /// views of the latent (distinct draws per call, like re-embedding a
    /// paraphrase); everything else is exact structure.
    pub fn features(
        &self,
        dag: &TaskDag,
        i: usize,
        latent: &SubtaskLatent,
        sp: &SimParams,
        rng: &mut Rng,
    ) -> Features {
        let node = &dag.nodes[i];
        let mut f = [0.0f32; FEAT_DIM];
        f[FEAT_ROLE + node.role.index()] = 1.0;
        f[FEAT_DIFF1] =
            clamp01(latent.difficulty + rng.normal_ms(0.0, sp.diff_noise_std)) as f32;
        f[FEAT_DIFF2] =
            clamp01(latent.difficulty + rng.normal_ms(0.0, sp.diff_noise_std)) as f32;
        let est = if node.est_tokens > 0.0 { node.est_tokens } else { latent.out_tokens };
        f[FEAT_TOKENS] = (est / TOKEN_NORM) as f32;
        f[FEAT_DOMAIN + self.domain] = 1.0;
        f[FEAT_POS] = if self.max_depth == 0 {
            0.0
        } else {
            self.depths[i] as f32 / self.max_depth as f32
        };
        f[FEAT_FANIN] = (node.deps.len() as f64 / FAN_NORM) as f32;
        f[FEAT_FANOUT] = (self.out_degrees[i] as f64 / FAN_NORM) as f32;
        f[FEAT_NSUB] = (self.n as f64 / sp.nmax as f64) as f32;
        f[FEAT_SINK] = if node.role == Role::Generate && self.out_degrees[i] == 0 {
            1.0
        } else {
            0.0
        };
        f[FEAT_CRIT] =
            clamp01(latent.criticality + rng.normal_ms(0.0, sp.crit_noise_std)) as f32;
        f
    }
}

fn clamp01(x: f64) -> f64 {
    x.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::Subtask;
    use crate::workload::{generate_queries, Benchmark};

    fn setup() -> (TaskDag, Query, Vec<SubtaskLatent>, SimParams) {
        let dag = TaskDag::new(vec![
            Subtask::new(0, Role::Explain, "r", vec![]),
            Subtask::new(1, Role::Analyze, "a", vec![0]),
            Subtask::new(2, Role::Analyze, "b", vec![0]),
            Subtask::new(3, Role::Generate, "g", vec![1, 2]),
        ]);
        let sp = SimParams::default();
        let q = generate_queries(Benchmark::Gpqa, 1, 0).pop().unwrap();
        let mut rng = Rng::new(3);
        let lat = crate::workload::sample_latents(&dag, &q, &sp, &mut rng);
        (dag, q, lat, sp)
    }

    #[test]
    fn one_hot_blocks_are_one_hot() {
        let (dag, q, lat, sp) = setup();
        let ctx = FeatureContext::new(&dag, &q);
        let mut rng = Rng::new(1);
        for i in 0..dag.len() {
            let f = ctx.features(&dag, i, &lat[i], &sp, &mut rng);
            let role_sum: f32 = f[FEAT_ROLE..FEAT_ROLE + 3].iter().sum();
            let dom_sum: f32 = f[FEAT_DOMAIN..FEAT_DOMAIN + 4].iter().sum();
            assert_eq!(role_sum, 1.0);
            assert_eq!(dom_sum, 1.0);
        }
    }

    #[test]
    fn structure_features_exact() {
        let (dag, q, lat, sp) = setup();
        let ctx = FeatureContext::new(&dag, &q);
        let mut rng = Rng::new(2);
        let f0 = ctx.features(&dag, 0, &lat[0], &sp, &mut rng);
        let f3 = ctx.features(&dag, 3, &lat[3], &sp, &mut rng);
        assert_eq!(f0[FEAT_POS], 0.0);
        assert_eq!(f3[FEAT_POS], 1.0);
        assert_eq!(f3[FEAT_SINK], 1.0);
        assert_eq!(f0[FEAT_SINK], 0.0);
        assert_eq!(f3[FEAT_FANIN], 2.0 / FAN_NORM as f32);
        assert_eq!(f0[FEAT_FANOUT], 2.0 / FAN_NORM as f32);
        assert_eq!(f0[FEAT_NSUB], (4.0 / 7.0) as f32);
    }

    #[test]
    fn difficulty_observations_are_noisy_but_correlated() {
        let (dag, q, lat, sp) = setup();
        let ctx = FeatureContext::new(&dag, &q);
        let mut rng = Rng::new(4);
        let mut errs = Vec::new();
        for _ in 0..500 {
            let f = ctx.features(&dag, 1, &lat[1], &sp, &mut rng);
            errs.push((f[FEAT_DIFF1] as f64 - lat[1].difficulty).abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err > 0.0 && mean_err < 3.0 * sp.diff_noise_std);
        // Two observations differ (independent noise).
        let f = ctx.features(&dag, 1, &lat[1], &sp, &mut rng);
        assert_ne!(f[FEAT_DIFF1], f[FEAT_DIFF2]);
    }

    #[test]
    fn features_in_bounds() {
        let (dag, q, lat, sp) = setup();
        let ctx = FeatureContext::new(&dag, &q);
        let mut rng = Rng::new(5);
        for i in 0..dag.len() {
            for _ in 0..50 {
                let f = ctx.features(&dag, i, &lat[i], &sp, &mut rng);
                for (k, v) in f.iter().enumerate() {
                    assert!(v.is_finite() && *v >= 0.0, "feat {k} = {v}");
                }
                assert!(f[FEAT_DIFF1] <= 1.0 && f[FEAT_CRIT] <= 1.0);
            }
        }
    }

    #[test]
    fn planner_token_estimate_preferred() {
        let (mut dag, q, lat, sp) = setup();
        dag.nodes[1].est_tokens = 256.0;
        let ctx = FeatureContext::new(&dag, &q);
        let mut rng = Rng::new(6);
        let f = ctx.features(&dag, 1, &lat[1], &sp, &mut rng);
        assert_eq!(f[FEAT_TOKENS], (256.0 / TOKEN_NORM) as f32);
    }
}

//! # HybridFlow
//!
//! Production-grade reproduction of *HybridFlow: Resource-Adaptive Subtask
//! Routing for Efficient Edge-Cloud LLM Inference* (CS.DC 2025) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: XML-plan
//!   parsing into a subtask DAG, validation + bounded repair (Def. C.2),
//!   dependency-triggered parallel scheduling, utility-based edge/cloud
//!   routing with projected-dual-ascent thresholds (Eqs. 10/11/27), LinUCB
//!   online calibration (Eqs. 13/14), budget accounting, baselines, workload
//!   generators, metrics, and the experiment harness for every table and
//!   figure in the paper.
//! * **L2 (python/compile/model.py, build-time)** — the learned router
//!   network and the tiny edge-LM block, lowered once by `make artifacts`
//!   to HLO text.
//! * **L1 (python/compile/kernels/, build-time)** — the fused
//!   `matmul+bias+activation` Pallas kernel behind every dense layer.
//!
//! The runtime module loads the AOT artifacts through the PJRT CPU client
//! (`xla` crate, behind the `pjrt` cargo feature) and serves routing
//! decisions **on the request path** — python is never invoked after
//! `make artifacts`.
//!
//! Beyond the paper's per-query semantics, the unified simulation kernel
//! (`sim::Kernel`) runs whole serving fleets on the same virtual clock:
//! N concurrent queries contending for a shared edge-worker pool and a
//! bounded cloud-API pool, with hierarchical tenant-to-global dollar
//! budgets, admission queueing, and open-loop arrivals
//! (`workload::trace::ArrivalProcess`). The single-query scheduler is the
//! kernel's N=1 special case. Experiments are described declaratively:
//! `scenario::ScenarioSpec` is a JSON-serializable description of
//! topology, workload, and engine options that `build()`s into a runnable
//! `Session` (see the "Scenario API" section of README.md and the shipped
//! `scenarios/*.json` files).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod bench;
pub mod testing;
pub mod util;

pub mod config;
pub mod dag;
pub mod embed;
pub mod planner;
pub mod runtime;

pub mod budget;
pub mod cache;
pub mod engine;
pub mod fault;
pub mod models;
pub mod obs;
pub mod router;
pub mod scheduler;
pub mod sim;
pub mod workload;

pub mod baselines;
pub mod eval;
pub mod metrics;
pub mod pipeline;
pub mod report;
pub mod scenario;
pub mod server;

/// Commonly used items for examples and binaries.
pub mod prelude {
    pub use crate::cache::{CachePolicyKind, CachedBackend, SubtaskCache};
    pub use crate::config::simparams::SimParams;
    pub use crate::dag::{Role, Subtask, TaskDag};
    pub use crate::engine::{Backend, ReplayBackend};
    pub use crate::metrics::QueryOutcome;
    pub use crate::models::{ModelKind, ModelProfile};
    pub use crate::pipeline::{HybridFlowPipeline, PipelineConfig};
    pub use crate::router::policy::RoutePolicy;
    pub use crate::scenario::{ScenarioSpec, Session, SweepSpec};
    pub use crate::util::json::Json;
    pub use crate::util::rng::Rng;
    pub use crate::workload::{Benchmark, Query};
}

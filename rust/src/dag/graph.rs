//! Task-level decomposition DAG `G(Q) = (T, E)` with the structural queries
//! the scheduler and metrics need: topological order, ready frontier,
//! critical path, and the paper's compression ratio `R_comp` (Eq. 28).

use super::node::{Role, Subtask};

/// A decomposition DAG. Nodes are stored by index; `Subtask::deps` encodes
/// the edge set E as parent lists (edge `t_j -> t_i` iff `j in nodes[i].deps`).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDag {
    pub nodes: Vec<Subtask>,
}

impl TaskDag {
    pub fn new(nodes: Vec<Subtask>) -> TaskDag {
        TaskDag { nodes }
    }

    /// Sequential chain fallback over `n` nodes (repair's last resort).
    /// Always at least 2 nodes: Definition C.2 needs an EXPLAIN root *and*
    /// a GENERATE sink.
    pub fn chain(descs: &[String]) -> TaskDag {
        let n = descs.len().max(2);
        let nodes = (0..n)
            .map(|i| {
                let role = if i == 0 {
                    Role::Explain
                } else if i == n - 1 {
                    Role::Generate
                } else {
                    Role::Analyze
                };
                let desc = descs.get(i).cloned().unwrap_or_else(|| format!("step {i}"));
                let deps = if i == 0 { vec![] } else { vec![i - 1] };
                Subtask::new(i, role, &desc, deps)
            })
            .collect();
        TaskDag { nodes }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// In-degree of every node.
    pub fn in_degrees(&self) -> Vec<usize> {
        self.nodes.iter().map(|n| n.deps.len()).collect()
    }

    /// Children adjacency (out-edges), derived from parent lists.
    pub fn children(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &d in &n.deps {
                if d < self.nodes.len() {
                    out[d].push(i);
                }
            }
        }
        out
    }

    /// Children adjacency in CSR (compressed sparse row) form: the flat
    /// edge layout the kernel's finish-event loop walks. Two allocations
    /// total (offsets + targets) instead of `children()`'s `n + 1` nested
    /// vectors, with per-node child lists contiguous in memory. Children
    /// of each node appear in ascending order — exactly the order
    /// [`children`](Self::children) yields — so frontier updates are
    /// order-identical between the two layouts. Out-of-range dep indices
    /// are skipped, matching `children()`.
    pub fn children_csr(&self) -> CsrChildren {
        let n = self.nodes.len();
        let mut offsets = vec![0u32; n + 1];
        for node in &self.nodes {
            for &d in &node.deps {
                if d < n {
                    offsets[d + 1] += 1;
                }
            }
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut next = offsets.clone();
        let mut targets = vec![0u32; offsets[n] as usize];
        for (i, node) in self.nodes.iter().enumerate() {
            for &d in &node.deps {
                if d < n {
                    targets[next[d] as usize] = i as u32;
                    next[d] += 1;
                }
            }
        }
        CsrChildren { offsets, targets }
    }

    /// Out-degree of every node.
    pub fn out_degrees(&self) -> Vec<usize> {
        self.children().iter().map(Vec::len).collect()
    }

    /// Nodes with no prerequisites (the initial ready frontier).
    pub fn roots(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| self.nodes[i].deps.is_empty()).collect()
    }

    /// Nodes with no children.
    pub fn sinks(&self) -> Vec<usize> {
        let deg = self.out_degrees();
        (0..self.nodes.len()).filter(|&i| deg[i] == 0).collect()
    }

    /// Kahn topological order; `None` if the graph has a cycle (or a dep
    /// index out of range, which we treat as an invalid edge).
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let n = self.nodes.len();
        for node in &self.nodes {
            if node.deps.iter().any(|&d| d >= n) {
                return None;
            }
        }
        let mut indeg = self.in_degrees();
        let children = self.children();
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop() {
            order.push(u);
            for &c in &children[u] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    queue.push(c);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Set of nodes reachable from `start` (following child edges).
    pub fn reachable_from(&self, start: usize) -> Vec<bool> {
        let children = self.children();
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![start];
        while let Some(u) = stack.pop() {
            if u >= seen.len() || seen[u] {
                continue;
            }
            seen[u] = true;
            for &c in &children[u] {
                stack.push(c);
            }
        }
        seen
    }

    /// Critical path length in *nodes* (longest chain; 0 for empty DAG).
    /// Requires acyclicity; returns `None` on cyclic graphs.
    pub fn critical_path_len(&self) -> Option<usize> {
        let order = self.topo_order()?;
        let mut depth = vec![1usize; self.nodes.len()];
        for &u in &order {
            for &d in &self.nodes[u].deps {
                depth[u] = depth[u].max(depth[d] + 1);
            }
        }
        Some(depth.into_iter().max().unwrap_or(0))
    }

    /// Weighted critical path: longest dependency chain where each node
    /// costs `weight(i)`. This is the virtual-clock lower bound on makespan
    /// with unlimited parallelism.
    pub fn critical_path_weighted<F: Fn(usize) -> f64>(&self, weight: F) -> Option<f64> {
        let order = self.topo_order()?;
        let mut finish = vec![0.0f64; self.nodes.len()];
        for &u in &order {
            let start = self.nodes[u]
                .deps
                .iter()
                .map(|&d| finish[d])
                .fold(0.0f64, f64::max);
            finish[u] = start + weight(u);
        }
        Some(finish.into_iter().fold(0.0, f64::max))
    }

    /// Paper Eq. 28: `R_comp = (n - L_crit) / n` — the fraction of steps
    /// that can be hidden by parallel execution.
    pub fn compression_ratio(&self) -> Option<f64> {
        let n = self.nodes.len();
        if n == 0 {
            return Some(0.0);
        }
        let lcrit = self.critical_path_len()?;
        Some((n - lcrit) as f64 / n as f64)
    }

    /// Topological position (depth from the roots) of each node; used as the
    /// "subtask position" axis of Figure 3 and as a router feature.
    pub fn depths(&self) -> Option<Vec<usize>> {
        let order = self.topo_order()?;
        let mut depth = vec![0usize; self.nodes.len()];
        for &u in &order {
            for &d in &self.nodes[u].deps {
                depth[u] = depth[u].max(depth[d] + 1);
            }
        }
        Some(depth)
    }

    /// The GENERATE sink (final aggregation node), if uniquely present.
    pub fn generate_sink(&self) -> Option<usize> {
        let sinks = self.sinks();
        let gens: Vec<usize> = sinks
            .into_iter()
            .filter(|&i| self.nodes[i].role == Role::Generate)
            .collect();
        (gens.len() == 1).then(|| gens[0])
    }
}

/// Flattened children adjacency (see [`TaskDag::children_csr`]):
/// `targets[offsets[i]..offsets[i + 1]]` are node `i`'s children in
/// ascending order. `u32` indices halve the edge-array footprint — plans
/// are bounded far below 2^32 nodes (`n_max` is single digits).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrChildren {
    offsets: Vec<u32>,
    targets: Vec<u32>,
}

impl CsrChildren {
    /// Children of node `i` (ascending node indices).
    pub fn children_of(&self, i: usize) -> &[u32] {
        &self.targets[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    pub fn n_nodes(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn n_edges(&self) -> usize {
        self.targets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Diamond: 0 -> {1, 2} -> 3.
    fn diamond() -> TaskDag {
        TaskDag::new(vec![
            Subtask::new(0, Role::Explain, "root", vec![]),
            Subtask::new(1, Role::Analyze, "left", vec![0]),
            Subtask::new(2, Role::Analyze, "right", vec![0]),
            Subtask::new(3, Role::Generate, "final", vec![1, 2]),
        ])
    }

    #[test]
    fn topo_respects_edges() {
        let d = diamond();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = (0..4).map(|i| order.iter().position(|&x| x == i).unwrap()).collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn cycle_detected() {
        let mut d = diamond();
        d.nodes[0].deps = vec![3];
        d.nodes[0].edge_conf = vec![1.0];
        assert!(!d.is_acyclic());
        assert!(d.topo_order().is_none());
        assert!(d.critical_path_len().is_none());
    }

    #[test]
    fn out_of_range_dep_is_cyclic_like() {
        let d = TaskDag::new(vec![Subtask::new(0, Role::Explain, "x", vec![7])]);
        assert!(d.topo_order().is_none());
    }

    #[test]
    fn critical_path_and_compression() {
        let d = diamond();
        assert_eq!(d.critical_path_len(), Some(3));
        assert!((d.compression_ratio().unwrap() - 0.25).abs() < 1e-12);

        let chain = TaskDag::chain(&["a".into(), "b".into(), "c".into()]);
        assert_eq!(chain.critical_path_len(), Some(3));
        assert_eq!(chain.compression_ratio(), Some(0.0));
    }

    #[test]
    fn weighted_critical_path() {
        let d = diamond();
        // weights: 1, 5, 2, 1 -> longest chain 0->1->3 = 7
        let w = [1.0, 5.0, 2.0, 1.0];
        let cp = d.critical_path_weighted(|i| w[i]).unwrap();
        assert!((cp - 7.0).abs() < 1e-12);
    }

    #[test]
    fn roots_sinks_depths() {
        let d = diamond();
        assert_eq!(d.roots(), vec![0]);
        assert_eq!(d.sinks(), vec![3]);
        assert_eq!(d.depths().unwrap(), vec![0, 1, 1, 2]);
        assert_eq!(d.generate_sink(), Some(3));
    }

    #[test]
    fn csr_matches_nested_children() {
        let mut cases = vec![
            diamond(),
            TaskDag::chain(&["a".into(), "b".into(), "c".into(), "d".into()]),
            TaskDag::new(vec![]),
            // Orphan + fan-in with an out-of-range dep (skipped by both).
            TaskDag::new(vec![
                Subtask::new(0, Role::Explain, "r", vec![]),
                Subtask::new(1, Role::Analyze, "a", vec![0, 9]),
                Subtask::new(2, Role::Analyze, "b", vec![0]),
                Subtask::new(3, Role::Generate, "g", vec![2, 1]),
            ]),
        ];
        // Wide fan-out: one root feeding many children.
        let mut wide = vec![Subtask::new(0, Role::Explain, "r", vec![])];
        for i in 1..30 {
            wide.push(Subtask::new(i, Role::Analyze, "x", vec![0]));
        }
        cases.push(TaskDag::new(wide));

        for dag in cases {
            let nested = dag.children();
            let csr = dag.children_csr();
            assert_eq!(csr.n_nodes(), dag.len());
            assert_eq!(csr.n_edges(), nested.iter().map(Vec::len).sum::<usize>());
            for (i, kids) in nested.iter().enumerate() {
                let flat: Vec<usize> =
                    csr.children_of(i).iter().map(|&c| c as usize).collect();
                assert_eq!(&flat, kids, "node {i}: CSR order must match children()");
            }
        }
    }

    #[test]
    fn chain_fallback_shape() {
        let c = TaskDag::chain(&["q1".into(), "q2".into(), "q3".into(), "q4".into()]);
        assert_eq!(c.nodes[0].role, Role::Explain);
        assert_eq!(c.nodes[3].role, Role::Generate);
        assert_eq!(c.nodes[2].deps, vec![1]);
        assert_eq!(c.roots(), vec![0]);
    }

    #[test]
    fn reachability() {
        let mut d = diamond();
        // Orphan node 4.
        d.nodes.push(Subtask::new(4, Role::Analyze, "orphan", vec![]));
        let seen = d.reachable_from(0);
        assert!(seen[0] && seen[1] && seen[2] && seen[3]);
        assert!(!seen[4]);
    }
}

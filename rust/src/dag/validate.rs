//! Definition C.2 validation: the six structural rules a decomposition must
//! satisfy before the scheduler will execute it as a DAG.

use super::graph::TaskDag;
use super::node::Role;
use std::collections::BTreeSet;
use std::fmt;

/// A specific rule violation found during validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Violation {
    /// Rule 1: graph contains a cycle (or an out-of-range dep index).
    Cyclic,
    /// Rule 2: no unique EXPLAIN root with empty prerequisites.
    BadRoot { roots: Vec<usize> },
    /// Rule 3: node unreachable from the root.
    Unreachable { node: usize },
    /// Rule 4a: no GENERATE node at all.
    NoGenerate,
    /// Rule 4b: a GENERATE node has outgoing edges.
    GenerateNotSink { node: usize },
    /// Rule 4c: more than one GENERATE sink.
    MultipleGenerateSinks { nodes: Vec<usize> },
    /// Rule 5: more than `n_max` subtasks.
    TooLarge { n: usize, n_max: usize },
    /// Rule 6: a required symbol is not produced by any parent.
    MissingSymbol { node: usize, symbol: String },
    /// Structural: duplicate dep entries or self-dependency.
    MalformedDeps { node: usize },
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Violation::Cyclic => write!(f, "graph is cyclic"),
            Violation::BadRoot { roots } => write!(f, "no unique EXPLAIN root (roots: {roots:?})"),
            Violation::Unreachable { node } => write!(f, "node {node} unreachable from root"),
            Violation::NoGenerate => write!(f, "no GENERATE node"),
            Violation::GenerateNotSink { node } => write!(f, "GENERATE node {node} has children"),
            Violation::MultipleGenerateSinks { nodes } => {
                write!(f, "multiple GENERATE sinks: {nodes:?}")
            }
            Violation::TooLarge { n, n_max } => write!(f, "{n} subtasks exceeds n_max={n_max}"),
            Violation::MissingSymbol { node, symbol } => {
                write!(f, "node {node} requires '{symbol}' not produced by its parents")
            }
            Violation::MalformedDeps { node } => write!(f, "node {node} has malformed deps"),
        }
    }
}

/// Result of validating a DAG.
#[derive(Debug, Clone, Default)]
pub struct ValidationReport {
    pub violations: Vec<Violation>,
}

impl ValidationReport {
    pub fn is_valid(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Validate `dag` against Definition C.2 with subtask cap `n_max`.
pub fn validate(dag: &TaskDag, n_max: usize) -> ValidationReport {
    let mut report = ValidationReport::default();
    let n = dag.len();

    if n == 0 {
        report.violations.push(Violation::BadRoot { roots: vec![] });
        return report;
    }

    // Rule 5: size.
    if n > n_max {
        report.violations.push(Violation::TooLarge { n, n_max });
    }

    // Structural: self-deps / duplicate deps / range (range also caught by
    // topo, but report it as malformed for better repair targeting).
    for (i, node) in dag.nodes.iter().enumerate() {
        let unique: BTreeSet<usize> = node.deps.iter().copied().collect();
        if unique.len() != node.deps.len() || unique.contains(&i) || unique.iter().any(|&d| d >= n)
        {
            report.violations.push(Violation::MalformedDeps { node: i });
        }
    }

    // Rule 1: acyclicity (only meaningful if deps are in range).
    let acyclic = dag.is_acyclic();
    if !acyclic && !report.violations.iter().any(|v| matches!(v, Violation::MalformedDeps { .. })) {
        report.violations.push(Violation::Cyclic);
    } else if !acyclic {
        // Both malformed and possibly cyclic; record cycle only if real
        // cycle exists among in-range edges.
        let cleaned = clean_range(dag);
        if !cleaned.is_acyclic() {
            report.violations.push(Violation::Cyclic);
        }
    }

    // Rule 2: unique EXPLAIN root.
    let roots = dag.roots();
    let root_ok = roots.len() == 1 && dag.nodes[roots[0]].role == Role::Explain;
    if !root_ok {
        report.violations.push(Violation::BadRoot { roots: roots.clone() });
    }

    // Rule 3: reachability from the root (only checkable with a root).
    if let [root] = roots.as_slice() {
        let seen = dag.reachable_from(*root);
        for (i, ok) in seen.iter().enumerate() {
            if !ok {
                report.violations.push(Violation::Unreachable { node: i });
            }
        }
    }

    // Rule 4: GENERATE sink discipline.
    let children = dag.children();
    let gens: Vec<usize> =
        (0..n).filter(|&i| dag.nodes[i].role == Role::Generate).collect();
    if gens.is_empty() {
        report.violations.push(Violation::NoGenerate);
    }
    for &g in &gens {
        if !children[g].is_empty() {
            report.violations.push(Violation::GenerateNotSink { node: g });
        }
    }
    let gen_sinks: Vec<usize> =
        gens.iter().copied().filter(|&g| children[g].is_empty()).collect();
    if gen_sinks.len() > 1 {
        report.violations.push(Violation::MultipleGenerateSinks { nodes: gen_sinks });
    }

    // Rule 6: dependency consistency Req(t_i) ⊆ ∪ Prod(parents).
    for (i, node) in dag.nodes.iter().enumerate() {
        if node.req.is_empty() {
            continue;
        }
        let produced: BTreeSet<&str> = node
            .deps
            .iter()
            .filter(|&&d| d < n)
            .flat_map(|&d| dag.nodes[d].prod.iter().map(String::as_str))
            .collect();
        for sym in &node.req {
            if !produced.contains(sym.as_str()) {
                report
                    .violations
                    .push(Violation::MissingSymbol { node: i, symbol: sym.clone() });
            }
        }
    }

    report
}

/// Copy of the DAG with out-of-range / duplicate / self deps dropped.
pub(crate) fn clean_range(dag: &TaskDag) -> TaskDag {
    let n = dag.len();
    let mut out = dag.clone();
    for (i, node) in out.nodes.iter_mut().enumerate() {
        let mut seen = BTreeSet::new();
        let mut deps = Vec::new();
        let mut conf = Vec::new();
        for (k, &d) in node.deps.iter().enumerate() {
            if d < n && d != i && seen.insert(d) {
                deps.push(d);
                conf.push(node.edge_conf.get(k).copied().unwrap_or(1.0));
            }
        }
        node.deps = deps;
        node.edge_conf = conf;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::node::Subtask;

    fn valid_dag() -> TaskDag {
        TaskDag::new(vec![
            Subtask::new(0, Role::Explain, "root", vec![]),
            Subtask::new(1, Role::Analyze, "a", vec![0]),
            Subtask::new(2, Role::Analyze, "b", vec![0]),
            Subtask::new(3, Role::Generate, "final", vec![1, 2]),
        ])
    }

    #[test]
    fn valid_dag_passes() {
        let r = validate(&valid_dag(), 7);
        assert!(r.is_valid(), "{:?}", r.violations);
    }

    #[test]
    fn detects_cycle() {
        let mut d = valid_dag();
        d.nodes[1].deps = vec![0, 3];
        d.nodes[1].edge_conf = vec![1.0, 1.0];
        let r = validate(&d, 7);
        assert!(r.violations.contains(&Violation::Cyclic));
    }

    #[test]
    fn detects_bad_root() {
        let mut d = valid_dag();
        d.nodes[0].role = Role::Analyze;
        let r = validate(&d, 7);
        assert!(matches!(r.violations[0], Violation::BadRoot { .. }));

        // Two roots.
        let mut d = valid_dag();
        d.nodes[1].deps.clear();
        d.nodes[1].edge_conf.clear();
        let r = validate(&d, 7);
        assert!(r.violations.iter().any(|v| matches!(v, Violation::BadRoot { .. })));
    }

    #[test]
    fn detects_unreachable() {
        let mut d = valid_dag();
        d.nodes.push(Subtask::new(4, Role::Analyze, "orphan... depends on nothing", vec![]));
        // Node 4 is now a second root AND unreachable; make it non-root by
        // pointing it at itself -> malformed; instead test pure orphan:
        let r = validate(&d, 7);
        assert!(r.violations.iter().any(|v| matches!(v, Violation::BadRoot { .. })));
    }

    #[test]
    fn detects_generate_rules() {
        // No generate.
        let mut d = valid_dag();
        d.nodes[3].role = Role::Analyze;
        let r = validate(&d, 7);
        assert!(r.violations.contains(&Violation::NoGenerate));

        // Generate with children.
        let mut d = valid_dag();
        d.nodes[1].role = Role::Generate;
        let r = validate(&d, 7);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, Violation::GenerateNotSink { node: 1 })));
    }

    #[test]
    fn detects_multiple_generate_sinks() {
        let mut d = valid_dag();
        d.nodes.push(Subtask::new(4, Role::Generate, "final2", vec![1]));
        let r = validate(&d, 7);
        assert!(r
            .violations
            .iter()
            .any(|v| matches!(v, Violation::MultipleGenerateSinks { .. })));
    }

    #[test]
    fn detects_too_large() {
        let descs: Vec<String> = (0..9).map(|i| format!("s{i}")).collect();
        let d = TaskDag::chain(&descs);
        let r = validate(&d, 7);
        assert!(r.violations.contains(&Violation::TooLarge { n: 9, n_max: 7 }));
    }

    #[test]
    fn detects_missing_symbol() {
        let mut d = valid_dag();
        d.nodes[3].req = vec!["closure".into()];
        d.nodes[1].prod = vec!["assoc".into()];
        let r = validate(&d, 7);
        assert!(r.violations.iter().any(
            |v| matches!(v, Violation::MissingSymbol { node: 3, symbol } if symbol == "closure")
        ));
        // Satisfy it.
        d.nodes[1].prod = vec!["closure".into()];
        assert!(validate(&d, 7).is_valid());
    }

    #[test]
    fn detects_malformed_deps() {
        let mut d = valid_dag();
        d.nodes[2].deps = vec![0, 0];
        d.nodes[2].edge_conf = vec![1.0, 1.0];
        let r = validate(&d, 7);
        assert!(r.violations.iter().any(|v| matches!(v, Violation::MalformedDeps { node: 2 })));

        let mut d = valid_dag();
        d.nodes[2].deps = vec![9];
        d.nodes[2].edge_conf = vec![1.0];
        let r = validate(&d, 7);
        assert!(r.violations.iter().any(|v| matches!(v, Violation::MalformedDeps { node: 2 })));
    }

    #[test]
    fn clean_range_strips_bad_edges() {
        let mut d = valid_dag();
        d.nodes[2].deps = vec![0, 0, 9, 2];
        d.nodes[2].edge_conf = vec![0.5, 0.6, 0.7, 0.8];
        let c = clean_range(&d);
        assert_eq!(c.nodes[2].deps, vec![0]);
        assert_eq!(c.nodes[2].edge_conf, vec![0.5]);
    }

    #[test]
    fn empty_dag_invalid() {
        let r = validate(&TaskDag::new(vec![]), 7);
        assert!(!r.is_valid());
    }
}

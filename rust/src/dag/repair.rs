//! Bounded, deterministic plan repair (App. C "Validation and repair").
//!
//! The repair pipeline applies, per iteration:
//!   (i)   drop ill-typed edges (out-of-range, duplicate, self, and edges
//!         violating Req/Prod dependency consistency when a better producer
//!         exists),
//!   (ii)  break cycles by removing the lowest-confidence edge on a cycle
//!         (planner self-reported confidence; fixed priority order when
//!         absent, per the paper's footnote),
//!   (iii) enforce rootedness/reachability by attaching orphan nodes to the
//!         root,
//!   (iv)  GENERATE-sink discipline: relabel extra GENERATE nodes, append
//!         sinks to the final aggregation node, create one if missing,
//!   (v)   truncate to `n_max` subtasks (merging trailing nodes into the
//!         final GENERATE).
//!
//! If the plan is still invalid after `R_MAX` iterations (2 in all paper
//! experiments), we fall back to a sequential chain — execution is then
//! strictly ordered but always possible.

use super::graph::TaskDag;
use super::node::{Role, Subtask};
use super::validate::{clean_range, validate, Violation};

/// Repair iteration bound (paper: `R_max = 2`).
pub const R_MAX: usize = 2;

/// How a plan reached executable form (Table 5's row categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepairOutcome {
    /// Passed validation untouched.
    Valid,
    /// Fixed within `R_MAX` repair iterations (value = iterations used).
    Repaired(usize),
    /// Replaced by the sequential chain fallback.
    Fallback,
}

/// Validate and, if needed, repair `dag`. Always returns an executable DAG.
pub fn validate_and_repair(dag: &TaskDag, n_max: usize) -> (TaskDag, RepairOutcome) {
    if validate(dag, n_max).is_valid() {
        return (dag.clone(), RepairOutcome::Valid);
    }
    if dag.is_empty() {
        return (TaskDag::chain(&["answer the question".to_string()]), RepairOutcome::Fallback);
    }
    let mut cur = dag.clone();
    for iter in 1..=R_MAX {
        cur = repair_once(&cur, n_max);
        if validate(&cur, n_max).is_valid() {
            return (cur, RepairOutcome::Repaired(iter));
        }
    }
    let descs: Vec<String> = dag.nodes.iter().map(|n| n.desc.clone()).collect();
    let truncated: Vec<String> = descs.into_iter().take(n_max.max(1)).collect();
    (TaskDag::chain(&truncated), RepairOutcome::Fallback)
}

/// One deterministic repair sweep.
fn repair_once(dag: &TaskDag, n_max: usize) -> TaskDag {
    // (i) structural edge cleanup.
    let mut d = clean_range(dag);

    // (i-b) dependency consistency: for every missing required symbol, add an
    // edge from a producer if one exists (and it would not self-loop);
    // otherwise drop the requirement (the executor will re-derive it).
    let producers: Vec<(usize, Vec<String>)> =
        d.nodes.iter().map(|n| (n.id, n.prod.clone())).collect();
    for i in 0..d.nodes.len() {
        let mut add: Vec<usize> = Vec::new();
        let mut keep_req: Vec<String> = Vec::new();
        for sym in d.nodes[i].req.clone() {
            let satisfied = d.nodes[i]
                .deps
                .iter()
                .any(|&p| d.nodes[p].prod.iter().any(|s| *s == sym));
            if satisfied {
                keep_req.push(sym);
                continue;
            }
            if let Some((j, _)) = producers
                .iter()
                .enumerate()
                .find(|(j, (_, prods))| *j != i && prods.iter().any(|s| *s == sym))
            {
                add.push(j);
                keep_req.push(sym);
            }
            // No producer anywhere: requirement dropped.
        }
        d.nodes[i].req = keep_req;
        for j in add {
            if !d.nodes[i].deps.contains(&j) {
                d.nodes[i].deps.push(j);
                d.nodes[i].edge_conf.push(0.5); // synthetic edge, low confidence
            }
        }
    }

    // (ii) cycle breaking.
    while !d.is_acyclic() {
        remove_weakest_cycle_edge(&mut d);
    }

    // (iv-a) GENERATE discipline: relabel all but the best GENERATE.
    let gens: Vec<usize> =
        (0..d.nodes.len()).filter(|&i| d.nodes[i].role == Role::Generate).collect();
    if gens.is_empty() {
        if let Some(last) = d.nodes.len().checked_sub(1) {
            d.nodes[last].role = Role::Generate;
        }
    } else if gens.len() > 1 {
        // Keep the GENERATE with the largest depth (latest in the plan);
        // relabel the rest ANALYZE.
        let depths = d.depths().unwrap_or_else(|| vec![0; d.nodes.len()]);
        let keep = *gens.iter().max_by_key(|&&g| (depths[g], g)).unwrap();
        for &g in &gens {
            if g != keep {
                d.nodes[g].role = Role::Analyze;
            }
        }
    }

    // (ii-b) root discipline: choose the root, clear its deps, relabel.
    let root = choose_root(&d);
    d.nodes[root].deps.clear();
    d.nodes[root].edge_conf.clear();
    d.nodes[root].role = Role::Explain;

    // (iii) reachability: attach orphan subgraphs to the root.
    let seen = d.reachable_from(root);
    for i in 0..d.nodes.len() {
        if !seen[i] && d.nodes[i].deps.is_empty() && i != root {
            d.nodes[i].deps.push(root);
            d.nodes[i].edge_conf.push(0.5);
        }
    }
    // Second pass for nodes that were non-root orphans with deps inside an
    // unreachable cluster: attach cluster entry points to the root.
    let seen = d.reachable_from(root);
    for i in 0..d.nodes.len() {
        if !seen[i] {
            let reachable_dep = d.nodes[i].deps.iter().any(|&p| seen[p]);
            if !reachable_dep {
                d.nodes[i].deps.push(root);
                d.nodes[i].edge_conf.push(0.5);
            }
        }
    }

    // (iv-b) make the GENERATE node the unique sink: all other sinks feed it.
    let gen = (0..d.nodes.len())
        .filter(|&i| d.nodes[i].role == Role::Generate)
        .max_by_key(|&i| i)
        .unwrap_or(d.nodes.len() - 1);
    // GENERATE must have no children: re-point its children's dep to gen's deps.
    let children = d.children();
    for &c in &children[gen] {
        let node = &mut d.nodes[c];
        if let Some(k) = node.deps.iter().position(|&p| p == gen) {
            node.deps.remove(k);
            node.edge_conf.remove(k);
        }
    }
    let sinks = d.sinks();
    for s in sinks {
        if s != gen && !d.nodes[gen].deps.contains(&s) {
            d.nodes[gen].deps.push(s);
            d.nodes[gen].edge_conf.push(0.5);
        }
    }

    // (v) size cap: merge overflow nodes into the GENERATE node.
    if d.nodes.len() > n_max {
        d = truncate_to(&d, n_max);
    }

    d
}

/// Remove the lowest-confidence edge participating in a cycle.
fn remove_weakest_cycle_edge(d: &mut TaskDag) {
    // Find a cycle via DFS back-edge detection.
    let n = d.nodes.len();
    let children = d.children();
    let mut color = vec![0u8; n]; // 0 white, 1 gray, 2 black
    let mut parent_edge: Vec<Option<usize>> = vec![None; n];
    let mut cycle: Option<(usize, usize)> = None; // back edge u -> v

    fn dfs(
        u: usize,
        children: &[Vec<usize>],
        color: &mut [u8],
        parent_edge: &mut [Option<usize>],
        cycle: &mut Option<(usize, usize)>,
    ) {
        color[u] = 1;
        for &c in &children[u] {
            if cycle.is_some() {
                return;
            }
            if color[c] == 0 {
                parent_edge[c] = Some(u);
                dfs(c, children, color, parent_edge, cycle);
            } else if color[c] == 1 {
                *cycle = Some((u, c));
                return;
            }
        }
        color[u] = 2;
    }

    for s in 0..n {
        if color[s] == 0 && cycle.is_none() {
            dfs(s, &children, &mut color, &mut parent_edge, &mut cycle);
        }
    }

    let Some((u, v)) = cycle else {
        return; // acyclic (or out-of-range deps already cleaned)
    };

    // Reconstruct the cycle node list v -> ... -> u -> v.
    let mut path = vec![u];
    let mut cur = u;
    while cur != v {
        match parent_edge[cur] {
            Some(p) => {
                path.push(p);
                cur = p;
            }
            None => break,
        }
    }
    path.reverse(); // v ... u

    // Candidate edges on the cycle: (path[k] -> path[k+1]) and (u -> v).
    // Each edge (a -> b) is stored as `b.deps` containing `a`.
    let mut edges: Vec<(usize, usize, f64)> = Vec::new(); // (parent, child, conf)
    for w in path.windows(2) {
        let (a, b) = (w[0], w[1]);
        if let Some(k) = d.nodes[b].deps.iter().position(|&p| p == a) {
            edges.push((a, b, d.nodes[b].edge_conf.get(k).copied().unwrap_or(1.0)));
        }
    }
    if let Some(k) = d.nodes[v].deps.iter().position(|&p| p == u) {
        edges.push((u, v, d.nodes[v].edge_conf.get(k).copied().unwrap_or(1.0)));
    }

    // Lowest confidence first; ties by (parent, child) for determinism (the
    // paper's "fixed priority order" when confidences are absent/equal).
    let (a, b, _) = edges
        .into_iter()
        .min_by(|x, y| x.2.total_cmp(&y.2).then(x.0.cmp(&y.0)).then(x.1.cmp(&y.1)))
        .expect("cycle must contain at least one edge");
    let node = &mut d.nodes[b];
    if let Some(k) = node.deps.iter().position(|&p| p == a) {
        node.deps.remove(k);
        node.edge_conf.remove(k);
    }
}

/// Root selection priority: existing unique deg-0 EXPLAIN; else the first
/// EXPLAIN node; else node 0.
fn choose_root(d: &TaskDag) -> usize {
    let roots = d.roots();
    if let [r] = roots.as_slice() {
        if d.nodes[*r].role == Role::Explain {
            return *r;
        }
    }
    roots
        .iter()
        .copied()
        .find(|&r| d.nodes[r].role == Role::Explain)
        .or_else(|| (0..d.nodes.len()).find(|&i| d.nodes[i].role == Role::Explain))
        .unwrap_or(0)
}

/// Keep the first `n_max - 1` non-GENERATE nodes plus the GENERATE node,
/// re-indexing deps (dropped deps are redirected to the kept prefix).
fn truncate_to(d: &TaskDag, n_max: usize) -> TaskDag {
    let gen = (0..d.nodes.len())
        .filter(|&i| d.nodes[i].role == Role::Generate)
        .max_by_key(|&i| i)
        .unwrap_or(d.nodes.len() - 1);
    let mut keep: Vec<usize> = (0..d.nodes.len()).filter(|&i| i != gen).take(n_max - 1).collect();
    keep.push(gen);
    let index_of = |old: usize| keep.iter().position(|&k| k == old);

    let mut nodes = Vec::with_capacity(keep.len());
    for (new_id, &old) in keep.iter().enumerate() {
        let mut n = d.nodes[old].clone();
        n.id = new_id;
        let mut deps = Vec::new();
        let mut conf = Vec::new();
        for (k, &p) in n.deps.iter().enumerate() {
            if let Some(np) = index_of(p) {
                if np != new_id && !deps.contains(&np) {
                    deps.push(np);
                    conf.push(n.edge_conf.get(k).copied().unwrap_or(1.0));
                }
            }
        }
        n.deps = deps;
        n.edge_conf = conf;
        nodes.push(n);
    }
    TaskDag::new(nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn valid_dag() -> TaskDag {
        TaskDag::new(vec![
            Subtask::new(0, Role::Explain, "root", vec![]),
            Subtask::new(1, Role::Analyze, "a", vec![0]),
            Subtask::new(2, Role::Analyze, "b", vec![0]),
            Subtask::new(3, Role::Generate, "final", vec![1, 2]),
        ])
    }

    #[test]
    fn valid_passes_through_unchanged() {
        let d = valid_dag();
        let (out, outcome) = validate_and_repair(&d, 7);
        assert_eq!(outcome, RepairOutcome::Valid);
        assert_eq!(out, d);
    }

    #[test]
    fn repairs_cycle_by_lowest_confidence() {
        let mut d = valid_dag();
        // Introduce cycle 1 -> 3 -> 1 where the 3->1 edge has low confidence.
        d.nodes[1].deps = vec![0, 3];
        d.nodes[1].edge_conf = vec![1.0, 0.1];
        let (out, outcome) = validate_and_repair(&d, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_)));
        assert!(validate(&out, 7).is_valid());
        // The low-confidence edge 3->1 is gone; 0->1 survives.
        assert!(out.nodes[1].deps.contains(&0));
        assert!(!out.nodes[1].deps.contains(&3));
    }

    #[test]
    fn repairs_orphans_to_root() {
        let mut d = valid_dag();
        d.nodes.push(Subtask::new(4, Role::Analyze, "orphan", vec![]));
        let (out, outcome) = validate_and_repair(&d, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_)));
        assert!(validate(&out, 7).is_valid());
        // Orphan now hangs off the root and feeds the GENERATE sink.
        assert!(out.nodes[4].deps.contains(&0));
    }

    #[test]
    fn repairs_missing_generate() {
        let mut d = valid_dag();
        d.nodes[3].role = Role::Analyze;
        let (out, outcome) = validate_and_repair(&d, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_)));
        assert_eq!(out.generate_sink().is_some(), true);
    }

    #[test]
    fn repairs_multiple_generates() {
        let mut d = valid_dag();
        d.nodes[1].role = Role::Generate;
        let (out, outcome) = validate_and_repair(&d, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_)));
        assert!(validate(&out, 7).is_valid());
        let gens = out.nodes.iter().filter(|n| n.role == Role::Generate).count();
        assert_eq!(gens, 1);
    }

    #[test]
    fn repairs_missing_symbol_by_adding_edge() {
        let mut d = valid_dag();
        d.nodes[2].prod = vec!["lemma".into()];
        d.nodes[1].req = vec!["lemma".into()]; // parent 0 doesn't produce it
        let (out, outcome) = validate_and_repair(&d, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_)), "{outcome:?}");
        assert!(validate(&out, 7).is_valid());
        assert!(out.nodes[1].deps.contains(&2), "edge from producer added");
    }

    #[test]
    fn drops_unproducible_symbol() {
        let mut d = valid_dag();
        d.nodes[1].req = vec!["nowhere".into()];
        let (out, outcome) = validate_and_repair(&d, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_)));
        assert!(out.nodes[1].req.is_empty());
    }

    #[test]
    fn truncates_oversized_plans() {
        let descs: Vec<String> = (0..10).map(|i| format!("s{i}")).collect();
        let mut big = TaskDag::chain(&descs); // 10 nodes, valid except size
        big.nodes[9].role = Role::Generate;
        let (out, outcome) = validate_and_repair(&big, 7);
        assert!(matches!(outcome, RepairOutcome::Repaired(_) | RepairOutcome::Fallback));
        assert!(out.len() <= 7);
        assert!(validate(&out, 7).is_valid());
    }

    #[test]
    fn hopeless_plan_falls_back_to_chain() {
        // All nodes in one big cycle of confident edges AND self-deps AND no
        // roles — after R_MAX sweeps this may still fail; fallback guarantees
        // an executable chain either way.
        let mut nodes = Vec::new();
        for i in 0..5 {
            let mut t = Subtask::new(i, Role::Analyze, &format!("s{i}"), vec![(i + 1) % 5]);
            t.edge_conf = vec![1.0];
            nodes.push(t);
        }
        let d = TaskDag::new(nodes);
        let (out, _outcome) = validate_and_repair(&d, 7);
        assert!(validate(&out, 7).is_valid());
    }

    #[test]
    fn fallback_preserves_descriptions() {
        let d = TaskDag::new(vec![]);
        let (out, outcome) = validate_and_repair(&d, 7);
        // Empty plan -> minimal valid chain (EXPLAIN root + GENERATE sink).
        assert_eq!(outcome, RepairOutcome::Fallback);
        assert_eq!(out.len(), 2);
        assert!(validate(&out, 7).is_valid());
    }

    #[test]
    fn repair_is_deterministic() {
        let mut d = valid_dag();
        d.nodes[1].deps = vec![0, 3];
        d.nodes[1].edge_conf = vec![1.0, 1.0]; // equal confidence -> priority order
        let (a, _) = validate_and_repair(&d, 7);
        let (b, _) = validate_and_repair(&d, 7);
        assert_eq!(a, b);
    }
}

//! Decomposition DAG subsystem: node/graph types, Definition C.2 validation,
//! bounded repair with chain fallback, and the XML plan format.

pub mod graph;
pub mod node;
pub mod repair;
pub mod validate;
pub mod xml;

pub use graph::{CsrChildren, TaskDag};
pub use node::{Role, Subtask};
pub use repair::{validate_and_repair, RepairOutcome, R_MAX};
pub use validate::{validate, ValidationReport, Violation};
pub use xml::{emit_plan, parse_plan};

//! XML plan format: the planner's output representation (Fig. 6).
//!
//! ```xml
//! <Plan>
//!   <Step ID="1" Task="Explain: What is asked?" Rely=""/>
//!   <Step ID="2" Task="Analyze: Check closure" Rely="1" Conf="0.9"
//!         Req="set_def" Prod="closure_ok" Tokens="120"/>
//!   <Step ID="6" Task="Generate: final answer" Rely="2,3,4,5"/>
//! </Plan>
//! ```
//!
//! The parser is hand-rolled (no XML crate offline) and deliberately
//! tolerant: unknown attributes are ignored, entity escapes are decoded,
//! `Rely` references to unknown IDs are preserved as out-of-range deps so
//! the validator reports them and repair drops them. A parse that cannot
//! even produce a node list is an error — the planner layer then falls back
//! to a chain plan, mirroring the paper's robustness path.

use super::graph::TaskDag;
use super::node::{Role, Subtask};
use std::collections::BTreeMap;

/// Parse an XML plan string into a [`TaskDag`].
///
/// Step IDs are arbitrary integers in the text and are remapped to dense
/// indices in document order. `Rely` entries naming unknown IDs map to an
/// out-of-range index (`usize::MAX`-ish sentinel clamped to `n`), which the
/// validator flags as `MalformedDeps`.
pub fn parse_plan(text: &str) -> anyhow::Result<TaskDag> {
    let steps = extract_elements(text, "Step")?;
    anyhow::ensure!(!steps.is_empty(), "plan contains no <Step> elements");

    // First pass: collect ids in document order.
    let mut id_to_index: BTreeMap<i64, usize> = BTreeMap::new();
    let mut parsed: Vec<(i64, BTreeMap<String, String>)> = Vec::new();
    for attrs in steps {
        let id: i64 = attrs
            .get("ID")
            .or_else(|| attrs.get("Id"))
            .or_else(|| attrs.get("id"))
            .ok_or_else(|| anyhow::anyhow!("<Step> missing ID attribute"))?
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("<Step> ID is not an integer"))?;
        let next = id_to_index.len();
        id_to_index.entry(id).or_insert(next);
        parsed.push((id, attrs));
    }

    let n = parsed.len();
    let mut nodes = Vec::with_capacity(n);
    for (idx, (_id, attrs)) in parsed.iter().enumerate() {
        let task = attrs.get("Task").cloned().unwrap_or_default();
        let role = Role::parse(&task).unwrap_or(Role::Analyze);
        let rely = attrs.get("Rely").map(String::as_str).unwrap_or("");
        let mut deps = Vec::new();
        for part in rely.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.parse::<i64>() {
                Ok(rid) => {
                    // Unknown IDs become out-of-range deps (flagged later).
                    deps.push(id_to_index.get(&rid).copied().unwrap_or(n));
                }
                Err(_) => deps.push(n),
            }
        }
        let conf: Vec<f64> = match attrs.get("Conf") {
            Some(c) => {
                let vals: Vec<f64> =
                    c.split(',').filter_map(|v| v.trim().parse().ok()).collect();
                if vals.len() == deps.len() {
                    vals
                } else if vals.len() == 1 {
                    vec![vals[0]; deps.len()]
                } else {
                    vec![1.0; deps.len()]
                }
            }
            None => vec![1.0; deps.len()],
        };
        let split_syms = |key: &str| -> Vec<String> {
            attrs
                .get(key)
                .map(|s| {
                    s.split(',')
                        .map(str::trim)
                        .filter(|x| !x.is_empty())
                        .map(String::from)
                        .collect()
                })
                .unwrap_or_default()
        };
        let est_tokens = attrs.get("Tokens").and_then(|t| t.trim().parse().ok()).unwrap_or(0.0);

        let mut node = Subtask::new(idx, role, task.trim(), deps);
        node.edge_conf = conf;
        node.req = split_syms("Req");
        node.prod = split_syms("Prod");
        node.est_tokens = est_tokens;
        nodes.push(node);
    }
    Ok(TaskDag::new(nodes))
}

/// Serialize a DAG back to the XML plan format (round-trip support).
pub fn emit_plan(dag: &TaskDag) -> String {
    let mut out = String::from("<Plan>\n");
    for node in &dag.nodes {
        let rely: Vec<String> = node.deps.iter().map(|d| (d + 1).to_string()).collect();
        // The role is carried by the Task prefix (Fig. 6's format); prepend
        // it when the description does not already encode the same role, so
        // emit -> parse round-trips preserve roles.
        let desc = if Role::parse(&node.desc) == Some(node.role) {
            node.desc.clone()
        } else {
            format!("{}: {}", capitalized(node.role), node.desc)
        };
        out.push_str(&format!(
            "  <Step ID=\"{}\" Task=\"{}\" Rely=\"{}\"",
            node.id + 1,
            escape(&desc),
            rely.join(",")
        ));
        if node.edge_conf.iter().any(|&c| c != 1.0) {
            let confs: Vec<String> = node.edge_conf.iter().map(|c| format!("{c}")).collect();
            out.push_str(&format!(" Conf=\"{}\"", confs.join(",")));
        }
        if !node.req.is_empty() {
            out.push_str(&format!(" Req=\"{}\"", escape(&node.req.join(","))));
        }
        if !node.prod.is_empty() {
            out.push_str(&format!(" Prod=\"{}\"", escape(&node.prod.join(","))));
        }
        if node.est_tokens > 0.0 {
            out.push_str(&format!(" Tokens=\"{}\"", node.est_tokens));
        }
        out.push_str("/>\n");
    }
    out.push_str("</Plan>");
    out
}

// ---------------------------------------------------------------------------
// Minimal tolerant XML scanning.
// ---------------------------------------------------------------------------

/// Extract attribute maps of every `<name .../>` or `<name ...>` element.
fn extract_elements(text: &str, name: &str) -> anyhow::Result<Vec<BTreeMap<String, String>>> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    let open = format!("<{name}");
    while let Some(pos) = text[i..].find(&open) {
        let start = i + pos + open.len();
        // Must be followed by whitespace, '/', or '>' (not a longer tag name).
        match bytes.get(start) {
            Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r') | Some(b'/') | Some(b'>') => {}
            _ => {
                i = start;
                continue;
            }
        }
        let end = text[start..]
            .find('>')
            .ok_or_else(|| anyhow::anyhow!("unterminated <{name}> element"))?;
        let attr_text = text[start..start + end].trim_end_matches('/');
        out.push(parse_attrs(attr_text)?);
        i = start + end + 1;
    }
    Ok(out)
}

/// Parse `key="value"` pairs; values may use single or double quotes.
fn parse_attrs(s: &str) -> anyhow::Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let bytes = s.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() {
            break;
        }
        let key_start = i;
        while i < bytes.len() && bytes[i] != b'=' && !(bytes[i] as char).is_whitespace() {
            i += 1;
        }
        let key = s[key_start..i].trim().to_string();
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'=' {
            // Attribute without value (HTML-ish); store empty.
            if !key.is_empty() {
                out.insert(key, String::new());
            }
            continue;
        }
        i += 1; // '='
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        anyhow::ensure!(i < bytes.len(), "attribute '{key}' missing value");
        let quote = bytes[i];
        anyhow::ensure!(quote == b'"' || quote == b'\'', "attribute '{key}' value not quoted");
        i += 1;
        let val_start = i;
        while i < bytes.len() && bytes[i] != quote {
            i += 1;
        }
        anyhow::ensure!(i < bytes.len(), "attribute '{key}' unterminated value");
        out.insert(key, unescape(&s[val_start..i]));
        i += 1;
    }
    Ok(out)
}

fn capitalized(role: Role) -> &'static str {
    match role {
        Role::Explain => "Explain",
        Role::Analyze => "Analyze",
        Role::Generate => "Generate",
    }
}

fn unescape(s: &str) -> String {
    s.replace("&lt;", "<")
        .replace("&gt;", ">")
        .replace("&quot;", "\"")
        .replace("&apos;", "'")
        .replace("&amp;", "&")
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::validate::validate;

    const PAPER_EXAMPLE: &str = r#"<Plan>
      <Step ID="1" Task="Explain: What is the set and the operation?" Rely=""/>
      <Step ID="2" Task="Analyze: Check the closure property" Rely="1"/>
      <Step ID="3" Task="Analyze: Check the associative property" Rely="1"/>
      <Step ID="4" Task="Analyze: Check the identity property" Rely="1"/>
      <Step ID="5" Task="Analyze: Check the inverse property" Rely="1"/>
      <Step ID="6" Task="Generate: final answer to the question" Rely="2,3,4,5"/>
    </Plan>"#;

    #[test]
    fn parses_paper_example() {
        let dag = parse_plan(PAPER_EXAMPLE).unwrap();
        assert_eq!(dag.len(), 6);
        assert_eq!(dag.nodes[0].role, Role::Explain);
        assert_eq!(dag.nodes[5].role, Role::Generate);
        assert_eq!(dag.nodes[5].deps, vec![1, 2, 3, 4]);
        assert!(validate(&dag, 7).is_valid());
        assert_eq!(dag.compression_ratio(), Some(0.5)); // 6 nodes, L_crit 3
    }

    #[test]
    fn parses_attributes() {
        let xml = r#"<Plan><Step ID="1" Task="Explain: x" Rely=""/>
            <Step ID="2" Task="Analyze: y" Rely="1" Conf="0.7" Req="a, b" Prod="c" Tokens="140"/>
            <Step ID="3" Task="Generate: z" Rely="2"/></Plan>"#;
        let dag = parse_plan(xml).unwrap();
        assert_eq!(dag.nodes[1].edge_conf, vec![0.7]);
        assert_eq!(dag.nodes[1].req, vec!["a", "b"]);
        assert_eq!(dag.nodes[1].prod, vec!["c"]);
        assert_eq!(dag.nodes[1].est_tokens, 140.0);
    }

    #[test]
    fn unknown_rely_id_becomes_out_of_range() {
        let xml = r#"<Plan><Step ID="1" Task="Explain: x" Rely=""/>
            <Step ID="2" Task="Generate: y" Rely="9"/></Plan>"#;
        let dag = parse_plan(xml).unwrap();
        assert_eq!(dag.nodes[1].deps, vec![2]); // n == 2, out of range
        assert!(!validate(&dag, 7).is_valid());
    }

    #[test]
    fn non_sequential_ids_are_remapped() {
        let xml = r#"<Plan><Step ID="10" Task="Explain: x" Rely=""/>
            <Step ID="30" Task="Analyze: y" Rely="10"/>
            <Step ID="20" Task="Generate: z" Rely="30,10"/></Plan>"#;
        let dag = parse_plan(xml).unwrap();
        assert_eq!(dag.nodes[1].deps, vec![0]);
        assert_eq!(dag.nodes[2].deps, vec![1, 0]);
    }

    #[test]
    fn entity_escapes_decode() {
        let xml = r#"<Plan><Step ID="1" Task="Explain: is x &lt; y &amp; z &quot;q&quot;?" Rely=""/></Plan>"#;
        let dag = parse_plan(xml).unwrap();
        assert_eq!(dag.nodes[0].desc, "Explain: is x < y & z \"q\"?");
    }

    #[test]
    fn garbage_is_an_error() {
        assert!(parse_plan("").is_err());
        assert!(parse_plan("<Plan></Plan>").is_err());
        assert!(parse_plan("no xml here").is_err());
        assert!(parse_plan(r#"<Plan><Step Task="x" Rely=""/></Plan>"#).is_err()); // no ID
        assert!(parse_plan(r#"<Plan><Step ID="a" Task="x"/></Plan>"#).is_err()); // bad ID
        assert!(parse_plan(r#"<Plan><Step ID="1" Task="x" Rely="1"#).is_err()); // unterminated
    }

    #[test]
    fn whitespace_and_single_quotes_tolerated() {
        let xml = "<Plan>\n  <Step  ID = '1'  Task = 'Explain: q'   Rely = '' />\n</Plan>";
        let dag = parse_plan(xml).unwrap();
        assert_eq!(dag.len(), 1);
        assert_eq!(dag.nodes[0].role, Role::Explain);
    }

    #[test]
    fn missing_role_prefix_defaults_to_analyze() {
        let xml = r#"<Plan><Step ID="1" Task="do something" Rely=""/></Plan>"#;
        let dag = parse_plan(xml).unwrap();
        assert_eq!(dag.nodes[0].role, Role::Analyze);
    }

    #[test]
    fn emit_parse_roundtrip() {
        let dag = parse_plan(PAPER_EXAMPLE).unwrap();
        let xml = emit_plan(&dag);
        let dag2 = parse_plan(&xml).unwrap();
        assert_eq!(dag.len(), dag2.len());
        for (a, b) in dag.nodes.iter().zip(&dag2.nodes) {
            assert_eq!(a.deps, b.deps);
            assert_eq!(a.role, b.role);
            assert_eq!(a.desc, b.desc);
        }
    }

    #[test]
    fn roundtrip_with_symbols_and_escapes() {
        let xml = r#"<Plan><Step ID="1" Task="Explain: &quot;tricky&quot; &amp; <ok>" Rely=""/></Plan>"#;
        // The raw '<ok>' inside the attribute is malformed XML; our tolerant
        // parser stops the attr at the quote, so craft via emit instead:
        let mut dag = parse_plan(r#"<Plan><Step ID="1" Task="Explain: q" Rely=""/></Plan>"#).unwrap();
        dag.nodes[0].desc = "Explain: \"tricky\" & <ok>".into();
        dag.nodes[0].prod = vec!["sym<1>".into()];
        let emitted = emit_plan(&dag);
        let back = parse_plan(&emitted).unwrap();
        assert_eq!(back.nodes[0].desc, dag.nodes[0].desc);
        assert_eq!(back.nodes[0].prod, dag.nodes[0].prod);
        let _ = xml;
    }
}

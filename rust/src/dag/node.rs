//! Subtask node type (Definition C.1: `t_i = (d_i, P_i, tau_i)` plus the
//! Req/Prod symbol sets used by the dependency-consistency check).

use std::fmt;

/// EAG role label (Definition C.1's `tau_i`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    Explain,
    Analyze,
    Generate,
}

impl Role {
    pub fn parse(s: &str) -> Option<Role> {
        let lower = s.trim().to_ascii_lowercase();
        if lower.starts_with("explain") {
            Some(Role::Explain)
        } else if lower.starts_with("analyze") || lower.starts_with("analyse") {
            Some(Role::Analyze)
        } else if lower.starts_with("generate") {
            Some(Role::Generate)
        } else {
            None
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Role::Explain => "EXPLAIN",
            Role::Analyze => "ANALYZE",
            Role::Generate => "GENERATE",
        }
    }

    /// Index into the feature one-hot / `role_tokens` tables.
    pub fn index(&self) -> usize {
        match self {
            Role::Explain => 0,
            Role::Analyze => 1,
            Role::Generate => 2,
        }
    }
}

impl fmt::Display for Role {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One subtask in a decomposition DAG.
///
/// `deps` holds indices of prerequisite subtasks within the owning
/// [`super::TaskDag`]; `edge_conf[k]` is the planner's self-reported
/// confidence for `deps[k]` (used by cycle-breaking repair; defaults to 1.0
/// when the planner does not report one — repair then falls back to a fixed
/// priority order, as in the paper's footnote 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Subtask {
    pub id: usize,
    pub desc: String,
    pub role: Role,
    pub deps: Vec<usize>,
    pub edge_conf: Vec<f64>,
    /// Symbols this subtask requires from its parents (Def. C.2 rule 6).
    pub req: Vec<String>,
    /// Symbols this subtask produces.
    pub prod: Vec<String>,
    /// Planner's output-token estimate (feature input; 0 = unknown).
    pub est_tokens: f64,
}

impl Subtask {
    pub fn new(id: usize, role: Role, desc: &str, deps: Vec<usize>) -> Subtask {
        let edge_conf = vec![1.0; deps.len()];
        Subtask {
            id,
            desc: desc.to_string(),
            role,
            deps,
            edge_conf,
            req: Vec::new(),
            prod: Vec::new(),
            est_tokens: 0.0,
        }
    }

    pub fn with_symbols(mut self, req: Vec<&str>, prod: Vec<&str>) -> Subtask {
        self.req = req.into_iter().map(String::from).collect();
        self.prod = prod.into_iter().map(String::from).collect();
        self
    }

    pub fn with_tokens(mut self, est: f64) -> Subtask {
        self.est_tokens = est;
        self
    }

    pub fn with_conf(mut self, conf: Vec<f64>) -> Subtask {
        assert_eq!(conf.len(), self.deps.len());
        self.edge_conf = conf;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn role_parse_variants() {
        assert_eq!(Role::parse("Explain: what is x"), Some(Role::Explain));
        assert_eq!(Role::parse("  ANALYZE the data"), Some(Role::Analyze));
        assert_eq!(Role::parse("analyse the data"), Some(Role::Analyze));
        assert_eq!(Role::parse("Generate: final"), Some(Role::Generate));
        assert_eq!(Role::parse("Summarize"), None);
    }

    #[test]
    fn role_roundtrip() {
        for r in [Role::Explain, Role::Analyze, Role::Generate] {
            assert_eq!(Role::parse(r.as_str()), Some(r));
        }
        assert_eq!(Role::Explain.index(), 0);
        assert_eq!(Role::Generate.index(), 2);
    }

    #[test]
    fn subtask_builders() {
        let t = Subtask::new(2, Role::Analyze, "check closure", vec![0, 1])
            .with_symbols(vec!["set_def"], vec!["closure_ok"])
            .with_tokens(120.0)
            .with_conf(vec![0.9, 0.4]);
        assert_eq!(t.deps, vec![0, 1]);
        assert_eq!(t.edge_conf, vec![0.9, 0.4]);
        assert_eq!(t.req, vec!["set_def"]);
        assert_eq!(t.prod, vec!["closure_ok"]);
        assert_eq!(t.est_tokens, 120.0);
    }

    #[test]
    #[should_panic]
    fn conf_length_must_match_deps() {
        let _ = Subtask::new(0, Role::Explain, "x", vec![1]).with_conf(vec![0.5, 0.5]);
    }
}

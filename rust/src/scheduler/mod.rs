//! Dependency-triggered subtask scheduler (Algorithm 1, Stage 2).
//!
//! Event-driven virtual-clock simulation with the paper's resource
//! semantics:
//! * the **edge** model serializes on a single on-device worker (one RTX
//!   3090 in the paper),
//! * **cloud** API calls run concurrently (bounded by `cloud_workers`),
//! * a subtask becomes *ready* the instant its last parent finishes; the
//!   router decides edge-vs-cloud at that moment with the budget state of
//!   that moment (online routing, Eq. 8's `C_used(t)`),
//! * `chain_mode` (HybridFlow-Chain ablation, Table 3) forces strictly
//!   sequential execution while keeping routing identical.
//!
//! The scheduler is built on two replaceable seams: model endpoints are
//! consumed through [`crate::engine::Backend`] (simulation, replay, or
//! future network backends) and routing decisions go through
//! `dyn Router` via [`RouterState`] — the scheduler never matches on
//! policy variants.
//!
//! **Hedged speculative dispatch** (`ScheduleConfig::hedge`): a pivotal
//! subtask (predicted utility above `hedge_threshold`) that the router
//! kept on the edge also dispatches a speculative cloud replica. The first
//! replica to finish wins — its result and timing are used — and the loser
//! is cancelled: its worker slot is released and the unconsumed share of
//! any speculative cloud spend is refunded (`Cancel` events, see
//! [`CancelTicket`]). This cuts the latency tail that budget-pressured
//! routing otherwise inflicts on pivotal subtasks (cf. CE-CoLLM-style
//! edge-cloud speculation) at the cost of the consumed share of cancelled
//! cloud calls. With `hedge` off the engine is RNG-for-RNG identical to
//! the non-speculative scheduler (the fleet golden trace pins this).
//!
//! **Cross-query result cache** (`ScheduleConfig::cache`): with a
//! [`crate::cache::SubtaskCache`] attached, every decision point first
//! probes the cache under the node's canonical fingerprint (both side
//! keys, one lookup); a hit serves the stored record at the cache's
//! near-zero hit latency without occupying a worker or spending any
//! budget scope, and executed results are inserted for later queries.
//! With no cache (or capacity 0) the engine is byte-identical to the
//! uncached scheduler — the fleet golden trace pins this.
//!
//! The virtual clock measures `C_time` exactly as the paper does: planner
//! decomposition latency + DAG makespan under these constraints. Wall-clock
//! coordinator overhead is measured separately (`server` module + benches).
//!
//! There is exactly **one** event loop in the engine: the unified
//! [`crate::sim::Kernel`]. This module owns the per-group decision core
//! ([`run_group`]) the kernel calls at every decision point, and
//! [`execute_query`] — the paper's per-query semantics — is literally the
//! kernel with one tenant and one pre-planned arrival under a query-local
//! budget scope. Fleet mode (shared pools, tenant/global dollar scopes,
//! admission queueing) is the same kernel via [`crate::sim::run_fleet`].

pub mod events;
pub mod fleet;
pub mod pool;

use crate::budget::{BudgetState, GlobalBudget, TenantPool};
use crate::cache::{CachedResult, Fingerprint, SubtaskCache};
use crate::dag::TaskDag;
use crate::embed::{FeatureContext, Features};
use crate::engine::Backend;
use crate::fault::{FaultMark, FaultModel, FaultStats};
use crate::router::predictor::UtilityPredictor;
use crate::router::{RoutePolicy, RouterState};
use crate::util::rng::Rng;
use crate::workload::{Query, SubtaskLatent};
use events::TraceEvent;
use pool::WorkerPool;
use std::sync::Arc;

/// Scheduling configuration.
#[derive(Debug, Clone)]
pub struct ScheduleConfig {
    /// Force sequential execution (HybridFlow-Chain).
    pub chain_mode: bool,
    /// On-device workers (paper: 1).
    pub edge_workers: usize,
    /// Concurrent cloud calls allowed (API concurrency).
    pub cloud_workers: usize,
    /// Score the whole ready frontier in one batched predictor call
    /// (performance path) vs. one call per decision (paper-literal path).
    pub batch_frontier: bool,
    /// Hedged speculative dispatch: edge-routed pivotal subtasks also
    /// dispatch a speculative cloud replica; first finish wins, the loser
    /// is cancelled with a budget refund. Ignored in `chain_mode`.
    pub hedge: bool,
    /// Predicted-utility cutoff above which an edge-routed subtask counts
    /// as pivotal enough to hedge.
    pub hedge_threshold: f64,
    /// Cross-query subtask result cache ([`crate::cache::SubtaskCache`]).
    /// `None` (or an attached cache with capacity 0) leaves every
    /// execution path untouched — RNG-for-RNG identical to the uncached
    /// engine (the fleet golden trace pins this). With a cache attached,
    /// decision points whose fingerprint hits short-circuit to a
    /// near-zero-latency completion: no worker is occupied, no budget is
    /// spent, and the stored record is served bit-identically.
    pub cache: Option<Arc<SubtaskCache>>,
    /// Run the kernel's worker pools on the retained linear `argmin`
    /// reference ([`pool::WorkerPool::linear_reference`]) instead of the
    /// O(log W) ordered index. Byte-identical semantics, O(W) claims —
    /// exists only so parity tests and `benches/kernel.rs` can measure
    /// the index against the baseline it replaced. Leave `false`.
    pub linear_pool_reference: bool,
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        ScheduleConfig {
            chain_mode: false,
            edge_workers: 1,
            cloud_workers: 8,
            batch_frontier: true,
            hedge: false,
            hedge_threshold: 0.55,
            cache: None,
            linear_pool_reference: false,
        }
    }
}

impl ScheduleConfig {
    /// The hedge gate passed to [`run_group`]: `Some(threshold)` when
    /// speculative dispatch is active for this configuration.
    pub(crate) fn hedge_gate(&self) -> Option<f64> {
        if self.hedge && !self.chain_mode {
            Some(self.hedge_threshold)
        } else {
            None
        }
    }

    /// The live cache passed to [`run_group`]: `None` when no cache is
    /// attached *or* the attached cache is disabled (capacity 0), so a
    /// `--cache 0` configuration takes the exact uncached code path.
    pub(crate) fn cache_gate(&self) -> Option<&SubtaskCache> {
        self.cache.as_deref().filter(|c| c.enabled())
    }
}

/// Outcome of one query's scheduled execution.
#[derive(Debug, Clone)]
pub struct QueryExecution {
    pub correct: bool,
    /// Virtual-clock end-to-end latency (planning + makespan), seconds.
    pub latency: f64,
    pub api_cost: f64,
    pub offload_rate: f64,
    pub n_subtasks: usize,
    pub events: Vec<TraceEvent>,
    pub budget: BudgetState,
    /// At least one subtask completed through graceful degradation (retry
    /// budget exhausted, served by the edge with fault checks suppressed).
    pub degraded: bool,
}

/// Mutable per-query execution accumulators shared by the single-query
/// scheduler and the fleet simulator.
pub(crate) struct QueryExecState {
    pub out_tokens: Vec<f64>,
    pub correct: Vec<bool>,
    pub api_total: f64,
    pub events: Vec<TraceEvent>,
    /// Query-local budget (reported in [`QueryExecution`]; also the routing
    /// budget in single-query mode).
    pub budget: BudgetState,
    /// Dispatch attempts made per node under the fault layer (0-based; the
    /// next attempt's index). Stays all-zero with faults off.
    pub attempts: Vec<u32>,
    /// Per-node failure counts by side (`[edge, cloud]`) — the failover
    /// trigger state.
    pub side_fails: Vec<[u32; 2]>,
    /// Whether any subtask completed through graceful degradation.
    pub degraded: bool,
    /// Per-query fault tally, rolled into the run's [`FaultStats`] at
    /// finalization (`degraded_queries` is derived there from `degraded`).
    pub fault: FaultStats,
}

impl QueryExecState {
    pub(crate) fn new(n: usize) -> QueryExecState {
        QueryExecState {
            out_tokens: vec![0.0; n],
            correct: vec![false; n],
            api_total: 0.0,
            events: Vec::with_capacity(n),
            budget: BudgetState::new(),
            attempts: vec![0; n],
            side_fails: vec![[0, 0]; n],
            degraded: false,
            fault: FaultStats::default(),
        }
    }
}

/// Immutable per-query context for group decisions.
pub(crate) struct GroupCtx<'a> {
    pub dag: &'a TaskDag,
    pub latents: &'a [SubtaskLatent],
    pub query: &'a Query,
    pub executor: &'a dyn Backend,
    pub predictor: &'a dyn UtilityPredictor,
    pub ctx: &'a FeatureContext,
    pub depths: &'a [usize],
    pub max_depth: usize,
}

/// Fleet-mode routing context: the tenant pool whose *aggregated* state the
/// router sees (fleet-level `C_used(t)` in Eq. 8's sense), the global
/// dollar ceiling it draws from, and the counter of decisions forced back
/// to the edge because a pool was exhausted.
pub(crate) struct FleetRouteCtx<'a> {
    pub tenant: &'a mut TenantPool,
    /// Index of `tenant` in the fleet's pool list — the cache partition
    /// this query's lookups and inserts are scoped to.
    pub tenant_idx: usize,
    pub global: &'a mut GlobalBudget,
    pub forced_edge: &'a mut usize,
}

/// What the caller should do when a dispatched attempt reaches `finish`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum DispatchOutcome {
    /// The node completed: mark it done and release its children.
    Done,
    /// The attempt failed (transient fault, outage rejection, or timeout):
    /// re-dispatch the node at virtual time `at` (finish + backoff). The
    /// node is *not* done; its children stay blocked.
    Retry { at: f64 },
}

/// One decided-and-dispatched node: the winning replica's timing plus the
/// optional losing replica of a hedged dispatch, to be cancelled by the
/// caller at the winner's finish instant.
#[derive(Debug, Clone)]
pub(crate) struct Dispatch {
    pub node: usize,
    pub start: f64,
    pub finish: f64,
    pub cancel: Option<CancelTicket>,
    pub outcome: DispatchOutcome,
}

/// A reservation to cancel: the losing replica of a hedged dispatch, or a
/// timed-out fault-layer attempt. `refund_*` is the unconsumed share of
/// the cloud spend (zero when the replica ran on the edge, which is free).
#[derive(Debug, Clone)]
pub(crate) struct CancelTicket {
    pub node: usize,
    /// Side of the cancelled replica.
    pub cloud: bool,
    /// Worker index holding the reservation.
    pub worker: usize,
    /// Reserved start / end on that worker.
    pub start: f64,
    pub reserved_until: f64,
    /// Normalized-cost and dollar refund due at cancellation.
    pub refund_c: f64,
    pub refund_k: f64,
    /// `true` for a fault-layer timeout cancellation (accounted in the
    /// fault stats), `false` for a hedge loser (accounted in the hedge
    /// stats).
    pub timeout: bool,
}

/// Fault-layer context for one query's dispatches: the kernel's
/// [`FaultModel`] plus the query's *global* arrival index, the axis that
/// keeps per-attempt fault streams shard-invariant.
pub(crate) struct FaultCtx<'a> {
    pub model: &'a FaultModel,
    pub q_global: u64,
}

/// Apply one cancellation at virtual time `cancel_time`: release the
/// loser's worker slot (unless a later reservation already stacked on top
/// of it) and refund the unconsumed speculative spend at every budget
/// scope the dispatch charged.
pub(crate) fn apply_cancel(
    t: &CancelTicket,
    cancel_time: f64,
    st: &mut QueryExecState,
    edge: &mut WorkerPool,
    cloud: &mut WorkerPool,
    mut fleet: Option<&mut FleetRouteCtx<'_>>,
) {
    let pool = if t.cloud { cloud } else { edge };
    if pool.free_at(t.worker) == t.reserved_until {
        // Cancelled before start => released at the reserved start (the
        // replica never ran); mid-flight => released at the cancel instant.
        pool.set_free(t.worker, cancel_time.clamp(t.start, t.reserved_until));
    }
    if t.refund_c > 0.0 || t.refund_k > 0.0 {
        st.budget.refund(t.refund_c, t.refund_k);
        st.api_total = (st.api_total - t.refund_k).max(0.0);
        if let Some(f) = fleet.as_deref_mut() {
            f.tenant.state.refund(t.refund_c, t.refund_k);
            f.global.refund(t.refund_k);
        }
    }
}

/// Decide and execute one ready group (Algorithm 1's inner loop).
///
/// This is the shared decision core the unified kernel
/// ([`crate::sim::Kernel`]) calls at every decision point: in query-local
/// scope with `fleet = None` (routing budget = the query's own
/// `st.budget`, the `execute_query` semantics), in fleet scope with
/// `fleet = Some(..)` (routing budget = the tenant's aggregated state,
/// shared pools, cap overrides). The RNG consumption sequence is
/// identical in both modes, which is what makes the kernel's
/// single-query case reproduce `execute_query` exactly.
///
/// `hedge` is `Some(threshold)` to enable speculative dual dispatch for
/// edge-routed subtasks with `u_hat > threshold`. Hedged replicas draw
/// from a per-node RNG stream forked off the query stream (one fork draw
/// per hedged node), so the main stream's consumption with `hedge = None`
/// is exactly the pre-hedging sequence.
///
/// `cache` is the cross-query result cache gate (`None` = uncached engine,
/// byte-identical to the pre-cache scheduler). A fingerprint hit
/// short-circuits the whole decision: the stored record is served at the
/// cache's near-zero hit latency on no worker, no tenant/global budget is
/// spent, and the router is consulted only through the advisory
/// `cached = true` hook (fresh tau for the trace event, no threshold
/// step). Executed (non-hit) results are inserted under the node's
/// fingerprint for later queries.
///
/// `faults` is the fault-injection + resilience gate (`None` = the exact
/// pre-fault engine). With a fault context, every non-cached dispatch is
/// one *attempt*: it may be rejected instantly by an outage window (no
/// work, no cost), fail transiently after performing (and billing) its
/// work, straggle, or be cancelled by the per-subtask timeout with the
/// unconsumed cost share refunded. Failed attempts return a
/// [`DispatchOutcome::Retry`] carrying the backoff-delayed re-dispatch
/// time; the retry budget's exhaustion degrades the node to a guaranteed
/// edge completion. All fault draws come from streams forked off the
/// global `(query, node, attempt)` index — never from the query stream —
/// so a fault config that never fires consumes RNG identically to
/// `faults = None`. Hedging is disabled under the fault layer (a
/// speculative replica of a failing attempt has no defined semantics).
///
/// `plan_done` is the virtual time planning finished (the origin for the
/// budget's latency frontier). Executed nodes are appended to `dispatched`;
/// the caller schedules winner completions, retries, and cancellations.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_group(
    g: &GroupCtx<'_>,
    now: f64,
    group: &[usize],
    plan_done: f64,
    st: &mut QueryExecState,
    router: &mut RouterState,
    rng: &mut Rng,
    edge: &mut WorkerPool,
    cloud: &mut WorkerPool,
    mut chain_clock: Option<&mut f64>,
    mut fleet: Option<&mut FleetRouteCtx<'_>>,
    hedge: Option<f64>,
    cache: Option<&SubtaskCache>,
    faults: Option<&FaultCtx<'_>>,
    dispatched: &mut Vec<Dispatch>,
) {
    let sp = g.executor.sp();
    st.budget.advance_latency(now - plan_done);
    if let Some(f) = fleet.as_deref_mut() {
        f.tenant.state.advance_latency(now - plan_done);
    }

    // Score the whole group in one predictor call (batched-frontier path);
    // decisions still apply sequentially so budget/threshold dynamics are
    // unchanged.
    let group_feats: Vec<Features> = group
        .iter()
        .map(|&i| g.ctx.features(g.dag, i, &g.latents[i], sp, rng))
        .collect();
    let c_used = match fleet.as_deref_mut() {
        Some(f) => f.tenant.state.c_used,
        None => st.budget.c_used,
    };
    let group_u = g.predictor.predict(&group_feats, c_used);

    for (gi, &node) in group.iter().enumerate() {
        let u_hat = group_u[gi];
        let position = g.depths[node] as f64 / g.max_depth as f64;

        // --- Cross-query cache probe ---------------------------------------
        // Probe both side-fingerprints as one decision-point lookup; a hit
        // serves the stored record at near-zero latency on no worker and
        // spends no budget at any scope. Cloud-side first: when both sides
        // are cached, the stronger model's record wins, so a
        // cloud-preferring tenant is never silently downgraded to an
        // edge-quality answer another tenant warmed.
        if let Some(c) = cache {
            let tenant_part = fleet.as_deref().map_or(0, |f| f.tenant_idx);
            let role = g.dag.nodes[node].role;
            let probe = [
                Fingerprint::of_node(g.query, node, role, true),
                Fingerprint::of_node(g.query, node, role, false),
            ];
            if let Some(hit) = c.lookup_any(tenant_part, &probe, now) {
                // Advisory cache-aware routing hook: the router sees the
                // decision point (fresh tau for the trace) but must not
                // step resource-consumption state (RouteCtx::cached).
                let _ = match fleet.as_deref_mut() {
                    Some(f) => router
                        .decide_hinted(sp, u_hat, position, &f.tenant.state, None, true, rng),
                    None => {
                        router.decide_hinted(sp, u_hat, position, &st.budget, None, true, rng)
                    }
                };
                let tau = *router.tau_trace.last().unwrap_or(&0.0);
                let (start, finish_t) = if let Some(clock) = chain_clock.as_deref_mut() {
                    let s = *clock;
                    *clock += c.hit_latency();
                    (s, *clock)
                } else {
                    (now, now + c.hit_latency())
                };
                st.out_tokens[node] = hit.rec.out_tokens;
                st.correct[node] = hit.rec.correct;
                st.events.push(TraceEvent {
                    node,
                    position: g.depths[node],
                    cloud: hit.cloud,
                    tau,
                    u_hat,
                    start,
                    finish: finish_t,
                    api_cost: 0.0,
                    correct: hit.rec.correct,
                    in_tokens: hit.rec.in_tokens,
                    hedged: false,
                    cached: true,
                    worker: 0,
                    fault: FaultMark::default(),
                });
                dispatched.push(Dispatch {
                    node,
                    start,
                    finish: finish_t,
                    cancel: None,
                    outcome: DispatchOutcome::Done,
                });
                continue;
            }
        }

        let oracle_ratio = {
            let dq = g.executor.true_dq(g.query.domain, g.latents, node);
            // True normalized cost (mean latency form).
            let in_tok = g.query.query_tokens
                + g.dag.nodes[node].deps.iter().map(|&d| st.out_tokens[d]).sum::<f64>();
            let cloud_out = g.latents[node].out_tokens * sp.cloud_verbosity;
            let dl = (g.executor.profile(true).latency_mean(in_tok, cloud_out)
                - g.executor.profile(false).latency_mean(in_tok, g.latents[node].out_tokens))
                .max(0.0);
            let dk = g.executor.profile(true).api_cost(in_tok, cloud_out);
            let c = BudgetState::normalized_cost(sp, dl, dk);
            Some(dq / (c + sp.eps_utility))
        };
        // The bandit's delayed feedback needs the budget *as seen at
        // decision time*; `BudgetState` is plain-old-data (`Copy`), so the
        // snapshot is a stack copy — no allocation, no Clone machinery.
        let budget_at_decision;
        let decided_cloud;
        match fleet.as_deref_mut() {
            Some(f) => {
                budget_at_decision = f.tenant.state.snapshot();
                decided_cloud =
                    router.decide(sp, u_hat, position, &f.tenant.state, oracle_ratio, rng);
            }
            None => {
                budget_at_decision = st.budget.snapshot();
                decided_cloud =
                    router.decide(sp, u_hat, position, &st.budget, oracle_ratio, rng);
            }
        }
        // Pool exhaustion (fleet mode only): a tenant or global dollar cap
        // that has run dry forces the subtask back to the edge.
        let mut to_cloud = decided_cloud;
        if to_cloud {
            if let Some(f) = fleet.as_deref_mut() {
                if !(f.tenant.can_spend() && f.global.can_spend()) {
                    to_cloud = false;
                    *f.forced_edge += 1;
                }
            }
        }
        let tau = *router.tau_trace.last().unwrap_or(&0.0);

        let in_tok = g.query.query_tokens
            + g.dag.nodes[node].deps.iter().map(|&d| st.out_tokens[d]).sum::<f64>();

        // --- Fault layer: attempt bookkeeping, failover, degradation,
        // --- outage rejection ---------------------------------------------
        let mut fmark = FaultMark::default();
        let mut exec_cloud = to_cloud;
        let mut fdraws = None;
        if let Some(fc) = faults {
            let attempt = st.attempts[node];
            fmark.attempt = attempt;
            st.attempts[node] += 1;
            st.fault.attempts += 1;
            if attempt >= fc.model.max_attempts() {
                // Retry budget exhausted: graceful degradation. The attempt
                // runs on the edge with every fault check suppressed, so
                // the node — and therefore the DAG — always terminates.
                fmark.degraded = true;
                st.degraded = true;
                exec_cloud = false;
            } else {
                if fc.model.resilience.failover_after > 0
                    && st.side_fails[node][usize::from(exec_cloud)]
                        >= fc.model.resilience.failover_after as u32
                {
                    // Cross-side failover; onto the cloud side only while
                    // the dollar pools can still spend — otherwise degrade
                    // to edge instead of burning budget on a failing side.
                    let target = !exec_cloud;
                    let spendable = !target
                        || match fleet.as_deref_mut() {
                            Some(f) => f.tenant.can_spend() && f.global.can_spend(),
                            None => true,
                        };
                    if spendable {
                        exec_cloud = target;
                        fmark.failed_over = true;
                        st.fault.failovers += 1;
                    } else {
                        fmark.degraded = true;
                        st.degraded = true;
                        exec_cloud = false;
                    }
                }
                if !fmark.degraded {
                    fdraws = Some(fc.model.draws(
                        fc.q_global,
                        node as u64,
                        u64::from(attempt),
                        exec_cloud,
                    ));
                    let t_dispatch = chain_clock.as_deref().map_or(now, |c| *c);
                    if fc.model.in_outage(exec_cloud, t_dispatch) {
                        // Outage rejection: instant failure, no work
                        // performed, nothing billed, no worker occupied.
                        fmark.outage = true;
                        fmark.failed = true;
                        st.side_fails[node][usize::from(exec_cloud)] += 1;
                        st.fault.failures += 1;
                        st.fault.retries += 1;
                        let backoff = fdraws.as_ref().map_or(0.0, |d| d.backoff);
                        st.events.push(TraceEvent {
                            node,
                            position: g.depths[node],
                            cloud: exec_cloud,
                            tau,
                            u_hat,
                            start: t_dispatch,
                            finish: t_dispatch,
                            api_cost: 0.0,
                            correct: false,
                            in_tokens: in_tok,
                            hedged: false,
                            cached: false,
                            worker: 0,
                            fault: fmark,
                        });
                        if let Some(clock) = chain_clock.as_deref_mut() {
                            *clock += backoff;
                        }
                        dispatched.push(Dispatch {
                            node,
                            start: t_dispatch,
                            finish: t_dispatch,
                            cancel: None,
                            outcome: DispatchOutcome::Retry { at: t_dispatch + backoff },
                        });
                        continue;
                    }
                }
            }
        }

        // Speculative dual dispatch: an edge-routed pivotal subtask also
        // fires a cloud replica. In fleet mode the replica is gated on the
        // same dollar pools a routed cloud decision draws from; in
        // single-query mode there are no dollar pools (caps are a fleet
        // concept — routed cloud calls are ungated there too). Disabled
        // under the fault layer (see the function docs).
        let hedge_this = match hedge {
            Some(threshold)
                if faults.is_none() && !to_cloud && u_hat > threshold && chain_clock.is_none() =>
            {
                match fleet.as_deref_mut() {
                    Some(f) => f.tenant.can_spend() && f.global.can_spend(),
                    None => true,
                }
            }
            _ => false,
        };

        if hedge_this {
            // Per-node speculative stream: both replicas (and the bandit's
            // observation noise) draw from a fork, so the query stream
            // consumes exactly one draw per hedged node and the hedge-off
            // trace stays byte-identical.
            let mut hrng = rng.fork(node as u64);
            let rec_e =
                g.executor.execute_subtask(g.query.domain, &g.latents[node], in_tok, false, &mut hrng);
            let rec_c =
                g.executor.execute_subtask(g.query.domain, &g.latents[node], in_tok, true, &mut hrng);

            let (we, s_e, f_e) = edge.claim(now, rec_e.latency);
            let (wc, s_c, f_c) = cloud.claim(now, rec_c.latency);

            let cloud_wins = f_c < f_e;
            let edge_equiv =
                g.executor.profile(false).latency_mean(in_tok, g.latents[node].out_tokens);
            let dl_c = (rec_c.latency - edge_equiv).max(0.0);
            let c_norm = BudgetState::normalized_cost(sp, dl_c, rec_c.api_cost);

            let (start, finish_t, rec) =
                if cloud_wins { (s_c, f_c, rec_c) } else { (s_e, f_e, rec_e) };
            let cancel = if cloud_wins {
                // Winner = cloud: normal cloud accounting (the node counts
                // as offloaded); the edge loser just releases its worker.
                st.budget.record_cloud(sp, dl_c, rec_c.api_cost);
                st.api_total += rec_c.api_cost;
                if let Some(f) = fleet.as_deref_mut() {
                    f.tenant.state.record_cloud(sp, dl_c, rec_c.api_cost);
                    f.global.record(rec_c.api_cost);
                }
                let realized_dq = g.executor.true_dq(g.query.domain, g.latents, node)
                    + hrng.normal_ms(0.0, 0.02);
                router.observe_offloaded(
                    sp,
                    u_hat,
                    position,
                    &budget_at_decision,
                    realized_dq,
                    c_norm,
                );
                CancelTicket {
                    node,
                    cloud: false,
                    worker: we,
                    start: s_e,
                    reserved_until: f_e,
                    refund_c: 0.0,
                    refund_k: 0.0,
                    timeout: false,
                }
            } else {
                // Winner = edge: the node counts as an edge decision; the
                // speculative cloud call bills in full at dispatch and the
                // unconsumed share comes back at the cancel instant.
                st.budget.record_edge();
                st.budget.record_hedge_spend(c_norm, rec_c.api_cost);
                st.api_total += rec_c.api_cost;
                if let Some(f) = fleet.as_deref_mut() {
                    f.tenant.state.record_edge();
                    f.tenant.state.record_hedge_spend(c_norm, rec_c.api_cost);
                    f.global.record(rec_c.api_cost);
                }
                let consumed = if rec_c.latency > 0.0 {
                    ((finish_t - s_c) / rec_c.latency).clamp(0.0, 1.0)
                } else {
                    1.0
                };
                CancelTicket {
                    node,
                    cloud: true,
                    worker: wc,
                    start: s_c,
                    reserved_until: f_c,
                    refund_c: c_norm * (1.0 - consumed),
                    refund_k: rec_c.api_cost * (1.0 - consumed),
                    timeout: false,
                }
            };

            st.out_tokens[node] = rec.out_tokens;
            st.correct[node] = rec.correct;
            // The winning replica's result is cacheable like any other
            // execution; later fingerprint hits skip the whole hedge. The
            // entry only becomes servable at the winner's finish instant.
            if let Some(c) = cache {
                let tenant_part = fleet.as_deref().map_or(0, |f| f.tenant_idx);
                let role = g.dag.nodes[node].role;
                c.insert(
                    tenant_part,
                    Fingerprint::of_node(g.query, node, role, cloud_wins),
                    CachedResult { cloud: cloud_wins, rec },
                    now,
                    finish_t,
                );
            }
            st.events.push(TraceEvent {
                node,
                position: g.depths[node],
                cloud: cloud_wins,
                tau,
                u_hat,
                start,
                finish: finish_t,
                api_cost: rec_c.api_cost,
                correct: rec.correct,
                in_tokens: in_tok,
                hedged: true,
                cached: false,
                worker: if cloud_wins { wc } else { we },
                fault: FaultMark::default(),
            });
            dispatched.push(Dispatch {
                node,
                start,
                finish: finish_t,
                cancel: Some(cancel),
                outcome: DispatchOutcome::Done,
            });
            continue;
        }

        // --- Execution (non-hedged path) ----------------------------------
        // The backend call draws from the query stream exactly as in the
        // fault-free engine; straggler inflation and the fail verdict come
        // from the pre-drawn attempt stream, so a zero-probability fault
        // config consumes RNG identically to `faults = None`.
        let rec =
            g.executor.execute_subtask(g.query.domain, &g.latents[node], in_tok, exec_cloud, rng);
        let mut service = rec.latency;
        let mut transient_fail = false;
        if let Some(d) = fdraws.as_ref() {
            if d.straggler {
                if let Some(fc) = faults {
                    service *= fc.model.faults.straggler_mult;
                }
            }
            transient_fail = d.failed;
        }
        let timeout_hit = match faults {
            Some(fc) if !fmark.degraded => match fc.model.resilience.timeout {
                Some(tmo) if service > tmo => Some(tmo),
                _ => None,
            },
            _ => None,
        };
        let success = fmark.degraded || (!transient_fail && timeout_hit.is_none());

        if success {
            st.out_tokens[node] = rec.out_tokens;
            st.correct[node] = rec.correct;
        }
        st.api_total += rec.api_cost;

        // The worker is reserved for the full (possibly straggling) service
        // time; a timeout releases it at the deadline through the Cancel
        // machinery below, so `finish_t` (the attempt's observable end) and
        // `reserved_end` (the pool reservation) diverge only then.
        let dur = timeout_hit.unwrap_or(service);
        let (worker, start, finish_t, reserved_end) =
            if let Some(clock) = chain_clock.as_deref_mut() {
                let s = *clock;
                *clock += dur;
                (0, s, *clock, *clock)
            } else {
                let (w, s, f) =
                    if exec_cloud { cloud.claim(now, service) } else { edge.claim(now, service) };
                let finish = match timeout_hit {
                    Some(tmo) => s + tmo,
                    None => f,
                };
                (w, s, finish, f)
            };

        // --- Budget + bandit feedback -------------------------------------
        // Billing covers work actually performed: a failed or timed-out
        // cloud attempt still dispatched the call, so it bills in full here
        // (the timeout's unconsumed share comes back as a refund below).
        // The bandit observes zero quality gain for a failed attempt.
        let mut attempt_cost_c = 0.0;
        if exec_cloud {
            let edge_equiv =
                g.executor.profile(false).latency_mean(in_tok, g.latents[node].out_tokens);
            let dl = (service - edge_equiv).max(0.0);
            st.budget.record_cloud(sp, dl, rec.api_cost);
            if let Some(f) = fleet.as_deref_mut() {
                f.tenant.state.record_cloud(sp, dl, rec.api_cost);
                f.global.record(rec.api_cost);
            }
            let true_dq =
                if success { g.executor.true_dq(g.query.domain, g.latents, node) } else { 0.0 };
            let realized_dq = true_dq + rng.normal_ms(0.0, 0.02);
            let realized_c = BudgetState::normalized_cost(sp, dl, rec.api_cost);
            attempt_cost_c = realized_c;
            router.observe_offloaded(
                sp,
                u_hat,
                position,
                &budget_at_decision,
                realized_dq,
                realized_c,
            );
        } else {
            st.budget.record_edge();
            if let Some(f) = fleet.as_deref_mut() {
                f.tenant.state.record_edge();
            }
        }

        // --- Timeout: refund the unconsumed cost share; non-chain mode
        // --- releases the worker at the deadline via a Cancel ticket ------
        let mut cancel = None;
        if let Some(tmo) = timeout_hit {
            let consumed = if service > 0.0 { (tmo / service).clamp(0.0, 1.0) } else { 1.0 };
            let refund_c = attempt_cost_c * (1.0 - consumed);
            let refund_k = rec.api_cost * (1.0 - consumed);
            st.fault.refund += refund_k;
            if chain_clock.is_some() {
                // Chain mode occupies no pool worker and schedules no
                // Cancel event: the refund applies inline at the deadline.
                if refund_c > 0.0 || refund_k > 0.0 {
                    st.budget.refund(refund_c, refund_k);
                    st.api_total = (st.api_total - refund_k).max(0.0);
                    if let Some(f) = fleet.as_deref_mut() {
                        f.tenant.state.refund(refund_c, refund_k);
                        f.global.refund(refund_k);
                    }
                }
            } else {
                cancel = Some(CancelTicket {
                    node,
                    cloud: exec_cloud,
                    worker,
                    start,
                    reserved_until: reserved_end,
                    refund_c,
                    refund_k,
                    timeout: true,
                });
            }
        }

        if !success {
            st.side_fails[node][usize::from(exec_cloud)] += 1;
            if timeout_hit.is_some() {
                fmark.timeout = true;
                st.fault.timeouts += 1;
            } else {
                fmark.failed = true;
                st.fault.failures += 1;
            }
            st.fault.retries += 1;
        }

        // Populate the cross-query cache with the realized result; it is
        // servable to same-session probes only from its finish instant
        // (a result must not be consumed before it exists). Failed attempts
        // produced no servable result and are never cached.
        if success {
            if let Some(c) = cache {
                let tenant_part = fleet.as_deref().map_or(0, |f| f.tenant_idx);
                let role = g.dag.nodes[node].role;
                c.insert(
                    tenant_part,
                    Fingerprint::of_node(g.query, node, role, exec_cloud),
                    CachedResult { cloud: exec_cloud, rec },
                    now,
                    finish_t,
                );
            }
        }

        st.events.push(TraceEvent {
            node,
            position: g.depths[node],
            cloud: exec_cloud,
            tau,
            u_hat,
            start,
            finish: finish_t,
            api_cost: rec.api_cost,
            correct: success && rec.correct,
            in_tokens: rec.in_tokens,
            hedged: false,
            cached: false,
            worker,
            fault: fmark,
        });
        if success {
            dispatched.push(Dispatch {
                node,
                start,
                finish: finish_t,
                cancel,
                outcome: DispatchOutcome::Done,
            });
        } else {
            let backoff = fdraws.as_ref().map_or(0.0, |d| d.backoff);
            if let Some(clock) = chain_clock.as_deref_mut() {
                *clock += backoff;
            }
            dispatched.push(Dispatch {
                node,
                start,
                finish: finish_t,
                cancel,
                outcome: DispatchOutcome::Retry { at: finish_t + backoff },
            });
        }
    }
}

/// Execute one decomposed query under the routing policy.
///
/// `latents` must align with `dag.nodes`. The predictor scores features
/// packed by [`FeatureContext`]; the router state carries threshold/bandit
/// dynamics across the query (call `reset_for_query` between queries for
/// per-query dual state).
///
/// This is the unified kernel's N=1 special case: one pre-planned job
/// arriving at t=0 under a **query-local** budget scope (the router sees
/// the query's own [`BudgetState`], worker pools are private to the run,
/// and no tenant/global dollar pool exists to force-edge a decision). The
/// caller's RNG and router state flow through the kernel and come back
/// advanced, so call-for-call stream alignment with the pre-unification
/// scheduler holds (pinned by the single-query bit-identity grid).
///
/// Borrow-based compatibility wrapper over [`execute_query_arc`]: it
/// deep-copies the DAG (subtask text included) into the job. Hot callers
/// that own their plan — the pipeline does — should call
/// [`execute_query_arc`] instead, which moves the plan behind `Arc`s and
/// clones no node text.
#[allow(clippy::too_many_arguments)]
pub fn execute_query(
    dag: &TaskDag,
    latents: &[SubtaskLatent],
    query: &Query,
    executor: &dyn Backend,
    predictor: &dyn UtilityPredictor,
    router: &mut RouterState,
    planning_latency: f64,
    cfg: &ScheduleConfig,
    rng: &mut Rng,
) -> QueryExecution {
    execute_query_arc(
        Arc::new(dag.clone()),
        latents.to_vec(),
        Arc::new(query.clone()),
        executor,
        predictor,
        router,
        planning_latency,
        cfg,
        rng,
    )
}

/// Zero-copy form of [`execute_query`]: the caller hands over its plan
/// (`dag`, `latents`) and query by value/`Arc`, so building the kernel
/// job allocates nothing per query beyond the `Arc` headers — no
/// `Query`/DAG-text deep copies on the per-query hot path.
#[allow(clippy::too_many_arguments)]
pub fn execute_query_arc(
    dag: Arc<TaskDag>,
    latents: Vec<SubtaskLatent>,
    query: Arc<Query>,
    executor: &dyn Backend,
    predictor: &dyn UtilityPredictor,
    router: &mut RouterState,
    planning_latency: f64,
    cfg: &ScheduleConfig,
    rng: &mut Rng,
) -> QueryExecution {
    use crate::sim::{CacheSessions, Job, Kernel, KernelSpec, Preplanned};

    assert_eq!(dag.len(), latents.len(), "latents must align with dag");
    let job = Job {
        tenant: 0,
        query,
        arrival: 0.0,
        global_index: 0,
        rng: rng.clone(),
        // The kernel owns the router for the duration of the run; a cheap
        // placeholder keeps the caller's binding valid until hand-back.
        router: std::mem::replace(router, RouterState::new(RoutePolicy::AllEdge)),
        preplanned: Some(Preplanned { dag, latents, planning_latency }),
    };
    let kernel = Kernel {
        spec: KernelSpec {
            planner: None, // pre-planned job: the planner is never consulted
            executor,
            predictor,
            schedule: cfg,
            n_max: 0, // unused without a planner
            admission_limit: 0,
            record_trace: false,
            query_local: true,
            global_k_cap: f64::INFINITY,
            cache_sessions: CacheSessions::EpochPerRun,
            observe: None, // single-query mode is never observed
            fault: None,   // single-query mode runs fault-free
        },
        tenants: Vec::new(),
        jobs: vec![job],
    };
    let mut run = kernel.run();
    *router = run.routers.pop().expect("kernel returns the job's router");
    *rng = run.rngs.pop().expect("kernel returns the job's rng");
    run.report.results.pop().expect("single job completed").exec
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{Role, Subtask};
    use crate::models::SimExecutor;
    use crate::router::{MirrorPredictor, RoutePolicy};
    use crate::workload::{generate_queries, sample_latents, Benchmark};

    fn setup(seed: u64) -> (TaskDag, Query, Vec<SubtaskLatent>, SimExecutor) {
        let dag = TaskDag::new(vec![
            Subtask::new(0, Role::Explain, "r", vec![]),
            Subtask::new(1, Role::Analyze, "a", vec![0]),
            Subtask::new(2, Role::Analyze, "b", vec![0]),
            Subtask::new(3, Role::Analyze, "c", vec![0]),
            Subtask::new(4, Role::Generate, "g", vec![1, 2, 3]),
        ]);
        let ex = SimExecutor::paper_pair();
        let q = generate_queries(Benchmark::Gpqa, 1, seed).pop().unwrap();
        let mut rng = Rng::new(seed);
        let lat = sample_latents(&dag, &q, &ex.sp, &mut rng);
        (dag, q, lat, ex)
    }

    fn run(policy: RoutePolicy, cfg: &ScheduleConfig, seed: u64) -> QueryExecution {
        let (dag, q, lat, ex) = setup(seed);
        let pred = MirrorPredictor::synthetic_for_tests();
        let mut router = RouterState::new(policy);
        let mut rng = Rng::new(seed + 1);
        execute_query(&dag, &lat, &q, &ex, &pred, &mut router, 2.0, cfg, &mut rng)
    }

    #[test]
    fn all_edge_serializes_fully() {
        let exec = run(RoutePolicy::AllEdge, &ScheduleConfig::default(), 3);
        assert_eq!(exec.offload_rate, 0.0);
        assert_eq!(exec.api_cost, 0.0);
        // Single edge worker: makespan ~= planning + sum of latencies.
        let total: f64 = exec.events.iter().map(|e| e.finish - e.start).sum();
        assert!((exec.latency - (2.0 + total)).abs() < 1e-9, "{} vs {}", exec.latency, 2.0 + total);
    }

    #[test]
    fn all_cloud_exploits_parallelism() {
        let exec = run(RoutePolicy::AllCloud, &ScheduleConfig::default(), 4);
        assert_eq!(exec.offload_rate, 1.0);
        assert!(exec.api_cost > 0.0);
        // Parallel middle layer: makespan < sum of latencies.
        let total: f64 = exec.events.iter().map(|e| e.finish - e.start).sum();
        assert!(exec.latency < 2.0 + total - 1e-9);
    }

    #[test]
    fn chain_mode_removes_parallelism() {
        let cfg = ScheduleConfig { chain_mode: true, ..Default::default() };
        let par = run(RoutePolicy::AllCloud, &ScheduleConfig::default(), 5);
        let chain = run(RoutePolicy::AllCloud, &cfg, 5);
        assert!(chain.latency > par.latency, "chain {} par {}", chain.latency, par.latency);
        // Chain latency == planning + sum of latencies.
        let total: f64 = chain.events.iter().map(|e| e.finish - e.start).sum();
        assert!((chain.latency - (2.0 + total)).abs() < 1e-9);
    }

    #[test]
    fn dependencies_respected() {
        for seed in 0..10 {
            let exec = run(RoutePolicy::Random(0.5), &ScheduleConfig::default(), seed);
            let (dag, ..) = setup(seed);
            let finish_of = |n: usize| {
                exec.events.iter().find(|e| e.node == n).map(|e| e.finish).unwrap()
            };
            let start_of = |n: usize| {
                exec.events.iter().find(|e| e.node == n).map(|e| e.start).unwrap()
            };
            for node in &dag.nodes {
                for &d in &node.deps {
                    assert!(
                        start_of(node.id) >= finish_of(d) - 1e-9,
                        "node {} started before dep {} finished (seed {seed})",
                        node.id,
                        d
                    );
                }
            }
        }
    }

    #[test]
    fn makespan_bounds() {
        // critical path <= makespan <= planning + sum (single-worker bound).
        for seed in 0..10 {
            let exec = run(RoutePolicy::Random(0.4), &ScheduleConfig::default(), seed + 100);
            let total: f64 = exec.events.iter().map(|e| e.finish - e.start).sum();
            let longest = exec
                .events
                .iter()
                .map(|e| e.finish - e.start)
                .fold(0.0, f64::max);
            assert!(exec.latency >= 2.0 + longest - 1e-9);
            assert!(exec.latency <= 2.0 + total + 1e-9);
        }
    }

    #[test]
    fn budget_accumulates_only_for_cloud() {
        let exec = run(RoutePolicy::AllEdge, &ScheduleConfig::default(), 7);
        assert_eq!(exec.budget.c_used, 0.0);
        let exec = run(RoutePolicy::AllCloud, &ScheduleConfig::default(), 7);
        assert!(exec.budget.c_used > 0.0);
        assert!((exec.budget.k_used - exec.api_cost).abs() < 1e-12);
    }

    #[test]
    fn events_complete_and_positions_valid() {
        let exec = run(RoutePolicy::Random(0.5), &ScheduleConfig::default(), 8);
        assert_eq!(exec.events.len(), 5);
        assert_eq!(exec.n_subtasks, 5);
        let mut nodes: Vec<usize> = exec.events.iter().map(|e| e.node).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, vec![0, 1, 2, 3, 4]);
        for e in &exec.events {
            assert!(e.position <= 2);
            assert!(e.finish > e.start);
            assert!((0.0..=1.0).contains(&e.tau));
            assert!(!e.hedged, "hedging is off by default");
        }
    }

    #[test]
    fn hybridflow_policy_runs_and_adapts() {
        let sp = crate::config::simparams::SimParams::default();
        let exec = run(RoutePolicy::hybridflow(&sp), &ScheduleConfig::default(), 9);
        // Threshold trace exists and starts at tau0.
        assert_eq!(exec.events.len(), 5);
        let first_tau = exec.events.iter().min_by(|a, b| a.start.total_cmp(&b.start)).unwrap().tau;
        assert!((first_tau - sp.tau0).abs() < 0.3);
    }

    #[test]
    fn more_edge_workers_reduce_makespan() {
        let base = ScheduleConfig::default();
        let wide = ScheduleConfig { edge_workers: 4, ..Default::default() };
        let a = run(RoutePolicy::AllEdge, &base, 10);
        let b = run(RoutePolicy::AllEdge, &wide, 10);
        assert!(b.latency <= a.latency + 1e-9);
        assert!(b.latency < a.latency - 1e-9, "parallel edge should help on diamond");
    }

    // --- Hedged speculative dispatch --------------------------------------

    #[test]
    fn hedge_knobs_are_inert_when_off() {
        // Touching the hedge knobs with hedge=false must not perturb a
        // single RNG draw or timestamp (regression guard for the golden
        // trace's byte-identity).
        let base = ScheduleConfig::default();
        let touched = ScheduleConfig { hedge: false, hedge_threshold: 0.01, ..Default::default() };
        for seed in [3u64, 11, 42] {
            let a = run(RoutePolicy::Random(0.5), &base, seed);
            let b = run(RoutePolicy::Random(0.5), &touched, seed);
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.api_cost, b.api_cost);
            assert_eq!(a.correct, b.correct);
            assert_eq!(a.events.len(), b.events.len());
            for (x, y) in a.events.iter().zip(&b.events) {
                assert_eq!(x.start, y.start);
                assert_eq!(x.finish, y.finish);
                assert_eq!(x.cloud, y.cloud);
            }
        }
    }

    #[test]
    fn hedge_ignored_in_chain_mode() {
        let plain = ScheduleConfig { chain_mode: true, ..Default::default() };
        let hedged = ScheduleConfig {
            chain_mode: true,
            hedge: true,
            hedge_threshold: f64::NEG_INFINITY,
            ..Default::default()
        };
        let a = run(RoutePolicy::AllEdge, &plain, 6);
        let b = run(RoutePolicy::AllEdge, &hedged, 6);
        assert_eq!(a.latency, b.latency);
        assert!(b.events.iter().all(|e| !e.hedged));
    }

    #[test]
    fn hedged_dispatch_structure_and_accounting() {
        // Edge-routing policy + hedge-everything: every node is a hedged
        // dual dispatch; accounting must stay consistent under refunds.
        let cfg = ScheduleConfig {
            hedge: true,
            hedge_threshold: f64::NEG_INFINITY,
            ..Default::default()
        };
        for seed in 0..12u64 {
            let exec = run(RoutePolicy::AllEdge, &cfg, seed + 40);
            assert!(exec.events.iter().all(|e| e.hedged), "all nodes pivotal");
            // Net spend is consumed-share only: non-negative, and bounded
            // by the sum of full per-event bills.
            let billed: f64 = exec.events.iter().map(|e| e.api_cost).sum();
            assert!(exec.api_cost >= -1e-12, "net api {}", exec.api_cost);
            assert!(exec.api_cost <= billed + 1e-12, "net {} billed {billed}", exec.api_cost);
            assert!(exec.budget.k_used >= -1e-12);
            assert!((exec.budget.k_used - exec.api_cost).abs() < 1e-9);
            // Offload counters track cloud winners exactly.
            let cloud_wins = exec.events.iter().filter(|e| e.cloud).count();
            assert_eq!(exec.budget.n_offloaded, cloud_wins);
            assert_eq!(exec.budget.n_decided, exec.n_subtasks);
            // Dependencies still respected through winner finishes.
            let (dag, ..) = setup(seed + 40);
            let finish_of = |n: usize| {
                exec.events.iter().find(|e| e.node == n).map(|e| e.finish).unwrap()
            };
            for node in &dag.nodes {
                for &d in &node.deps {
                    let start =
                        exec.events.iter().find(|e| e.node == node.id).unwrap().start;
                    assert!(start >= finish_of(d) - 1e-9);
                }
            }
        }
    }

    #[test]
    fn hedging_cuts_mean_latency_on_serialized_edge() {
        // One edge worker fully serializes the diamond; hedging every node
        // lets pivotal subtasks escape to the parallel cloud pool, so mean
        // makespan across seeds must drop.
        let plain = ScheduleConfig::default();
        let hedged = ScheduleConfig {
            hedge: true,
            hedge_threshold: f64::NEG_INFINITY,
            ..Default::default()
        };
        let n = 40u64;
        let mean = |cfg: &ScheduleConfig| -> f64 {
            (0..n).map(|s| run(RoutePolicy::AllEdge, cfg, 200 + s).latency).sum::<f64>()
                / n as f64
        };
        let lat_plain = mean(&plain);
        let lat_hedged = mean(&hedged);
        assert!(
            lat_hedged < lat_plain,
            "hedged mean {lat_hedged} should beat serialized {lat_plain}"
        );
    }

    #[test]
    fn hedge_threshold_gates_speculation() {
        // An unreachable pivot threshold disables hedging entirely even
        // with hedge=true.
        let cfg = ScheduleConfig { hedge: true, hedge_threshold: f64::INFINITY, ..Default::default() };
        let exec = run(RoutePolicy::AllEdge, &cfg, 13);
        assert!(exec.events.iter().all(|e| !e.hedged));
        assert_eq!(exec.api_cost, 0.0);
    }

    // --- Cross-query result cache -----------------------------------------

    #[test]
    fn cache_absent_and_zero_capacity_are_identical() {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        use std::sync::Arc;
        // A capacity-0 cache must take the exact uncached code path: no
        // RNG perturbation, no timing drift, no cached events.
        let plain = ScheduleConfig::default();
        let zeroed = ScheduleConfig {
            cache: Some(Arc::new(SubtaskCache::new(0, CachePolicyKind::Lru))),
            ..Default::default()
        };
        for seed in [3u64, 11, 42] {
            let a = run(RoutePolicy::Random(0.5), &plain, seed);
            let b = run(RoutePolicy::Random(0.5), &zeroed, seed);
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.api_cost, b.api_cost);
            assert_eq!(a.correct, b.correct);
            for (x, y) in a.events.iter().zip(&b.events) {
                assert_eq!(x.start, y.start);
                assert_eq!(x.finish, y.finish);
                assert_eq!(x.cloud, y.cloud);
                assert!(!y.cached);
            }
        }
    }

    #[test]
    fn repeated_query_hits_cache_and_skips_cost() {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        use std::sync::Arc;
        // Same query executed twice through one cache: the second run must
        // serve every subtask from the cache — zero API cost, near-zero
        // makespan, results replaying the first run's records.
        let cache = Arc::new(SubtaskCache::new(64, CachePolicyKind::Lru));
        let cfg = ScheduleConfig { cache: Some(Arc::clone(&cache)), ..Default::default() };
        let (dag, q, lat, ex) = setup(21);
        let pred = MirrorPredictor::synthetic_for_tests();
        let run_once = |rng_seed: u64| {
            let mut router = RouterState::new(RoutePolicy::AllCloud);
            let mut rng = Rng::new(rng_seed);
            execute_query(&dag, &lat, &q, &ex, &pred, &mut router, 2.0, &cfg, &mut rng)
        };
        let first = run_once(100);
        assert!(first.events.iter().all(|e| !e.cached), "cold cache cannot hit");
        assert!(first.api_cost > 0.0);

        let second = run_once(200);
        assert!(second.events.iter().all(|e| e.cached), "warm cache must hit every node");
        assert_eq!(second.api_cost, 0.0, "hits spend nothing");
        assert_eq!(second.budget.k_used, 0.0);
        assert_eq!(second.budget.n_decided, 0, "hits are not routing decisions");
        // Cached correctness replays the first execution bit-for-bit.
        for (a, b) in first.events.iter().zip(&second.events) {
            assert_eq!(a.correct, b.correct, "node {}", a.node);
            assert_eq!(b.api_cost, 0.0);
            assert!(b.finish > b.start, "hit latency strictly positive");
        }
        // Near-zero completion: all 5 hits finish within 5 hit-latencies.
        let makespan = second.latency - 2.0;
        assert!(
            makespan <= 5.0 * cache.hit_latency() + 1e-9,
            "cached makespan {makespan} too large"
        );
        assert!(makespan < first.latency - 2.0, "cache must beat real execution");
        let stats = cache.stats();
        assert!(stats.hits >= 5);
        assert!(stats.tokens_saved > 0.0, "cloud-side hits save tokens");
        assert!(stats.dollars_saved > 0.0);
    }

    #[test]
    fn cache_hits_work_in_chain_mode() {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        use std::sync::Arc;
        let cache = Arc::new(SubtaskCache::new(64, CachePolicyKind::Lfu));
        let cfg = ScheduleConfig {
            chain_mode: true,
            cache: Some(Arc::clone(&cache)),
            ..Default::default()
        };
        let (dag, q, lat, ex) = setup(33);
        let pred = MirrorPredictor::synthetic_for_tests();
        let mut router = RouterState::new(RoutePolicy::AllEdge);
        let mut rng = Rng::new(1);
        let first = execute_query(&dag, &lat, &q, &ex, &pred, &mut router, 2.0, &cfg, &mut rng);
        let mut router = RouterState::new(RoutePolicy::AllEdge);
        let mut rng = Rng::new(2);
        let second = execute_query(&dag, &lat, &q, &ex, &pred, &mut router, 2.0, &cfg, &mut rng);
        assert!(second.events.iter().all(|e| e.cached));
        // Chain clock advances by one hit latency per node.
        assert!(
            (second.latency - (2.0 + 5.0 * cache.hit_latency())).abs() < 1e-9,
            "chain cached latency {}",
            second.latency
        );
        assert!(second.latency < first.latency);
    }
}

//! Fleet-scale multi-tenant simulator: N concurrent queries contending for
//! one shared edge-worker pool, one bounded cloud-API pool, and per-tenant
//! dollar budgets drawn from a global ceiling.
//!
//! The per-query scheduler ([`super::execute_query`]) simulates each query
//! against *private* resources, which makes cross-query queueing delay,
//! pool contention, and budget exhaustion invisible. This module extends
//! the same event-driven virtual clock to a whole serving fleet:
//!
//! * a single tagged event heap (keyed by [`super::events::EventKey`])
//!   orders **arrivals**, **planner completions**, **ready-frontier
//!   markers**, **subtask finishes**, and **hedge cancellations** across
//!   all queries (ties resolve control-before-marker-before-finish,
//!   matching the single-query scheduler);
//! * worker pools are shared: a subtask decided at `t` starts at
//!   `max(t, earliest_free_worker)`, so fleet load shows up as per-subtask
//!   queueing delay;
//! * routing decisions see the **tenant's aggregated** [`BudgetState`]
//!   (fleet-level `C_used(t)` in Eq. 8's sense) instead of the query-local
//!   one, and a tenant or global dollar pool that has run dry forces
//!   subtasks back to the edge;
//! * **per-tenant policy overrides** ([`FleetConfig::tenant_policies`]):
//!   heterogeneous tenants run different routers in one fleet — each
//!   query's router is built from its tenant's policy (falling back to the
//!   pipeline's default);
//! * an admission limit bounds in-service queries; excess arrivals wait in
//!   FIFO order and their admission delay is reported.
//!
//! With `schedule.hedge` on, edge-routed pivotal subtasks dispatch
//! speculatively to both pools; the losing replica's `Cancel` event
//! releases its worker slot and refunds the unconsumed cloud spend to the
//! tenant and global pools (see [`super::CancelTicket`]).
//!
//! Determinism: every query gets an RNG forked from `(seed, job index)` —
//! never from arrival interleaving — and all state lives in vectors and
//! binary heaps with total orderings, so a fixed `(workload, seed)` pair
//! reproduces the event trace byte-for-byte. With one tenant, one query,
//! and unlimited pools, the engine reproduces `execute_query` exactly
//! (same RNG stream, same event order — see `rust/tests/fleet.rs`).
//!
//! `chain_mode` queries execute strictly sequentially on the virtual clock
//! without occupying shared pools, mirroring the single-query ablation
//! semantics (Table 3's HybridFlow-Chain); their admission slot is still
//! held until the chain's virtual makespan, so admission limits see them
//! as in-service. Pool-utilization metrics read 0 for chain fleets.

use super::events::EventKey;
use super::{apply_cancel, run_group, CancelTicket, Dispatch, FleetRouteCtx, GroupCtx};
use super::{QueryExecState, QueryExecution, RouterState};
use crate::budget::{GlobalBudget, TenantPool};
use crate::cache::CacheStats;
use crate::embed::FeatureContext;
use crate::engine::Backend;
use crate::pipeline::HybridFlowPipeline;
use crate::planner::synthetic::SyntheticPlanner;
use crate::planner::Planner;
use crate::router::RoutePolicy;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::{sample_latents, Query};
use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// Fleet-level knobs (per-query scheduling semantics come from the
/// pipeline's [`ScheduleConfig`](super::ScheduleConfig)).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Maximum queries in service at once; 0 = unlimited. Arrivals beyond
    /// the limit queue FIFO and are admitted as earlier queries complete.
    pub admission_limit: usize,
    /// Fleet-wide cloud-dollar ceiling shared by every tenant pool.
    pub global_k_cap: f64,
    /// Record the human-readable event trace (golden-trace tests, debug).
    pub record_trace: bool,
    /// Per-tenant routing-policy overrides, indexed like the tenant list.
    /// `None` (or an index beyond the vector) falls back to the pipeline's
    /// default policy, so an empty vector reproduces a homogeneous fleet.
    pub tenant_policies: Vec<Option<RoutePolicy>>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            admission_limit: 0,
            global_k_cap: f64::INFINITY,
            record_trace: true,
            tenant_policies: Vec::new(),
        }
    }
}

/// One query arriving at the fleet.
#[derive(Debug, Clone)]
pub struct FleetArrival {
    pub time: f64,
    /// Index into the tenant pool list.
    pub tenant: usize,
    pub query: Query,
}

/// Per-query outcome with fleet timing attached.
#[derive(Debug, Clone)]
pub struct FleetQueryResult {
    pub tenant: usize,
    pub query_id: u64,
    pub arrival: f64,
    pub admitted: f64,
    pub plan_done: f64,
    pub completed_at: f64,
    /// Decisions overridden to edge because a dollar pool was exhausted.
    pub forced_edge: usize,
    /// `latency` is the sojourn time (arrival to completion, planning and
    /// admission queueing included); for an uncontended single query this
    /// equals `execute_query`'s latency exactly.
    pub exec: QueryExecution,
}

/// Aggregate outcome of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Per-query results in job (arrival-list) order.
    pub results: Vec<FleetQueryResult>,
    /// Final tenant pools (aggregated budget state, spend vs cap).
    pub tenants: Vec<TenantPool>,
    pub global: GlobalBudget,
    /// Virtual time of the last completion.
    pub horizon: f64,
    /// Queries per virtual second over the horizon.
    pub throughput_qps: f64,
    /// Admission-queue delay per query (seconds).
    pub admission_delay: Summary,
    /// Per-subtask wait between routing decision and worker start.
    pub queue_wait: Summary,
    /// Arrival-to-completion time per query.
    pub sojourn: Summary,
    pub offload_rate: f64,
    pub total_api_cost: f64,
    pub forced_edge: usize,
    /// Hedged replicas cancelled (losing side of speculative dispatch).
    pub hedge_cancelled: usize,
    /// Dollars refunded for the unconsumed share of cancelled replicas.
    pub hedge_refund: f64,
    /// Cross-query result-cache counters for this run (`None` when no
    /// enabled cache was attached): hit rate, cloud tokens saved, budget
    /// avoided, evictions. The cache is reset at run start, so these are
    /// exactly this run's numbers.
    pub cache: Option<CacheStats>,
    pub edge_utilization: f64,
    pub cloud_utilization: f64,
    /// True unless the event heap ever popped times out of order.
    pub clock_monotone: bool,
    /// Human-readable event log (empty unless `record_trace`).
    pub trace: Vec<String>,
}

impl FleetReport {
    /// The serialized event trace (golden-file format): one event per
    /// line, newline-terminated.
    pub fn trace_text(&self) -> String {
        let mut out = self.trace.join("\n");
        out.push('\n');
        out
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "fleet: {} queries over {:.1}s virtual ({:.3} q/s)\n\
             admission delay: mean {:.2}s  p99 {:.2}s\n\
             subtask queue wait: mean {:.2}s  p99 {:.2}s\n\
             sojourn: p50 {:.2}s  p95 {:.2}s  p99 {:.2}s  max {:.2}s\n\
             offload {:.1}%  C_API ${:.4}  forced-to-edge {}\n\
             utilization: edge {:.1}%  cloud {:.1}%",
            self.results.len(),
            self.horizon,
            self.throughput_qps,
            self.admission_delay.mean,
            self.admission_delay.p99,
            self.queue_wait.mean,
            self.queue_wait.p99,
            self.sojourn.p50,
            self.sojourn.p95,
            self.sojourn.p99,
            self.sojourn.max,
            self.offload_rate * 100.0,
            self.total_api_cost,
            self.forced_edge,
            self.edge_utilization * 100.0,
            self.cloud_utilization * 100.0,
        );
        if self.hedge_cancelled > 0 {
            out.push_str(&format!(
                "\nhedge: {} losers cancelled, ${:.4} refunded",
                self.hedge_cancelled, self.hedge_refund
            ));
        }
        if let Some(c) = &self.cache {
            out.push('\n');
            out.push_str(&c.render_line());
        }
        out
    }
}

// Event-kind priorities: at equal times, control events (arrival/planner/
// cancel) run first, then ready-frontier markers, then subtask finishes —
// the marker-before-finish order reproduces the single-query scheduler's
// "ready first" tie-break, and cancel-before-marker makes freed workers
// and refunds visible to decisions at the same instant (exactly like the
// single-query scheduler's pre-decision cancel flush).
const PRI_CTRL: u8 = 0;
const PRI_MARKER: u8 = 1;
const PRI_DONE: u8 = 2;

#[derive(Debug, Clone, Copy, PartialEq)]
enum EvKind {
    Arrival,
    PlanDone,
    Marker,
    Done,
    /// Cancellation of a hedged dispatch's losing replica.
    Cancel,
    /// Completion of a chain-mode query: its subtasks executed
    /// synchronously at PlanDone, but the service slot is held until the
    /// chain's virtual makespan.
    ChainDone,
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Ev {
    key: EventKey,
    kind: EvKind,
}

impl Eq for Ev {}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Single shared ordering rule: scheduler::events::EventKey.
        self.key.cmp(&other.key)
    }
}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Scheduling state built at admission (planning done lazily so queued
/// queries consume planner latency when they actually start).
struct PlanState {
    dag: crate::dag::TaskDag,
    latents: Vec<crate::workload::SubtaskLatent>,
    fctx: FeatureContext,
    depths: Vec<usize>,
    max_depth: usize,
    children: Vec<Vec<usize>>,
    indeg: Vec<usize>,
    done: Vec<bool>,
    ready: BinaryHeap<EventKey>,
    st: QueryExecState,
    /// Outstanding hedge-cancel tickets, indexed by node.
    cancel_tickets: Vec<Option<CancelTicket>>,
    completed: usize,
}

struct QueryRun {
    tenant: usize,
    query: Query,
    arrival: f64,
    admitted: f64,
    plan_done: f64,
    rng: Rng,
    router: RouterState,
    forced_edge: usize,
    plan: Option<PlanState>,
    outcome: Option<QueryExecution>,
    completed_at: f64,
}

struct RunStats {
    admission_delays: Vec<f64>,
    queue_waits: Vec<f64>,
    sojourns: Vec<f64>,
    hedge_cancelled: usize,
    hedge_refund: f64,
    /// Worker-busy seconds consumed by hedged losing replicas before their
    /// cancellation, per side (edge, cloud) — counted into utilization so
    /// the report reflects real pool occupancy, not just winner events.
    hedge_loser_busy: [f64; 2],
    clock_monotone: bool,
}

#[allow(clippy::too_many_arguments)]
fn admit_query(
    qi: usize,
    now: f64,
    q: &mut QueryRun,
    planner: &SyntheticPlanner,
    executor: &dyn Backend,
    n_max: usize,
    heap: &mut BinaryHeap<Ev>,
    stats: &mut RunStats,
    trace: &mut Vec<String>,
    record_trace: bool,
) {
    q.admitted = now;
    stats.admission_delays.push(now - q.arrival);
    // Same call order as `HybridFlowPipeline::run_query_traced`: plan, then
    // latents, both on the query's own RNG stream.
    let plan = planner.plan(&q.query, n_max, &mut q.rng);
    let latents = sample_latents(&plan.dag, &q.query, executor.sp(), &mut q.rng);
    let n = plan.dag.len();
    let fctx = FeatureContext::new(&plan.dag, &q.query);
    let depths = plan.dag.depths().unwrap_or_else(|| vec![0; n]);
    let max_depth = depths.iter().copied().max().unwrap_or(0).max(1);
    let children = plan.dag.children();
    let indeg = plan.dag.in_degrees();
    q.plan_done = now + plan.planning_latency;
    q.plan = Some(PlanState {
        dag: plan.dag,
        latents,
        fctx,
        depths,
        max_depth,
        children,
        indeg,
        done: vec![false; n],
        ready: BinaryHeap::new(),
        st: QueryExecState::new(n),
        cancel_tickets: (0..n).map(|_| None).collect(),
        completed: 0,
    });
    heap.push(Ev {
        key: EventKey { time: q.plan_done, pri: PRI_CTRL, q: qi, node: 0 },
        kind: EvKind::PlanDone,
    });
    if record_trace {
        trace.push(format!(
            "t={:.6} tenant={} q={} admit wait={:.6}",
            now,
            q.tenant,
            qi,
            now - q.arrival
        ));
    }
}

fn finalize_query(
    qi: usize,
    q: &mut QueryRun,
    tenant: &mut TenantPool,
    executor: &dyn Backend,
    stats: &mut RunStats,
    trace: &mut Vec<String>,
    record_trace: bool,
) {
    let makespan_abs = {
        let ps = q.plan.as_mut().expect("finalize before planning");
        debug_assert!(
            ps.cancel_tickets.iter().all(Option::is_none),
            "outstanding hedge cancels at finalize"
        );
        let makespan_abs =
            ps.st.events.iter().map(|e| e.finish).fold(q.plan_done, f64::max);
        ps.st.budget.advance_latency(makespan_abs - q.plan_done);
        tenant.state.advance_latency(makespan_abs - q.plan_done);
        makespan_abs
    };
    let final_correct = {
        let ps = q.plan.as_ref().expect("plan state");
        executor.final_answer_correct(&ps.latents, &ps.st.correct, &mut q.rng)
    };
    let ps = q.plan.take().expect("plan state");
    let exec = QueryExecution {
        correct: final_correct,
        latency: makespan_abs - q.arrival,
        api_cost: ps.st.api_total,
        offload_rate: ps.st.budget.offload_rate(),
        n_subtasks: ps.dag.len(),
        events: ps.st.events,
        budget: ps.st.budget,
    };
    stats.sojourns.push(makespan_abs - q.arrival);
    if record_trace {
        trace.push(format!(
            "t={:.6} tenant={} q={} complete correct={} latency={:.6} api={:.6} offload={:.6}",
            makespan_abs, q.tenant, qi, exec.correct, exec.latency, exec.api_cost,
            exec.offload_rate
        ));
    }
    q.completed_at = makespan_abs;
    q.outcome = Some(exec);
}

/// Run a multi-tenant fleet workload against shared resources.
///
/// Planner, executor, predictor, routing policy, and per-query scheduling
/// semantics all come from `pipeline` (so a fleet with one tenant and one
/// query is exactly `pipeline.run_query_traced` with the job's RNG).
/// `tenants` are the hierarchical dollar pools (see
/// [`crate::budget::split_evenly`]); `arrivals` reference tenants by
/// index. `cfg.tenant_policies` may override the routing policy per
/// tenant. Router state is per-query (the paper's evaluation protocol);
/// `persist_router` is ignored in fleet mode.
pub fn run_fleet(
    pipeline: &HybridFlowPipeline,
    cfg: &FleetConfig,
    tenants: Vec<TenantPool>,
    arrivals: Vec<FleetArrival>,
    seed: u64,
) -> FleetReport {
    let schedule = pipeline.config.schedule.clone();
    let n_max = pipeline.config.n_max;
    let planner = &pipeline.planner;
    let executor: &dyn Backend = pipeline.executor.as_ref();
    let predictor = pipeline.predictor.as_ref();
    let record_trace = cfg.record_trace;
    let hedge = schedule.hedge_gate();
    // Every fleet run starts with a cold cache so a fixed (workload, seed)
    // pair reproduces the same hit/miss/eviction sequence byte-for-byte.
    let cache = schedule.cache_gate();
    if let Some(c) = cache {
        c.reset();
    }

    let mut tenants = tenants;
    assert!(!tenants.is_empty(), "fleet needs at least one tenant pool");
    let mut global = GlobalBudget::new(cfg.global_k_cap);

    // Shared worker pools: next-free virtual time per worker.
    let mut edge_free: Vec<f64> = vec![0.0; schedule.edge_workers.max(1)];
    let mut cloud_free: Vec<f64> = vec![0.0; schedule.cloud_workers.max(1)];

    let mut queries: Vec<QueryRun> = arrivals
        .into_iter()
        .enumerate()
        .map(|(i, a)| {
            assert!(a.tenant < tenants.len(), "arrival references unknown tenant {}", a.tenant);
            // Seed by job index, not arrival interleaving, so results are
            // exactly reproducible (same scheme as `server::serve`).
            let rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97f4A7C15));
            // Per-tenant policy override (heterogeneous fleets); absent or
            // None falls back to the pipeline default.
            let policy = cfg
                .tenant_policies
                .get(a.tenant)
                .and_then(|p| p.clone())
                .unwrap_or_else(|| pipeline.config.policy.clone());
            let mut router = RouterState::new(policy);
            router.begin_query(false);
            QueryRun {
                tenant: a.tenant,
                query: a.query,
                arrival: a.time,
                admitted: f64::NAN,
                plan_done: f64::NAN,
                rng,
                router,
                forced_edge: 0,
                plan: None,
                outcome: None,
                completed_at: f64::NAN,
            }
        })
        .collect();

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    for (i, q) in queries.iter().enumerate() {
        heap.push(Ev {
            key: EventKey { time: q.arrival, pri: PRI_CTRL, q: i, node: 0 },
            kind: EvKind::Arrival,
        });
    }

    let mut stats = RunStats {
        admission_delays: Vec::new(),
        queue_waits: Vec::new(),
        sojourns: Vec::new(),
        hedge_cancelled: 0,
        hedge_refund: 0.0,
        hedge_loser_busy: [0.0, 0.0],
        clock_monotone: true,
    };
    let mut trace: Vec<String> = Vec::new();
    let mut waitq: VecDeque<usize> = VecDeque::new();
    let mut active = 0usize;
    let mut dispatched: Vec<Dispatch> = Vec::new();
    let mut last_time = f64::NEG_INFINITY;

    while let Some(ev) = heap.pop() {
        if ev.key.time < last_time - 1e-9 {
            stats.clock_monotone = false;
            debug_assert!(
                false,
                "virtual clock moved backwards: {} < {}",
                ev.key.time, last_time
            );
        }
        last_time = last_time.max(ev.key.time);

        match ev.kind {
            EvKind::Arrival => {
                let qi = ev.key.q;
                if record_trace {
                    trace.push(format!(
                        "t={:.6} tenant={} q={} arrive",
                        ev.key.time, queries[qi].tenant, qi
                    ));
                }
                if cfg.admission_limit == 0 || active < cfg.admission_limit {
                    active += 1;
                    admit_query(
                        qi,
                        ev.key.time,
                        &mut queries[qi],
                        planner,
                        executor,
                        n_max,
                        &mut heap,
                        &mut stats,
                        &mut trace,
                        record_trace,
                    );
                } else {
                    waitq.push_back(qi);
                }
            }

            EvKind::PlanDone => {
                let qi = ev.key.q;
                {
                    let q = &mut queries[qi];
                    let ti = q.tenant;
                    let ps = q.plan.as_mut().expect("plan state exists after admission");
                    if record_trace {
                        trace.push(format!(
                            "t={:.6} tenant={} q={} plan nodes={}",
                            ev.key.time,
                            ti,
                            qi,
                            ps.dag.len()
                        ));
                    }
                    let chain_order =
                        if schedule.chain_mode { ps.dag.topo_order() } else { None };
                    if let Some(order) = chain_order {
                        // Chain ablation: the whole query runs sequentially
                        // on the virtual clock, bypassing shared pools
                        // (single-query semantics preserved exactly).
                        let mut chain_clock = q.plan_done;
                        for &node in &order {
                            let now = chain_clock;
                            let gctx = GroupCtx {
                                dag: &ps.dag,
                                latents: &ps.latents,
                                query: &q.query,
                                executor,
                                predictor,
                                ctx: &ps.fctx,
                                depths: &ps.depths,
                                max_depth: ps.max_depth,
                            };
                            let mut route = FleetRouteCtx {
                                tenant: &mut tenants[ti],
                                tenant_idx: ti,
                                global: &mut global,
                                forced_edge: &mut q.forced_edge,
                            };
                            dispatched.clear();
                            run_group(
                                &gctx,
                                now,
                                &[node],
                                q.plan_done,
                                &mut ps.st,
                                &mut q.router,
                                &mut q.rng,
                                &mut edge_free,
                                &mut cloud_free,
                                Some(&mut chain_clock),
                                Some(&mut route),
                                hedge,
                                cache,
                                &mut dispatched,
                            );
                            // Chain subtasks bypass the pools: zero wait by
                            // construction (keeps the queue-wait summary
                            // well-defined for chain fleets).
                            for _ in &dispatched {
                                stats.queue_waits.push(0.0);
                            }
                            if record_trace {
                                let tail = ps.st.events.len() - dispatched.len();
                                for (k, d) in dispatched.iter().enumerate() {
                                    let e = &ps.st.events[tail + k];
                                    let side = if e.cached {
                                        "cache"
                                    } else if e.cloud {
                                        "cloud"
                                    } else {
                                        "edge"
                                    };
                                    trace.push(format!(
                                        "t={:.6} tenant={} q={} exec node={} side={} start={:.6} finish={:.6} wait={:.6}",
                                        now, ti, qi, d.node, side, d.start, d.finish, 0.0
                                    ));
                                }
                            }
                        }
                        for d in ps.done.iter_mut() {
                            *d = true;
                        }
                        ps.completed = ps.dag.len();
                        // Hold the service slot until the chain's virtual
                        // makespan; finalization happens at that instant so
                        // admission limits see the query as in-service.
                        heap.push(Ev {
                            key: EventKey {
                                time: chain_clock,
                                pri: PRI_DONE,
                                q: qi,
                                node: 0,
                            },
                            kind: EvKind::ChainDone,
                        });
                    } else {
                        // Dependency-triggered path: seed the ready frontier.
                        let n = ps.dag.len();
                        for i in 0..n {
                            if ps.indeg[i] == 0 {
                                ps.ready.push(EventKey::ready(q.plan_done, i));
                                heap.push(Ev {
                                    key: EventKey {
                                        time: q.plan_done,
                                        pri: PRI_MARKER,
                                        q: qi,
                                        node: i,
                                    },
                                    kind: EvKind::Marker,
                                });
                            }
                        }
                    }
                }
            }

            EvKind::ChainDone => {
                let qi = ev.key.q;
                let ti = queries[qi].tenant;
                finalize_query(
                    qi,
                    &mut queries[qi],
                    &mut tenants[ti],
                    executor,
                    &mut stats,
                    &mut trace,
                    record_trace,
                );
                if let Some(next) = waitq.pop_front() {
                    admit_query(
                        next,
                        ev.key.time,
                        &mut queries[next],
                        planner,
                        executor,
                        n_max,
                        &mut heap,
                        &mut stats,
                        &mut trace,
                        record_trace,
                    );
                } else {
                    active -= 1;
                }
            }

            EvKind::Cancel => {
                let qi = ev.key.q;
                let q = &mut queries[qi];
                let ti = q.tenant;
                if let Some(ps) = q.plan.as_mut() {
                    if let Some(ticket) = ps.cancel_tickets[ev.key.node].take() {
                        let mut route = FleetRouteCtx {
                            tenant: &mut tenants[ti],
                            tenant_idx: ti,
                            global: &mut global,
                            forced_edge: &mut q.forced_edge,
                        };
                        apply_cancel(
                            &ticket,
                            ev.key.time,
                            &mut ps.st,
                            &mut edge_free,
                            &mut cloud_free,
                            Some(&mut route),
                        );
                        stats.hedge_cancelled += 1;
                        stats.hedge_refund += ticket.refund_k;
                        // The loser occupied its worker from start until
                        // the cancel instant (zero if cancelled pre-start).
                        let release =
                            ev.key.time.clamp(ticket.start, ticket.reserved_until);
                        stats.hedge_loser_busy[usize::from(ticket.cloud)] +=
                            release - ticket.start;
                        if record_trace {
                            trace.push(format!(
                                "t={:.6} tenant={} q={} cancel node={} side={} refund={:.6}",
                                ev.key.time,
                                ti,
                                qi,
                                ticket.node,
                                if ticket.cloud { "cloud" } else { "edge" },
                                ticket.refund_k
                            ));
                        }
                    }
                }
            }

            EvKind::Marker => {
                let qi = ev.key.q;
                let q = &mut queries[qi];
                let ti = q.tenant;
                let ps = match q.plan.as_mut() {
                    Some(p) => p,
                    None => continue, // query already finalized
                };
                // Stale marker: its ready entry was consumed by an earlier
                // group at the same instant.
                let first_time = match ps.ready.peek() {
                    Some(f) => f.time,
                    None => continue,
                };
                if first_time > ev.key.time + 1e-12 {
                    continue;
                }
                let f0 = ps.ready.pop().unwrap();
                let mut group = vec![f0.node];
                if schedule.batch_frontier {
                    while let Some(peek) = ps.ready.peek() {
                        if peek.time <= f0.time + 1e-12 {
                            group.push(ps.ready.pop().unwrap().node);
                        } else {
                            break;
                        }
                    }
                }
                let now = f0.time;
                let gctx = GroupCtx {
                    dag: &ps.dag,
                    latents: &ps.latents,
                    query: &q.query,
                    executor,
                    predictor,
                    ctx: &ps.fctx,
                    depths: &ps.depths,
                    max_depth: ps.max_depth,
                };
                let mut route = FleetRouteCtx {
                    tenant: &mut tenants[ti],
                    tenant_idx: ti,
                    global: &mut global,
                    forced_edge: &mut q.forced_edge,
                };
                dispatched.clear();
                run_group(
                    &gctx,
                    now,
                    &group,
                    q.plan_done,
                    &mut ps.st,
                    &mut q.router,
                    &mut q.rng,
                    &mut edge_free,
                    &mut cloud_free,
                    None,
                    Some(&mut route),
                    hedge,
                    cache,
                    &mut dispatched,
                );
                for d in &dispatched {
                    stats.queue_waits.push(d.start - now);
                    heap.push(Ev {
                        key: EventKey { time: d.finish, pri: PRI_DONE, q: qi, node: d.node },
                        kind: EvKind::Done,
                    });
                    if let Some(ticket) = &d.cancel {
                        ps.cancel_tickets[d.node] = Some(ticket.clone());
                        heap.push(Ev {
                            key: EventKey {
                                time: d.finish,
                                pri: PRI_CTRL,
                                q: qi,
                                node: d.node,
                            },
                            kind: EvKind::Cancel,
                        });
                    }
                }
                if record_trace {
                    let tail = ps.st.events.len() - dispatched.len();
                    for (k, d) in dispatched.iter().enumerate() {
                        let e = &ps.st.events[tail + k];
                        let side = if e.cached {
                            "cache"
                        } else if e.cloud {
                            "cloud"
                        } else {
                            "edge"
                        };
                        trace.push(format!(
                            "t={:.6} tenant={} q={} exec node={} side={} start={:.6} finish={:.6} wait={:.6}",
                            now,
                            ti,
                            qi,
                            d.node,
                            side,
                            d.start,
                            d.finish,
                            d.start - now
                        ));
                    }
                }
            }

            EvKind::Done => {
                let qi = ev.key.q;
                let mut completed_query = false;
                {
                    let q = &mut queries[qi];
                    let ti = q.tenant;
                    let ps = q.plan.as_mut().expect("plan state exists");
                    let node = ev.key.node;
                    if !ps.done[node] {
                        ps.done[node] = true;
                        for &c in &ps.children[node] {
                            ps.indeg[c] -= 1;
                            if ps.indeg[c] == 0 {
                                ps.ready.push(EventKey::ready(ev.key.time, c));
                                heap.push(Ev {
                                    key: EventKey {
                                        time: ev.key.time,
                                        pri: PRI_MARKER,
                                        q: qi,
                                        node: c,
                                    },
                                    kind: EvKind::Marker,
                                });
                            }
                        }
                    }
                    ps.completed += 1;
                    if record_trace {
                        trace.push(format!(
                            "t={:.6} tenant={} q={} done node={}",
                            ev.key.time, ti, qi, node
                        ));
                    }
                    if ps.completed == ps.dag.len() {
                        completed_query = true;
                    }
                }
                if completed_query {
                    let ti = queries[qi].tenant;
                    finalize_query(
                        qi,
                        &mut queries[qi],
                        &mut tenants[ti],
                        executor,
                        &mut stats,
                        &mut trace,
                        record_trace,
                    );
                    if let Some(next) = waitq.pop_front() {
                        admit_query(
                            next,
                            ev.key.time,
                            &mut queries[next],
                            planner,
                            executor,
                            n_max,
                            &mut heap,
                            &mut stats,
                            &mut trace,
                            record_trace,
                        );
                    } else {
                        active -= 1;
                    }
                }
            }
        }
    }

    // ---- Report assembly --------------------------------------------------
    let results: Vec<FleetQueryResult> = queries
        .into_iter()
        .enumerate()
        .map(|(qi, q)| FleetQueryResult {
            tenant: q.tenant,
            query_id: q.query.id,
            arrival: q.arrival,
            admitted: q.admitted,
            plan_done: q.plan_done,
            completed_at: q.completed_at,
            forced_edge: q.forced_edge,
            exec: q
                .outcome
                .unwrap_or_else(|| panic!("fleet query {qi} never completed (engine invariant)")),
        })
        .collect();

    let horizon = results.iter().map(|r| r.completed_at).fold(0.0f64, f64::max);
    let n_decided: usize = tenants.iter().map(|t| t.state.n_decided).sum();
    let n_offloaded: usize = tenants.iter().map(|t| t.state.n_offloaded).sum();
    let forced_edge: usize = results.iter().map(|r| r.forced_edge).sum();
    // Winner events plus the consumed share of hedged losing replicas.
    let (mut edge_busy, mut cloud_busy) =
        (stats.hedge_loser_busy[0], stats.hedge_loser_busy[1]);
    // Chain-mode queries bypass the shared pools, so their events are not
    // pool busy time; utilization reads 0 for the chain ablation. Cached
    // hits run on no worker at all, so they are never busy time either.
    if !schedule.chain_mode {
        for r in &results {
            for e in &r.exec.events {
                if e.cached {
                    continue;
                }
                if e.cloud {
                    cloud_busy += e.finish - e.start;
                } else {
                    edge_busy += e.finish - e.start;
                }
            }
        }
    }
    let span = horizon.max(1e-9);
    FleetReport {
        admission_delay: Summary::of_or_zero(&stats.admission_delays),
        queue_wait: Summary::of_or_zero(&stats.queue_waits),
        sojourn: Summary::of_or_zero(&stats.sojourns),
        throughput_qps: results.len() as f64 / span,
        offload_rate: if n_decided == 0 {
            0.0
        } else {
            n_offloaded as f64 / n_decided as f64
        },
        total_api_cost: global.k_spent,
        forced_edge,
        hedge_cancelled: stats.hedge_cancelled,
        hedge_refund: stats.hedge_refund,
        cache: cache.map(|c| c.stats()),
        edge_utilization: edge_busy / (span * edge_free.len() as f64),
        cloud_utilization: cloud_busy / (span * cloud_free.len() as f64),
        clock_monotone: stats.clock_monotone,
        horizon,
        results,
        tenants,
        global,
        trace,
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::TenantPool;
    use crate::config::simparams::SimParams;
    use crate::models::SimExecutor;
    use crate::pipeline::PipelineConfig;
    use crate::router::{MirrorPredictor, RoutePolicy};
    use crate::workload::{generate_queries, Benchmark};
    use std::sync::Arc;

    fn pipeline(policy: RoutePolicy) -> HybridFlowPipeline {
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = policy;
        HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        )
    }

    fn arrivals(n: usize, gap: f64, tenants: usize, seed: u64) -> Vec<FleetArrival> {
        generate_queries(Benchmark::Gpqa, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| FleetArrival { time: i as f64 * gap, tenant: i % tenants, query })
            .collect()
    }

    #[test]
    fn fleet_runs_and_reports() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let tenants = vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")];
        let report =
            run_fleet(&p, &FleetConfig::default(), tenants, arrivals(12, 2.0, 2, 3), 99);
        assert_eq!(report.results.len(), 12);
        assert!(report.clock_monotone);
        assert!(report.horizon > 0.0);
        assert!(report.throughput_qps > 0.0);
        assert!((0.0..=1.0).contains(&report.offload_rate));
        assert_eq!(report.hedge_cancelled, 0, "hedging is off by default");
        for r in &report.results {
            assert!(r.completed_at >= r.plan_done && r.plan_done >= r.admitted);
            assert!(r.admitted >= r.arrival);
            assert_eq!(r.exec.events.len(), r.exec.n_subtasks);
            assert!(r.exec.latency > 0.0);
        }
        assert!(!report.trace.is_empty());
        assert!(report.render().contains("fleet: 12 queries"));
    }

    #[test]
    fn fleet_is_deterministic() {
        let sp = SimParams::default();
        let make = || {
            let p = pipeline(RoutePolicy::hybridflow(&sp));
            let tenants = vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")];
            run_fleet(&p, &FleetConfig::default(), tenants, arrivals(10, 1.0, 2, 5), 17)
        };
        let a = make();
        let b = make();
        assert_eq!(a.trace_text(), b.trace_text());
        assert_eq!(a.total_api_cost, b.total_api_cost);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.exec.latency, y.exec.latency);
            assert_eq!(x.exec.correct, y.exec.correct);
        }
    }

    #[test]
    fn contention_raises_queue_wait() {
        // Same workload, back-to-back arrivals: one shared edge worker must
        // produce strictly more queueing than a wide pool.
        let narrow = {
            let mut p = pipeline(RoutePolicy::AllEdge);
            p.config.schedule.edge_workers = 1;
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                arrivals(8, 0.1, 1, 7),
                1,
            )
        };
        let wide = {
            let mut p = pipeline(RoutePolicy::AllEdge);
            p.config.schedule.edge_workers = 64;
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                arrivals(8, 0.1, 1, 7),
                1,
            )
        };
        assert!(
            narrow.queue_wait.mean > wide.queue_wait.mean + 1e-9,
            "narrow {} wide {}",
            narrow.queue_wait.mean,
            wide.queue_wait.mean
        );
        assert!(narrow.sojourn.p99 > wide.sojourn.p99);
    }

    #[test]
    fn admission_limit_queues_arrivals() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let cfg = FleetConfig { admission_limit: 1, ..Default::default() };
        let report =
            run_fleet(&p, &cfg, vec![TenantPool::unlimited("t")], arrivals(6, 0.0, 1, 11), 2);
        // All queries arrive at t=0; only one is in service at a time, so
        // later queries see positive admission delay.
        assert!(report.admission_delay.max > 0.0);
        assert_eq!(report.results.len(), 6);
        // Serialized service: completions strictly ordered.
        let mut times: Vec<f64> = report.results.iter().map(|r| r.completed_at).collect();
        let sorted = {
            let mut s = times.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s
        };
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(times, sorted);
    }

    #[test]
    fn chain_mode_holds_admission_slot_until_makespan() {
        let sp = SimParams::default();
        let mut p = pipeline(RoutePolicy::hybridflow(&sp));
        p.config.schedule.chain_mode = true;
        let cfg = FleetConfig { admission_limit: 1, ..Default::default() };
        let report =
            run_fleet(&p, &cfg, vec![TenantPool::unlimited("t")], arrivals(4, 0.0, 1, 21), 6);
        // Service is strictly serialized: each admission waits for the
        // previous chain's full virtual makespan, not just its planning.
        let mut order: Vec<&FleetQueryResult> = report.results.iter().collect();
        order.sort_by(|a, b| a.admitted.partial_cmp(&b.admitted).unwrap());
        for w in order.windows(2) {
            assert!(
                w[1].admitted >= w[0].completed_at - 1e-9,
                "admitted {} before previous completion {}",
                w[1].admitted,
                w[0].completed_at
            );
        }
        assert_eq!(report.edge_utilization, 0.0);
    }

    #[test]
    fn exhausted_tenant_pool_forces_edge() {
        let p = pipeline(RoutePolicy::AllCloud);
        // Tiny dollar pool: after it drains, every further decision is
        // forced to the edge even under an all-cloud policy.
        let report = run_fleet(
            &p,
            &FleetConfig::default(),
            vec![TenantPool::new("capped", 1e-6)],
            arrivals(6, 5.0, 1, 13),
            3,
        );
        assert!(report.forced_edge > 0, "no forced-edge decisions");
        assert!(report.offload_rate < 1.0);
        // Overshoot bounded by one call: spend < cap + the priciest call.
        let max_call = report
            .results
            .iter()
            .flat_map(|r| r.exec.events.iter())
            .map(|e| e.api_cost)
            .fold(0.0f64, f64::max);
        assert!(report.tenants[0].state.k_used <= 1e-6 + max_call + 1e-12);
    }

    #[test]
    fn global_cap_gates_all_tenants() {
        let p = pipeline(RoutePolicy::AllCloud);
        let cfg = FleetConfig { global_k_cap: 1e-6, ..Default::default() };
        let report = run_fleet(
            &p,
            &cfg,
            vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")],
            arrivals(6, 5.0, 2, 19),
            4,
        );
        assert!(report.forced_edge > 0);
        let max_call = report
            .results
            .iter()
            .flat_map(|r| r.exec.events.iter())
            .map(|e| e.api_cost)
            .fold(0.0f64, f64::max);
        assert!(report.global.k_spent <= 1e-6 + max_call + 1e-12);
    }

    #[test]
    fn per_tenant_policies_route_differently() {
        // One fleet, two tenants, opposite policies: the override layer
        // must steer every decision per tenant.
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp)); // default, unused by overrides
        let cfg = FleetConfig {
            tenant_policies: vec![Some(RoutePolicy::AllEdge), Some(RoutePolicy::AllCloud)],
            ..Default::default()
        };
        let tenants = vec![TenantPool::unlimited("edge"), TenantPool::unlimited("cloud")];
        let report = run_fleet(&p, &cfg, tenants, arrivals(8, 2.0, 2, 31), 9);
        assert_eq!(report.tenants[0].state.n_offloaded, 0, "all-edge tenant offloaded");
        assert!(report.tenants[0].state.n_decided > 0);
        assert_eq!(
            report.tenants[1].state.n_offloaded, report.tenants[1].state.n_decided,
            "all-cloud tenant kept something on edge"
        );
        assert_eq!(report.tenants[0].state.k_used, 0.0);
        assert!(report.tenants[1].state.k_used > 0.0);
    }

    #[test]
    fn missing_override_falls_back_to_pipeline_policy() {
        // Tenant 1 has no override entry: it must behave like the pipeline
        // default (AllCloud here), while tenant 0 is pinned to AllEdge.
        let p = pipeline(RoutePolicy::AllCloud);
        let cfg = FleetConfig {
            tenant_policies: vec![Some(RoutePolicy::AllEdge)],
            ..Default::default()
        };
        let tenants = vec![TenantPool::unlimited("pinned"), TenantPool::unlimited("default")];
        let report = run_fleet(&p, &cfg, tenants, arrivals(6, 2.0, 2, 33), 12);
        assert_eq!(report.tenants[0].state.n_offloaded, 0);
        assert_eq!(
            report.tenants[1].state.n_offloaded,
            report.tenants[1].state.n_decided
        );
    }

    #[test]
    fn hedged_fleet_cancels_and_refunds() {
        // Edge-pinned policy + hedge-everything: speculative cloud replicas
        // fire for every subtask; losers must be cancelled with refunds and
        // all dollar scopes must stay consistent.
        let mut p = pipeline(RoutePolicy::AllEdge);
        p.config.schedule.hedge = true;
        p.config.schedule.hedge_threshold = f64::NEG_INFINITY;
        let report = run_fleet(
            &p,
            &FleetConfig::default(),
            vec![TenantPool::unlimited("t")],
            arrivals(8, 1.0, 1, 41),
            7,
        );
        assert!(report.hedge_cancelled > 0, "no hedged losers cancelled");
        assert!(report.hedge_refund >= 0.0);
        let tenant_sum: f64 = report.tenants.iter().map(|t| t.state.k_used).sum();
        assert!(
            (report.global.k_spent - tenant_sum).abs() < 1e-9,
            "global {} vs tenants {}",
            report.global.k_spent,
            tenant_sum
        );
        assert!(report.global.k_spent >= 0.0);
        assert!(report.render().contains("hedge:"));
        // Cancel lines appear in the trace (hedge-on only).
        assert!(report.trace.iter().any(|l| l.contains(" cancel node=")));
    }

    #[test]
    fn hedged_fleet_is_deterministic() {
        let make = || {
            let mut p = pipeline(RoutePolicy::AllEdge);
            p.config.schedule.hedge = true;
            p.config.schedule.hedge_threshold = 0.2;
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                arrivals(8, 0.5, 1, 43),
                23,
            )
        };
        let a = make();
        let b = make();
        assert_eq!(a.trace_text(), b.trace_text());
        assert_eq!(a.total_api_cost, b.total_api_cost);
        assert_eq!(a.hedge_cancelled, b.hedge_cancelled);
        assert_eq!(a.hedge_refund, b.hedge_refund);
    }

    // --- Cross-query result cache -----------------------------------------

    /// The same query content arriving `n` times, widely spaced (no
    /// contention), on one tenant.
    fn repeated_arrivals(n: usize, seed: u64) -> Vec<FleetArrival> {
        let q = generate_queries(Benchmark::Gpqa, 1, seed).pop().unwrap();
        (0..n)
            .map(|i| FleetArrival { time: i as f64 * 100.0, tenant: 0, query: q.clone() })
            .collect()
    }

    fn cached_pipeline(policy: RoutePolicy, capacity: usize) -> HybridFlowPipeline {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        let mut p = pipeline(policy);
        if capacity > 0 {
            p.config.schedule.cache =
                Some(Arc::new(SubtaskCache::new(capacity, CachePolicyKind::Lru)));
        }
        p
    }

    use crate::eval::experiments::fleet_cloud_tokens as cloud_tokens;

    #[test]
    fn repeated_queries_hit_cache_and_cut_cloud_spend() {
        let run = |capacity: usize| {
            let p = cached_pipeline(RoutePolicy::AllCloud, capacity);
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                repeated_arrivals(6, 51),
                9,
            )
        };
        let off = run(0);
        let on = run(256);
        assert!(off.cache.is_none(), "no cache attached => no cache column");
        let stats = on.cache.as_ref().expect("cache stats present");
        assert!(stats.hits > 0, "repeated content must hit");
        assert!(stats.hit_rate() > 0.2, "hit rate {} too low", stats.hit_rate());
        assert!(stats.tokens_saved > 0.0);
        assert!(stats.dollars_saved > 0.0);
        assert!(
            cloud_tokens(&on) < cloud_tokens(&off),
            "cached run must transmit strictly fewer cloud tokens"
        );
        assert!(on.total_api_cost < off.total_api_cost, "hits spend no dollars");
        // Cached events show up in the trace as side=cache.
        assert!(on.trace.iter().any(|l| l.contains("side=cache")));
        assert!(on.render().contains("cache: hit rate"));
    }

    #[test]
    fn cached_fleet_is_deterministic_across_runs() {
        // The cache is reset at run start, so back-to-back runs over one
        // shared Arc'd cache must produce byte-identical traces.
        let p = cached_pipeline(RoutePolicy::AllCloud, 128);
        let make = || {
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                repeated_arrivals(5, 77),
                13,
            )
        };
        let a = make();
        let b = make();
        assert_eq!(a.trace_text(), b.trace_text());
        let (sa, sb) = (a.cache.unwrap(), b.cache.unwrap());
        assert_eq!(sa.lookups, sb.lookups);
        assert_eq!(sa.hits, sb.hits);
        assert_eq!(sa.insertions, sb.insertions);
    }

    #[test]
    fn tenant_partitions_isolate_in_fleet_unless_shared() {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        // The same query alternates between two tenants. Isolated
        // partitions force each tenant to warm its own cache; a shared
        // tier lets tenant B hit tenant A's entries (shared_hits > 0).
        let run = |shared: bool| {
            let mut p = pipeline(RoutePolicy::AllCloud);
            let cache = SubtaskCache::new(256, CachePolicyKind::Lru);
            let cache = if shared { cache.with_shared_tier() } else { cache };
            p.config.schedule.cache = Some(Arc::new(cache));
            let q = generate_queries(Benchmark::Gpqa, 1, 61).pop().unwrap();
            let arrivals: Vec<FleetArrival> = (0..6)
                .map(|i| FleetArrival {
                    time: i as f64 * 100.0,
                    tenant: i % 2,
                    query: q.clone(),
                })
                .collect();
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")],
                arrivals,
                3,
            )
        };
        let isolated = run(false);
        let shared = run(true);
        let iso_stats = isolated.cache.unwrap();
        let sh_stats = shared.cache.unwrap();
        assert_eq!(iso_stats.shared_hits, 0, "no shared tier => no shared hits");
        assert!(sh_stats.shared_hits > 0, "shared tier must serve cross-tenant hits");
        assert!(sh_stats.hits >= iso_stats.hits, "sharing can only add hits");
    }

    #[test]
    fn empty_fleet_reports_zeros_not_nan() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let report =
            run_fleet(&p, &FleetConfig::default(), vec![TenantPool::unlimited("t")], vec![], 1);
        assert_eq!(report.results.len(), 0);
        assert_eq!(report.offload_rate, 0.0);
        assert_eq!(report.admission_delay.mean, 0.0);
        assert_eq!(report.queue_wait.p99, 0.0);
        assert_eq!(report.sojourn.p95, 0.0);
        assert_eq!(report.admission_delay.n, 0, "still marked as an empty sample");
        let rendered = report.render();
        assert!(!rendered.contains("NaN"), "empty fleet must render zeros: {rendered}");
    }
}

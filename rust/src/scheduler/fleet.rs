//! Fleet-scale multi-tenant simulation — compatibility surface.
//!
//! The fleet event loop now lives in the unified simulation kernel
//! ([`crate::sim::Kernel`]); this module re-exports the fleet-facing
//! types and the [`run_fleet`] entrypoint under their historical paths so
//! downstream code (`server`, `eval`, examples, benches, tests) keeps
//! compiling unchanged. New code should prefer the declarative
//! [`crate::scenario`] API, which resolves a JSON `ScenarioSpec` into a
//! runnable session over the same kernel.
//!
//! The integration tests below pin the kernel's fleet-mode semantics:
//! determinism, contention, admission limits, budget caps, per-tenant
//! policy overrides, hedged cancellation/refunds, and the result cache.

pub use crate::sim::{
    run_fleet, run_fleet_sharded, FleetArrival, FleetConfig, FleetQueryResult, FleetReport,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::HybridFlowPipeline;
    use crate::planner::synthetic::SyntheticPlanner;
    use crate::budget::TenantPool;
    use crate::config::simparams::SimParams;
    use crate::models::SimExecutor;
    use crate::pipeline::PipelineConfig;
    use crate::router::{MirrorPredictor, RoutePolicy};
    use crate::workload::{generate_queries, Benchmark};
    use std::sync::Arc;

    fn pipeline(policy: RoutePolicy) -> HybridFlowPipeline {
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = policy;
        HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        )
    }

    fn arrivals(n: usize, gap: f64, tenants: usize, seed: u64) -> Vec<FleetArrival> {
        generate_queries(Benchmark::Gpqa, n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, query)| FleetArrival { time: i as f64 * gap, tenant: i % tenants, query })
            .collect()
    }

    #[test]
    fn fleet_runs_and_reports() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let tenants = vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")];
        let report =
            run_fleet(&p, &FleetConfig::default(), tenants, arrivals(12, 2.0, 2, 3), 99);
        assert_eq!(report.results.len(), 12);
        assert!(report.clock_monotone);
        assert!(report.horizon > 0.0);
        assert!(report.throughput_qps > 0.0);
        assert!((0.0..=1.0).contains(&report.offload_rate));
        assert_eq!(report.hedge_cancelled, 0, "hedging is off by default");
        for r in &report.results {
            assert!(r.completed_at >= r.plan_done && r.plan_done >= r.admitted);
            assert!(r.admitted >= r.arrival);
            assert_eq!(r.exec.events.len(), r.exec.n_subtasks);
            assert!(r.exec.latency > 0.0);
        }
        assert!(!report.trace.is_empty());
        assert!(report.render().contains("fleet: 12 queries"));
    }

    #[test]
    fn fleet_is_deterministic() {
        let sp = SimParams::default();
        let make = || {
            let p = pipeline(RoutePolicy::hybridflow(&sp));
            let tenants = vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")];
            run_fleet(&p, &FleetConfig::default(), tenants, arrivals(10, 1.0, 2, 5), 17)
        };
        let a = make();
        let b = make();
        assert_eq!(a.trace_text(), b.trace_text());
        assert_eq!(a.total_api_cost, b.total_api_cost);
        for (x, y) in a.results.iter().zip(&b.results) {
            assert_eq!(x.exec.latency, y.exec.latency);
            assert_eq!(x.exec.correct, y.exec.correct);
        }
    }

    #[test]
    fn contention_raises_queue_wait() {
        // Same workload, back-to-back arrivals: one shared edge worker must
        // produce strictly more queueing than a wide pool.
        let narrow = {
            let mut p = pipeline(RoutePolicy::AllEdge);
            p.config.schedule.edge_workers = 1;
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                arrivals(8, 0.1, 1, 7),
                1,
            )
        };
        let wide = {
            let mut p = pipeline(RoutePolicy::AllEdge);
            p.config.schedule.edge_workers = 64;
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                arrivals(8, 0.1, 1, 7),
                1,
            )
        };
        assert!(
            narrow.queue_wait.mean > wide.queue_wait.mean + 1e-9,
            "narrow {} wide {}",
            narrow.queue_wait.mean,
            wide.queue_wait.mean
        );
        assert!(narrow.sojourn.p99 > wide.sojourn.p99);
    }

    #[test]
    fn admission_limit_queues_arrivals() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let cfg = FleetConfig { admission_limit: 1, ..Default::default() };
        let report =
            run_fleet(&p, &cfg, vec![TenantPool::unlimited("t")], arrivals(6, 0.0, 1, 11), 2);
        // All queries arrive at t=0; only one is in service at a time, so
        // later queries see positive admission delay.
        assert!(report.admission_delay.max > 0.0);
        assert_eq!(report.results.len(), 6);
        // Serialized service: completions strictly ordered.
        let mut times: Vec<f64> = report.results.iter().map(|r| r.completed_at).collect();
        let sorted = {
            let mut s = times.clone();
            s.sort_by(f64::total_cmp);
            s
        };
        times.sort_by(f64::total_cmp);
        assert_eq!(times, sorted);
    }

    #[test]
    fn chain_mode_holds_admission_slot_until_makespan() {
        let sp = SimParams::default();
        let mut p = pipeline(RoutePolicy::hybridflow(&sp));
        p.config.schedule.chain_mode = true;
        let cfg = FleetConfig { admission_limit: 1, ..Default::default() };
        let report =
            run_fleet(&p, &cfg, vec![TenantPool::unlimited("t")], arrivals(4, 0.0, 1, 21), 6);
        // Service is strictly serialized: each admission waits for the
        // previous chain's full virtual makespan, not just its planning.
        let mut order: Vec<&FleetQueryResult> = report.results.iter().collect();
        order.sort_by(|a, b| a.admitted.total_cmp(&b.admitted));
        for w in order.windows(2) {
            assert!(
                w[1].admitted >= w[0].completed_at - 1e-9,
                "admitted {} before previous completion {}",
                w[1].admitted,
                w[0].completed_at
            );
        }
        assert_eq!(report.edge_utilization, 0.0);
    }

    #[test]
    fn exhausted_tenant_pool_forces_edge() {
        let p = pipeline(RoutePolicy::AllCloud);
        // Tiny dollar pool: after it drains, every further decision is
        // forced to the edge even under an all-cloud policy.
        let report = run_fleet(
            &p,
            &FleetConfig::default(),
            vec![TenantPool::new("capped", 1e-6)],
            arrivals(6, 5.0, 1, 13),
            3,
        );
        assert!(report.forced_edge > 0, "no forced-edge decisions");
        assert!(report.offload_rate < 1.0);
        // Overshoot bounded by one call: spend < cap + the priciest call.
        let max_call = report
            .results
            .iter()
            .flat_map(|r| r.exec.events.iter())
            .map(|e| e.api_cost)
            .fold(0.0f64, f64::max);
        assert!(report.tenants[0].state.k_used <= 1e-6 + max_call + 1e-12);
    }

    #[test]
    fn global_cap_gates_all_tenants() {
        let p = pipeline(RoutePolicy::AllCloud);
        let cfg = FleetConfig { global_k_cap: 1e-6, ..Default::default() };
        let report = run_fleet(
            &p,
            &cfg,
            vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")],
            arrivals(6, 5.0, 2, 19),
            4,
        );
        assert!(report.forced_edge > 0);
        let max_call = report
            .results
            .iter()
            .flat_map(|r| r.exec.events.iter())
            .map(|e| e.api_cost)
            .fold(0.0f64, f64::max);
        assert!(report.global.k_spent <= 1e-6 + max_call + 1e-12);
    }

    #[test]
    fn per_tenant_policies_route_differently() {
        // One fleet, two tenants, opposite policies: the override layer
        // must steer every decision per tenant.
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp)); // default, unused by overrides
        let cfg = FleetConfig {
            tenant_policies: vec![Some(RoutePolicy::AllEdge), Some(RoutePolicy::AllCloud)],
            ..Default::default()
        };
        let tenants = vec![TenantPool::unlimited("edge"), TenantPool::unlimited("cloud")];
        let report = run_fleet(&p, &cfg, tenants, arrivals(8, 2.0, 2, 31), 9);
        assert_eq!(report.tenants[0].state.n_offloaded, 0, "all-edge tenant offloaded");
        assert!(report.tenants[0].state.n_decided > 0);
        assert_eq!(
            report.tenants[1].state.n_offloaded, report.tenants[1].state.n_decided,
            "all-cloud tenant kept something on edge"
        );
        assert_eq!(report.tenants[0].state.k_used, 0.0);
        assert!(report.tenants[1].state.k_used > 0.0);
    }

    #[test]
    fn missing_override_falls_back_to_pipeline_policy() {
        // Tenant 1 has no override entry: it must behave like the pipeline
        // default (AllCloud here), while tenant 0 is pinned to AllEdge.
        let p = pipeline(RoutePolicy::AllCloud);
        let cfg = FleetConfig {
            tenant_policies: vec![Some(RoutePolicy::AllEdge)],
            ..Default::default()
        };
        let tenants = vec![TenantPool::unlimited("pinned"), TenantPool::unlimited("default")];
        let report = run_fleet(&p, &cfg, tenants, arrivals(6, 2.0, 2, 33), 12);
        assert_eq!(report.tenants[0].state.n_offloaded, 0);
        assert_eq!(
            report.tenants[1].state.n_offloaded,
            report.tenants[1].state.n_decided
        );
    }

    #[test]
    fn hedged_fleet_cancels_and_refunds() {
        // Edge-pinned policy + hedge-everything: speculative cloud replicas
        // fire for every subtask; losers must be cancelled with refunds and
        // all dollar scopes must stay consistent.
        let mut p = pipeline(RoutePolicy::AllEdge);
        p.config.schedule.hedge = true;
        p.config.schedule.hedge_threshold = f64::NEG_INFINITY;
        let report = run_fleet(
            &p,
            &FleetConfig::default(),
            vec![TenantPool::unlimited("t")],
            arrivals(8, 1.0, 1, 41),
            7,
        );
        assert!(report.hedge_cancelled > 0, "no hedged losers cancelled");
        assert!(report.hedge_refund >= 0.0);
        let tenant_sum: f64 = report.tenants.iter().map(|t| t.state.k_used).sum();
        assert!(
            (report.global.k_spent - tenant_sum).abs() < 1e-9,
            "global {} vs tenants {}",
            report.global.k_spent,
            tenant_sum
        );
        assert!(report.global.k_spent >= 0.0);
        assert!(report.render().contains("hedge:"));
        // Cancel lines appear in the trace (hedge-on only).
        assert!(report.trace.iter().any(|l| l.contains(" cancel node=")));
    }

    #[test]
    fn hedged_fleet_is_deterministic() {
        let make = || {
            let mut p = pipeline(RoutePolicy::AllEdge);
            p.config.schedule.hedge = true;
            p.config.schedule.hedge_threshold = 0.2;
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                arrivals(8, 0.5, 1, 43),
                23,
            )
        };
        let a = make();
        let b = make();
        assert_eq!(a.trace_text(), b.trace_text());
        assert_eq!(a.total_api_cost, b.total_api_cost);
        assert_eq!(a.hedge_cancelled, b.hedge_cancelled);
        assert_eq!(a.hedge_refund, b.hedge_refund);
    }

    // --- Cross-query result cache -----------------------------------------

    /// The same query content arriving `n` times, widely spaced (no
    /// contention), on one tenant.
    fn repeated_arrivals(n: usize, seed: u64) -> Vec<FleetArrival> {
        let q = generate_queries(Benchmark::Gpqa, 1, seed).pop().unwrap();
        (0..n)
            .map(|i| FleetArrival { time: i as f64 * 100.0, tenant: 0, query: q.clone() })
            .collect()
    }

    fn cached_pipeline(policy: RoutePolicy, capacity: usize) -> HybridFlowPipeline {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        let mut p = pipeline(policy);
        if capacity > 0 {
            p.config.schedule.cache =
                Some(Arc::new(SubtaskCache::new(capacity, CachePolicyKind::Lru)));
        }
        p
    }

    use crate::eval::experiments::fleet_cloud_tokens as cloud_tokens;

    #[test]
    fn repeated_queries_hit_cache_and_cut_cloud_spend() {
        let run = |capacity: usize| {
            let p = cached_pipeline(RoutePolicy::AllCloud, capacity);
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                repeated_arrivals(6, 51),
                9,
            )
        };
        let off = run(0);
        let on = run(256);
        assert!(off.cache.is_none(), "no cache attached => no cache column");
        let stats = on.cache.as_ref().expect("cache stats present");
        assert!(stats.hits > 0, "repeated content must hit");
        assert!(stats.hit_rate() > 0.2, "hit rate {} too low", stats.hit_rate());
        assert!(stats.tokens_saved > 0.0);
        assert!(stats.dollars_saved > 0.0);
        assert!(
            cloud_tokens(&on) < cloud_tokens(&off),
            "cached run must transmit strictly fewer cloud tokens"
        );
        assert!(on.total_api_cost < off.total_api_cost, "hits spend no dollars");
        // Cached events show up in the trace as side=cache.
        assert!(on.trace.iter().any(|l| l.contains("side=cache")));
        assert!(on.render().contains("cache: hit rate"));
    }

    #[test]
    fn cached_fleet_is_deterministic_across_runs() {
        // The cache is reset at run start, so back-to-back runs over one
        // shared Arc'd cache must produce byte-identical traces.
        let p = cached_pipeline(RoutePolicy::AllCloud, 128);
        let make = || {
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("t")],
                repeated_arrivals(5, 77),
                13,
            )
        };
        let a = make();
        let b = make();
        assert_eq!(a.trace_text(), b.trace_text());
        let (sa, sb) = (a.cache.unwrap(), b.cache.unwrap());
        assert_eq!(sa.lookups, sb.lookups);
        assert_eq!(sa.hits, sb.hits);
        assert_eq!(sa.insertions, sb.insertions);
    }

    #[test]
    fn tenant_partitions_isolate_in_fleet_unless_shared() {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        // The same query alternates between two tenants. Isolated
        // partitions force each tenant to warm its own cache; a shared
        // tier lets tenant B hit tenant A's entries (shared_hits > 0).
        let run = |shared: bool| {
            let mut p = pipeline(RoutePolicy::AllCloud);
            let cache = SubtaskCache::new(256, CachePolicyKind::Lru);
            let cache = if shared { cache.with_shared_tier() } else { cache };
            p.config.schedule.cache = Some(Arc::new(cache));
            let q = generate_queries(Benchmark::Gpqa, 1, 61).pop().unwrap();
            let arrivals: Vec<FleetArrival> = (0..6)
                .map(|i| FleetArrival {
                    time: i as f64 * 100.0,
                    tenant: i % 2,
                    query: q.clone(),
                })
                .collect();
            run_fleet(
                &p,
                &FleetConfig::default(),
                vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")],
                arrivals,
                3,
            )
        };
        let isolated = run(false);
        let shared = run(true);
        let iso_stats = isolated.cache.unwrap();
        let sh_stats = shared.cache.unwrap();
        assert_eq!(iso_stats.shared_hits, 0, "no shared tier => no shared hits");
        assert!(sh_stats.shared_hits > 0, "shared tier must serve cross-tenant hits");
        assert!(sh_stats.hits >= iso_stats.hits, "sharing can only add hits");
    }

    #[test]
    fn empty_fleet_reports_zeros_not_nan() {
        let sp = SimParams::default();
        let p = pipeline(RoutePolicy::hybridflow(&sp));
        let report =
            run_fleet(&p, &FleetConfig::default(), vec![TenantPool::unlimited("t")], vec![], 1);
        assert_eq!(report.results.len(), 0);
        assert_eq!(report.offload_rate, 0.0);
        assert_eq!(report.admission_delay.mean, 0.0);
        assert_eq!(report.queue_wait.p99, 0.0);
        assert_eq!(report.sojourn.p95, 0.0);
        assert_eq!(report.admission_delay.n, 0, "still marked as an empty sample");
        let rendered = report.render();
        assert!(!rendered.contains("NaN"), "empty fleet must render zeros: {rendered}");
    }
}

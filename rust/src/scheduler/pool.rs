//! Shared worker pools with O(log W) claim/release.
//!
//! The kernel's innermost loop claims a worker on every dispatch (twice
//! for a hedged dispatch — one replica per side). Historically that claim
//! was an `argmin` scan over a `Vec<f64>` of per-worker next-free times:
//! O(W) on every decision, which turns the dispatch path linear in pool
//! size exactly where "as fast as the hardware allows" wants it flat.
//!
//! [`WorkerPool`] keeps the same `Vec<f64>` of next-free times as the
//! source of truth and adds an ordered index — a `BTreeSet` of
//! `(ordered_bits(free_time), worker)` pairs — so the earliest-free
//! worker is the set's first element: O(log W) claim, O(log W) release.
//! `ordered_bits` (shared with the cache's eviction index) maps `f64`
//! onto `u64` preserving `total_cmp` order, so the integer index orders
//! exactly like the floats.
//!
//! **Tie-break contract** (pinned by the golden fleet trace): among
//! workers with equal next-free times, the *lowest worker index* wins —
//! the same worker the historical `argmin` scan chose (first strict
//! minimum). Equal `f64` times have equal `ordered_bits`, so the
//! `(bits, worker)` key degenerates to worker order on ties. The one
//! place bit order and `<` disagree is `-0.0` vs `0.0`, which cannot
//! occur here: free times are `0.0` at construction and evolve through
//! `max`/`+`/`clamp` over non-negative operands.
//!
//! [`WorkerPool::linear_reference`] retains the historical scan as a
//! drop-in reference implementation: the scripted-churn parity tests
//! below replay identical claim/release sequences against both and
//! require identical worker choices, and `benches/kernel.rs` measures
//! the indexed kernel against the linear-scan baseline it replaced
//! (`BENCH_kernel.json`).

use crate::cache::policy::ordered_bits;
use std::collections::BTreeSet;

/// A pool of virtual-clock workers: per-worker next-free times plus an
/// ordered free-time index. See the module docs for the tie-break and
/// complexity contract.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    /// Next-free virtual time per worker (the source of truth).
    free: Vec<f64>,
    /// Configured worker count *before* phantom padding — the utilization
    /// denominator. A zero-worker side still carries one phantom slot so
    /// the engine's claim path stays total, but reports no capacity.
    configured: usize,
    /// Ordered `(ordered_bits(free), worker)` index; `None` selects the
    /// retained linear `argmin` reference semantics (parity tests, perf
    /// baseline).
    index: Option<BTreeSet<(u64, u32)>>,
}

impl WorkerPool {
    /// Indexed pool of `configured` workers (padded to one phantom worker
    /// when zero, matching the engine's historical `max(1)` padding).
    pub fn new(configured: usize) -> WorkerPool {
        let n = configured.max(1);
        WorkerPool {
            free: vec![0.0; n],
            configured,
            index: Some((0..n as u32).map(|w| (ordered_bits(0.0), w)).collect()),
        }
    }

    /// The historical O(W) linear-scan pool, kept as the reference
    /// implementation the indexed pool is verified and benchmarked
    /// against. Byte-identical semantics, linear claim cost.
    pub fn linear_reference(configured: usize) -> WorkerPool {
        WorkerPool { free: vec![0.0; configured.max(1)], configured, index: None }
    }

    /// Effective pool size (phantom-padded, always >= 1).
    pub fn len(&self) -> usize {
        self.free.len()
    }

    pub fn is_empty(&self) -> bool {
        self.free.is_empty()
    }

    /// Configured worker count before phantom padding — the utilization
    /// denominator (0 means this side has no real capacity).
    pub fn configured(&self) -> usize {
        self.configured
    }

    /// Earliest-free worker: O(log W) via the index, O(W) in reference
    /// mode. Ties break to the lowest worker index in both modes.
    pub fn earliest(&self) -> usize {
        match &self.index {
            Some(ix) => {
                ix.iter().next().expect("worker pool is never empty").1 as usize
            }
            None => argmin(&self.free),
        }
    }

    /// Next-free time of one worker.
    pub fn free_at(&self, w: usize) -> f64 {
        self.free[w]
    }

    /// Reserve the earliest-free worker for a task of `latency` starting
    /// no earlier than `now`. Returns `(worker, start, finish)` and
    /// advances the worker's next-free time to `finish`.
    pub fn claim(&mut self, now: f64, latency: f64) -> (usize, f64, f64) {
        let w = self.earliest();
        let start = self.free[w].max(now);
        let finish = start + latency;
        self.set_free(w, finish);
        (w, start, finish)
    }

    /// Workers still busy at virtual time `t` (next-free strictly after
    /// `t`), capped at the configured count so a zero-worker side's
    /// phantom slot never reports occupancy. O(W) — called only on the
    /// observability layer's metrics-snapshot path, never per dispatch.
    pub fn busy_at(&self, t: f64) -> usize {
        self.free.iter().filter(|&&f| f > t).count().min(self.configured)
    }

    /// Move one worker's next-free time (cancellation release path: a
    /// hedged loser hands back the unconsumed tail of its reservation).
    pub fn set_free(&mut self, w: usize, t: f64) {
        if let Some(ix) = self.index.as_mut() {
            let removed = ix.remove(&(ordered_bits(self.free[w]), w as u32));
            debug_assert!(removed, "pool index out of sync for worker {w}");
            ix.insert((ordered_bits(t), w as u32));
        }
        self.free[w] = t;
    }
}

/// First index holding the strict minimum — the historical linear-scan
/// worker selection (lowest index wins ties), retained as the reference
/// semantics of [`WorkerPool::earliest`].
pub fn argmin(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ties_break_to_lowest_index() {
        let mut pool = WorkerPool::new(4);
        // All free at 0: indices claimed in order.
        assert_eq!(pool.claim(0.0, 5.0).0, 0);
        assert_eq!(pool.claim(0.0, 5.0).0, 1);
        assert_eq!(pool.claim(0.0, 5.0).0, 2);
        assert_eq!(pool.claim(0.0, 5.0).0, 3);
        // All free at 5: wraps back to 0.
        let (w, start, finish) = pool.claim(1.0, 2.0);
        assert_eq!(w, 0);
        assert_eq!(start, 5.0, "start waits for the worker, not `now`");
        assert_eq!(finish, 7.0);
    }

    #[test]
    fn claim_starts_at_now_when_idle() {
        let mut pool = WorkerPool::new(2);
        let (w, start, finish) = pool.claim(3.5, 1.0);
        assert_eq!((w, start, finish), (0, 3.5, 4.5));
        // Second worker still idle at 0 — earliest is now worker 1.
        assert_eq!(pool.earliest(), 1);
    }

    #[test]
    fn release_reorders_index() {
        let mut pool = WorkerPool::new(3);
        pool.claim(0.0, 10.0); // w0 busy till 10
        pool.claim(0.0, 20.0); // w1 busy till 20
        pool.claim(0.0, 30.0); // w2 busy till 30
        assert_eq!(pool.earliest(), 0);
        // Cancel releases w2 back to 5: it becomes the earliest.
        pool.set_free(2, 5.0);
        assert_eq!(pool.earliest(), 2);
        assert_eq!(pool.free_at(2), 5.0);
        let (w, start, _) = pool.claim(6.0, 1.0);
        assert_eq!((w, start), (2, 6.0));
    }

    #[test]
    fn busy_at_counts_strictly_later_free_times() {
        let mut pool = WorkerPool::new(3);
        assert_eq!(pool.busy_at(0.0), 0, "all idle at construction");
        pool.claim(0.0, 10.0); // w0 busy till 10
        pool.claim(0.0, 4.0); // w1 busy till 4
        assert_eq!(pool.busy_at(2.0), 2);
        assert_eq!(pool.busy_at(4.0), 1, "boundary: next-free == t is idle");
        assert_eq!(pool.busy_at(10.0), 0);
        // The phantom slot of a zero-worker side never reports occupancy.
        let mut empty = WorkerPool::new(0);
        empty.claim(0.0, 5.0);
        assert_eq!(empty.busy_at(1.0), 0);
    }

    #[test]
    fn zero_configured_pads_one_phantom_worker() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.len(), 1, "claim path stays total");
        assert_eq!(pool.configured(), 0, "but the side reports no capacity");
        let linear = WorkerPool::linear_reference(0);
        assert_eq!(linear.len(), 1);
        assert_eq!(linear.configured(), 0);
    }

    /// Scripted-churn parity (the PR 4 cache-evict-index pattern): replay
    /// one randomized claim/release script against the indexed pool and
    /// the retained linear `argmin` reference, and require the *same
    /// worker* (and identical timing) at every step — including ties.
    #[test]
    fn indexed_pool_matches_linear_reference_under_churn() {
        for seed in [1u64, 7, 42, 1234] {
            for workers in [1usize, 2, 3, 8, 17] {
                let mut rng = Rng::new(seed ^ workers as u64);
                let mut fast = WorkerPool::new(workers);
                let mut slow = WorkerPool::linear_reference(workers);
                let mut now = 0.0f64;
                // (worker, start, reserved_until) of claims eligible for a
                // scripted cancel-style release.
                let mut open: Vec<(usize, f64, f64)> = Vec::new();
                for step in 0..600 {
                    now += rng.uniform(0.0, 0.7);
                    if !open.is_empty() && rng.bernoulli(0.25) {
                        // Cancel-style release: hand back the unconsumed
                        // tail of a past reservation (same guard as
                        // `apply_cancel`: only if the reservation is still
                        // the top of that worker's timeline).
                        let k = (rng.next_u64() % open.len() as u64) as usize;
                        let (w, start, reserved) = open.swap_remove(k);
                        let release = now.clamp(start, reserved);
                        if fast.free_at(w) == reserved {
                            assert_eq!(slow.free_at(w), reserved, "step {step}");
                            fast.set_free(w, release);
                            slow.set_free(w, release);
                        }
                    } else {
                        // Quantized latencies force frequent exact ties.
                        let latency = (rng.uniform(0.0, 4.0) * 2.0).round() / 2.0;
                        let a = fast.claim(now, latency);
                        let b = slow.claim(now, latency);
                        assert_eq!(a, b, "seed {seed} workers {workers} step {step}");
                        open.push((a.0, a.1, a.2));
                    }
                    assert_eq!(fast.earliest(), slow.earliest(), "step {step}");
                }
                // Final per-worker timelines agree exactly.
                for w in 0..fast.len() {
                    assert_eq!(fast.free_at(w).to_bits(), slow.free_at(w).to_bits());
                }
            }
        }
    }

    #[test]
    fn argmin_reference_picks_first_minimum() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 2.0]), 1);
        assert_eq!(argmin(&[0.0]), 0);
        assert_eq!(argmin(&[5.0, 4.0, 3.0]), 2);
    }
}

//! Execution trace events — the raw material for Figure 3 (edge/cloud
//! distribution by subtask position + adaptive threshold line) and for
//! debugging scheduling decisions.

/// One subtask's routing + execution record.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub node: usize,
    /// Topological depth (Figure 3's "subtask position" axis).
    pub position: usize,
    pub cloud: bool,
    /// Threshold in force at decision time.
    pub tau: f64,
    /// Predicted utility at decision time.
    pub u_hat: f64,
    /// Virtual-clock start/finish (seconds, includes planning offset).
    pub start: f64,
    pub finish: f64,
    pub api_cost: f64,
    pub correct: bool,
    /// Input tokens of the call (query prompt + dependency outputs) — the
    /// transmitted payload `tok(x_i)` of the App. D.1 exposure proxy.
    pub in_tokens: f64,
}

/// Position histogram used by Figure 3: per position, (edge count, cloud
/// count, mean tau).
#[derive(Debug, Clone, Default)]
pub struct PositionHistogram {
    pub edge: Vec<usize>,
    pub cloud: Vec<usize>,
    pub tau_sum: Vec<f64>,
    pub tau_count: Vec<usize>,
}

impl PositionHistogram {
    pub fn add(&mut self, events: &[TraceEvent]) {
        for e in events {
            let p = e.position;
            if self.edge.len() <= p {
                self.edge.resize(p + 1, 0);
                self.cloud.resize(p + 1, 0);
                self.tau_sum.resize(p + 1, 0.0);
                self.tau_count.resize(p + 1, 0);
            }
            if e.cloud {
                self.cloud[p] += 1;
            } else {
                self.edge[p] += 1;
            }
            self.tau_sum[p] += e.tau;
            self.tau_count[p] += 1;
        }
    }

    pub fn mean_tau(&self, p: usize) -> f64 {
        if p < self.tau_count.len() && self.tau_count[p] > 0 {
            self.tau_sum[p] / self.tau_count[p] as f64
        } else {
            f64::NAN
        }
    }

    pub fn positions(&self) -> usize {
        self.edge.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(position: usize, cloud: bool, tau: f64) -> TraceEvent {
        TraceEvent {
            node: 0,
            position,
            cloud,
            tau,
            u_hat: 0.5,
            start: 0.0,
            finish: 1.0,
            api_cost: 0.0,
            correct: true,
            in_tokens: 100.0,
        }
    }

    #[test]
    fn histogram_accumulates() {
        let mut h = PositionHistogram::default();
        h.add(&[ev(0, true, 0.2), ev(0, false, 0.4), ev(2, false, 0.8)]);
        assert_eq!(h.positions(), 3);
        assert_eq!(h.cloud[0], 1);
        assert_eq!(h.edge[0], 1);
        assert_eq!(h.edge[2], 1);
        assert!((h.mean_tau(0) - 0.3).abs() < 1e-12);
        assert!(h.mean_tau(1).is_nan());
        assert!((h.mean_tau(2) - 0.8).abs() < 1e-12);
    }
}

//! Execution trace events — the raw material for Figure 3 (edge/cloud
//! distribution by subtask position + adaptive threshold line) and for
//! debugging scheduling decisions — plus [`EventKey`], the single heap
//! ordering shared by every scheduler event queue.

use crate::fault::FaultMark;
use std::cmp::Ordering;

/// Shared min-heap key for every scheduler event queue: the single-query
/// ready/pending heaps and the fleet's tagged event heap all order on
/// `(time, pri, q, node)` through this one `Ord` impl, so there is exactly
/// one tie-break rule in the engine — control events (pri 0) before
/// ready-frontier markers (pri 1) before subtask finishes (pri 2), then
/// queue index, then node index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct EventKey {
    pub time: f64,
    pub pri: u8,
    pub q: usize,
    pub node: usize,
}

impl EventKey {
    /// Single-query key: no queue or priority dimension, so the ordering
    /// degenerates to the classic `(time, node)` min-heap.
    pub fn ready(time: f64, node: usize) -> EventKey {
        EventKey { time, pri: 0, q: 0, node }
    }
}

impl Eq for EventKey {}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on (time, pri, q, node): reversed operand order because
        // BinaryHeap is a max-heap.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.pri.cmp(&self.pri))
            .then_with(|| other.q.cmp(&self.q))
            .then_with(|| other.node.cmp(&self.node))
    }
}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One subtask's routing + execution record.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub node: usize,
    /// Topological depth (Figure 3's "subtask position" axis).
    pub position: usize,
    /// Side whose result was used. For a hedged dispatch this is the
    /// winning replica.
    pub cloud: bool,
    /// Threshold in force at decision time.
    pub tau: f64,
    /// Predicted utility at decision time.
    pub u_hat: f64,
    /// Virtual-clock start/finish (seconds, includes planning offset).
    pub start: f64,
    pub finish: f64,
    /// Dollars billed at dispatch time. For a hedged dispatch whose cloud
    /// replica lost, this is the *full* speculative call cost; the
    /// unconsumed remainder is refunded later by the `Cancel` event, so
    /// net totals can be below the sum of event costs.
    pub api_cost: f64,
    pub correct: bool,
    /// Input tokens of the call (query prompt + dependency outputs) — the
    /// transmitted payload `tok(x_i)` of the App. D.1 exposure proxy.
    pub in_tokens: f64,
    /// Whether this node was speculatively dispatched to both sides.
    pub hedged: bool,
    /// Whether this node was served from the cross-query result cache
    /// (no worker occupied, no budget spent; `cloud` then records the
    /// side that produced the *original* cached record).
    pub cached: bool,
    /// Worker index of the winning replica within its side's pool (0 for
    /// cache hits, chain-mode virtual execution, and outage rejections,
    /// which occupy no pool worker) — the observability layer's span lane.
    pub worker: usize,
    /// Fault/resilience annotation of this dispatch attempt. `Default`
    /// means "nothing fault-related" and renders to zero extra bytes, so
    /// fault-free traces keep their golden format.
    pub fault: FaultMark,
}

/// Position histogram used by Figure 3: per position, (edge count, cloud
/// count, mean tau).
#[derive(Debug, Clone, Default)]
pub struct PositionHistogram {
    pub edge: Vec<usize>,
    pub cloud: Vec<usize>,
    pub tau_sum: Vec<f64>,
    pub tau_count: Vec<usize>,
}

impl PositionHistogram {
    pub fn add(&mut self, events: &[TraceEvent]) {
        for e in events {
            let p = e.position;
            if self.edge.len() <= p {
                self.edge.resize(p + 1, 0);
                self.cloud.resize(p + 1, 0);
                self.tau_sum.resize(p + 1, 0.0);
                self.tau_count.resize(p + 1, 0);
            }
            if e.cloud {
                self.cloud[p] += 1;
            } else {
                self.edge[p] += 1;
            }
            self.tau_sum[p] += e.tau;
            self.tau_count[p] += 1;
        }
    }

    pub fn mean_tau(&self, p: usize) -> f64 {
        if p < self.tau_count.len() && self.tau_count[p] > 0 {
            self.tau_sum[p] / self.tau_count[p] as f64
        } else {
            f64::NAN
        }
    }

    pub fn positions(&self) -> usize {
        self.edge.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(position: usize, cloud: bool, tau: f64) -> TraceEvent {
        TraceEvent {
            node: 0,
            position,
            cloud,
            tau,
            u_hat: 0.5,
            start: 0.0,
            finish: 1.0,
            api_cost: 0.0,
            correct: true,
            in_tokens: 100.0,
            hedged: false,
            cached: false,
            worker: 0,
            fault: FaultMark::default(),
        }
    }

    #[test]
    fn event_key_orders_time_then_pri_then_q_then_node() {
        use std::collections::BinaryHeap;
        let mut heap = BinaryHeap::new();
        heap.push(EventKey { time: 2.0, pri: 0, q: 0, node: 0 });
        heap.push(EventKey { time: 1.0, pri: 2, q: 0, node: 1 });
        heap.push(EventKey { time: 1.0, pri: 1, q: 1, node: 0 });
        heap.push(EventKey { time: 1.0, pri: 1, q: 0, node: 5 });
        heap.push(EventKey { time: 1.0, pri: 1, q: 0, node: 2 });
        let order: Vec<(f64, u8, usize, usize)> = std::iter::from_fn(|| heap.pop())
            .map(|k| (k.time, k.pri, k.q, k.node))
            .collect();
        assert_eq!(
            order,
            vec![
                (1.0, 1, 0, 2), // same time: lowest pri, then q, then node
                (1.0, 1, 0, 5),
                (1.0, 1, 1, 0),
                (1.0, 2, 0, 1),
                (2.0, 0, 0, 0), // later time loses regardless of pri
            ]
        );
    }

    #[test]
    fn ready_key_degenerates_to_time_node_order() {
        let a = EventKey::ready(1.0, 3);
        let b = EventKey::ready(1.0, 4);
        let c = EventKey::ready(0.5, 9);
        // Min-heap semantics: larger in `Ord` pops first from BinaryHeap.
        assert!(a > b, "lower node pops first at equal time");
        assert!(c > a, "earlier time pops first");
    }

    #[test]
    fn histogram_accumulates() {
        let mut h = PositionHistogram::default();
        h.add(&[ev(0, true, 0.2), ev(0, false, 0.4), ev(2, false, 0.8)]);
        assert_eq!(h.positions(), 3);
        assert_eq!(h.cloud[0], 1);
        assert_eq!(h.edge[0], 1);
        assert_eq!(h.edge[2], 1);
        assert!((h.mean_tau(0) - 0.3).abs() < 1e-12);
        assert!(h.mean_tau(1).is_nan());
        assert!((h.mean_tau(2) - 0.8).abs() < 1e-12);
    }
}

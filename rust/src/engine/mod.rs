//! Pluggable execution backends — the `Backend` seam of the engine.
//!
//! Everything above the model layer (scheduler, fleet simulator, baselines,
//! pipeline, profiling) consumes endpoints exclusively through [`Backend`]:
//! the simulation/normalization parameters, the per-side [`ModelProfile`]s,
//! and per-call [`ExecRecord`]s. That surface is all a *real* serving
//! backend could expose too, which is what makes the seam load-bearing:
//!
//! * [`crate::models::SimExecutor`] is the canonical implementation (the
//!   paper's calibrated simulation substrate);
//! * [`ReplayBackend`] re-serves a recorded `ExecRecord` tape
//!   deterministically — trace-driven evaluation, and the structural
//!   template for an HTTP or PJRT-served endpoint behind the `pjrt`
//!   feature (implement `Backend`, return real records);
//! * [`RecordingBackend`] wraps any backend and captures the tape.
//!
//! Determinism contract: a backend may consume the *caller's* RNG stream
//! (as `SimExecutor` does) or none of it (as `ReplayBackend` does), but it
//! must never consume a data-dependent amount based on hidden state — the
//! scheduler's reproducibility guarantees (fleet golden trace,
//! fleet(N=1) == `execute_query`) rely on call-for-call stream alignment.
//! Any backend-internal randomness must come from streams forked per call
//! site (see the hedged-dispatch paths in `scheduler`), never from the
//! shared query stream.

use crate::config::simparams::SimParams;
use crate::models::{ExecRecord, ModelProfile, SimExecutor};
use crate::util::rng::Rng;
use crate::workload::SubtaskLatent;
use std::collections::VecDeque;
use std::sync::Mutex;

/// An execution endpoint pair (edge + cloud) the engine can drive.
pub trait Backend: Send + Sync {
    /// Short diagnostics label ("sim", "replay", ...).
    fn name(&self) -> &'static str;

    /// Simulation / normalization parameters shared with routing + budget.
    fn sp(&self) -> &SimParams;

    /// Serving profile of one side (`false` = edge, `true` = cloud).
    fn profile(&self, cloud: bool) -> &ModelProfile;

    /// Execute one decomposed subtask on the chosen side. `in_tokens` must
    /// include the query prompt plus dependency outputs.
    fn execute_subtask(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord;

    /// Execute the whole query as a single (direct or CoT) call.
    fn execute_direct(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord;

    /// Final-answer correctness draw: `P(correct) = prod_i (1 - w_i (1 - s_i))`.
    /// Default implementation is the aggregation model shared by every
    /// backend (it depends only on latents, not on endpoint behavior).
    fn final_answer_correct(
        &self,
        latents: &[SubtaskLatent],
        subtask_correct: &[bool],
        rng: &mut Rng,
    ) -> bool {
        let mut p = 1.0;
        for (l, &ok) in latents.iter().zip(subtask_correct) {
            if !ok {
                p *= 1.0 - l.criticality;
            }
        }
        rng.bernoulli(p)
    }

    /// Expected accuracy gain of offloading subtask `i` with the rest of
    /// the pipeline mixed (profiling ground truth; oracle policy input).
    /// Default derives it from the two profiles, which is exact for any
    /// backend whose correctness model is the shared `p_solve` sigmoid.
    fn true_dq(&self, domain: usize, latents: &[SubtaskLatent], i: usize) -> f64 {
        let sp = self.sp();
        let (edge, cloud) = (self.profile(false), self.profile(true));
        let p_e = edge.p_solve(domain, latents[i].difficulty, sp);
        let p_c = cloud.p_solve(domain, latents[i].difficulty, sp);
        let mut pipeline = 1.0;
        for (j, l) in latents.iter().enumerate() {
            if j != i {
                let p_avg = 0.5
                    * (edge.p_solve(domain, l.difficulty, sp)
                        + cloud.p_solve(domain, l.difficulty, sp));
                pipeline *= 1.0 - l.criticality * (1.0 - p_avg);
            }
        }
        (p_c - p_e) * latents[i].criticality * pipeline
    }
}

impl Backend for SimExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn sp(&self) -> &SimParams {
        &self.sp
    }

    fn profile(&self, cloud: bool) -> &ModelProfile {
        SimExecutor::profile(self, cloud)
    }

    fn execute_subtask(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        SimExecutor::execute_subtask(self, domain, latent, in_tokens, cloud, rng)
    }

    fn execute_direct(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        SimExecutor::execute_direct(self, domain, latent, in_tokens, cloud, rng)
    }

    fn final_answer_correct(
        &self,
        latents: &[SubtaskLatent],
        subtask_correct: &[bool],
        rng: &mut Rng,
    ) -> bool {
        SimExecutor::final_answer_correct(self, latents, subtask_correct, rng)
    }

    fn true_dq(&self, domain: usize, latents: &[SubtaskLatent], i: usize) -> f64 {
        SimExecutor::true_dq(self, domain, latents, i)
    }
}

/// Wraps any backend and captures every `(cloud, ExecRecord)` in call
/// order, so a run can be re-served later by [`ReplayBackend`].
pub struct RecordingBackend<B: Backend> {
    inner: B,
    log: Mutex<Vec<(bool, ExecRecord)>>,
    finals: Mutex<Vec<bool>>,
}

impl<B: Backend> RecordingBackend<B> {
    pub fn new(inner: B) -> RecordingBackend<B> {
        RecordingBackend { inner, log: Mutex::new(Vec::new()), finals: Mutex::new(Vec::new()) }
    }

    /// Snapshot of the recorded per-call tape (call order preserved).
    pub fn records(&self) -> Vec<(bool, ExecRecord)> {
        self.log.lock().expect("record log poisoned").clone()
    }

    /// Snapshot of the recorded final-answer draws (call order preserved).
    pub fn final_draws(&self) -> Vec<bool> {
        self.finals.lock().expect("finals log poisoned").clone()
    }

    /// Freeze the tapes into a replay backend with the same profiles.
    pub fn into_replay(self) -> ReplayBackend {
        let records = self.records();
        let finals = self.final_draws();
        ReplayBackend::new(
            self.inner.sp().clone(),
            self.inner.profile(false).clone(),
            self.inner.profile(true).clone(),
            records,
            finals,
        )
    }
}

impl<B: Backend> Backend for RecordingBackend<B> {
    fn name(&self) -> &'static str {
        "recording"
    }

    fn sp(&self) -> &SimParams {
        self.inner.sp()
    }

    fn profile(&self, cloud: bool) -> &ModelProfile {
        self.inner.profile(cloud)
    }

    fn execute_subtask(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        let rec = self.inner.execute_subtask(domain, latent, in_tokens, cloud, rng);
        self.log.lock().expect("record log poisoned").push((cloud, rec));
        rec
    }

    fn execute_direct(
        &self,
        domain: usize,
        latent: &SubtaskLatent,
        in_tokens: f64,
        cloud: bool,
        rng: &mut Rng,
    ) -> ExecRecord {
        let rec = self.inner.execute_direct(domain, latent, in_tokens, cloud, rng);
        self.log.lock().expect("record log poisoned").push((cloud, rec));
        rec
    }

    fn final_answer_correct(
        &self,
        latents: &[SubtaskLatent],
        subtask_correct: &[bool],
        rng: &mut Rng,
    ) -> bool {
        // Delegate (the inner backend may override the aggregation model)
        // and record the draw so replay can reproduce it without RNG.
        let v = self.inner.final_answer_correct(latents, subtask_correct, rng);
        self.finals.lock().expect("finals log poisoned").push(v);
        v
    }

    fn true_dq(&self, domain: usize, latents: &[SubtaskLatent], i: usize) -> f64 {
        self.inner.true_dq(domain, latents, i)
    }
}

/// Deterministic backend that serves a recorded `ExecRecord` tape.
///
/// Records are kept in one FIFO per side, so edge and cloud calls may
/// interleave differently on replay (e.g. a different scheduler
/// configuration) as long as each side's call sequence is preserved.
/// Replay consumes **no RNG at all** — the tape is the randomness — which
/// also makes it the reference shape for future network-backed endpoints:
/// anything observable must fit in an `ExecRecord`.
pub struct ReplayBackend {
    sp: SimParams,
    edge: ModelProfile,
    cloud: ModelProfile,
    /// `[edge tape, cloud tape]`.
    tapes: [Mutex<VecDeque<ExecRecord>>; 2],
    /// Recorded final-answer draws, served FIFO.
    finals: Mutex<VecDeque<bool>>,
}

impl ReplayBackend {
    pub fn new(
        sp: SimParams,
        edge: ModelProfile,
        cloud: ModelProfile,
        records: Vec<(bool, ExecRecord)>,
        finals: Vec<bool>,
    ) -> ReplayBackend {
        let mut edge_tape = VecDeque::new();
        let mut cloud_tape = VecDeque::new();
        for (cloud_side, rec) in records {
            if cloud_side {
                cloud_tape.push_back(rec);
            } else {
                edge_tape.push_back(rec);
            }
        }
        ReplayBackend {
            sp,
            edge,
            cloud,
            tapes: [Mutex::new(edge_tape), Mutex::new(cloud_tape)],
            finals: Mutex::new(finals.into()),
        }
    }

    /// Records still queued (both sides, excluding final-answer draws).
    pub fn remaining(&self) -> usize {
        self.tapes.iter().map(|t| t.lock().expect("tape poisoned").len()).sum()
    }

    fn pop(&self, cloud: bool) -> ExecRecord {
        self.tapes[usize::from(cloud)]
            .lock()
            .expect("tape poisoned")
            .pop_front()
            .unwrap_or_else(|| {
                panic!(
                    "replay tape exhausted on the {} side (workload diverged from recording)",
                    if cloud { "cloud" } else { "edge" }
                )
            })
    }
}

impl Backend for ReplayBackend {
    fn name(&self) -> &'static str {
        "replay"
    }

    fn sp(&self) -> &SimParams {
        &self.sp
    }

    fn profile(&self, cloud: bool) -> &ModelProfile {
        if cloud {
            &self.cloud
        } else {
            &self.edge
        }
    }

    fn execute_subtask(
        &self,
        _domain: usize,
        _latent: &SubtaskLatent,
        _in_tokens: f64,
        cloud: bool,
        _rng: &mut Rng,
    ) -> ExecRecord {
        self.pop(cloud)
    }

    fn execute_direct(
        &self,
        _domain: usize,
        _latent: &SubtaskLatent,
        _in_tokens: f64,
        cloud: bool,
        _rng: &mut Rng,
    ) -> ExecRecord {
        self.pop(cloud)
    }

    fn final_answer_correct(
        &self,
        _latents: &[SubtaskLatent],
        _subtask_correct: &[bool],
        _rng: &mut Rng,
    ) -> bool {
        // Served from the tape, not re-drawn: replay reproduces the
        // recorded run's accuracy verdicts exactly and consumes no RNG.
        self.finals
            .lock()
            .expect("finals tape poisoned")
            .pop_front()
            .unwrap_or_else(|| {
                panic!("replay tape exhausted for final-answer draws (workload diverged)")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn latent(d: f64, w: f64, toks: f64) -> SubtaskLatent {
        SubtaskLatent { difficulty: d, criticality: w, out_tokens: toks }
    }

    #[test]
    fn sim_backend_matches_inherent_calls() {
        let ex = SimExecutor::paper_pair();
        let via_trait: &dyn Backend = &ex;
        let l = latent(0.5, 0.5, 100.0);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = via_trait.execute_subtask(1, &l, 200.0, true, &mut r1);
        let b = SimExecutor::execute_subtask(&ex, 1, &l, 200.0, true, &mut r2);
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.api_cost, b.api_cost);
        assert_eq!(a.correct, b.correct);
        assert_eq!(a.out_tokens, b.out_tokens);
        assert_eq!(via_trait.true_dq(1, &[l], 0), ex.true_dq(1, &[l], 0));
        assert_eq!(via_trait.sp().tau0, ex.sp.tau0);
        assert_eq!(via_trait.profile(true).kind, ex.cloud.kind);
    }

    #[test]
    fn default_true_dq_matches_sim_formula() {
        // The trait's default derivation must agree with SimExecutor's
        // closed form (both are the App. C profiling ground truth).
        struct Thin(SimExecutor);
        impl Backend for Thin {
            fn name(&self) -> &'static str {
                "thin"
            }
            fn sp(&self) -> &SimParams {
                &self.0.sp
            }
            fn profile(&self, cloud: bool) -> &ModelProfile {
                self.0.profile(cloud)
            }
            fn execute_subtask(
                &self,
                domain: usize,
                latent: &SubtaskLatent,
                in_tokens: f64,
                cloud: bool,
                rng: &mut Rng,
            ) -> ExecRecord {
                self.0.execute_subtask(domain, latent, in_tokens, cloud, rng)
            }
            fn execute_direct(
                &self,
                domain: usize,
                latent: &SubtaskLatent,
                in_tokens: f64,
                cloud: bool,
                rng: &mut Rng,
            ) -> ExecRecord {
                self.0.execute_direct(domain, latent, in_tokens, cloud, rng)
            }
            // final_answer_correct / true_dq: trait defaults.
        }
        let thin = Thin(SimExecutor::paper_pair());
        let lat = vec![latent(0.4, 0.4, 80.0), latent(0.6, 0.6, 120.0), latent(0.55, 0.7, 100.0)];
        for i in 0..3 {
            let a = thin.true_dq(1, &lat, i);
            let b = thin.0.true_dq(1, &lat, i);
            assert!((a - b).abs() < 1e-15, "node {i}: {a} vs {b}");
        }
        let mut r1 = Rng::new(3);
        let mut r2 = Rng::new(3);
        for mask in [[true, true, true], [true, false, true], [false, false, false]] {
            let a = thin.final_answer_correct(&lat, &mask, &mut r1);
            let b = thin.0.final_answer_correct(&lat, &mask, &mut r2);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn record_then_replay_serves_identical_records() {
        let rec_backend = RecordingBackend::new(SimExecutor::paper_pair());
        let l = latent(0.5, 0.5, 100.0);
        let mut rng = Rng::new(11);
        let mut originals = Vec::new();
        for i in 0..6 {
            let cloud = i % 2 == 0;
            originals.push((cloud, rec_backend.execute_subtask(1, &l, 150.0, cloud, &mut rng)));
        }
        let final_draw = rec_backend.final_answer_correct(&[l], &[true], &mut rng);
        assert_eq!(rec_backend.records().len(), 6);
        assert_eq!(rec_backend.final_draws(), vec![final_draw]);

        let replay = rec_backend.into_replay();
        assert_eq!(replay.remaining(), 6);
        // Replay ignores the rng entirely; a fresh stream must not matter.
        let mut other_rng = Rng::new(999);
        for (cloud, orig) in &originals {
            let got = replay.execute_subtask(1, &l, 150.0, *cloud, &mut other_rng);
            assert_eq!(got.latency, orig.latency);
            assert_eq!(got.api_cost, orig.api_cost);
            assert_eq!(got.correct, orig.correct);
            assert_eq!(got.out_tokens, orig.out_tokens);
        }
        // The final-answer draw replays from the tape too (no RNG).
        assert_eq!(replay.final_answer_correct(&[l], &[true], &mut other_rng), final_draw);
        assert_eq!(replay.remaining(), 0);
    }

    #[test]
    #[should_panic(expected = "replay tape exhausted")]
    fn replay_panics_on_exhausted_tape() {
        let ex = SimExecutor::paper_pair();
        let replay =
            ReplayBackend::new(ex.sp.clone(), ex.edge.clone(), ex.cloud.clone(), vec![], vec![]);
        let mut rng = Rng::new(0);
        replay.execute_subtask(0, &latent(0.5, 0.5, 50.0), 100.0, false, &mut rng);
    }

    #[test]
    fn replay_sides_are_independent_fifos() {
        let ex = SimExecutor::paper_pair();
        let mk = |lat: f64, cost: f64| ExecRecord {
            correct: true,
            latency: lat,
            api_cost: cost,
            in_tokens: 10.0,
            out_tokens: 20.0,
        };
        let replay = ReplayBackend::new(
            ex.sp.clone(),
            ex.edge.clone(),
            ex.cloud.clone(),
            vec![(false, mk(1.0, 0.0)), (true, mk(2.0, 0.5)), (false, mk(3.0, 0.0))],
            vec![],
        );
        let l = latent(0.5, 0.5, 50.0);
        let mut rng = Rng::new(0);
        // Cloud first, even though it was recorded second: per-side FIFO.
        assert_eq!(replay.execute_subtask(0, &l, 1.0, true, &mut rng).latency, 2.0);
        assert_eq!(replay.execute_subtask(0, &l, 1.0, false, &mut rng).latency, 1.0);
        assert_eq!(replay.execute_direct(0, &l, 1.0, false, &mut rng).latency, 3.0);
    }
}

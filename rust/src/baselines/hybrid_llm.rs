//! HybridLLM baseline (Ding et al., 2024): **query-level** routing — a
//! small difficulty estimator gates the *whole query* to either the edge or
//! the cloud model, which then answers with CoT.
//!
//! This is the coarse-granularity straw the paper argues against: no
//! decomposition means no parallelism, and the all-or-nothing decision
//! wastes cloud budget on queries where only one step is hard.

use super::{sample_chain_len, Cot, Method};
use crate::engine::Backend;
use crate::metrics::QueryOutcome;
use crate::util::rng::Rng;
use crate::workload::{direct_latent, Query};

pub struct HybridLlm {
    pub executor: Box<dyn Backend>,
    /// Route to cloud when the estimated difficulty exceeds this.
    pub threshold: f64,
    /// Noise of the difficulty estimator.
    pub estimator_noise: f64,
    /// Router forward latency (BERT-scale encoder on the edge GPU).
    pub router_overhead: f64,
}

impl HybridLlm {
    pub fn paper_default(executor: impl Backend + 'static) -> HybridLlm {
        HybridLlm {
            executor: Box::new(executor),
            threshold: 0.58,
            estimator_noise: 0.10,
            router_overhead: 0.08,
        }
    }
}

impl Method for HybridLlm {
    fn name(&self) -> &str {
        "HybridLLM"
    }

    fn model_label(&self) -> String {
        format!(
            "{}&{}",
            self.executor.profile(false).kind.label(),
            self.executor.profile(true).kind.label()
        )
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        let d_hat = (query.difficulty + rng.normal_ms(0.0, self.estimator_noise)).clamp(0.0, 1.0);
        let cloud = d_hat > self.threshold;

        // Chosen model answers with CoT (cost/latency = one inflated call).
        let latent = direct_latent(query, self.executor.sp(), cloud, true, rng);
        let rec = self.executor.execute_direct(
            query.domain,
            &latent,
            query.query_tokens,
            cloud,
            rng,
        );
        let n = sample_chain_len(rng);
        let correct = Cot::chain_correct(self.executor.as_ref(), query, cloud, n, rng);

        QueryOutcome {
            correct,
            latency: self.router_overhead + rec.latency,
            api_cost: rec.api_cost,
            offload_rate: if cloud { 1.0 } else { 0.0 },
            n_subtasks: n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SimExecutor;
    use crate::workload::{generate_queries, Benchmark};

    fn stats(bench: Benchmark, n: usize, seed: u64) -> (f64, f64, f64) {
        let m = HybridLlm::paper_default(SimExecutor::paper_pair());
        let mut rng = Rng::new(seed);
        let qs = generate_queries(bench, n, seed);
        let outs: Vec<_> = qs.iter().map(|q| m.run(q, &mut rng)).collect();
        let acc = outs.iter().filter(|o| o.correct).count() as f64 / n as f64 * 100.0;
        let api = outs.iter().map(|o| o.api_cost).sum::<f64>() / n as f64;
        let off = outs.iter().map(|o| o.offload_rate).sum::<f64>() / n as f64;
        (acc, api, off)
    }

    #[test]
    fn routes_hard_benchmarks_to_cloud() {
        let (_, _, off_gpqa) = stats(Benchmark::Gpqa, 400, 0);
        let (_, _, off_mmlu) = stats(Benchmark::MmluPro, 400, 0);
        // GPQA queries are mostly above the threshold; MMLU-Pro mostly not.
        assert!(off_gpqa > 0.6, "gpqa offload {off_gpqa}");
        assert!(off_mmlu < off_gpqa - 0.2, "mmlu {off_mmlu} vs gpqa {off_gpqa}");
    }

    #[test]
    fn accuracy_between_edge_and_cloud_cot() {
        // Paper Table 1 GPQA: HybridLLM 52.9, between CoT L3B 25.5 and CoT
        // G4.1 57.3 (closer to cloud since most GPQA goes to cloud).
        let (acc, api, _) = stats(Benchmark::Gpqa, 800, 1);
        assert!((40.0..=62.0).contains(&acc), "acc {acc}");
        assert!(api > 0.0);
    }

    #[test]
    fn no_parallelism_means_high_latency() {
        let m = HybridLlm::paper_default(SimExecutor::paper_pair());
        let mut rng = Rng::new(2);
        let qs = generate_queries(Benchmark::Aime24, 200, 2);
        let lat = qs.iter().map(|q| m.run(q, &mut rng).latency).sum::<f64>() / 200.0;
        // Paper Table 2 AIME24: HybridLLM 40.11s — the worst hybrid.
        assert!(lat > 15.0, "latency {lat}");
    }
}

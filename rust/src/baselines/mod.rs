//! Comparison pipelines (Tables 1–2): single-model prompting methods
//! (Direct, CoT, SoT, PASTA) and edge-cloud collaborative baselines
//! (HybridLLM, DoT), all running over the same simulation substrate as
//! HybridFlow so the comparison isolates *coordination* differences.
//!
//! Method-shape summary (how each maps onto the substrate):
//!
//! | Method    | Decomposition        | Dependency handling | Routing          |
//! |-----------|----------------------|---------------------|------------------|
//! | Direct    | none                 | —                   | fixed model      |
//! | CoT       | latent chain         | sequential          | fixed model      |
//! | SoT       | skeleton + branches  | ignored (penalty)   | fixed model      |
//! | PASTA     | flat async branches  | ignored (penalty)   | fixed model      |
//! | HybridLLM | none (query-level)   | —                   | difficulty gate  |
//! | DoT       | planner DAG as chain | sequential          | per-subtask gate |
//! | HybridFlow| planner DAG          | DAG-parallel        | learned utility  |

pub mod cot;
pub mod direct;
pub mod dot;
pub mod hybrid_llm;
pub mod sot_pasta;

use crate::metrics::QueryOutcome;
use crate::util::rng::Rng;
use crate::workload::Query;

/// A runnable evaluation method.
pub trait Method: Send + Sync {
    /// Row label ("CoT", "HybridFlow", ...).
    fn name(&self) -> &str;
    /// Model column ("L3B", "G4.1", "L3B&G4.1").
    fn model_label(&self) -> String;
    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome;
}

pub use cot::Cot;
pub use direct::Direct;
pub use dot::Dot;
pub use hybrid_llm::HybridLlm;
pub use sot_pasta::{Pasta, Sot};

/// Chain length distribution shared by the latent-decomposition methods
/// (CoT's implicit steps, matching the planner's 3–6 node plans).
pub(crate) fn sample_chain_len(rng: &mut Rng) -> usize {
    rng.int_range(3, 7)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SimExecutor;
    use crate::workload::{generate_queries, Benchmark};

    /// Every method must run on every benchmark without panicking and
    /// produce sane outcome fields.
    #[test]
    fn all_methods_run_everywhere() {
        let methods: Vec<Box<dyn Method>> = vec![
            Box::new(Direct::new(SimExecutor::paper_pair(), true)),
            Box::new(Direct::new(SimExecutor::paper_pair(), false)),
            Box::new(Cot::new(SimExecutor::paper_pair(), true)),
            Box::new(Cot::new(SimExecutor::paper_pair(), false)),
            Box::new(Sot::new(SimExecutor::paper_pair(), true)),
            Box::new(Sot::new(SimExecutor::paper_pair(), false)),
            Box::new(Pasta::new(SimExecutor::paper_pair(), true)),
            Box::new(Pasta::new(SimExecutor::paper_pair(), false)),
            Box::new(HybridLlm::paper_default(SimExecutor::paper_pair())),
            Box::new(Dot::paper_default(SimExecutor::paper_pair())),
        ];
        let mut rng = Rng::new(0);
        for bench in Benchmark::ALL {
            for q in generate_queries(bench, 5, 1) {
                for m in &methods {
                    let o = m.run(&q, &mut rng);
                    assert!(o.latency > 0.0, "{} latency", m.name());
                    assert!(o.api_cost >= 0.0);
                    assert!((0.0..=1.0).contains(&o.offload_rate));
                    assert!(o.n_subtasks >= 1);
                }
            }
        }
    }

    /// Decomposition methods must beat Direct prompting in accuracy on the
    /// same model (the paper's first finding).
    #[test]
    fn cot_beats_direct_on_accuracy() {
        let qs = generate_queries(Benchmark::Gpqa, 400, 2);
        let acc = |m: &dyn Method, seed: u64| {
            let mut rng = Rng::new(seed);
            qs.iter().filter(|q| m.run(q, &mut rng).correct).count() as f64 / qs.len() as f64
        };
        for cloud in [false, true] {
            let d = acc(&Direct::new(SimExecutor::paper_pair(), cloud), 3);
            let c = acc(&Cot::new(SimExecutor::paper_pair(), cloud), 3);
            assert!(c > d, "cloud={cloud}: cot {c} direct {d}");
        }
    }
}

//! Chain-of-Thought baseline (Wei et al., 2022): one model produces a long
//! sequential reasoning trace in a single call.
//!
//! Substrate mapping: *latency/cost* are one direct call with
//! `cot_token_mult` inflated output; *accuracy* follows the latent chain
//! model — stepwise reasoning solves easier sub-problems (`d_i = phi d_q`)
//! but every critical step must survive aggregation, which is what gives
//! CoT its accuracy lift over Direct at higher token cost.

use super::{sample_chain_len, Method};
use crate::engine::Backend;
use crate::metrics::QueryOutcome;
use crate::util::rng::Rng;
use crate::workload::{direct_latent, Query, SubtaskLatent};

pub struct Cot {
    pub executor: Box<dyn Backend>,
    pub cloud: bool,
}

impl Cot {
    pub fn new(executor: impl Backend + 'static, cloud: bool) -> Cot {
        Cot { executor: Box::new(executor), cloud }
    }

    /// Latent chain accuracy draw on a single model.
    pub(crate) fn chain_correct(
        executor: &dyn Backend,
        query: &Query,
        cloud: bool,
        n: usize,
        rng: &mut Rng,
    ) -> bool {
        let sp = executor.sp();
        let profile = executor.profile(cloud);
        let mut latents = Vec::with_capacity(n);
        let mut success = Vec::with_capacity(n);
        for i in 0..n {
            let phi = rng.uniform(sp.phi.0, sp.phi.1);
            let d = (query.difficulty * phi).min(1.0);
            let pos = i as f64 / (n - 1).max(1) as f64;
            let w = if i == n - 1 {
                sp.generate_crit
            } else {
                crate::workload::sample_criticality_at(sp, pos, rng)
            };
            latents.push(SubtaskLatent { difficulty: d, criticality: w, out_tokens: 0.0 });
            success.push(rng.bernoulli(profile.p_solve(query.domain, d, sp)));
        }
        executor.final_answer_correct(&latents, &success, rng)
    }
}

impl Method for Cot {
    fn name(&self) -> &str {
        "CoT"
    }

    fn model_label(&self) -> String {
        self.executor.profile(self.cloud).kind.label().to_string()
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        // Cost/latency: one call with CoT-inflated output tokens.
        let latent = direct_latent(query, self.executor.sp(), self.cloud, true, rng);
        let rec = self.executor.execute_direct(
            query.domain,
            &latent,
            query.query_tokens,
            self.cloud,
            rng,
        );
        // Accuracy: the latent chain aggregation (overrides the single
        // Bernoulli in `rec`).
        let n = sample_chain_len(rng);
        let correct = Self::chain_correct(self.executor.as_ref(), query, self.cloud, n, rng);
        QueryOutcome {
            correct,
            latency: rec.latency,
            api_cost: rec.api_cost,
            offload_rate: if self.cloud { 1.0 } else { 0.0 },
            n_subtasks: n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SimExecutor;
    use crate::workload::{generate_queries, Benchmark};

    fn acc(m: &dyn Method, bench: Benchmark, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let qs = generate_queries(bench, n, seed);
        qs.iter().filter(|q| m.run(q, &mut rng).correct).count() as f64 / n as f64 * 100.0
    }

    #[test]
    fn cot_gpqa_accuracy_bands() {
        // Paper: CoT L3B 25.54, CoT G4.1 57.28 on GPQA. Our substrate
        // equilibrium sits a few points higher on the edge side (see
        // EXPERIMENTS.md "Calibration residuals"); ordering is what matters.
        let edge = acc(&Cot::new(SimExecutor::paper_pair(), false), Benchmark::Gpqa, 800, 3);
        let cloud = acc(&Cot::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 800, 3);
        assert!((20.0..=45.0).contains(&edge), "edge CoT acc {edge}");
        assert!((48.0..=72.0).contains(&cloud), "cloud CoT acc {cloud}");
        assert!(cloud > edge + 15.0, "cloud must dominate edge");
    }

    #[test]
    fn cot_costs_more_than_direct() {
        use super::super::Direct;
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let qs = generate_queries(Benchmark::Gpqa, 200, 4);
        let cot = Cot::new(SimExecutor::paper_pair(), true);
        let direct = Direct::new(SimExecutor::paper_pair(), true);
        let cot_cost: f64 = qs.iter().map(|q| cot.run(q, &mut r1).api_cost).sum();
        let dir_cost: f64 = qs.iter().map(|q| direct.run(q, &mut r2).api_cost).sum();
        assert!(cot_cost > dir_cost * 1.3, "cot {cot_cost} direct {dir_cost}");
    }
}

//! DoT baseline (Division-of-Thoughts, Shao et al., 2025): planner-based
//! decomposition with **per-subtask** difficulty-gated routing but
//! **strictly sequential** execution ("sequentially constrained DoT" in the
//! paper's Table 2 discussion).
//!
//! DoT is the closest baseline to HybridFlow: same decomposition substrate,
//! same edge/cloud pair — the deltas are (i) no DAG parallelism and (ii) a
//! difficulty heuristic instead of the learned benefit–cost utility with
//! budget adaptation.

use super::Method;
use crate::engine::Backend;
use crate::metrics::QueryOutcome;
use crate::planner::{synthetic::SyntheticPlanner, Planner};
use crate::util::rng::Rng;
use crate::workload::{sample_latents, Query};

pub struct Dot {
    pub executor: Box<dyn Backend>,
    pub planner: SyntheticPlanner,
    /// Offload a subtask when its estimated difficulty exceeds this.
    pub threshold: f64,
    pub estimator_noise: f64,
}

impl Dot {
    pub fn paper_default(executor: impl Backend + 'static) -> Dot {
        Dot {
            executor: Box::new(executor),
            planner: SyntheticPlanner::paper_main(),
            threshold: 0.52,
            estimator_noise: 0.08,
        }
    }
}

impl Method for Dot {
    fn name(&self) -> &str {
        "DoT"
    }

    fn model_label(&self) -> String {
        format!(
            "{}&{}",
            self.executor.profile(false).kind.label(),
            self.executor.profile(true).kind.label()
        )
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        let sp = self.executor.sp();
        let plan = self.planner.plan(query, sp.nmax, rng);
        let dag = &plan.dag;
        let latents = sample_latents(dag, query, sp, rng);
        let order = dag.topo_order().expect("repaired plan is a DAG");

        let mut latency = plan.planning_latency;
        let mut api = 0.0;
        let mut offloaded = 0usize;
        let mut out_tokens = vec![0.0f64; dag.len()];
        let mut success = vec![false; dag.len()];

        for &i in &order {
            let d_hat =
                (latents[i].difficulty + rng.normal_ms(0.0, self.estimator_noise)).clamp(0.0, 1.0);
            let cloud = d_hat > self.threshold;
            let in_tok: f64 = query.query_tokens
                + dag.nodes[i].deps.iter().map(|&d| out_tokens[d]).sum::<f64>();
            let rec = self.executor.execute_subtask(query.domain, &latents[i], in_tok, cloud, rng);
            latency += rec.latency; // sequential: no overlap
            api += rec.api_cost;
            out_tokens[i] = rec.out_tokens;
            success[i] = rec.correct;
            if cloud {
                offloaded += 1;
            }
        }

        let correct = self.executor.final_answer_correct(&latents, &success, rng);
        QueryOutcome {
            correct,
            latency,
            api_cost: api,
            offload_rate: offloaded as f64 / dag.len() as f64,
            n_subtasks: dag.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SimExecutor;
    use crate::workload::{generate_queries, Benchmark};

    fn run_many(n: usize, seed: u64) -> Vec<QueryOutcome> {
        let m = Dot::paper_default(SimExecutor::paper_pair());
        let mut rng = Rng::new(seed);
        generate_queries(Benchmark::Gpqa, n, seed)
            .iter()
            .map(|q| m.run(q, &mut rng))
            .collect()
    }

    #[test]
    fn partial_offloading() {
        let outs = run_many(300, 0);
        let off = outs.iter().map(|o| o.offload_rate).sum::<f64>() / outs.len() as f64;
        // Paper Table 3 regime: ~40% subtask offload for the hybrids.
        assert!((0.25..=0.75).contains(&off), "offload {off}");
        assert!(outs.iter().any(|o| o.api_cost > 0.0));
    }

    #[test]
    fn accuracy_between_edge_and_cloud() {
        let outs = run_many(800, 1);
        let acc = outs.iter().filter(|o| o.correct).count() as f64 / outs.len() as f64 * 100.0;
        // Paper Table 1 GPQA: DoT 50.54.
        assert!((38.0..=62.0).contains(&acc), "acc {acc}");
    }

    #[test]
    fn sequential_latency_includes_planning() {
        let m = Dot::paper_default(SimExecutor::paper_pair());
        let mut rng = Rng::new(2);
        let q = &generate_queries(Benchmark::Gpqa, 1, 2)[0];
        let out = m.run(q, &mut rng);
        // Must at least pay planner + a few subtask executions.
        assert!(out.latency > 3.0, "latency {}", out.latency);
    }
}

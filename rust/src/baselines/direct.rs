//! Direct prompting baseline: one model, one call, no decomposition.
//! The shaded reference rows of Tables 1–2.

use super::Method;
use crate::engine::Backend;
use crate::metrics::QueryOutcome;
use crate::util::rng::Rng;
use crate::workload::{direct_latent, Query};

pub struct Direct {
    pub executor: Box<dyn Backend>,
    pub cloud: bool,
}

impl Direct {
    pub fn new(executor: impl Backend + 'static, cloud: bool) -> Direct {
        Direct { executor: Box::new(executor), cloud }
    }
}

impl Method for Direct {
    fn name(&self) -> &str {
        "Direct Prompt"
    }

    fn model_label(&self) -> String {
        self.executor.profile(self.cloud).kind.label().to_string()
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        let latent = direct_latent(query, self.executor.sp(), self.cloud, false, rng);
        let rec = self.executor.execute_direct(
            query.domain,
            &latent,
            query.query_tokens,
            self.cloud,
            rng,
        );
        QueryOutcome {
            correct: rec.correct,
            latency: rec.latency,
            api_cost: rec.api_cost,
            offload_rate: if self.cloud { 1.0 } else { 0.0 },
            n_subtasks: 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SimExecutor;
    use crate::workload::{generate_queries, Benchmark};

    #[test]
    fn edge_direct_is_free_and_fast() {
        let m = Direct::new(SimExecutor::paper_pair(), false);
        let mut rng = Rng::new(0);
        let qs = generate_queries(Benchmark::Gpqa, 100, 0);
        let outs: Vec<_> = qs.iter().map(|q| m.run(q, &mut rng)).collect();
        assert!(outs.iter().all(|o| o.api_cost == 0.0));
        let mean_lat = outs.iter().map(|o| o.latency).sum::<f64>() / outs.len() as f64;
        // Paper Table 2: Direct L3B GPQA = 6.61s.
        assert!((3.0..=11.0).contains(&mean_lat), "mean latency {mean_lat}");
    }

    #[test]
    fn cloud_direct_accuracy_band() {
        let m = Direct::new(SimExecutor::paper_pair(), true);
        let mut rng = Rng::new(1);
        let qs = generate_queries(Benchmark::Gpqa, 800, 1);
        let acc = qs.iter().filter(|q| m.run(q, &mut rng).correct).count() as f64
            / qs.len() as f64
            * 100.0;
        // Paper Table 1: Direct G4.1 GPQA = 51.79. Our substrate's
        // decomposition bonus is stronger than the paper's, which pushes
        // Direct lower relative to CoT (EXPERIMENTS.md "Calibration
        // residuals"); the Direct < CoT < cloud orderings all hold.
        assert!((22.0..=62.0).contains(&acc), "acc {acc}");
    }

    #[test]
    fn edge_direct_accuracy_band() {
        let m = Direct::new(SimExecutor::paper_pair(), false);
        let mut rng = Rng::new(2);
        let qs = generate_queries(Benchmark::Gpqa, 800, 2);
        let acc = qs.iter().filter(|q| m.run(q, &mut rng).correct).count() as f64
            / qs.len() as f64
            * 100.0;
        // Paper Table 1: Direct L3B GPQA = 16.89.
        assert!((9.0..=26.0).contains(&acc), "acc {acc}");
    }
}

//! Aggressively-parallel decomposition baselines:
//!
//! * **SoT** (Skeleton-of-Thought, Ning et al. 2024): a short skeleton call
//!   enumerates points, then every point expands *in parallel with no
//!   inter-point context*. Fast on the cloud (parallel calls), but
//!   dependency-heavy domains (math) collapse — Table 1's AIME24 cliff.
//! * **PASTA** (Jin et al. 2025): learned asynchronous decoding; flatter
//!   parallelism without a skeleton round-trip, with a learned-but-
//!   imperfect notion of what can safely run concurrently. Strong on
//!   loosely-coupled domains (MMLU-Pro), weak where latent steps interlock.
//!
//! Substrate mapping: branches execute independently; each branch's solve
//! probability is scaled by a per-domain *context-retention* factor
//! representing the information lost by ignoring dependencies. Edge
//! execution still serializes on the single on-device worker, which is why
//! SoT on the edge is *slower* than CoT (paper Table 2: 18.55 vs 11.99 on
//! GPQA) while cloud SoT is faster than cloud CoT.

use super::Method;
use crate::engine::Backend;
use crate::metrics::QueryOutcome;
use crate::util::rng::Rng;
use crate::workload::{Query, SubtaskLatent};

/// Per-domain context retention: [math, science, general, logic].
const SOT_RETENTION: [f64; 4] = [0.42, 0.92, 0.93, 0.82];
const PASTA_RETENTION: [f64; 4] = [0.55, 0.70, 1.00, 0.68];

/// Difficulty relief from finer-grained parallel decomposition (PASTA's
/// learned splitting makes slightly easier units on domains it fits).
const PASTA_PHI_MULT: f64 = 0.92;

struct ParallelCfg {
    name: &'static str,
    retention: [f64; 4],
    phi_mult: f64,
    /// Skeleton pass before branches (SoT) vs. fully async (PASTA).
    has_skeleton: bool,
    /// Branch count range.
    branches: (usize, usize),
}

fn run_parallel(
    cfg: &ParallelCfg,
    executor: &dyn Backend,
    cloud: bool,
    query: &Query,
    rng: &mut Rng,
) -> QueryOutcome {
    let sp = executor.sp();
    let profile = executor.profile(cloud);
    let n_branches = rng.int_range(cfg.branches.0, cfg.branches.1 + 1);
    let retention = cfg.retention[query.domain];

    let mut latency = 0.0;
    let mut api = 0.0;

    // Skeleton pass: short enumeration call.
    if cfg.has_skeleton {
        let skel_out = rng.lognormal(3.6, 0.25) * query.tok_mult; // ~37 tokens
        latency += profile.latency(query.query_tokens, skel_out, rng);
        api += profile.api_cost(query.query_tokens, skel_out);
    }

    // Branches: independent expansions.
    let mut latents = Vec::with_capacity(n_branches);
    let mut success = Vec::with_capacity(n_branches);
    let mut branch_lat = Vec::with_capacity(n_branches);
    for i in 0..n_branches {
        let phi = rng.uniform(sp.phi.0, sp.phi.1) * cfg.phi_mult;
        let d = (query.difficulty * phi).min(1.0);
        let w = if i == n_branches - 1 {
            sp.generate_crit
        } else {
            crate::workload::sample_criticality(sp, rng)
        };
        let (mu, sig) = sp.role_tokens[1]; // ANALYZE-sized expansions
        let out = rng.lognormal(mu, sig) * query.tok_mult
            * if cloud { sp.cloud_verbosity } else { 1.0 };
        let p = profile.p_solve(query.domain, d, sp) * retention;
        latents.push(SubtaskLatent { difficulty: d, criticality: w, out_tokens: out });
        success.push(rng.bernoulli(p));
        branch_lat.push(profile.latency(query.query_tokens, out, rng));
        api += profile.api_cost(query.query_tokens, out);
    }

    // Edge: single worker serializes branches; cloud: parallel calls.
    latency += if cloud {
        branch_lat.iter().copied().fold(0.0, f64::max)
    } else {
        branch_lat.iter().sum::<f64>()
    };

    let correct = executor.final_answer_correct(&latents, &success, rng);
    QueryOutcome {
        correct,
        latency,
        api_cost: api,
        offload_rate: if cloud { 1.0 } else { 0.0 },
        n_subtasks: n_branches + usize::from(cfg.has_skeleton),
    }
}

pub struct Sot {
    pub executor: Box<dyn Backend>,
    pub cloud: bool,
}

impl Sot {
    pub fn new(executor: impl Backend + 'static, cloud: bool) -> Sot {
        Sot { executor: Box::new(executor), cloud }
    }

    fn cfg() -> ParallelCfg {
        ParallelCfg {
            name: "SoT",
            retention: SOT_RETENTION,
            phi_mult: 1.0,
            has_skeleton: true,
            branches: (4, 6),
        }
    }
}

impl Method for Sot {
    fn name(&self) -> &str {
        "SoT"
    }

    fn model_label(&self) -> String {
        self.executor.profile(self.cloud).kind.label().to_string()
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        run_parallel(&Self::cfg(), self.executor.as_ref(), self.cloud, query, rng)
    }
}

pub struct Pasta {
    pub executor: Box<dyn Backend>,
    pub cloud: bool,
}

impl Pasta {
    pub fn new(executor: impl Backend + 'static, cloud: bool) -> Pasta {
        Pasta { executor: Box::new(executor), cloud }
    }

    fn cfg() -> ParallelCfg {
        ParallelCfg {
            name: "PASTA",
            retention: PASTA_RETENTION,
            phi_mult: PASTA_PHI_MULT,
            has_skeleton: false,
            branches: (4, 7),
        }
    }
}

impl Method for Pasta {
    fn name(&self) -> &str {
        "PASTA"
    }

    fn model_label(&self) -> String {
        self.executor.profile(self.cloud).kind.label().to_string()
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        run_parallel(&Self::cfg(), self.executor.as_ref(), self.cloud, query, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Cot;
    use crate::models::SimExecutor;
    use crate::workload::{generate_queries, Benchmark};

    fn acc(m: &dyn Method, bench: Benchmark, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let qs = generate_queries(bench, n, seed);
        qs.iter().filter(|q| m.run(q, &mut rng).correct).count() as f64 / n as f64 * 100.0
    }

    fn mean_latency(m: &dyn Method, bench: Benchmark, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let qs = generate_queries(bench, n, seed);
        qs.iter().map(|q| m.run(q, &mut rng).latency).sum::<f64>() / n as f64
    }

    #[test]
    fn sot_collapses_on_math() {
        // Paper Table 1: SoT AIME24 1.11 (L3B) / 28.89 (G4.1) — far below
        // CoT cloud 44.42. The dependency-penalty must crush math accuracy.
        let sot_cloud = acc(&Sot::new(SimExecutor::paper_pair(), true), Benchmark::Aime24, 600, 5);
        let cot_cloud = acc(&Cot::new(SimExecutor::paper_pair(), true), Benchmark::Aime24, 600, 5);
        assert!(sot_cloud < cot_cloud - 5.0, "sot {sot_cloud} cot {cot_cloud}");
    }

    #[test]
    fn sot_cloud_is_faster_than_cot_cloud() {
        // Paper Table 2 GPQA: SoT G4.1 16.27 < CoT G4.1 18.26.
        let sot = mean_latency(&Sot::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 300, 6);
        let cot = mean_latency(&Cot::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 300, 6);
        assert!(sot < cot, "sot {sot} cot {cot}");
    }

    #[test]
    fn sot_edge_is_slower_than_cot_edge() {
        // Paper Table 2 GPQA: SoT L3B 18.55 > CoT L3B 11.99 (branches
        // serialize on the single edge worker).
        let sot =
            mean_latency(&Sot::new(SimExecutor::paper_pair(), false), Benchmark::Gpqa, 300, 7);
        let cot =
            mean_latency(&Cot::new(SimExecutor::paper_pair(), false), Benchmark::Gpqa, 300, 7);
        assert!(sot > cot, "sot {sot} cot {cot}");
    }

    #[test]
    fn pasta_beats_sot_on_general_domain() {
        // Paper Table 1 MMLU-Pro (G4.1): PASTA 75.52 > SoT 71.8.
        let pasta =
            acc(&Pasta::new(SimExecutor::paper_pair(), true), Benchmark::MmluPro, 700, 8);
        let sot = acc(&Sot::new(SimExecutor::paper_pair(), true), Benchmark::MmluPro, 700, 8);
        assert!(pasta > sot - 1.0, "pasta {pasta} sot {sot}");
    }

    #[test]
    fn pasta_much_worse_than_sot_on_science() {
        // Paper Table 1 GPQA (G4.1): PASTA 41.28 << SoT 56.4.
        let pasta = acc(&Pasta::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 700, 9);
        let sot = acc(&Sot::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 700, 9);
        assert!(pasta < sot - 4.0, "pasta {pasta} sot {sot}");
    }

    #[test]
    fn pasta_is_faster_than_sot() {
        // No skeleton round-trip: paper Table 2 averages 15.37 vs 19.52.
        let pasta =
            mean_latency(&Pasta::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 300, 10);
        let sot =
            mean_latency(&Sot::new(SimExecutor::paper_pair(), true), Benchmark::Gpqa, 300, 10);
        assert!(pasta < sot, "pasta {pasta} sot {sot}");
    }
}

//! Serving telemetry: lock-light counters and latency histograms for the
//! coordinator, rendered in a Prometheus-style text format.
//!
//! Counters are atomics (safe to bump from any worker thread); histograms
//! use fixed log-spaced buckets so recording is a single atomic increment.

use std::sync::atomic::{AtomicU64, Ordering};

/// The shared log-spaced latency histogram (moved to [`crate::obs`] so the
/// virtual-clock observability layer and this wall-clock telemetry record
/// into identical buckets); re-exported here so existing
/// `server::telemetry::Histogram` users keep compiling.
pub use crate::obs::metrics::Histogram;

/// Coordinator-wide telemetry.
#[derive(Default)]
pub struct Telemetry {
    pub queries_total: AtomicU64,
    pub queries_correct: AtomicU64,
    pub subtasks_total: AtomicU64,
    pub subtasks_offloaded: AtomicU64,
    pub plans_valid: AtomicU64,
    pub plans_repaired: AtomicU64,
    pub plans_fallback: AtomicU64,
    /// Cloud dollars in micro-cents (atomic-friendly integer).
    pub api_microcents: AtomicU64,
    pub wall_latency: Histogram,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry { wall_latency: Histogram::new(), ..Default::default() }
    }

    pub fn record_query(
        &self,
        correct: bool,
        n_subtasks: usize,
        offloaded: usize,
        api_cost: f64,
        wall_secs: f64,
    ) {
        self.queries_total.fetch_add(1, Ordering::Relaxed);
        if correct {
            self.queries_correct.fetch_add(1, Ordering::Relaxed);
        }
        self.subtasks_total.fetch_add(n_subtasks as u64, Ordering::Relaxed);
        self.subtasks_offloaded.fetch_add(offloaded as u64, Ordering::Relaxed);
        self.api_microcents.fetch_add((api_cost * 1e8) as u64, Ordering::Relaxed);
        self.wall_latency.record(wall_secs);
    }

    pub fn record_plan_outcome(&self, outcome: crate::dag::RepairOutcome) {
        use crate::dag::RepairOutcome::*;
        match outcome {
            Valid => &self.plans_valid,
            Repaired(_) => &self.plans_repaired,
            Fallback => &self.plans_fallback,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    /// Prometheus-style exposition text.
    pub fn render(&self) -> String {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        let mut s = String::new();
        s.push_str(&format!("hybridflow_queries_total {}\n", g(&self.queries_total)));
        s.push_str(&format!("hybridflow_queries_correct {}\n", g(&self.queries_correct)));
        s.push_str(&format!("hybridflow_subtasks_total {}\n", g(&self.subtasks_total)));
        s.push_str(&format!(
            "hybridflow_subtasks_offloaded {}\n",
            g(&self.subtasks_offloaded)
        ));
        s.push_str(&format!("hybridflow_plans_valid {}\n", g(&self.plans_valid)));
        s.push_str(&format!("hybridflow_plans_repaired {}\n", g(&self.plans_repaired)));
        s.push_str(&format!("hybridflow_plans_fallback {}\n", g(&self.plans_fallback)));
        s.push_str(&format!(
            "hybridflow_api_dollars {:.6}\n",
            g(&self.api_microcents) as f64 / 1e8
        ));
        s.push_str(&format!(
            "hybridflow_wall_latency_seconds_mean {:.6}\n",
            self.wall_latency.mean_secs()
        ));
        s.push_str(&format!(
            "hybridflow_wall_latency_seconds_p99 {:.6}\n",
            self.wall_latency.quantile(0.99)
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::RepairOutcome;

    #[test]
    fn histogram_records_and_quantiles() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(0.001); // 1ms
        }
        for _ in 0..10 {
            h.record(1.0); // 1s
        }
        assert_eq!(h.count(), 100);
        assert!(h.mean_secs() > 0.05 && h.mean_secs() < 0.2);
        assert!(h.quantile(0.5) < 0.01, "p50 {}", h.quantile(0.5));
        assert!(h.quantile(0.99) >= 1.0, "p99 {}", h.quantile(0.99));
    }

    #[test]
    fn histogram_empty_is_nan() {
        let h = Histogram::new();
        assert!(h.mean_secs().is_nan());
        assert!(h.quantile(0.5).is_nan());
    }

    #[test]
    fn telemetry_accumulates_and_renders() {
        let t = Telemetry::new();
        t.record_query(true, 5, 2, 0.0075, 0.002);
        t.record_query(false, 4, 1, 0.0030, 0.004);
        t.record_plan_outcome(RepairOutcome::Valid);
        t.record_plan_outcome(RepairOutcome::Repaired(1));
        t.record_plan_outcome(RepairOutcome::Fallback);
        let out = t.render();
        assert!(out.contains("hybridflow_queries_total 2"));
        assert!(out.contains("hybridflow_queries_correct 1"));
        assert!(out.contains("hybridflow_subtasks_total 9"));
        assert!(out.contains("hybridflow_subtasks_offloaded 3"));
        assert!(out.contains("hybridflow_plans_repaired 1"));
        // Dollar accounting to ~1e-8 resolution.
        assert!(out.contains("hybridflow_api_dollars 0.0105"), "{out}");
    }

    #[test]
    fn concurrent_recording_is_consistent() {
        use std::sync::Arc;
        let t = Arc::new(Telemetry::new());
        let pool = crate::util::pool::ThreadPool::new(4);
        pool.map((0..200).collect::<Vec<_>>(), {
            let t = Arc::clone(&t);
            move |i| {
                t.record_query(i % 2 == 0, 4, 2, 0.001, 0.001);
            }
        });
        assert_eq!(t.queries_total.load(Ordering::Relaxed), 200);
        assert_eq!(t.queries_correct.load(Ordering::Relaxed), 100);
        assert_eq!(t.subtasks_total.load(Ordering::Relaxed), 800);
    }
}

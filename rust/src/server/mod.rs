//! Serving front: a concurrent request loop over the HybridFlow pipeline.
//!
//! This is where the *real* wall-clock story lives: queries arrive, worker
//! threads run plan -> route -> schedule concurrently, the PJRT scoring
//! service is shared, and we report coordinator throughput and latency
//! percentiles — the serving-paper deliverable. (Simulated model latencies
//! are virtual-clock quantities; `wall_*` fields measure the coordinator
//! itself.)
//!
//! [`serve_fleet`] is the virtual-clock counterpart: an open-loop
//! multi-tenant workload driven through the unified simulation kernel,
//! where shared worker pools and tenant budgets make cross-query
//! contention visible. Both fleet entrypoints are thin shims over the
//! declarative scenario layer ([`crate::scenario::WorkloadSpec`] builds
//! the arrival list) — prefer a [`crate::scenario::ScenarioSpec`] for new
//! experiments.

pub mod telemetry;

use crate::cache::CacheStats;
use crate::metrics::QueryOutcome;
use crate::pipeline::HybridFlowPipeline;
use crate::report::ReportRenderer;
use crate::scenario::WorkloadSpec;
use crate::sim::{run_fleet, FleetConfig, FleetReport};
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::workload::trace::{ArrivalProcess, ZipfMix};
use crate::workload::{Benchmark, Query};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
// lint:allow(wall_clock): the wall-clock serving loop measures real throughput
use std::time::Instant;

/// Serving statistics for one run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub n_queries: usize,
    pub wall_seconds: f64,
    /// Coordinator throughput (queries/s of real wall time).
    pub throughput_qps: f64,
    /// Per-query coordinator wall latency (s).
    pub wall_latency: Summary,
    /// Simulated end-to-end C_time (s).
    pub sim_latency: Summary,
    pub accuracy_pct: f64,
    pub total_api_cost: f64,
    pub mean_offload_rate: f64,
    /// Result-cache counters for this run (`None` when the pipeline has
    /// no enabled cache attached). Note the wall-clock serving loop runs
    /// queries on a thread pool, so the *hit pattern* depends on thread
    /// interleaving — per-query outcomes stay seed-deterministic only
    /// with the cache off; the virtual-clock fleet path
    /// ([`serve_fleet`]) is the deterministic one.
    pub cache: Option<CacheStats>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let mut r = ReportRenderer::new(format!(
            "served {} queries in {:.2}s wall ({:.1} q/s)",
            self.n_queries, self.wall_seconds, self.throughput_qps,
        ));
        r.line(format!(
            "coordinator wall latency: p50 {:.2}ms  p90 {:.2}ms  p99 {:.2}ms",
            self.wall_latency.p50 * 1e3,
            self.wall_latency.p90 * 1e3,
            self.wall_latency.p99 * 1e3,
        ));
        r.line(format!(
            "simulated C_time:         mean {:.2}s  p50 {:.2}s  p99 {:.2}s",
            self.sim_latency.mean, self.sim_latency.p50, self.sim_latency.p99,
        ));
        r.line(format!(
            "accuracy {:.2}%  total C_API ${:.4}  offload {:.1}%",
            self.accuracy_pct,
            self.total_api_cost,
            self.mean_offload_rate * 100.0,
        ));
        r.cache(self.cache.as_ref());
        r.finish()
    }

    /// Machine-readable report (`util::json`) — the wall-clock
    /// counterpart of [`FleetReport::to_json`], behind the CLI's
    /// `serve --json` flag.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::report::{cache_stats_json, summary_json};
        use crate::util::json::Json;
        Json::obj(vec![
            ("n_queries", Json::Num(self.n_queries as f64)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("throughput_qps", Json::Num(self.throughput_qps)),
            ("wall_latency", summary_json(&self.wall_latency)),
            ("sim_latency", summary_json(&self.sim_latency)),
            ("accuracy_pct", Json::Num(self.accuracy_pct)),
            ("total_api_cost", Json::Num(self.total_api_cost)),
            ("mean_offload_rate", Json::Num(self.mean_offload_rate)),
            ("cache", self.cache.as_ref().map_or(Json::Null, cache_stats_json)),
        ])
    }
}

/// Serve a batch of queries concurrently over `workers` threads.
pub fn serve(
    pipeline: Arc<HybridFlowPipeline>,
    queries: Vec<Query>,
    workers: usize,
    seed: u64,
) -> ServeReport {
    let n = queries.len();
    let pool = ThreadPool::new(workers);
    let counter = Arc::new(AtomicUsize::new(0));
    // Each serve run starts with a cold cache so the report's cache
    // counters are exactly this run's numbers.
    if let Some(c) = pipeline.config.schedule.cache.as_deref() {
        c.reset();
    }
    // lint:allow(wall_clock): coordinator throughput is a real-time metric
    let t0 = Instant::now();

    let results: Vec<(QueryOutcome, f64)> = pool.map(queries, {
        let pipeline = Arc::clone(&pipeline);
        let counter = Arc::clone(&counter);
        move |q| {
            counter.fetch_add(1, Ordering::Relaxed);
            // Seed by query id (not arrival order) so results are exactly
            // reproducible regardless of thread interleaving.
            let mut rng = Rng::new(seed ^ q.id.wrapping_mul(0x9E3779B97f4A7C15));
            // lint:allow(wall_clock): per-query wall latency is the point here
            let start = Instant::now();
            let outcome = pipeline.run_query(&q, &mut rng);
            (outcome, start.elapsed().as_secs_f64())
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let wall_lats: Vec<f64> = results.iter().map(|(_, w)| *w).collect();
    let sim_lats: Vec<f64> = results.iter().map(|(o, _)| o.latency).collect();
    let correct = results.iter().filter(|(o, _)| o.correct).count();
    let api: f64 = results.iter().map(|(o, _)| o.api_cost).sum();
    let off: f64 = results.iter().map(|(o, _)| o.offload_rate).sum::<f64>() / n.max(1) as f64;

    ServeReport {
        n_queries: n,
        wall_seconds: wall,
        throughput_qps: n as f64 / wall.max(1e-9),
        wall_latency: Summary::of_or_zero(&wall_lats),
        sim_latency: Summary::of_or_zero(&sim_lats),
        accuracy_pct: correct as f64 / n.max(1) as f64 * 100.0,
        total_api_cost: api,
        mean_offload_rate: off,
        cache: pipeline
            .config
            .schedule
            .cache
            .as_deref()
            .filter(|c| c.enabled())
            .map(|c| c.stats()),
    }
}

/// Serve an open-loop multi-tenant workload on the unified kernel.
///
/// Builds `n` queries from `bench`, assigns tenants round-robin over the
/// provided pools, samples arrival times from `process`, and runs the
/// whole thing through [`run_fleet`] under the pipeline's scheduling
/// semantics. Everything is deterministic in `(bench, n, seed)`. This is
/// a compatibility shim over the declarative workload layer
/// ([`WorkloadSpec::arrivals`] builds the exact same arrival list a
/// scenario file would).
pub fn serve_fleet(
    pipeline: &HybridFlowPipeline,
    cfg: &FleetConfig,
    tenants: Vec<crate::budget::TenantPool>,
    bench: Benchmark,
    n: usize,
    process: &ArrivalProcess,
    seed: u64,
) -> FleetReport {
    let workload = WorkloadSpec { benchmark: bench, n, arrival: process.clone(), zipf: None };
    let arrivals = workload.arrivals(tenants.len(), seed);
    run_fleet(pipeline, cfg, tenants, arrivals, seed)
}

/// [`serve_fleet`] with a Zipf-popularity repetition knob: the fresh
/// query set is rewritten by `zipf` (see
/// [`crate::workload::trace::ZipfMix`]) before arrival assignment, so
/// popular prototypes repeat across the fleet — the workload shape that
/// exercises the cross-query result cache. Deterministic in
/// `(bench, n, zipf, seed)`.
#[allow(clippy::too_many_arguments)]
pub fn serve_fleet_zipf(
    pipeline: &HybridFlowPipeline,
    cfg: &FleetConfig,
    tenants: Vec<crate::budget::TenantPool>,
    bench: Benchmark,
    n: usize,
    process: &ArrivalProcess,
    zipf: &ZipfMix,
    seed: u64,
) -> FleetReport {
    let workload = WorkloadSpec {
        benchmark: bench,
        n,
        arrival: process.clone(),
        zipf: Some(zipf.clone()),
    };
    let arrivals = workload.arrivals(tenants.len(), seed);
    run_fleet(pipeline, cfg, tenants, arrivals, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::TenantPool;
    use crate::config::simparams::SimParams;
    use crate::models::SimExecutor;
    use crate::pipeline::PipelineConfig;
    use crate::planner::synthetic::SyntheticPlanner;
    use crate::router::{MirrorPredictor, RoutePolicy};
    use crate::workload::generate_queries;

    fn pipeline() -> Arc<HybridFlowPipeline> {
        let sp = SimParams::default();
        Arc::new(HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            PipelineConfig::paper_default(&sp),
        ))
    }

    #[test]
    fn serves_concurrently_and_reports() {
        let qs = generate_queries(Benchmark::Gpqa, 60, 0);
        let report = serve(pipeline(), qs, 4, 7);
        assert_eq!(report.n_queries, 60);
        assert!(report.throughput_qps > 0.0);
        assert!(report.wall_latency.p50 > 0.0);
        assert!(report.sim_latency.mean > 1.0); // includes planning
        let rendered = report.render();
        assert!(rendered.contains("served 60 queries"));
    }

    #[test]
    fn deterministic_accuracy_given_seed() {
        let qs = generate_queries(Benchmark::Gpqa, 40, 1);
        let a = serve(pipeline(), qs.clone(), 3, 42);
        let b = serve(pipeline(), qs, 5, 42);
        // Per-query rngs are seeded by query id, so accuracy is exactly
        // reproducible regardless of worker count or interleaving.
        assert_eq!(a.n_queries, b.n_queries);
        assert_eq!(a.accuracy_pct, b.accuracy_pct);
        assert_eq!(a.total_api_cost, b.total_api_cost);
    }

    #[test]
    fn serve_fleet_zipf_repeats_prototypes_and_feeds_cache() {
        use crate::cache::{CachePolicyKind, SubtaskCache};
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = RoutePolicy::AllCloud;
        cfg.schedule.cache =
            Some(Arc::new(SubtaskCache::new(256, CachePolicyKind::Lru).with_shared_tier()));
        let p = HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        );
        let report = serve_fleet_zipf(
            &p,
            &FleetConfig { record_trace: false, ..Default::default() },
            vec![TenantPool::unlimited("a"), TenantPool::unlimited("b")],
            Benchmark::Gpqa,
            24,
            // Wide spacing: repeats arrive after their prototype's first
            // execution has finished (entries are availability-gated).
            &ArrivalProcess::Periodic { gap: 40.0 },
            &ZipfMix::new(1.2, 4),
            7,
        );
        assert_eq!(report.results.len(), 24);
        // Only the 4 prototype ids appear.
        let mut ids: Vec<u64> = report.results.iter().map(|r| r.query_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert!(ids.len() <= 4, "expected <=4 prototypes, saw {ids:?}");
        let stats = report.cache.expect("cache stats");
        assert!(stats.hits > 0, "zipf repetition must produce cache hits");
        assert!(stats.hit_rate() > 0.2, "hit rate {}", stats.hit_rate());
    }

    #[test]
    fn serve_fleet_open_loop_round_robins_tenants() {
        let p = pipeline();
        let tenants =
            vec![TenantPool::unlimited("a"), TenantPool::unlimited("b"), TenantPool::unlimited("c")];
        let report = serve_fleet(
            &p,
            &FleetConfig::default(),
            tenants,
            Benchmark::Gpqa,
            9,
            &ArrivalProcess::Periodic { gap: 1.0 },
            5,
        );
        assert_eq!(report.results.len(), 9);
        for (i, r) in report.results.iter().enumerate() {
            assert_eq!(r.tenant, i % 3);
            assert!((r.arrival - i as f64).abs() < 1e-12);
        }
        // Every tenant saw decisions.
        for t in &report.tenants {
            assert!(t.state.n_decided > 0, "tenant {} idle", t.name);
        }
    }
}

//! `hybridflow` — CLI entry point for the HybridFlow coordinator.
//!
//! Commands:
//!   plan     decompose one synthetic query and print the XML plan + DAG
//!   run      run queries through the full pipeline, print outcomes;
//!            `--scenario <file.json>` executes a declarative scenario
//!   serve    concurrent serving loop, report throughput/latency
//!   profile  regenerate the App. C profiling dataset (JSONL)
//!   exp      run a paper experiment (table1..table8, fig3, fig5, calibrate)
//!   check    verify artifacts + PJRT round trip + mirror parity;
//!            `--scenario <file.json>` statically checks a spec's
//!            feasibility instead (queueing stability, budgets, cache)
//!   lint     dependency-free determinism lint over rust/src
//!   fuzz     random-but-valid scenario specs through the invariant harness
//!
//! Unknown options and malformed values print the usage block and exit
//! non-zero (`validate_command_args`).

use hybridflow::cache::{CachePolicyKind, SubtaskCache};
use hybridflow::config::simparams::SimParams;
use hybridflow::dag::emit_plan;
use hybridflow::eval::{run_experiment, ExpContext, EXPERIMENT_IDS};
use hybridflow::models::SimExecutor;
use hybridflow::obs::ObserveConfig;
use hybridflow::pipeline::{HybridFlowPipeline, PipelineConfig};
use hybridflow::planner::synthetic::SyntheticPlanner;
use hybridflow::planner::Planner;
use hybridflow::router::{MirrorPredictor, RoutePolicy, UtilityPredictor};
use hybridflow::runtime::RouterService;
use hybridflow::scenario::{ScenarioSpec, SweepSpec};
use hybridflow::server::serve;
use hybridflow::util::cli::{usage, Args};
use hybridflow::util::json::Json;
use hybridflow::util::rng::Rng;
use hybridflow::workload::{generate_queries, profiling, Benchmark};
use std::path::PathBuf;
use std::sync::Arc;

const COMMANDS: [(&str, &str); 8] = [
    ("plan", "decompose a synthetic query and print plan + repaired DAG"),
    ("run", "run N queries end-to-end (or --scenario <file.json> for a declarative fleet scenario; --shards N overrides its shard count, --fault-seed S reseeds its faults block, --trace-out/--metrics-out/--metrics-interval export observability artifacts, --threads N caps the shard fan-out)"),
    ("serve", "concurrent serving loop with throughput/latency report"),
    ("profile", "emit the offline profiling dataset as JSONL"),
    ("exp", "run an experiment: --id <table1|table2|table3|table5|table6_fig4|fig3|table7|table8|fig5|calibrate|d1_exposure|ablations|fleet_serve|fleet_mixed_policy|fleet_cache>"),
    ("check", "verify artifacts, PJRT round trip, and mirror parity; or --scenario <file.json> for a static feasibility check of a spec (no kernel execution)"),
    ("lint", "determinism lint over the rust source tree: [--json] [--src <dir>]"),
    ("fuzz", "run random-but-valid scenario specs through the invariant harness: --cases <n> --seed <s> [--adversarial]"),
];

/// Options/flags shared by every pipeline-building command.
const PIPELINE_OPTS: &[&str] = &[
    "artifacts", "benchmark", "seed", "pjrt", "fixed-tau", "chain", "hedge",
    "hedge-threshold", "calibrated", "cache", "cache-policy",
];

/// Per-command extra options (appended to [`PIPELINE_OPTS`] where the
/// command builds a pipeline).
fn allowed_options(cmd: &str) -> Vec<&'static str> {
    let mut allowed: Vec<&'static str> = match cmd {
        "plan" => return vec!["artifacts", "benchmark", "seed"],
        "profile" => return vec!["n", "seed", "out"],
        "fuzz" => return vec!["cases", "seed", "adversarial"],
        "check" => return vec!["artifacts", "scenario"],
        "lint" => return vec!["json", "src"],
        "exp" => return vec!["artifacts", "id", "quick", "scale", "seeds", "out", "json"],
        "run" => vec![
            "n", "scenario", "json", "shards", "threads", "trace-out", "metrics-out",
            "metrics-interval", "fault-seed",
        ],
        "serve" => vec!["n", "workers", "trace-in", "trace-out", "metrics", "json"],
        _ => vec![],
    };
    allowed.extend_from_slice(PIPELINE_OPTS);
    allowed
}

/// Reject unknown options/flags and malformed values *before* a command
/// runs, so typos fail fast with the usage block instead of being
/// silently ignored (or panicking deep inside a run).
/// Options that would silently lose to a `--scenario` spec (the spec
/// defines the whole run: workload, seed, and every engine knob).
/// `--shards` is deliberately absent: it is an explicit topology
/// *override* applied on top of the spec, not a competing definition.
const SCENARIO_CONFLICTS: &[&str] = &[
    "benchmark", "n", "seed", "fixed-tau", "chain", "hedge", "hedge-threshold",
    "calibrated", "cache", "cache-policy",
];

fn validate_command_args(cmd: &str, args: &Args) -> anyhow::Result<()> {
    args.validate_known(&allowed_options(cmd))?;
    if cmd == "run" && args.get("scenario").is_some() {
        let conflicting: Vec<&str> = SCENARIO_CONFLICTS
            .iter()
            .copied()
            .filter(|k| args.get(k).is_some() || args.flag(k))
            .collect();
        anyhow::ensure!(
            conflicting.is_empty(),
            "--scenario defines the whole run (workload, seed, engine knobs); \
             drop the conflicting option(s) or edit the spec file: --{}",
            conflicting.join(", --")
        );
    }
    // Typed-value sanity (parse errors surface here, not mid-run).
    for key in ["n", "workers", "cache", "seeds", "cases", "shards", "threads", "fault-seed"] {
        let _ = args.get_usize(key)?;
    }
    // Artifact options take a file path; a bare `--trace-out` means the
    // path was forgotten (or swallowed by a following `--option`).
    for key in ["trace-out", "metrics-out", "json", "out"] {
        // `lint --json` is an output *mode* (JSON to stdout), not a path.
        if cmd == "lint" && key == "json" {
            continue;
        }
        anyhow::ensure!(!args.flag(key), "--{key} expects a file path");
    }
    // `lint --src` names the tree to scan; bare means the path was lost.
    if cmd == "lint" {
        anyhow::ensure!(!args.flag("src"), "--src expects a directory path");
    }
    // `--shards` overrides the spec's `topology.shards`, so it only makes
    // sense next to a scenario file, and zero shards is meaningless
    // (negative/fractional values already fail the usize parse above).
    if let Some(shards) = args.get_usize("shards")? {
        anyhow::ensure!(shards >= 1, "--shards expects a positive shard count, got {shards}");
        if cmd == "run" {
            anyhow::ensure!(
                args.get("scenario").is_some(),
                "--shards overrides a scenario's topology; pass it with --scenario <file.json>"
            );
        }
    }
    // The observability exports and the explicit thread budget configure a
    // scenario run; on the plain `run` path they would be silently dead.
    if cmd == "run" && args.get("scenario").is_none() {
        for key in ["trace-out", "metrics-out", "metrics-interval", "threads", "fault-seed"] {
            anyhow::ensure!(
                args.get(key).is_none(),
                "--{key} configures a scenario run; pass it with --scenario <file.json>"
            );
        }
    }
    if let Some(threads) = args.get_usize("threads")? {
        anyhow::ensure!(threads >= 1, "--threads expects a positive thread count, got {threads}");
    }
    if let Some(iv) = args.get_f64("metrics-interval")? {
        anyhow::ensure!(
            iv.is_finite() && iv > 0.0,
            "--metrics-interval expects a finite positive number of virtual seconds, got {iv}"
        );
    }
    let _ = args.get_u64_or("seed", 0)?;
    for key in ["fixed-tau", "scale"] {
        let _ = args.get_f64(key)?;
    }
    if let Some(thr) = args.get_f64("hedge-threshold")? {
        anyhow::ensure!(
            thr.is_finite() && thr >= 0.0,
            "--hedge-threshold expects a finite non-negative utility cutoff, got {thr}"
        );
    }
    if let Some(s) = args.get("cache-policy") {
        anyhow::ensure!(
            CachePolicyKind::parse(s).is_some(),
            "unknown cache policy '{s}' (lru|lfu|ttl[:secs])"
        );
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand.as_deref() {
        Some(cmd @ ("plan" | "run" | "serve" | "profile" | "exp" | "check" | "lint" | "fuzz")) => {
            // Argument problems (unknown options, malformed values) print
            // the usage block; runtime failures inside a command print
            // just the error, so the cause is not buried under help text.
            match validate_command_args(cmd, &args) {
                Err(e) => {
                    eprintln!("error: {e}");
                    eprint!("{}", usage("hybridflow", &COMMANDS));
                    1
                }
                Ok(()) => {
                    let out = match cmd {
                        "plan" => cmd_plan(&args),
                        "run" => cmd_run(&args),
                        "serve" => cmd_serve(&args),
                        "profile" => cmd_profile(&args),
                        "exp" => cmd_exp(&args),
                        "check" => cmd_check(&args),
                        "lint" => cmd_lint(&args),
                        "fuzz" => cmd_fuzz(&args),
                        _ => unreachable!("dispatch covers every command"),
                    };
                    out.map(|_| 0).unwrap_or_else(|e| {
                        eprintln!("error: {e}");
                        1
                    })
                }
            }
        }
        _ => {
            eprintln!("error: missing or unknown command");
            eprint!("{}", usage("hybridflow", &COMMANDS));
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(hybridflow::config::default_artifacts_dir)
}

fn bench_arg(args: &Args) -> anyhow::Result<Benchmark> {
    let name = args.get_or("benchmark", "gpqa");
    Benchmark::parse(name).ok_or_else(|| anyhow::anyhow!("unknown benchmark '{name}'"))
}

fn predictor(args: &Args) -> anyhow::Result<Arc<dyn UtilityPredictor>> {
    let dir = artifacts_dir(args);
    if args.flag("pjrt") {
        let svc = RouterService::start(&dir)?;
        println!("[runtime] PJRT platform: {}", svc.platform());
        Ok(Arc::new(svc))
    } else {
        Ok(Arc::new(MirrorPredictor::from_meta_file(&dir.join("router_meta.json"))?))
    }
}

fn build_pipeline(args: &Args) -> anyhow::Result<HybridFlowPipeline> {
    let sp = SimParams::default();
    let mut cfg = PipelineConfig::paper_default(&sp);
    if let Some(tau) = args.get_f64("fixed-tau")? {
        cfg.policy = RoutePolicy::FixedThreshold(tau);
    }
    if args.flag("chain") {
        cfg.schedule.chain_mode = true;
    }
    if args.flag("hedge") {
        cfg.schedule.hedge = true;
        if let Some(thr) = args.get_f64("hedge-threshold")? {
            cfg.schedule.hedge_threshold = thr;
        }
    }
    if args.flag("calibrated") {
        cfg.policy = RoutePolicy::hybridflow_calibrated(&sp);
    }
    // Cross-query result cache: `--cache <cap>` entries per partition
    // (0 = disabled), eviction via `--cache-policy <lru|lfu|ttl[:secs]>`.
    let cache_cap = args.get_usize_or("cache", 0)?;
    if cache_cap > 0 {
        let kind = match args.get("cache-policy") {
            None => CachePolicyKind::Lru,
            Some(s) => CachePolicyKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown cache policy '{s}' (lru|lfu|ttl[:secs])"))?,
        };
        cfg.schedule.cache = Some(Arc::new(SubtaskCache::new(cache_cap, kind)));
    }
    Ok(HybridFlowPipeline::with_predictor(
        SimExecutor::paper_pair(),
        SyntheticPlanner::paper_main(),
        predictor(args)?,
        cfg,
    ))
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let bench = bench_arg(args)?;
    let seed = args.get_u64_or("seed", 0)?;
    let q = generate_queries(bench, 1, seed)
        .pop()
        .ok_or_else(|| anyhow::anyhow!("no query"))?;
    let planner = SyntheticPlanner::paper_main();
    let mut rng = Rng::new(seed);
    let text = planner.plan_text(&q, &mut rng);
    println!("--- planner XML (latency {:.2}s) ---\n{}", text.planning_latency, text.xml);
    let mut rng = Rng::new(seed);
    let plan = planner.plan(&q, 7, &mut rng);
    println!("\n--- executable DAG ({:?}) ---\n{}", plan.outcome, emit_plan(&plan.dag));
    println!(
        "\nnodes={} critical_path={:?} R_comp={:.2}",
        plan.dag.len(),
        plan.dag.critical_path_len(),
        plan.dag.compression_ratio().unwrap_or(0.0)
    );
    Ok(())
}

/// Predictor for scenario runs: like [`predictor`], but a missing trained
/// artifact falls back to the synthetic mirror (with a loud note) instead
/// of failing — scenario files must be runnable on a fresh checkout, the
/// same contract the example binaries and `eval` experiments honor.
/// `--pjrt` stays a hard requirement (an explicit runtime request).
fn scenario_predictor(args: &Args) -> anyhow::Result<Arc<dyn UtilityPredictor>> {
    if args.flag("pjrt") {
        return predictor(args);
    }
    let dir = artifacts_dir(args);
    match MirrorPredictor::from_meta_file(&dir.join("router_meta.json")) {
        Ok(p) => Ok(Arc::new(p)),
        Err(e) => {
            eprintln!("[scenario] WARNING: trained router unavailable ({e}); using synthetic predictor");
            Ok(Arc::new(MirrorPredictor::synthetic_for_tests()))
        }
    }
}

/// Write a machine-readable artifact for `--json <path>` (pretty-printed
/// `util::json`, trailing newline).
fn write_json(path: &str, j: &Json) -> anyhow::Result<()> {
    let mut text = j.to_string_pretty();
    text.push('\n');
    std::fs::write(path, text)?;
    println!("json written to {path}");
    Ok(())
}

/// `run --scenario <file.json>` on a sweep file: resolve the grid, fan it
/// out across the thread pool, print the tabulated cells.
fn cmd_run_sweep(args: &Args, path: &str, j: &Json) -> anyhow::Result<()> {
    // A sweep aggregates many cells into one table; there is no single
    // span stream or metrics series to export (and no single faults block
    // to reseed).
    for key in ["trace-out", "metrics-out", "metrics-interval", "fault-seed"] {
        anyhow::ensure!(
            args.get(key).is_none(),
            "--{key} applies to a single scenario run, not a sweep"
        );
    }
    let mut sweep = SweepSpec::from_json(j)?;
    if let Some(shards) = args.get_usize("shards")? {
        sweep.base.topology.shards = shards;
    }
    let n_cells: usize = sweep.axes.iter().map(|a| a.values.len()).product();
    let threads = match args.get_usize("threads")? {
        Some(t) => t,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    };
    println!(
        "sweep '{}' from {path}: {} cells over {} axis(es), {} threads",
        sweep.name,
        n_cells,
        sweep.axes.len(),
        threads,
    );
    let report = sweep.run(scenario_predictor(args)?, threads)?;
    println!("{}", report.table().render());
    if let Some(out) = args.get("json") {
        write_json(out, &report.to_json())?;
    }
    Ok(())
}

/// `run --scenario <file.json>`: execute a declarative fleet scenario.
fn cmd_run_scenario(args: &Args, path: &str) -> anyhow::Result<()> {
    let parsed = Json::parse_file(std::path::Path::new(path))?;
    if SweepSpec::is_sweep_json(&parsed) {
        return cmd_run_sweep(args, path, &parsed);
    }
    let mut spec = ScenarioSpec::from_json(&parsed)?;
    if let Some(shards) = args.get_usize("shards")? {
        spec.topology.shards = shards;
    }
    // `--fault-seed` reseeds the spec's fault streams (a different
    // realization of the same fault process); it needs a faults block to
    // reseed — silently creating one would turn the override into a
    // competing run definition.
    if let Some(seed) = args.get_usize("fault-seed")? {
        let faults = spec.engine.faults.as_mut().ok_or_else(|| {
            anyhow::anyhow!(
                "--fault-seed reseeds a scenario's engine.faults block, but {path} has none"
            )
        })?;
        faults.seed = seed as u64;
    }
    // `--trace-out` / `--metrics-out` switch the matching recorder on (on
    // top of whatever the spec's `observe` block enables), and
    // `--metrics-interval` overrides the snapshot cadence; the values
    // themselves were validated in `validate_command_args`.
    let want_trace = args.get("trace-out").is_some();
    let want_metrics = args.get("metrics-out").is_some();
    let interval = args.get_f64("metrics-interval")?;
    if want_trace || want_metrics || interval.is_some() {
        let mut o = spec.engine.observe.clone().unwrap_or(ObserveConfig {
            spans: false,
            metrics: false,
            ..Default::default()
        });
        o.spans |= want_trace;
        o.metrics |= want_metrics || interval.is_some();
        if let Some(iv) = interval {
            o.metrics_interval = iv;
        }
        spec.engine.observe = Some(o);
    }
    println!(
        "scenario '{}' from {path}: {} x {} queries, {} tenants, {} shard(s), seed {}",
        spec.name,
        spec.workload.n,
        spec.workload.benchmark.display(),
        spec.topology.tenants.len(),
        spec.topology.shards,
        spec.seed,
    );
    let session = spec.build(scenario_predictor(args)?)?;
    let report = match args.get_usize("threads")? {
        Some(t) => session.run_with_threads(t),
        None => session.run(),
    };
    println!("{}", report.render());
    if let Some(out) = args.get("json") {
        write_json(out, &report.to_json())?;
    }
    if let Some(obs) = &report.obs {
        if let Some(path) = args.get("trace-out") {
            std::fs::write(path, obs.chrome_trace_text())?;
            println!("trace written to {path} ({} spans)", obs.spans.len());
        }
        if let Some(path) = args.get("metrics-out") {
            std::fs::write(path, obs.metrics_jsonl())?;
            println!("metrics written to {path} ({} snapshots)", obs.snapshots.len());
        }
    }
    for t in &report.tenants {
        println!(
            "  tenant {:<12} decided {:>4}  offload {:>5.1}%  spend ${:.4} (cap {})",
            t.name,
            t.state.n_decided,
            t.state.offload_rate() * 100.0,
            t.state.k_used,
            if t.k_cap.is_finite() { format!("${:.4}", t.k_cap) } else { "unlimited".into() },
        );
    }
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.get("scenario") {
        let path = path.to_string();
        return cmd_run_scenario(args, &path);
    }
    let bench = bench_arg(args)?;
    let n = args.get_usize_or("n", 10)?;
    let seed = args.get_u64_or("seed", 0)?;
    let pipeline = build_pipeline(args)?;
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    let mut rows: Vec<Json> = Vec::new();
    for q in generate_queries(bench, n, seed) {
        let out = pipeline.run_query(&q, &mut rng);
        correct += usize::from(out.correct);
        println!(
            "query {:>3}  d={:.2}  subtasks={}  offload={:>4.0}%  C_time={:>6.2}s  C_API=${:.4}  {}",
            q.id,
            q.difficulty,
            out.n_subtasks,
            out.offload_rate * 100.0,
            out.latency,
            out.api_cost,
            if out.correct { "CORRECT" } else { "wrong" }
        );
        if args.get("json").is_some() {
            rows.push(Json::obj(vec![
                ("id", Json::Num(q.id as f64)),
                ("correct", Json::Bool(out.correct)),
                ("latency", Json::Num(out.latency)),
                ("api_cost", Json::Num(out.api_cost)),
                ("offload_rate", Json::Num(out.offload_rate)),
                ("n_subtasks", Json::Num(out.n_subtasks as f64)),
            ]));
        }
    }
    println!("\naccuracy: {}/{} = {:.1}%", correct, n, correct as f64 / n as f64 * 100.0);
    // The cache persists across the whole run loop (that is the point:
    // cross-query reuse), so these are session totals.
    if let Some(c) = pipeline.config.schedule.cache.as_deref() {
        println!("{}", c.render_stats());
    }
    if let Some(out) = args.get("json") {
        write_json(
            out,
            &Json::obj(vec![
                ("n", Json::Num(n as f64)),
                (
                    "accuracy_pct",
                    Json::Num(correct as f64 / n.max(1) as f64 * 100.0),
                ),
                ("queries", Json::Arr(rows)),
            ]),
        )?;
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use hybridflow::server::telemetry::Telemetry;
    use hybridflow::workload::trace;

    let bench = bench_arg(args)?;
    let n = args.get_usize_or("n", 100)?;
    let workers = args.get_usize_or("workers", 8)?;
    let seed = args.get_u64_or("seed", 0)?;
    let pipeline = Arc::new(build_pipeline(args)?);

    // Workload: fresh synthetic set, or replayed from a recorded trace.
    let queries = match args.get("trace-in") {
        Some(path) => {
            let records = trace::read_jsonl(&std::fs::read_to_string(path)?)?;
            println!("replaying {} queries from {path}", records.len());
            trace::queries_of(&records)
        }
        None => generate_queries(bench, n, seed),
    };
    println!(
        "serving {} {} queries on {workers} workers (predictor: {})",
        queries.len(),
        bench.display(),
        pipeline.predictor.backend()
    );
    let report = serve(Arc::clone(&pipeline), queries.clone(), workers, seed);
    println!("{}", report.render());
    if let Some(out) = args.get("json") {
        write_json(out, &report.to_json())?;
    }

    // Optional trace recording (re-runs deterministically per query id).
    if let Some(path) = args.get("trace-out") {
        let mut records = Vec::with_capacity(queries.len());
        for q in &queries {
            let mut rng = hybridflow::util::rng::Rng::new(
                seed ^ q.id.wrapping_mul(0x9E3779B97f4A7C15),
            );
            let outcome = pipeline.run_query(q, &mut rng);
            records.push(trace::TraceRecord { query: q.clone(), outcome: Some(outcome) });
        }
        std::fs::write(path, trace::write_jsonl(&records))?;
        println!("trace written to {path}");
    }

    // Optional telemetry exposition.
    if args.flag("metrics") {
        let telemetry = Telemetry::new();
        for q in &queries {
            let mut rng = hybridflow::util::rng::Rng::new(
                seed ^ q.id.wrapping_mul(0x9E3779B97f4A7C15),
            );
            // lint:allow(wall_clock): CLI telemetry reports real elapsed time
            let t0 = std::time::Instant::now();
            let (exec, outcome) = pipeline.run_query_traced(q, &mut rng);
            telemetry.record_plan_outcome(outcome);
            telemetry.record_query(
                exec.correct,
                exec.n_subtasks,
                exec.budget.n_offloaded,
                exec.api_cost,
                t0.elapsed().as_secs_f64(),
            );
        }
        println!("\n--- telemetry ---\n{}", telemetry.render());
    }
    Ok(())
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize_or("n", 200)?;
    let seed = args.get_u64_or("seed", 0)?;
    let records = profiling::standard_profile_set(n, seed);
    let out = profiling::to_jsonl(&records);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &out)?;
            println!("wrote {} records to {path}", records.len());
        }
        None => print!("{out}"),
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let id = args
        .get("id")
        .or_else(|| args.positional.first().map(String::as_str))
        .ok_or_else(|| {
            anyhow::anyhow!("--id required; one of: {}", EXPERIMENT_IDS.join(", "))
        })?
        .to_string();
    let mut ctx = if args.flag("quick") { ExpContext::quick() } else { ExpContext::default() };
    ctx.artifacts_dir = artifacts_dir(args);
    if let Some(s) = args.get_f64("scale")? {
        ctx.scale = s;
    }
    if let Some(n) = args.get_usize("seeds")? {
        ctx.seeds = (0..n as u64).map(|i| 11 + 11 * i).collect();
    }
    // lint:allow(wall_clock): experiment runtimes are reported in real time
    let t0 = std::time::Instant::now();
    let out = run_experiment(&id, &ctx)?;
    println!("{out}");
    println!("[exp {id}] completed in {:.1}s", t0.elapsed().as_secs_f64());
    if let Some(path) = args.get("out") {
        std::fs::write(path, &out)?;
    }
    if let Some(path) = args.get("json") {
        // Experiments render text tables; the JSON wrapper carries the
        // rendered artifact with its id so downstream tooling can archive
        // runs uniformly with `run`/`serve` reports.
        write_json(
            path,
            &Json::obj(vec![
                ("id", Json::Str(id.clone())),
                ("scale", Json::Num(ctx.scale)),
                ("seeds", Json::from_f64_slice(
                    &ctx.seeds.iter().map(|&s| s as f64).collect::<Vec<_>>(),
                )),
                ("rendered", Json::Str(out)),
            ]),
        )?;
    }
    Ok(())
}

fn cmd_check(args: &Args) -> anyhow::Result<()> {
    use hybridflow::config::simparams::FEAT_DIM;
    // `check --scenario <file>` is the static feasibility path: analyse
    // the spec against the cost model, no artifacts and no kernel run.
    if let Some(path) = args.get("scenario") {
        return cmd_check_scenario(path);
    }
    let dir = artifacts_dir(args);
    println!("artifacts dir: {}", dir.display());

    // 1. simparams drift check.
    let sp = SimParams::load(&dir)?;
    println!("simparams.json matches compiled defaults (tau0={})", sp.tau0);
    let j = hybridflow::util::json::Json::parse_file(&dir.join("simparams.json"))?;
    hybridflow::config::simparams::verify_zoo_against_json(&j)?;
    println!("model/benchmark zoo matches python mirror");

    // 2. PJRT round trip.
    let svc = RouterService::start(&dir)?;
    println!("PJRT platform: {} (edge_lm: {})", svc.platform(), svc.has_edge_lm());

    // 3. Mirror parity on random features.
    let mirror = MirrorPredictor::from_meta_file(&dir.join("router_meta.json"))?;
    let mut rng = Rng::new(42);
    let feats: Vec<[f32; FEAT_DIM]> = (0..16)
        .map(|_| {
            let mut f = [0.0f32; FEAT_DIM];
            for v in f.iter_mut() {
                *v = rng.f64() as f32;
            }
            f
        })
        .collect();
    let a = svc.score(&feats, 0.3)?;
    let b = mirror.predict(&feats, 0.3);
    let max_err = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max);
    anyhow::ensure!(max_err < 2e-3, "PJRT vs mirror divergence: {max_err}");
    println!("PJRT vs rust-mirror parity: max |delta u_hat| = {max_err:.2e} OK");

    if svc.has_edge_lm() {
        let checksum = svc.edge_burn(2)?;
        println!("edge_lm burn OK (checksum {checksum:.4})");
    }
    println!("all checks passed");
    Ok(())
}

/// `check --scenario <file.json>`: static feasibility check of a
/// scenario (or sweep) spec — queueing stability, budget caps vs
/// expected spend, cache sizing, shard-split degeneracy — estimated
/// from the profiler's cost model without executing the kernel
/// ([`hybridflow::analysis::scenario`]). Sweep grids are checked cell
/// by cell. Exits non-zero on any error-severity finding.
fn cmd_check_scenario(path: &str) -> anyhow::Result<()> {
    use hybridflow::analysis::scenario::check_spec;

    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
    let parsed = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let mut errors = 0usize;
    if SweepSpec::is_sweep_json(&parsed) {
        let sweep = SweepSpec::from_json(&parsed)?;
        let cells = sweep.cells()?;
        println!("sweep '{}': {} cell(s)", sweep.name, cells.len());
        for cell in &cells {
            let label: Vec<String> = sweep
                .axes
                .iter()
                .zip(&cell.values)
                .map(|(a, v)| format!("{}={}", a.field.render(), v))
                .collect();
            println!("--- cell [{}] ---", label.join(", "));
            let report = check_spec(&cell.spec);
            print!("{}", report.render());
            errors += report.errors();
        }
    } else {
        let spec = ScenarioSpec::from_json(&parsed)?;
        let report = check_spec(&spec);
        print!("{}", report.render());
        errors += report.errors();
    }
    anyhow::ensure!(errors == 0, "{errors} feasibility error(s) in {path}");
    Ok(())
}

/// `lint [--json] [--src <dir>]`: dependency-free determinism lint over
/// the rust source tree ([`hybridflow::analysis::lint`]). Diagnostics
/// are sorted `(file, line, rule)` and byte-stable across reruns;
/// nonzero exit on any finding.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let root = args.get_or("src", "rust/src");
    let report = hybridflow::analysis::lint::lint_tree(std::path::Path::new(root))?;
    if args.flag("json") {
        print!("{}", report.json_text());
    } else {
        print!("{}", report.render());
    }
    anyhow::ensure!(report.clean(), "{} lint finding(s)", report.diagnostics.len());
    Ok(())
}

/// `fuzz --cases N --seed S [--adversarial]`: generate N random-but-valid
/// scenario specs and run each through the kernel under the invariant
/// harness ([`hybridflow::testing::fuzz`]). Any violation prints the full
/// spec JSON plus a one-line repro command and exits non-zero.
fn cmd_fuzz(args: &Args) -> anyhow::Result<()> {
    use hybridflow::testing::fuzz::{failure_report, minimize, run_case, spec_for_case};

    let cases = args.get_usize_or("cases", 200)?;
    let base_seed = args.get_u64_or("seed", 0)?;
    let adversarial = args.flag("adversarial");
    println!(
        "fuzz: {cases} case(s) from base seed {base_seed} ({} generator)",
        if adversarial { "adversarial" } else { "valid-surface" },
    );
    // lint:allow(wall_clock): fuzz progress lines report real elapsed time
    let t0 = std::time::Instant::now();
    for case in 0..cases {
        let spec = spec_for_case(base_seed, case, adversarial);
        let violations = run_case(&spec);
        if !violations.is_empty() {
            eprintln!("{}", failure_report(&spec, base_seed, case, adversarial, &violations));
            // Shrink the offender toward defaults while it still fails,
            // so the corpus entry checks in minimized (PR 6 convention).
            let min = minimize(&spec, |s| !run_case(s).is_empty());
            if min != spec {
                eprintln!(
                    "minimized spec (still failing; check in under rust/tests/corpus/):\n{}",
                    min.render()
                );
            }
            anyhow::bail!(
                "invariant violation at case {case} (seed {base_seed}): {}",
                violations[0]
            );
        }
        if (case + 1) % 50 == 0 {
            println!("  {} / {cases} cases clean", case + 1);
        }
    }
    println!(
        "fuzz: {cases} case(s) clean in {:.1}s (every spec built, ran twice \
         byte-identically, and held all kernel invariants)",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().skip(1).map(String::from))
    }

    #[test]
    fn known_options_pass_validation() {
        let a = parse("hybridflow run --n 10 --seed 3 --cache 64 --cache-policy ttl:30 --hedge --hedge-threshold 0.6");
        assert!(validate_command_args("run", &a).is_ok());
        let a = parse("hybridflow serve --n 100 --workers 8 --metrics");
        assert!(validate_command_args("serve", &a).is_ok());
        let a = parse("hybridflow run --scenario scenarios/fleet_sim.json");
        assert!(validate_command_args("run", &a).is_ok());
        // Predictor-selection options compose with a scenario file.
        let a = parse("hybridflow run --scenario s.json --artifacts ./artifacts --pjrt");
        assert!(validate_command_args("run", &a).is_ok());
        let a = parse("hybridflow fuzz --cases 32 --seed 7 --adversarial");
        assert!(validate_command_args("fuzz", &a).is_ok());
        // --shards composes with a scenario file (it is an override, not
        // a competing run definition).
        let a = parse("hybridflow run --scenario scenarios/fleet_sharded.json --shards 4");
        assert!(validate_command_args("run", &a).is_ok());
        // --cases is typed: a malformed count fails fast, not mid-fuzz.
        let a = parse("hybridflow fuzz --cases lots");
        assert!(validate_command_args("fuzz", &a).is_err());
    }

    #[test]
    fn lint_and_check_scenario_options_validate() {
        // `lint --json` is an output mode, not a file path.
        let a = parse("hybridflow lint --json");
        assert!(validate_command_args("lint", &a).is_ok());
        let a = parse("hybridflow lint --src rust/tests/lint_fixtures/clean");
        assert!(validate_command_args("lint", &a).is_ok());
        // A bare `--src` forgot its directory path.
        let a = parse("hybridflow lint --src");
        assert!(validate_command_args("lint", &a).is_err());
        // The lint has no scenario surface.
        let a = parse("hybridflow lint --scenario s.json");
        assert!(validate_command_args("lint", &a).is_err());
        // `check --scenario` is the static feasibility path.
        let a = parse("hybridflow check --scenario scenarios/fleet_sim.json");
        assert!(validate_command_args("check", &a).is_ok());
    }

    #[test]
    fn json_out_is_accepted_everywhere_it_is_documented() {
        // `--json <path>` dumps the machine-readable report; it composes
        // with a scenario file (it describes the *output*, not the run,
        // so it is not a SCENARIO_CONFLICTS member).
        for cmd_line in [
            "hybridflow run --n 5 --json out.json",
            "hybridflow run --scenario scenarios/fleet_sim.json --json out.json",
            "hybridflow run --scenario scenarios/fleet_cache_sweep.json --json out.json",
            "hybridflow serve --n 10 --json out.json",
            "hybridflow exp --id fleet_serve --json out.json",
        ] {
            let a = parse(cmd_line);
            let cmd = cmd_line.split_whitespace().nth(1).unwrap();
            assert!(validate_command_args(cmd, &a).is_ok(), "{cmd_line}");
        }
        // Commands that produce no report reject it like any unknown flag.
        let a = parse("hybridflow plan --json out.json");
        assert!(validate_command_args("plan", &a).is_err());
    }

    #[test]
    fn scenario_rejects_conflicting_engine_flags() {
        // A spec defines seed/workload/engine; co-passing those options
        // must error instead of being silently ignored.
        for flags in ["--seed 42", "--hedge", "--cache 64", "--n 10", "--benchmark gpqa"] {
            let a = parse(&format!("hybridflow run --scenario s.json {flags}"));
            let err = validate_command_args("run", &a).unwrap_err().to_string();
            assert!(err.contains("--scenario defines the whole run"), "{flags}: {err}");
        }
    }

    #[test]
    fn unknown_options_are_rejected() {
        let a = parse("hybridflow run --bogus 1");
        let err = validate_command_args("run", &a).unwrap_err().to_string();
        assert!(err.contains("unknown option --bogus"), "{err}");
        // Flags count too.
        let a = parse("hybridflow serve --turbo");
        assert!(validate_command_args("serve", &a).is_err());
        // Options valid for one command are not silently accepted by another.
        let a = parse("hybridflow plan --workers 8");
        assert!(validate_command_args("plan", &a).is_err());
    }

    #[test]
    fn malformed_values_are_rejected() {
        let a = parse("hybridflow run --cache-policy ttl:abc");
        let err = validate_command_args("run", &a).unwrap_err().to_string();
        assert!(err.contains("cache policy"), "{err}");
        let a = parse("hybridflow run --hedge --hedge-threshold=-0.5");
        assert!(validate_command_args("run", &a).is_err(), "negative threshold");
        let a = parse("hybridflow run --hedge-threshold nan");
        assert!(validate_command_args("run", &a).is_err(), "non-finite threshold");
        let a = parse("hybridflow run --n twelve");
        assert!(validate_command_args("run", &a).is_err(), "non-integer n");
        let a = parse("hybridflow serve --workers -3");
        assert!(validate_command_args("serve", &a).is_err(), "negative workers");
    }

    #[test]
    fn shards_override_is_validated() {
        // Zero shards is meaningless; fractional and negative counts fail
        // the usize parse.
        for bad in ["0", "2.5", "-1", "four"] {
            let a = parse(&format!("hybridflow run --scenario s.json --shards {bad}"));
            assert!(validate_command_args("run", &a).is_err(), "--shards {bad}");
        }
        // The override needs a scenario to override.
        let a = parse("hybridflow run --n 5 --shards 2");
        let err = validate_command_args("run", &a).unwrap_err().to_string();
        assert!(err.contains("--scenario"), "{err}");
    }

    #[test]
    fn observability_exports_are_validated() {
        // The happy path: exports + cadence + thread budget on a scenario.
        let a = parse(
            "hybridflow run --scenario scenarios/fleet_sharded.json --trace-out t.json \
             --metrics-out m.jsonl --metrics-interval 0.5 --threads 4",
        );
        assert!(validate_command_args("run", &a).is_ok());
        // A bare `--trace-out` / `--metrics-out` forgot its file path.
        for flag in ["--trace-out", "--metrics-out"] {
            let a = parse(&format!("hybridflow run --scenario s.json {flag}"));
            let err = validate_command_args("run", &a).unwrap_err().to_string();
            assert!(err.contains("file path"), "{flag}: {err}");
        }
        // The exports configure a scenario run; plain `run` has no spans.
        for opt in
            ["--trace-out t.json", "--metrics-out m.jsonl", "--metrics-interval 2", "--threads 2"]
        {
            let a = parse(&format!("hybridflow run --n 5 {opt}"));
            let err = validate_command_args("run", &a).unwrap_err().to_string();
            assert!(err.contains("--scenario"), "{opt}: {err}");
        }
        // The snapshot cadence must be a positive finite virtual-second gap.
        for bad in ["0", "-1", "nan", "inf"] {
            let a = parse(&format!(
                "hybridflow run --scenario s.json --metrics-out m.jsonl --metrics-interval {bad}"
            ));
            assert!(validate_command_args("run", &a).is_err(), "--metrics-interval {bad}");
        }
        // Zero threads cannot run anything.
        let a = parse("hybridflow run --scenario s.json --threads 0");
        assert!(validate_command_args("run", &a).is_err(), "--threads 0");
        // Commands without a scenario path reject the exports outright.
        let a = parse("hybridflow serve --n 10 --metrics-out m.jsonl");
        assert!(validate_command_args("serve", &a).is_err());
        let a = parse("hybridflow plan --trace-out t.json");
        assert!(validate_command_args("plan", &a).is_err());
    }

    #[test]
    fn fault_seed_override_is_validated() {
        // The happy path: reseed a scenario's fault streams.
        let a = parse("hybridflow run --scenario scenarios/fleet_faulty.json --fault-seed 9");
        assert!(validate_command_args("run", &a).is_ok());
        // Typed: a malformed seed fails fast.
        for bad in ["-1", "2.5", "lots"] {
            let a = parse(&format!("hybridflow run --scenario s.json --fault-seed {bad}"));
            assert!(validate_command_args("run", &a).is_err(), "--fault-seed {bad}");
        }
        // The override configures a scenario run; plain `run` has no
        // faults block to reseed.
        let a = parse("hybridflow run --n 5 --fault-seed 9");
        let err = validate_command_args("run", &a).unwrap_err().to_string();
        assert!(err.contains("--scenario"), "{err}");
        // Commands without a scenario surface reject it like any unknown
        // option.
        for cmd in ["serve", "plan", "check", "fuzz"] {
            let a = parse(&format!("hybridflow {cmd} --fault-seed 9"));
            assert!(validate_command_args(cmd, &a).is_err(), "{cmd}");
        }
    }

    #[test]
    fn every_command_has_an_allowlist() {
        for (cmd, _) in COMMANDS {
            assert!(!allowed_options(cmd).is_empty(), "{cmd} has no allowlist");
        }
    }
}

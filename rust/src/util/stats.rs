//! Streaming and batch statistics used throughout metrics and benches:
//! Welford mean/variance, percentiles, and `mean ± std` formatting that
//! matches the paper's tables.

/// Streaming mean/variance accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance (sigma^2, divide by n).
    pub fn var_pop(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (divide by n-1).
    pub fn var_sample(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_pop(&self) -> f64 {
        self.var_pop().sqrt()
    }

    pub fn std_sample(&self) -> f64 {
        self.var_sample().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        self.std_sample() / (self.n as f64).sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
    }
}

/// Batch summary of a sample.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
            };
        }
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        // total_cmp: a stray NaN sample must degrade the affected
        // quantiles, not abort the whole report (NaN sorts last).
        let mut sorted = xs.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std_pop(),
            min: sorted[0],
            max: sorted[sorted.len() - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }

    /// [`Summary::of`], except an empty sample reports zeros instead of
    /// NaN (`n == 0` still marks it empty) — for reports that render the
    /// raw values (empty fleets / serve runs must not print NaN).
    pub fn of_or_zero(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p90: 0.0,
                p95: 0.0,
                p99: 0.0,
            }
        } else {
            Summary::of(xs)
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice; p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_pop(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Paper-style "12.34±0.56" with given decimals.
pub fn fmt_mean_std(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.decimals$}\u{b1}{std:.decimals$}")
}

/// Pearson correlation of two equal-length slices.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    num / (dx.sqrt() * dy.sqrt() + 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_pop() - std_pop(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 6);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let xs: Vec<f64> = (0..50).map(|i| (i as f64).sin() * 10.0).collect();
        let (a, b) = xs.split_at(20);
        let mut wa = Welford::new();
        let mut wb = Welford::new();
        for &x in a {
            wa.push(x);
        }
        for &x in b {
            wb.push(x);
        }
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        wa.merge(&wb);
        assert!((wa.mean() - whole.mean()).abs() < 1e-10);
        assert!((wa.var_pop() - whole.var_pop()).abs() < 1e-10);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.n, 3);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.p50 - 2.0).abs() < 1e-12);
        assert!(s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        let empty = Summary::of(&[]);
        assert!(empty.mean.is_nan());
        assert!(empty.p95.is_nan());
    }

    #[test]
    fn of_or_zero_zeros_empty_and_matches_of_otherwise() {
        let empty = Summary::of_or_zero(&[]);
        assert_eq!(empty.n, 0);
        assert_eq!(empty.mean, 0.0);
        assert_eq!(empty.p50, 0.0);
        assert_eq!(empty.p99, 0.0);
        let xs = [3.0, 1.0, 2.0];
        let a = Summary::of(&xs);
        let b = Summary::of_or_zero(&xs);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.p95, b.p95);
        assert_eq!(a.n, b.n);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // Regression: `partial_cmp().unwrap()` used to panic here, taking
        // the whole fleet report down with one corrupt latency sample.
        // total_cmp sorts the NaN last, so finite quantiles stay usable.
        let s = Summary::of(&[1.0, f64::NAN, 2.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN sorts above every finite value");
        assert!((s.p50 - 2.0).abs() < 1e-12);
        assert!((percentile(&[f64::NAN, 3.0], 0.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_mean_std(53.333, 2.031, 2), "53.33\u{b1}2.03");
    }

    #[test]
    fn pearson_signs() {
        let xs: Vec<f64> = (0..40).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-9);
        let yneg: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-9);
    }
}

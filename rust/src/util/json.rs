//! Minimal JSON value model, parser, and writer.
//!
//! `serde`/`serde_json` are unavailable in this offline environment, so the
//! project carries its own JSON substrate. It supports the full JSON grammar
//! (RFC 8259) minus exotic number edge cases, preserves object insertion
//! order, and round-trips everything the repo produces
//! (`artifacts/*.json`, experiment results, traces, configs).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep sorted key order (BTreeMap) for deterministic output.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors (all non-panicking).
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// Strict integer accessor: `Some` only for finite numbers with no
    /// fractional part inside the exactly-representable f64 range
    /// (|n| <= 2^53). Unlike [`Json::as_i64`]/[`Json::as_usize`], which
    /// truncate (`2.7` reads as `2`), this rejects fractional and
    /// non-finite values — the accessor spec fields must use so that
    /// `"edge_workers": 2.7` is a schema error, not a different run.
    pub fn as_integer(&self) -> Option<i64> {
        match self.as_f64() {
            Some(n) if n.is_finite() && n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0 => {
                Some(n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` for missing keys on objects, `None`
    /// for non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj["a"]["b"][2]`-style path access: `value.path(&["a", "b", "2"])`.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = match cur {
                Json::Obj(o) => o.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Convenience: f64 array field.
    pub fn f64_array(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_f64).collect::<Vec<_>>())
            .filter(|v: &Vec<f64>| v.len() == self.as_arr().map_or(0, |a| a.len()))
    }

    // ------------------------------------------------------------------
    // Construction helpers.
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64_slice(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32_slice(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ------------------------------------------------------------------
    // Serialization.
    // ------------------------------------------------------------------

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty encoding with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // ------------------------------------------------------------------
    // Parsing.
    // ------------------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }

    /// Parse the contents of a file.
    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant writers.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle UTF-16 surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate pair"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad unicode escape"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { msg: format!("bad number '{text}'"), offset: start })
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["a", "2", "b"]), Some(&Json::Null));
        assert_eq!(v.get("c").and_then(Json::as_str), Some("x"));
        assert_eq!(v.path(&["a", "1"]).and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ \u{e9} \u{1F600}");
        // Raw multi-byte UTF-8 passes through.
        let v = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "caf\u{e9}");
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v \"q\"","n":null},"t":true}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn write_num_integer_form() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn f64_array_helper() {
        let v = Json::parse("[1, 2, 3.5]").unwrap();
        assert_eq!(v.f64_array().unwrap(), vec![1.0, 2.0, 3.5]);
        let bad = Json::parse("[1, \"x\"]").unwrap();
        assert!(bad.f64_array().is_none());
    }

    #[test]
    fn as_integer_is_strict() {
        assert_eq!(Json::Num(5.0).as_integer(), Some(5));
        assert_eq!(Json::Num(-3.0).as_integer(), Some(-3));
        assert_eq!(Json::Num(0.0).as_integer(), Some(0));
        // Fractional values truncate under as_i64/as_usize but must be
        // rejected by the strict accessor (regression: silent `2.7` -> 2).
        assert_eq!(Json::Num(2.7).as_i64(), Some(2));
        assert_eq!(Json::Num(2.7).as_integer(), None);
        assert_eq!(Json::Num(f64::NAN).as_integer(), None);
        assert_eq!(Json::Num(f64::INFINITY).as_integer(), None);
        // Beyond 2^53 integers are no longer exactly representable.
        assert_eq!(Json::Num(1e16).as_integer(), None);
        assert_eq!(Json::Str("5".into()).as_integer(), None);
    }

    #[test]
    fn builder_helpers() {
        let o = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("z".into()))]);
        assert_eq!(o.get("x").and_then(Json::as_i64), Some(1));
        assert_eq!(Json::from_f32_slice(&[1.0, 2.0]).f64_array().unwrap(), vec![1.0, 2.0]);
    }
}

//! Deterministic PRNG + distributions (the `rand` crate family is not
//! available offline).
//!
//! Core generator is xoshiro256++ seeded through SplitMix64 — fast, small,
//! and adequate for simulation workloads. Distributions cover everything the
//! HybridFlow substrate samples: uniform, normal (Box–Muller), lognormal,
//! exponential, Beta (via Marsaglia–Tsang Gamma), Bernoulli, categorical,
//! integer ranges, choice/shuffle.
//!
//! All experiment code takes an explicit seed so every table/figure is
//! exactly reproducible.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the last Box–Muller draw.
    spare_normal: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97f4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; any u64 seed is fine (SplitMix64 whitens it).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-query / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97f4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n64 = n as u64;
        // Rejection sampling on the biased tail to keep exact uniformity.
        let zone = u64::MAX - (u64::MAX % n64);
        loop {
            let x = self.next_u64();
            if x < zone {
                return (x % n64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi) (like `rng.integers` in numpy).
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty int_range");
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller with caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mu, sigma).
    pub fn normal_ms(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Lognormal: exp(Normal(mu, sigma)) — numpy's parameterization.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang; valid for all k > 0.
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0, "gamma shape must be positive");
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k).
            let g = self.gamma(k + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Beta(a, b) in (0, 1).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Uniformly pick one element.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample k distinct indices from 0..n (k <= n), sorted ascending.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut v = self.permutation(n);
        v.truncate(k);
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_std(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
        (m, var.sqrt())
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..20000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (m, s) = mean_std(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
        assert!((s - (1.0f64 / 12.0).sqrt()).abs() < 0.01, "std {s}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..30000).map(|_| r.normal()).collect();
        let (m, s) = mean_std(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((s - 1.0).abs() < 0.02, "std {s}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(4);
        let mu = 4.2;
        let mut xs: Vec<f64> = (0..20000).map(|_| r.lognormal(mu, 0.4)).collect();
        xs.sort_by(f64::total_cmp);
        let median = xs[xs.len() / 2];
        assert!((median.ln() - mu).abs() < 0.03, "median ln {}", median.ln());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..30000).map(|_| r.exponential(2.0)).collect();
        let (m, _) = mean_std(&xs);
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn gamma_mean_var() {
        let mut r = Rng::new(6);
        for &k in &[0.5, 1.0, 2.0, 7.5] {
            let xs: Vec<f64> = (0..30000).map(|_| r.gamma(k)).collect();
            let (m, s) = mean_std(&xs);
            assert!((m - k).abs() < 0.12 * k.max(1.0), "k={k} mean {m}");
            assert!((s * s - k).abs() < 0.25 * k.max(1.0), "k={k} var {}", s * s);
        }
    }

    #[test]
    fn beta_moments() {
        let mut r = Rng::new(7);
        let (a, b) = (2.0, 2.6);
        let xs: Vec<f64> = (0..30000).map(|_| r.beta(a, b)).collect();
        assert!(xs.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let (m, _) = mean_std(&xs);
        let expect = a / (a + b);
        assert!((m - expect).abs() < 0.01, "mean {m} expect {expect}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(8);
        let hits = (0..20000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 20000.0;
        assert!((rate - 0.3).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30000 {
            counts[r.categorical(&w)] += 1;
        }
        let total: usize = counts.iter().sum();
        for i in 0..3 {
            let got = counts[i] as f64 / total as f64;
            let want = w[i] / 10.0;
            assert!((got - want).abs() < 0.02, "i={i} got {got} want {want}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(10);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let s = r.sample_indices(10, 4);
            assert_eq!(s.len(), 4);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 10));
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(12);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}

//! Substrate utilities hand-rolled for the offline environment:
//! JSON, PRNG + distributions, CLI parsing, thread pool, statistics.

pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

//! Fixed-size worker thread pool (`tokio` is not available offline).
//!
//! The scheduler uses this for *real* concurrent subtask dispatch (edge-LM
//! PJRT forwards, cloud-call simulation) while the virtual clock handles
//! latency accounting. Also provides `parallel_map` for data-parallel
//! experiment sweeps.
//!
//! Design notes:
//! * Work items are boxed `FnOnce` closures over an `mpsc` channel guarded
//!   by a mutex (multi-consumer).
//! * Panics in jobs are caught and surfaced to the submitter instead of
//!   poisoning the pool.
//! * `Drop` joins all workers, so pools are safe to create per-scope.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Message {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    tx: Sender<Message>,
    shared_rx: Arc<Mutex<Receiver<Message>>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Message>();
        let shared_rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&shared_rx);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("hybridflow-worker-{i}"))
                    .spawn(move || loop {
                        let msg = {
                            let guard = rx.lock().expect("pool receiver poisoned");
                            guard.recv()
                        };
                        match msg {
                            Ok(Message::Run(job)) => {
                                // Job panics are contained per-job; results
                                // channels observe them as disconnects.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Message::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx, shared_rx, workers }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ThreadPool::new(n)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget execution.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Message::Run(Box::new(f))).expect("pool closed");
    }

    /// Submit a job returning a value; the handle's `join` blocks for it.
    pub fn submit<T, F>(&self, f: F) -> TaskHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        self.execute(move || {
            // Receiver may be dropped; ignore send failure.
            let _ = tx.send(f());
        });
        TaskHandle { rx }
    }

    /// Apply `f` to every item on the pool, preserving input order.
    pub fn map<T, U, F>(&self, items: Vec<T>, f: F) -> Vec<U>
    where
        T: Send + 'static,
        U: Send + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<TaskHandle<U>> = items
            .into_iter()
            .map(|item| {
                let f = Arc::clone(&f);
                self.submit(move || f(item))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("pool job panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Message::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Drain any remaining messages so senders don't block (bounded use).
        if let Ok(rx) = self.shared_rx.lock() {
            while rx.try_recv().is_ok() {}
        }
    }
}

/// Handle to a submitted job's result.
pub struct TaskHandle<T> {
    rx: Receiver<T>,
}

impl<T> TaskHandle<T> {
    /// Block until the job completes. `None` if the job panicked.
    pub fn join(self) -> Option<T> {
        self.rx.recv().ok()
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

/// One-off convenience: parallel map on a temporary pool.
pub fn parallel_map<T, U, F>(threads: usize, items: Vec<T>, f: F) -> Vec<U>
where
    T: Send + 'static,
    U: Send + 'static,
    F: Fn(T) -> U + Send + Sync + 'static,
{
    ThreadPool::new(threads).map(items, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_values() {
        let pool = ThreadPool::new(2);
        let h1 = pool.submit(|| 21 * 2);
        let h2 = pool.submit(|| "ok".to_string());
        assert_eq!(h1.join(), Some(42));
        assert_eq!(h2.join(), Some("ok".to_string()));
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let items: Vec<usize> = (0..200).collect();
        let out = pool.map(items, |i| i * i);
        assert_eq!(out, (0..200).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_runs_concurrently() {
        // With 4 workers, 8 sleeps of 30ms should take ~60ms, not ~240ms.
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        pool.map((0..8).collect::<Vec<_>>(), |_| {
            std::thread::sleep(Duration::from_millis(30));
        });
        let elapsed = t0.elapsed();
        assert!(elapsed < Duration::from_millis(200), "elapsed {elapsed:?}");
    }

    #[test]
    fn panicking_job_does_not_kill_pool() {
        let pool = ThreadPool::new(2);
        let bad = pool.submit(|| panic!("boom"));
        assert_eq!(bad.join(), None::<()>);
        let good = pool.submit(|| 7);
        assert_eq!(good.join(), Some(7));
    }

    #[test]
    fn parallel_map_helper() {
        let out = parallel_map(3, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn try_join_polls() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(|| {
            std::thread::sleep(Duration::from_millis(50));
            5
        });
        assert_eq!(h.try_join(), None);
        std::thread::sleep(Duration::from_millis(120));
        assert_eq!(h.try_join(), Some(5));
    }
}

//! Tiny command-line argument parser (`clap` is not available offline).
//!
//! Supports the shapes the `hybridflow` binary and examples need:
//! `prog <subcommand> [--key value] [--flag] [positional...]`,
//! with typed accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, named options, boolean flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    ///
    /// Rules: the first non-dashed token becomes the subcommand; `--key value`
    /// fills an option unless the next token is also dashed (then `--key` is
    /// a flag); `--key=value` is supported; remaining non-dashed tokens are
    /// positionals.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let tokens: Vec<String> = args.into_iter().collect();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    out.opts.insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("--{name} expects a number, got '{v}'")
            })?)),
        }
    }

    pub fn get_f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        Ok(self.get_f64(name)?.unwrap_or(default))
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().map_err(|_| {
                anyhow::anyhow!("--{name} expects a non-negative integer, got '{v}'")
            })?)),
        }
    }

    pub fn get_usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.get_usize(name)?.unwrap_or(default))
    }

    pub fn get_u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// All option keys seen (for unknown-option validation).
    pub fn option_keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(String::as_str).chain(self.flags.iter().map(String::as_str))
    }

    /// Error if any provided option/flag is not in `allowed`.
    pub fn validate_known(&self, allowed: &[&str]) -> anyhow::Result<()> {
        for k in self.option_keys() {
            if !allowed.contains(&k) {
                anyhow::bail!("unknown option --{k} (allowed: {})", allowed.join(", "));
            }
        }
        Ok(())
    }
}

/// Render a consistent usage/help block.
pub fn usage(prog: &str, subcommands: &[(&str, &str)]) -> String {
    let mut s = format!("usage: {prog} <command> [options]\n\ncommands:\n");
    for (name, desc) in subcommands {
        s.push_str(&format!("  {name:<18} {desc}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --workers 8 --benchmark gpqa --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("benchmark"), Some("gpqa"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("exp --id=table1 --seeds=3");
        assert_eq!(a.get("id"), Some("table1"));
        assert_eq!(a.get_usize("seeds").unwrap(), Some(3));
    }

    #[test]
    fn positionals() {
        let a = parse("run query1 query2 --tau 0.5");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["query1", "query2"]);
        assert_eq!(a.get_f64("tau").unwrap(), Some(0.5));
    }

    #[test]
    fn typed_errors() {
        let a = parse("x --n abc");
        assert!(a.get_usize("n").is_err());
        assert!(a.get_f64("n").is_err());
        assert_eq!(a.get_f64_or("missing", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("cmd --a --b val");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }

    #[test]
    fn validate_known_rejects() {
        let a = parse("cmd --good 1 --bad 2");
        assert!(a.validate_known(&["good"]).is_err());
        assert!(a.validate_known(&["good", "bad"]).is_ok());
    }

    #[test]
    fn usage_renders() {
        let u = usage("hybridflow", &[("serve", "run the server"), ("exp", "experiments")]);
        assert!(u.contains("serve"));
        assert!(u.contains("experiments"));
    }
}

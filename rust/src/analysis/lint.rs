//! Determinism lint driver: walk a source tree, lex each file, run the
//! rule set, apply `lint:allow` suppressions, and emit a byte-stable
//! report (text or JSON) sorted `(file, line, rule)`.
//!
//! `hybridflow lint [--json] [--src <dir>]` is the CLI surface; the
//! committed tree is pinned clean by `rust/tests/analysis.rs`, and
//! `scripts/verify.sh` additionally asserts that the seeded-bad fixture
//! corpus still draws a nonzero exit.

use crate::analysis::lexer::{lex, Tok, TokKind};
use crate::analysis::rules::{known_rule, run_rules, Diagnostic};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// A full lint pass over one tree.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Findings, sorted `(file, line, rule)`.
    pub diagnostics: Vec<Diagnostic>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Human-readable listing; one `file:line: [rule] message` row per
    /// finding plus a trailing summary. Deterministic.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&format!("{}:{}: [{}] {}\n", d.file, d.line, d.rule, d.message));
        }
        s.push_str(&format!(
            "lint: {} finding(s) across {} file(s)\n",
            self.diagnostics.len(),
            self.files
        ));
        s
    }

    /// Canonical JSON (sorted keys via `util::json`); byte-identical
    /// across reruns on the same tree.
    pub fn to_json(&self) -> Json {
        let findings = self
            .diagnostics
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("file", Json::Str(d.file.clone())),
                    ("line", Json::Num(d.line as f64)),
                    ("message", Json::Str(d.message.clone())),
                    ("rule", Json::Str(d.rule.to_string())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("files", Json::Num(self.files as f64)),
            ("findings", Json::Arr(findings)),
        ])
    }

    /// Rendered JSON with a trailing newline (the `--json` stdout form).
    pub fn json_text(&self) -> String {
        let mut t = self.to_json().to_string_pretty();
        t.push('\n');
        t
    }
}

/// Lint one file's source text. `file` is the display path used in
/// diagnostics (forward slashes; also drives path-based exemptions).
pub fn lint_source(file: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let regions = test_regions(&lexed.tokens);
    let in_test = |line: usize| regions.iter().any(|&(a, b)| a <= line && line <= b);
    let mut diags = run_rules(file, &lexed.tokens, &in_test);

    // Validate directives: a suppression must name a known rule and
    // carry a `: reason` justification, else it is itself a finding.
    for a in &lexed.allows {
        if !known_rule(&a.rule) {
            diags.push(Diagnostic {
                file: file.to_string(),
                line: a.line,
                rule: "bad_allow",
                message: format!("lint:allow names unknown rule '{}'", a.rule),
            });
        } else if a.reason.is_empty() {
            diags.push(Diagnostic {
                file: file.to_string(),
                line: a.line,
                rule: "bad_allow",
                message: format!("lint:allow({}) has no ': reason' justification", a.rule),
            });
        }
    }

    // Apply suppressions: a justified allow on line L covers findings of
    // its rule on L (trailing comment) and L+1 (preceding line).
    diags.retain(|d| {
        d.rule == "bad_allow"
            || !lexed.allows.iter().any(|a| {
                a.rule == d.rule
                    && !a.reason.is_empty()
                    && (a.line == d.line || a.line + 1 == d.line)
            })
    });
    diags.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    diags
}

/// Lint every `.rs` file under `root` (recursive, sorted traversal).
pub fn lint_tree(root: &Path) -> anyhow::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut diagnostics = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let name = slash_path(path);
        diagnostics.extend(lint_source(&name, &src));
    }
    diagnostics.sort_by(|a, b| {
        a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
    Ok(LintReport { files: files.len(), diagnostics })
}

fn slash_path(p: &Path) -> String {
    p.to_string_lossy().replace('\\', "/")
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("lint root {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for e in entries {
        let entry = e.map_err(|e| anyhow::anyhow!("read entry under {}: {e}", dir.display()))?;
        paths.push(entry.path());
    }
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Line ranges (inclusive) of `#[cfg(test)]`-gated items: from the
/// attribute line to the close of the item's brace block (or its `;`
/// for braceless items). The repo convention is `#[cfg(test)] mod
/// tests { .. }`, but gated fns/uses are handled too.
fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let is_p = |t: &Tok, s: &str| t.kind == TokKind::Punct && t.text == s;
    let is_id = |t: &Tok, s: &str| t.kind == TokKind::Ident && t.text == s;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let gate = is_p(&toks[i], "#")
            && is_p(&toks[i + 1], "[")
            && is_id(&toks[i + 2], "cfg")
            && is_p(&toks[i + 3], "(")
            && is_id(&toks[i + 4], "test")
            && is_p(&toks[i + 5], ")")
            && is_p(&toks[i + 6], "]");
        if !gate {
            i += 1;
            continue;
        }
        let start_line = toks[i].line;
        let mut j = i + 7;
        let mut depth = 0usize;
        let mut entered = false;
        let mut end_line = toks.last().map(|t| t.line).unwrap_or(start_line);
        while j < toks.len() {
            let t = &toks[j];
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" => {
                        depth += 1;
                        entered = true;
                    }
                    "}" => {
                        depth = depth.saturating_sub(1);
                        if entered && depth == 0 {
                            end_line = t.line;
                            break;
                        }
                    }
                    ";" if !entered && depth == 0 => {
                        end_line = t.line;
                        break;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        out.push((start_line, end_line));
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_suppresses_same_and_next_line() {
        let src = "\
// lint:allow(wall_clock): harness measures real time on purpose
let t0 = std::time::Instant::now();
let t1 = std::time::Instant::now(); // lint:allow(wall_clock): ditto
";
        assert!(lint_source("rust/src/eval/mod.rs", src).is_empty());
    }

    #[test]
    fn unjustified_or_unknown_allow_is_a_finding() {
        let src = "\
// lint:allow(wall_clock)
let t0 = std::time::Instant::now();
// lint:allow(no_such_rule): because
let x = 1;
";
        let d = lint_source("rust/src/eval/mod.rs", src);
        let rules: Vec<_> = d.iter().map(|x| x.rule).collect();
        // The reasonless allow does not suppress, so the wall_clock
        // finding survives alongside both bad_allow findings.
        assert_eq!(rules, ["bad_allow", "wall_clock", "bad_allow"]);
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "\
pub fn lib_code() {}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn t() {
        let _m: HashMap<u32, u32> = HashMap::new();
        let _t = std::time::Instant::now();
    }
}
";
        assert!(lint_source("rust/src/eval/mod.rs", src).is_empty());
    }

    #[test]
    fn gated_use_without_braces_is_bounded_by_semicolon() {
        let src = "\
#[cfg(test)]
use std::collections::HashMap;

pub fn lib_code() -> std::collections::HashMap<u32, u32> {
    std::collections::HashMap::new()
}
";
        let d = lint_source("rust/src/eval/mod.rs", src);
        // The gated `use` is exempt; the two real mentions flag.
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.rule == "hash_collection"));
    }
}

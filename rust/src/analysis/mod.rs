//! Static analysis: the determinism contract, enforced before anything
//! runs.
//!
//! Two dependency-free passes back the repo's reproducibility story:
//!
//! * [`lint`] — a token-level determinism lint over the source tree
//!   (`hybridflow lint`). A small Rust lexer ([`lexer`]) feeds pattern
//!   rules ([`rules`]) that ban the hazard classes which have actually
//!   bitten this codebase: `partial_cmp().unwrap()` NaN panics, hash-map
//!   iteration feeding rendered output, wall clocks and ad-hoc threads
//!   inside the virtual-time kernel, prints from library code, and
//!   silent float→int casts in kernel hot paths. Suppressions must be
//!   justified in-line (`// lint:allow(rule): reason`).
//! * [`scenario`] — a static feasibility checker for scenario specs
//!   (`hybridflow check --scenario`): queueing stability, budget
//!   feasibility, cache sizing, and shard-split degeneracy, estimated
//!   from the profiler's cost model without executing the kernel.
//!
//! Both passes emit byte-stable, sorted diagnostics, and both are wired
//! into `scripts/verify.sh` and the fuzz harness.

pub mod lexer;
pub mod lint;
pub mod rules;
pub mod scenario;

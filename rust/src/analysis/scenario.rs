//! Static feasibility checker for [`ScenarioSpec`]: queueing stability,
//! budget feasibility, cache sizing, and shard-split degeneracy —
//! computed *without executing the kernel*, by probing the same cost
//! model the offline profiler uses ([`crate::workload::profiling`]).
//!
//! `hybridflow check --scenario <file.json>` is the CLI surface (sweep
//! files are checked cell by cell). The checker is coherent with
//! [`ScenarioSpec::validate`]: it never panics on any spec, reports
//! validation failures as findings, and a spec that checks without
//! errors is guaranteed to `build()` (pinned by the fuzz harness).

use crate::engine::Backend;
use crate::fault::FaultModel;
use crate::models::SimExecutor;
use crate::planner::{synthetic::SyntheticPlanner, Planner};
use crate::scenario::{PolicySpec, ScenarioSpec};
use crate::util::rng::Rng;
use crate::workload::trace::ArrivalProcess;
use crate::workload::{generate_queries, sample_latents};

/// Queries probed through the planner/cost model per spec. Small and
/// fixed: the probe is a mean-service estimate, not a simulation.
pub const PROBE_QUERIES: usize = 16;

/// Offered-load ratio above which a side is called near-saturated.
pub const RHO_WARN: f64 = 0.9;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One checker finding. `code` groups findings by diagnostic family
/// (`validate`, `stability`, `budget`, `cache`, `shard_split`, `load`,
/// `fault_outage_total`, `fault_load`, `fault_timeout`).
#[derive(Debug, Clone)]
pub struct Finding {
    pub severity: Severity,
    pub code: &'static str,
    pub message: String,
}

/// The probe's aggregate cost estimates for one spec.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadEstimate {
    /// Long-run arrival rate (queries per virtual second; infinite for
    /// zero-gap bursts).
    pub lambda: f64,
    /// Expected per-query service seconds if every subtask ran on edge.
    pub edge_service: f64,
    /// Expected per-query service seconds if every subtask ran on cloud.
    pub cloud_service: f64,
    /// Expected per-query dollars if every subtask ran on cloud.
    pub cloud_dollars: f64,
    /// Mean subtasks per query under the planner's decomposition.
    pub mean_subtasks: f64,
    /// Offered load with all traffic on edge / on cloud workers.
    pub rho_edge: f64,
    pub rho_cloud: f64,
    /// Offered load under the best service-proportional split across
    /// both pools — the stability bound no router can beat.
    pub rho_split: f64,
}

/// Checker output: findings plus the load estimate they derive from.
#[derive(Debug, Clone, Default)]
pub struct CheckReport {
    pub name: String,
    pub findings: Vec<Finding>,
    pub load: LoadEstimate,
}

impl CheckReport {
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// True when the spec is feasible (warnings allowed, errors not).
    pub fn passed(&self) -> bool {
        self.errors() == 0
    }

    /// Deterministic text listing (the CLI output).
    pub fn render(&self) -> String {
        let mut s = format!("feasibility: {}\n", self.name);
        let l = &self.load;
        s.push_str(&format!(
            "  load: lambda={:.4}/s  edge={:.3}s/q  cloud={:.3}s/q  cloud-$={:.5}/q  \
             subtasks={:.2}\n",
            l.lambda,
            l.edge_service,
            l.cloud_service,
            l.cloud_dollars,
            l.mean_subtasks,
        ));
        s.push_str(&format!(
            "  rho: all-edge={:.3}  all-cloud={:.3}  best-split={:.3}\n",
            l.rho_edge, l.rho_cloud, l.rho_split,
        ));
        for f in &self.findings {
            s.push_str(&format!("  [{}] {}: {}\n", f.severity.label(), f.code, f.message));
        }
        s.push_str(&format!(
            "  result: {} error(s), {} warning(s)\n",
            self.errors(),
            self.warnings()
        ));
        s
    }
}

/// Run every static check against one spec. Never panics: an invalid
/// spec comes back as a single `validate` error finding.
pub fn check_spec(spec: &ScenarioSpec) -> CheckReport {
    let mut report = CheckReport { name: spec.name.clone(), ..CheckReport::default() };
    if let Err(e) = spec.validate() {
        report.findings.push(Finding {
            severity: Severity::Error,
            code: "validate",
            message: format!("spec fails validation: {e}"),
        });
        return report;
    }
    report.load = estimate_load(spec);
    stability_findings(spec, &report.load, &mut report.findings);
    budget_findings(spec, &report.load, &mut report.findings);
    cache_findings(spec, &mut report.findings);
    shard_findings(spec, &report.load, &mut report.findings);
    fault_findings(spec, &report.load, &mut report.findings);
    report
}

/// Probe the profiler's cost model: plan + latent-sample a small prefix
/// of the workload and price every subtask on both sides. Uses the
/// paper-pair executor (the same endpoints every scenario run uses), so
/// estimates line up with what the kernel will actually charge.
fn estimate_load(spec: &ScenarioSpec) -> LoadEstimate {
    let executor = SimExecutor::paper_pair();
    let sp = executor.sp();
    let planner = SyntheticPlanner::paper_main();
    let n_probe = spec.workload.n.min(PROBE_QUERIES).max(1);
    let base = generate_queries(spec.workload.benchmark, n_probe, spec.seed);
    let queries = match &spec.workload.zipf {
        Some(z) => z.apply(&base, spec.seed),
        None => base,
    };
    let mut rng = Rng::new(spec.seed);
    let (mut edge_s, mut cloud_s, mut dollars, mut subtasks) = (0.0f64, 0.0f64, 0.0f64, 0usize);
    for q in &queries {
        let plan = planner.plan(q, spec.engine.n_max, &mut rng);
        let dag = &plan.dag;
        let latents = sample_latents(dag, q, sp, &mut rng);
        let order = dag.topo_order().unwrap_or_else(|| (0..dag.len()).collect());
        let mut out_tokens: Vec<f64> = latents.iter().map(|l| l.out_tokens).collect();
        for &i in &order {
            let in_tok: f64 = q.query_tokens
                + dag.nodes[i].deps.iter().map(|&d| out_tokens[d]).sum::<f64>();
            let cloud_out = latents[i].out_tokens * sp.cloud_verbosity;
            edge_s += executor.profile(false).latency_mean(in_tok, latents[i].out_tokens);
            cloud_s += executor.profile(true).latency_mean(in_tok, cloud_out);
            dollars += executor.profile(true).api_cost(in_tok, cloud_out);
            out_tokens[i] = latents[i].out_tokens;
            subtasks += 1;
        }
    }
    let nq = queries.len().max(1) as f64;
    let edge_service = edge_s / nq;
    let cloud_service = cloud_s / nq;
    let cloud_dollars = dollars / nq;
    let mean_subtasks = subtasks as f64 / nq;
    let lambda = arrival_rate(&spec.workload.arrival, spec.workload.n, spec.seed);
    // Zero-worker sides are legal topology: the kernel pads a phantom
    // single slot per side, so capacity is max(workers, 1) either way.
    let we = spec.topology.edge_workers.max(1) as f64;
    let wc = spec.topology.cloud_workers.max(1) as f64;
    let rho_edge = offered(lambda, edge_service, we);
    let rho_cloud = offered(lambda, cloud_service, wc);
    // Best service-proportional split: route fraction p to edge so both
    // pools see equal utilization; rho* = lambda·Se·Sc / (Se·Wc + Sc·We)
    // is the utilization both sides share at that optimum.
    let denom = edge_service * wc + cloud_service * we;
    let rho_split = if denom > 0.0 {
        lambda * edge_service * cloud_service / denom
    } else {
        0.0
    };
    LoadEstimate {
        lambda,
        edge_service,
        cloud_service,
        cloud_dollars,
        mean_subtasks,
        rho_edge,
        rho_cloud,
        rho_split,
    }
}

fn offered(lambda: f64, service: f64, workers: f64) -> f64 {
    if service <= 0.0 {
        return 0.0;
    }
    lambda * service / workers
}

/// Long-run arrival rate of a (validated) arrival process.
fn arrival_rate(arrival: &ArrivalProcess, n: usize, seed: u64) -> f64 {
    match arrival {
        ArrivalProcess::Poisson { rate } => *rate,
        ArrivalProcess::Periodic { gap } => {
            if *gap > 0.0 {
                1.0 / gap
            } else if n > 1 {
                f64::INFINITY
            } else {
                0.0
            }
        }
        ArrivalProcess::Trace(_) => {
            if n < 2 {
                return 0.0;
            }
            let times = arrival.sample(n, seed);
            let span = times[times.len() - 1] - times[0];
            if span > 0.0 {
                (n as f64 - 1.0) / span
            } else {
                f64::INFINITY
            }
        }
    }
}

fn stability_findings(spec: &ScenarioSpec, load: &LoadEstimate, out: &mut Vec<Finding>) {
    let rho = load.rho_split;
    if rho >= 1.0 {
        if spec.topology.admission_limit == 0 {
            out.push(Finding {
                severity: Severity::Error,
                code: "stability",
                message: format!(
                    "offered load rho={:.3} >= 1 under the best edge/cloud split with \
                     unbounded admission (admission_limit = 0): the queue grows without bound",
                    rho,
                ),
            });
        } else {
            out.push(Finding {
                severity: Severity::Warning,
                code: "stability",
                message: format!(
                    "offered load rho={:.3} >= 1 under the best edge/cloud split; bounded \
                     admission (limit {}) caps the backlog but sojourn times will sit at \
                     the admission ceiling",
                    rho, spec.topology.admission_limit,
                ),
            });
        }
    } else if rho >= RHO_WARN {
        out.push(Finding {
            severity: Severity::Warning,
            code: "stability",
            message: format!(
                "offered load rho={:.3} >= {:.1} under the best edge/cloud split: the fleet \
                 runs near saturation and queueing delay dominates latency",
                rho, RHO_WARN,
            ),
        });
    }
}

fn budget_findings(spec: &ScenarioSpec, load: &LoadEstimate, out: &mut Vec<Finding>) {
    let per_query = load.cloud_dollars;
    if per_query <= 0.0 {
        return;
    }
    let n = spec.workload.n as f64;
    let n_tenants = spec.topology.tenants.len().max(1) as f64;
    // Arrivals are assigned round-robin, so each tenant sees ~n/T
    // queries (WorkloadSpec::arrivals).
    let tenant_share = n / n_tenants;
    for t in &spec.topology.tenants {
        let Some(cap) = t.k_cap else { continue };
        if cap < per_query {
            out.push(Finding {
                severity: Severity::Warning,
                code: "budget",
                message: format!(
                    "tenant '{}' cap ${:.5} is below the expected all-cloud cost of a \
                     single query (${:.5}): the cap force-edges ~100% of its traffic",
                    t.name, cap, per_query,
                ),
            });
        } else if cap < per_query * tenant_share {
            out.push(Finding {
                severity: Severity::Info,
                code: "budget",
                message: format!(
                    "tenant '{}' cap ${:.5} covers ~{:.0} of ~{:.0} expected queries at \
                     all-cloud cost; offloading throttles once the cap is drawn down",
                    t.name,
                    (cap / per_query).floor(),
                    tenant_share,
                ),
            });
        }
    }
    if let Some(cap) = spec.topology.global_k_cap {
        if cap < per_query {
            out.push(Finding {
                severity: Severity::Warning,
                code: "budget",
                message: format!(
                    "global cap ${:.5} is below the expected all-cloud cost of a single \
                     query (${:.5}): the fleet force-edges ~100% of traffic",
                    cap, per_query,
                ),
            });
        } else if cap < per_query * n {
            out.push(Finding {
                severity: Severity::Info,
                code: "budget",
                message: format!(
                    "global cap ${:.5} covers ~{:.0} of {} queries at all-cloud cost; \
                     offloading throttles once the cap is drawn down",
                    cap,
                    (cap / per_query).floor(),
                    spec.workload.n,
                ),
            });
        }
    }
}

fn cache_findings(spec: &ScenarioSpec, out: &mut Vec<Finding>) {
    let Some(cache) = &spec.engine.cache else {
        return;
    };
    if cache.capacity == 0 {
        out.push(Finding {
            severity: Severity::Info,
            code: "cache",
            message: "cache configured with capacity 0: the cache is disabled".into(),
        });
        return;
    }
    match &spec.workload.zipf {
        Some(z) => {
            let working_set = z.distinct.min(spec.workload.n);
            if cache.capacity < working_set {
                out.push(Finding {
                    severity: Severity::Warning,
                    code: "cache",
                    message: format!(
                        "cache capacity {} is below the Zipf working set of {} distinct \
                         queries: steady-state evictions churn the partition",
                        cache.capacity, working_set,
                    ),
                });
            }
        }
        None => {
            out.push(Finding {
                severity: Severity::Info,
                code: "cache",
                message: "cache on, but the workload has no zipf repetition: hit rate ~0".into(),
            });
        }
    }
}

fn shard_findings(spec: &ScenarioSpec, load: &LoadEstimate, out: &mut Vec<Finding>) {
    let shards = spec.topology.shards;
    if shards <= 1 {
        return;
    }
    // Expected dollars for a single cloud call, from the probe.
    let per_call = if load.mean_subtasks > 0.0 {
        load.cloud_dollars / load.mean_subtasks
    } else {
        0.0
    };
    if per_call <= 0.0 {
        return;
    }
    let s = shards as f64;
    for t in &spec.topology.tenants {
        let Some(cap) = t.k_cap else { continue };
        if cap / s < per_call && cap >= per_call {
            out.push(Finding {
                severity: Severity::Warning,
                code: "shard_split",
                message: format!(
                    "tenant '{}' cap ${:.5} splits to ${:.5} per shard across {} shards — \
                     below one expected cloud call (${:.5}); every shard force-edges even \
                     though the whole-fleet cap would not",
                    t.name,
                    cap,
                    cap / s,
                    shards,
                    per_call,
                ),
            });
        }
    }
    if let Some(cap) = spec.topology.global_k_cap {
        if cap / s < per_call && cap >= per_call {
            out.push(Finding {
                severity: Severity::Warning,
                code: "shard_split",
                message: format!(
                    "global cap ${:.5} splits to ${:.5} per shard across {} shards — below \
                     one expected cloud call (${:.5}); sharding alone disables offloading",
                    cap,
                    cap / s,
                    shards,
                    per_call,
                ),
            });
        }
    }
}

/// Fault-layer feasibility: a scheduled outage that blankets the whole
/// arrival horizon on a side some policy pins traffic to is an error
/// (every regular attempt on that traffic is rejected; the run completes
/// only through degraded completions). Retry/straggler inflation that
/// pushes the effective offered load past 1, or a timeout below the
/// profiled mean per-call service time, are warnings — the run still
/// terminates (retries are bounded), but mostly through the resilience
/// machinery rather than clean completions.
fn fault_findings(spec: &ScenarioSpec, load: &LoadEstimate, out: &mut Vec<Finding>) {
    let Some(model) =
        FaultModel::from_parts(spec.engine.faults.clone(), spec.engine.resilience.clone())
    else {
        return;
    };
    let f = &model.faults;
    let r = &model.resilience;

    // --- Total outage on a pinned side --------------------------------
    // Horizon estimate: the last expected arrival. A single window with
    // `start <= 0 <= horizon <= end` rejects every first-attempt dispatch
    // of the run on its side (later retries land inside it too).
    let horizon = if load.lambda.is_finite() && load.lambda > 0.0 {
        spec.workload.n as f64 / load.lambda
    } else {
        0.0
    };
    // Sides some traffic is pinned to: the engine default applies to any
    // tenant without an override; overrides pin their own tenant.
    let mut pinned = [false; 2]; // [edge, cloud]
    let mut note = |p: &PolicySpec| match p {
        PolicySpec::AllEdge => pinned[0] = true,
        PolicySpec::AllCloud => pinned[1] = true,
        _ => {}
    };
    if spec.topology.tenants.iter().any(|t| t.policy.is_none()) {
        note(&spec.engine.policy);
    }
    for t in &spec.topology.tenants {
        if let Some(p) = &t.policy {
            note(p);
        }
    }
    for (idx, cloud) in [(0usize, false), (1usize, true)] {
        if !pinned[idx] {
            continue;
        }
        let total = f
            .outages
            .iter()
            .find(|w| w.cloud == cloud && w.start <= 0.0 && w.end >= horizon && w.end > w.start);
        if let Some(w) = total {
            out.push(Finding {
                severity: Severity::Error,
                code: "fault_outage_total",
                message: format!(
                    "outage [{:.1}, {:.1}) on the {} side blankets the whole ~{:.1}s arrival \
                     horizon while a policy pins traffic there: every regular attempt is \
                     rejected and the run completes only through degraded completions",
                    w.start,
                    w.end,
                    if cloud { "cloud" } else { "edge" },
                    horizon,
                ),
            });
        }
    }

    // --- Retry + straggler load inflation -----------------------------
    // Expected attempts per call under the worst per-side failure
    // probability (geometric, truncated at the attempt budget), times the
    // expected straggler service multiplier, scales the offered load.
    let p_fail = f.edge_fail_p.max(f.cloud_fail_p);
    let attempts = (1.0 / (1.0 - p_fail).max(1e-9)).min(f64::from(model.max_attempts()));
    let service_mult = 1.0 + f.straggler_p * (f.straggler_mult - 1.0);
    let rho_eff = load.rho_split * attempts * service_mult;
    if rho_eff >= 1.0 && load.rho_split < 1.0 {
        out.push(Finding {
            severity: Severity::Warning,
            code: "fault_load",
            message: format!(
                "retries and stragglers inflate the offered load from rho={:.3} to \
                 ~{:.3} (x{:.2} expected attempts, x{:.2} straggler service): the fleet \
                 saturates under the fault process even though the clean workload would not",
                load.rho_split,
                rho_eff,
                attempts,
                service_mult,
            ),
        });
    }

    // --- Timeout below the profiled mean service ----------------------
    if let Some(tmo) = r.timeout {
        let per_call = if load.mean_subtasks > 0.0 {
            (load.edge_service / load.mean_subtasks)
                .max(load.cloud_service / load.mean_subtasks)
        } else {
            0.0
        };
        if tmo < per_call {
            out.push(Finding {
                severity: Severity::Warning,
                code: "fault_timeout",
                message: format!(
                    "resilience.timeout {:.2}s is below the profiled mean per-call service \
                     time {:.2}s: most attempts will time out, and with max_retries {} each \
                     subtask burns its whole attempt budget before degrading",
                    tmo, per_call, r.max_retries,
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{FaultConfig, OutageWindow, ResilienceConfig};
    use crate::scenario::presets;

    fn overloaded() -> ScenarioSpec {
        let mut spec = presets::golden_fleet();
        spec.name = "overloaded".into();
        spec.topology.edge_workers = 1;
        spec.topology.cloud_workers = 1;
        spec.topology.admission_limit = 0;
        spec.workload.n = 40;
        spec.workload.arrival = ArrivalProcess::Poisson { rate: 4.0 };
        spec
    }

    #[test]
    fn overload_with_unbounded_admission_is_an_error() {
        let report = check_spec(&overloaded());
        assert!(report.load.rho_split >= 1.0, "{:?}", report.load);
        assert!(!report.passed(), "{}", report.render());
        assert!(report
            .findings
            .iter()
            .any(|f| f.severity == Severity::Error && f.code == "stability"));
    }

    #[test]
    fn bounded_admission_downgrades_overload_to_warning() {
        let mut spec = overloaded();
        spec.topology.admission_limit = 8;
        let report = check_spec(&spec);
        assert!(report.passed(), "{}", report.render());
        assert!(report
            .findings
            .iter()
            .any(|f| f.severity == Severity::Warning && f.code == "stability"));
    }

    #[test]
    fn invalid_spec_reports_instead_of_panicking() {
        let mut spec = presets::golden_fleet();
        spec.workload.n = 0;
        let report = check_spec(&spec);
        assert!(!report.passed());
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].code, "validate");
    }

    #[test]
    fn tiny_tenant_cap_flags_force_edge() {
        let mut spec = presets::golden_fleet();
        spec.topology.tenants[0].k_cap = Some(1e-9);
        let report = check_spec(&spec);
        assert!(report
            .findings
            .iter()
            .any(|f| f.code == "budget" && f.severity == Severity::Warning));
    }

    #[test]
    fn report_render_is_rerun_identical() {
        let spec = overloaded();
        assert_eq!(check_spec(&spec).render(), check_spec(&spec).render());
    }

    #[test]
    fn shipped_faulty_preset_checks_clean() {
        // The shipped fault scenario must pass the checker with zero
        // errors (mid-run outage, modest failure probabilities, generous
        // timeout — nothing pins traffic to the outaged side).
        use crate::workload::Benchmark;
        let spec = presets::fleet_faulty(Benchmark::Gpqa, 60, 0.5, 11);
        let report = check_spec(&spec);
        assert!(report.passed(), "{}", report.render());
        assert!(
            !report.findings.iter().any(|f| f.code.starts_with("fault_")),
            "{}",
            report.render()
        );
    }

    #[test]
    fn total_outage_on_pinned_side_is_an_error() {
        let mut spec = presets::golden_fleet();
        spec.engine.policy = PolicySpec::AllCloud;
        spec.topology.tenants = vec![crate::scenario::TenantSpec::unlimited("a")];
        // Horizon: 12 periodic arrivals at 1.5s gaps => ~18s; blanket it.
        spec.engine.faults = Some(FaultConfig {
            outages: vec![OutageWindow { cloud: true, start: 0.0, end: 1e6 }],
            ..FaultConfig::default()
        });
        let report = check_spec(&spec);
        assert!(!report.passed(), "{}", report.render());
        assert!(report
            .findings
            .iter()
            .any(|f| f.severity == Severity::Error && f.code == "fault_outage_total"));
        // The same outage with traffic free to route around it is no error.
        let mut free = spec.clone();
        free.engine.policy = PolicySpec::HybridFlow;
        assert!(check_spec(&free).passed(), "{}", check_spec(&free).render());
        // A mid-run window on the pinned side is not total either.
        let mut partial = spec.clone();
        partial.engine.faults = Some(FaultConfig {
            outages: vec![OutageWindow { cloud: true, start: 5.0, end: 10.0 }],
            ..FaultConfig::default()
        });
        assert!(check_spec(&partial).passed(), "{}", check_spec(&partial).render());
        // A tenant override pins traffic even when the default does not.
        let mut via_tenant = partial.clone();
        via_tenant.engine.policy = PolicySpec::HybridFlow;
        via_tenant.topology.tenants =
            vec![crate::scenario::TenantSpec::unlimited("pinned")
                .with_policy(PolicySpec::AllCloud)];
        via_tenant.engine.faults = Some(FaultConfig {
            outages: vec![OutageWindow { cloud: true, start: 0.0, end: 1e6 }],
            ..FaultConfig::default()
        });
        assert!(!check_spec(&via_tenant).passed());
    }

    #[test]
    fn retry_inflation_and_short_timeout_warn() {
        // Rescale the overloaded spec's arrival rate so the clean load
        // sits at rho ~0.75 (rho is linear in the Poisson rate), then add
        // p=0.6 failures: ~2.5 expected attempts push the effective load
        // past 1 while the clean workload stays stable.
        let mut spec = overloaded();
        let base = check_spec(&spec);
        assert!(base.load.rho_split > 0.0, "{}", base.render());
        spec.workload.arrival = ArrivalProcess::Poisson { rate: 4.0 * 0.75 / base.load.rho_split };
        let clean = check_spec(&spec);
        assert!(clean.load.rho_split < 1.0, "{}", clean.render());
        assert!(clean.passed(), "{}", clean.render());
        spec.engine.faults =
            Some(FaultConfig { edge_fail_p: 0.6, cloud_fail_p: 0.6, ..FaultConfig::default() });
        let report = check_spec(&spec);
        assert!(report
            .findings
            .iter()
            .any(|f| f.severity == Severity::Warning && f.code == "fault_load"),
            "{}",
            report.render());
        // A timeout far below any realistic per-call service time warns.
        let mut spec = presets::golden_fleet();
        spec.engine.resilience = Some(ResilienceConfig {
            timeout: Some(1e-6),
            ..ResilienceConfig::default()
        });
        let report = check_spec(&spec);
        assert!(report
            .findings
            .iter()
            .any(|f| f.severity == Severity::Warning && f.code == "fault_timeout"),
            "{}",
            report.render());
        assert!(report.passed(), "warnings only: {}", report.render());
    }
}

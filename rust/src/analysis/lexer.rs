//! Minimal Rust lexer for the determinism lint.
//!
//! Dependency-free (no `syn`): the lint rules only need a token stream
//! with comments, strings, raw strings, char literals, and lifetimes
//! handled correctly — so that a banned pattern mentioned inside a doc
//! comment or a format string never produces a diagnostic. The lexer
//! also extracts `// lint:allow(rule): reason` suppression directives
//! from real comments (and only from comments, so a directive quoted in
//! a string literal does not suppress anything).

/// Token classification. Rules match on `(kind, text)` pairs; string and
/// comment *contents* never become `Ident`/`Punct` tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// Numeric literal; `float` distinguishes `3.5` / `1e-9` / `2f64`
    /// from integer literals (including `1usize`, whose suffix carries a
    /// non-exponent `e`).
    Number { float: bool },
    /// String literal (regular, raw, byte, raw-byte). Text is the body.
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// A `// lint:allow(rule): reason` directive found in a comment. An
/// empty `rule` or `reason` marks a malformed directive; `lint` reports
/// those as `bad_allow` findings so suppressions are always justified.
#[derive(Debug, Clone)]
pub struct AllowDirective {
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// Lexer output: the code token stream plus every allow directive.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub allows: Vec<AllowDirective>,
}

/// Multi-char punctuation the rules care about (`..` terminates a cast
/// operand scan; `::` joins paths; arrows terminate statements). Longest
/// match first.
const MULTI_PUNCT: [&str; 5] = ["..=", "..", "::", "->", "=>"];

pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (incl. doc comments). May carry an allow directive.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let body: String = chars[i..j].iter().collect();
            if let Some(d) = parse_allow(&body, line) {
                out.allows.push(d);
            }
            i = j;
            continue;
        }
        // Block comment (nested, per Rust).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw / byte string starts: r"…", r#"…"#, b"…", br"…", br#"…"#.
        if c == 'r' || c == 'b' {
            if let Some((body, next, lines)) = try_string_prefix(&chars, i) {
                out.tokens.push(Tok { kind: TokKind::Str, text: body, line });
                line += lines;
                i = next;
                continue;
            }
            if c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
                let (next, lines) = skip_char_literal(&chars, i + 1);
                out.tokens.push(Tok { kind: TokKind::Char, text: String::new(), line });
                line += lines;
                i = next;
                continue;
            }
        }
        if c == '"' {
            let (body, next, lines) = scan_string(&chars, i);
            out.tokens.push(Tok { kind: TokKind::Str, text: body, line });
            line += lines;
            i = next;
            continue;
        }
        if c == '\'' {
            // Char literal vs lifetime: 'x' / '\n' are chars; 'a (no
            // closing quote after one element) is a lifetime.
            if i + 1 < n && chars[i + 1] == '\\' {
                let (next, lines) = skip_char_literal(&chars, i);
                out.tokens.push(Tok { kind: TokKind::Char, text: String::new(), line });
                line += lines;
                i = next;
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                out.tokens.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            out.tokens.push(Tok { kind: TokKind::Lifetime, text, line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let (text, next) = scan_number(&chars, i);
            let float = number_is_float(&text);
            out.tokens.push(Tok { kind: TokKind::Number { float }, text, line });
            i = next;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            out.tokens.push(Tok { kind: TokKind::Ident, text, line });
            i = j;
            continue;
        }
        // Punctuation: longest known multi-char first, else single char.
        let mut matched = false;
        for p in MULTI_PUNCT {
            let pl = p.chars().count();
            if i + pl <= n && chars[i..i + pl].iter().collect::<String>() == p {
                out.tokens.push(Tok { kind: TokKind::Punct, text: p.to_string(), line });
                i += pl;
                matched = true;
                break;
            }
        }
        if !matched {
            out.tokens.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
            i += 1;
        }
    }
    out
}

/// Try to lex a raw/byte string starting at `i` (`r`, `b`, or `br`
/// prefix). Returns `(body, next_index, newlines_consumed)`.
fn try_string_prefix(chars: &[char], i: usize) -> Option<(String, usize, usize)> {
    let n = chars.len();
    let mut j = i;
    let mut raw = false;
    if chars[j] == 'b' {
        j += 1;
        if j < n && chars[j] == 'r' {
            raw = true;
            j += 1;
        }
    } else if chars[j] == 'r' {
        raw = true;
        j += 1;
    }
    if raw {
        let mut hashes = 0usize;
        while j < n && chars[j] == '#' {
            hashes += 1;
            j += 1;
        }
        // `r#ident` (raw identifier) has no quote after the hash run.
        if j >= n || chars[j] != '"' {
            return None;
        }
        let close: Vec<char> = format!("\"{}", "#".repeat(hashes)).chars().collect();
        let mut k = j + 1;
        let mut lines = 0usize;
        let start = k;
        while k < n {
            if chars[k] == '\n' {
                lines += 1;
            }
            if chars[k] == '"'
                && chars[k..].len() >= close.len()
                && chars[k..k + close.len()] == close[..]
            {
                let body: String = chars[start..k].iter().collect();
                return Some((body, k + close.len(), lines));
            }
            k += 1;
        }
        let body: String = chars[start..].iter().collect();
        return Some((body, n, lines));
    }
    // Non-raw byte string: b"…".
    if j < n && chars[j] == '"' {
        let (body, next, lines) = scan_string(chars, j);
        return Some((body, next, lines));
    }
    None
}

/// Scan a regular (escaped) string literal whose opening quote is at
/// `i`. Returns `(body, next_index, newlines_consumed)`.
fn scan_string(chars: &[char], i: usize) -> (String, usize, usize) {
    let n = chars.len();
    let mut j = i + 1;
    let mut lines = 0usize;
    let start = j;
    while j < n {
        match chars[j] {
            '\\' => j += 2,
            '\n' => {
                lines += 1;
                j += 1;
            }
            '"' => {
                let body: String = chars[start..j].iter().collect();
                return (body, j + 1, lines);
            }
            _ => j += 1,
        }
    }
    (chars[start..].iter().collect(), n, lines)
}

/// Skip a (possibly escaped) char literal whose opening quote is at `i`.
fn skip_char_literal(chars: &[char], i: usize) -> (usize, usize) {
    let n = chars.len();
    let mut j = i + 1;
    while j < n {
        match chars[j] {
            '\\' => j += 2,
            '\'' => return (j + 1, 0),
            '\n' => return (j, 0),
            _ => j += 1,
        }
    }
    (n, 0)
}

/// Scan a numeric literal starting at a digit. Consumes suffixes
/// (`u64`, `f32`), fractional parts, and signed exponents; stops before
/// `..` ranges and method calls on integer literals (`1.max(2)`).
fn scan_number(chars: &[char], i: usize) -> (String, usize) {
    let n = chars.len();
    let hex = chars[i] == '0'
        && i + 1 < n
        && matches!(chars[i + 1], 'x' | 'X' | 'b' | 'B' | 'o' | 'O');
    let mut j = i;
    while j < n {
        let ch = chars[j];
        if ch.is_ascii_alphanumeric() || ch == '_' {
            j += 1;
            continue;
        }
        if ch == '.' && !hex {
            if j + 1 < n
                && (chars[j + 1] == '.' || chars[j + 1].is_alphabetic() || chars[j + 1] == '_')
            {
                break;
            }
            j += 1;
            continue;
        }
        if (ch == '+' || ch == '-') && !hex && j > i && matches!(chars[j - 1], 'e' | 'E') {
            j += 1;
            continue;
        }
        break;
    }
    (chars[i..j].iter().collect(), j)
}

/// Float classification of a scanned numeric literal: fractional part,
/// `f32`/`f64` suffix, or an exponent with a digit after it (`usize`
/// carries an `e` but never `e<digit>`).
fn number_is_float(text: &str) -> bool {
    let lower = text.to_ascii_lowercase();
    if lower.starts_with("0x") || lower.starts_with("0b") || lower.starts_with("0o") {
        return false;
    }
    if lower.ends_with("f32") || lower.ends_with("f64") || lower.contains('.') {
        return true;
    }
    let b = lower.as_bytes();
    for k in 0..b.len() {
        if b[k] == b'e' && k + 1 < b.len() {
            let mut m = k + 1;
            if b[m] == b'+' || b[m] == b'-' {
                m += 1;
            }
            if m < b.len() && b[m].is_ascii_digit() {
                return true;
            }
        }
    }
    false
}

/// Parse an allow directive out of one line-comment body, if present.
/// Malformed directives (missing rule, close paren, or reason) come back
/// with empty fields and are reported as `bad_allow` by the linter.
fn parse_allow(comment: &str, line: usize) -> Option<AllowDirective> {
    let idx = comment.find("lint:allow")?;
    let rest = &comment[idx + "lint:allow".len()..];
    let Some(rest) = rest.strip_prefix('(') else {
        return Some(AllowDirective { line, rule: String::new(), reason: String::new() });
    };
    let Some(close) = rest.find(')') else {
        return Some(AllowDirective { line, rule: String::new(), reason: String::new() });
    };
    let rule = rest[..close].trim().to_string();
    let after = &rest[close + 1..];
    let reason = after.strip_prefix(':').map(str::trim).unwrap_or("").to_string();
    Some(AllowDirective { line, rule, reason })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn comments_and_strings_hide_contents() {
        let src = r##"
// HashMap in a comment
/* Instant::now() in a /* nested */ block */
const S: &str = "HashMap and println!";
const R: &str = r#"thread::spawn and .sum::<f64>()"#;
fn real() {}
"##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(!ids.contains(&"Instant".to_string()));
        assert!(!ids.contains(&"spawn".to_string()));
        assert!(ids.contains(&"real".to_string()));
    }

    #[test]
    fn lifetimes_and_chars_disambiguate() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }";
        let lexed = lex(src);
        let lifetimes: Vec<_> =
            lexed.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        let chars: Vec<_> = lexed.tokens.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(chars.len(), 1);
    }

    #[test]
    fn number_float_classification() {
        for (text, want) in [
            ("3.5", true),
            ("1e-9", true),
            ("7e3", true),
            ("2f64", true),
            ("1.0f32", true),
            ("42", false),
            ("1usize", false),
            ("0x9E37", false),
            ("1_000", false),
        ] {
            assert_eq!(number_is_float(text), want, "literal {text}");
        }
    }

    #[test]
    fn number_scan_stops_at_ranges_and_methods() {
        let lexed = lex("for i in 0..n { let x = 1.max(2); }");
        let nums: Vec<_> = lexed
            .tokens
            .iter()
            .filter(|t| matches!(t.kind, TokKind::Number { .. }))
            .map(|t| (t.text.clone(), t.kind))
            .collect();
        assert_eq!(nums[0].0, "0");
        assert_eq!(nums[0].1, TokKind::Number { float: false });
        assert_eq!(nums[1].0, "1");
        assert_eq!(nums[1].1, TokKind::Number { float: false });
    }

    #[test]
    fn allow_directives_parse_from_comments_only() {
        let src = r#"
let x = 1; // lint:allow(wall_clock): bench harness measures real time
const S: &str = "lint:allow(wall_clock): not a directive";
// lint:allow(bogus)
"#;
        let lexed = lex(src);
        assert_eq!(lexed.allows.len(), 2);
        assert_eq!(lexed.allows[0].rule, "wall_clock");
        assert!(!lexed.allows[0].reason.is_empty());
        assert_eq!(lexed.allows[1].rule, "bogus");
        assert!(lexed.allows[1].reason.is_empty());
    }

    #[test]
    fn multiline_strings_track_lines() {
        let src = "let a = \"x\ny\";\nlet b = 1;";
        let lexed = lex(src);
        let b = lexed.tokens.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3);
    }
}

//! Determinism lint rules: token-level patterns over [`crate::analysis::lexer`]
//! output that enforce the repo's reproducibility contract.
//!
//! Every rule is an over-approximation tuned to this codebase: the goal
//! is zero unexplained hazards, not soundness for arbitrary Rust. Code
//! inside `#[cfg(test)]`-gated items is exempt (tests may use wall
//! clocks and hash maps), and a few modules are path-exempt where the
//! hazard *is* the module's purpose (`util::pool` owns threads and the
//! wall clock; `main.rs` and `report/` own stdout).

use crate::analysis::lexer::{Tok, TokKind};

/// One lint finding. Sorted `(file, line, rule)` so output is
/// byte-stable and diffable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// The rule registry: `(id, rationale)`. The id is what `lint:allow(id)`
/// names; the rationale feeds the README table and `bad_allow`
/// validation (suppressing an unknown rule is itself a finding).
pub const RULES: [(&str, &str); 7] = [
    (
        "partial_cmp_unwrap",
        "`.partial_cmp().unwrap()` panics on NaN and hides total-order intent; use `total_cmp`",
    ),
    (
        "hash_collection",
        "HashMap/HashSet iteration order is nondeterministic; use BTreeMap/BTreeSet or sort",
    ),
    (
        "wall_clock",
        "Instant/SystemTime in kernel or library code breaks virtual-clock determinism",
    ),
    ("thread_spawn", "ad-hoc threads escape the deterministic util::pool merge discipline"),
    (
        "print_in_lib",
        "stdout/stderr writes from library code pollute byte-stable reports; route via CLI",
    ),
    (
        "unordered_float_sum",
        "float accumulation over a hash-ordered iterator is order-sensitive; sort first",
    ),
    (
        "float_int_cast",
        "`as` float->int in a kernel path rounds/saturates silently; make rounding explicit",
    ),
];

/// Integer target types for the cast rule.
const INT_TYPES: [&str; 12] =
    ["usize", "u8", "u16", "u32", "u64", "u128", "isize", "i8", "i16", "i32", "i64", "i128"];

/// True when `id` names a registered rule.
pub fn known_rule(id: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == id)
}

fn is_p(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn is_any_id(t: &Tok, names: &[&str]) -> bool {
    t.kind == TokKind::Ident && names.iter().any(|n| t.text == *n)
}

/// Index of the `)` matching the `(` at `open`, if any.
fn match_paren(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if is_p(t, "(") {
            depth += 1;
        } else if is_p(t, ")") {
            depth -= 1;
            if depth == 0 {
                return Some(k);
            }
        }
    }
    None
}

/// Path predicates, on forward-slash-normalized paths.
fn in_pool(file: &str) -> bool {
    file.ends_with("util/pool.rs")
}

fn print_exempt(file: &str) -> bool {
    file.ends_with("main.rs") || file.contains("/report/") || file.starts_with("report/")
}

fn kernel_path(file: &str) -> bool {
    file.contains("/sim/")
        || file.contains("/scheduler/")
        || file.starts_with("sim/")
        || file.starts_with("scheduler/")
}

/// Statement-boundary tokens for backward statement scans.
fn is_stmt_boundary(t: &Tok) -> bool {
    t.kind == TokKind::Punct && matches!(t.text.as_str(), ";" | "{" | "}")
}

/// Run every rule over one file's token stream. `in_test` reports
/// whether a source line sits inside a `#[cfg(test)]`-gated item.
pub fn run_rules(file: &str, toks: &[Tok], in_test: &dyn Fn(usize) -> bool) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, message: String| {
        out.push(Diagnostic { file: file.to_string(), line, rule, message });
    };

    for w in 0..toks.len() {
        let t = &toks[w];
        if t.kind != TokKind::Ident || in_test(t.line) {
            continue;
        }
        match t.text.as_str() {
            // `.partial_cmp(..).unwrap()` / `.expect(..)`: NaN panic +
            // non-total order. Trait impls (`fn partial_cmp`) and
            // `unwrap_or(..)` fallbacks do not match.
            "partial_cmp" => {
                if w > 0
                    && is_p(&toks[w - 1], ".")
                    && w + 1 < toks.len()
                    && is_p(&toks[w + 1], "(")
                {
                    if let Some(close) = match_paren(toks, w + 1) {
                        if close + 2 < toks.len()
                            && is_p(&toks[close + 1], ".")
                            && is_any_id(&toks[close + 2], &["unwrap", "expect"])
                        {
                            push(
                                t.line,
                                "partial_cmp_unwrap",
                                "`.partial_cmp().unwrap()` chain; use `total_cmp`".into(),
                            );
                        }
                    }
                }
            }
            // Hash collections anywhere in non-test library code. The
            // repo contract is BTree everywhere; the one justified use
            // (PJRT executable lookup) carries an allow.
            "HashMap" | "HashSet" => {
                push(
                    t.line,
                    "hash_collection",
                    format!("{} iteration order is nondeterministic; use BTree", t.text),
                );
            }
            // Wall-clock types outside util::pool: virtual time is the
            // only clock the kernel may observe.
            "Instant" | "SystemTime" => {
                if !in_pool(file) {
                    push(
                        t.line,
                        "wall_clock",
                        format!("{} is wall-clock; kernel code uses the virtual clock", t.text),
                    );
                }
            }
            // `thread::spawn` / `Builder::spawn` outside util::pool.
            "spawn" => {
                if !in_pool(file)
                    && w > 0
                    && (is_p(&toks[w - 1], "::") || is_p(&toks[w - 1], "."))
                    && w + 1 < toks.len()
                    && is_p(&toks[w + 1], "(")
                {
                    push(
                        t.line,
                        "thread_spawn",
                        "ad-hoc thread spawn; deterministic threads live in util::pool".into(),
                    );
                }
            }
            // println!/eprintln! in library modules.
            "println" | "eprintln" | "print" | "eprint" => {
                if !print_exempt(file) && w + 1 < toks.len() && is_p(&toks[w + 1], "!") {
                    push(
                        t.line,
                        "print_in_lib",
                        format!("{}! in library code; print from the CLI layer", t.text),
                    );
                }
            }
            // `.sum::<f64>()` with a hash collection in the same
            // statement: order-sensitive float accumulation.
            "sum" => {
                if w > 0
                    && is_p(&toks[w - 1], ".")
                    && w + 3 < toks.len()
                    && is_p(&toks[w + 1], "::")
                    && is_p(&toks[w + 2], "<")
                    && is_any_id(&toks[w + 3], &["f64", "f32"])
                    && stmt_mentions_hash(toks, w)
                {
                    push(
                        t.line,
                        "unordered_float_sum",
                        "float sum over a hash-ordered iterator; sort into a Vec first".into(),
                    );
                }
            }
            // `<float expr> as <int type>` in kernel paths (sim/,
            // scheduler/): silent truncation in the hot loop.
            "as" => {
                if kernel_path(file)
                    && w + 1 < toks.len()
                    && is_any_id(&toks[w + 1], &INT_TYPES)
                    && cast_operand_has_float(toks, w)
                {
                    push(
                        t.line,
                        "float_int_cast",
                        "float->int `as` cast in a kernel path; make rounding explicit".into(),
                    );
                }
            }
            _ => {}
        }
    }
    out
}

/// Backward statement scan from token `w`: does the current statement
/// mention a hash collection? (Defense-in-depth for the float-sum rule;
/// the `hash_collection` rule already flags the collection itself.)
fn stmt_mentions_hash(toks: &[Tok], w: usize) -> bool {
    let mut k = w;
    while k > 0 {
        k -= 1;
        let t = &toks[k];
        if is_stmt_boundary(t) {
            return false;
        }
        if is_any_id(t, &["HashMap", "HashSet"]) {
            return true;
        }
    }
    false
}

/// Backward operand scan from the `as` at `w`: walk left over the cast
/// operand (stopping at statement boundaries, commas, `=`, ranges, and
/// unbalanced open brackets) looking for float evidence — a float
/// literal, an `f64`/`f32` ident, or a rounding method. Integer-only
/// casts like `(0..n as u32)` terminate at `..` before reaching any
/// float elsewhere in the expression.
fn cast_operand_has_float(toks: &[Tok], w: usize) -> bool {
    let mut depth = 0usize;
    let mut k = w;
    while k > 0 {
        k -= 1;
        let t = &toks[k];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                ")" | "]" => depth += 1,
                "(" | "[" => {
                    if depth == 0 {
                        return false;
                    }
                    depth -= 1;
                }
                ";" | "{" | "}" | "," | "=" | ".." | "..=" | "=>" | "->" => {
                    if depth == 0 {
                        return false;
                    }
                }
                _ => {}
            }
            continue;
        }
        if matches!(t.kind, TokKind::Number { float: true }) {
            return true;
        }
        if is_any_id(t, &["f64", "f32", "floor", "ceil", "round", "trunc"]) {
            return true;
        }
        if depth == 0 && is_any_id(t, &["let", "return", "match", "if", "while", "for"]) {
            return false;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn diags(file: &str, src: &str) -> Vec<Diagnostic> {
        let lexed = lex(src);
        run_rules(file, &lexed.tokens, &|_| false)
    }

    fn rules_of(d: &[Diagnostic]) -> Vec<&'static str> {
        d.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn partial_cmp_chain_flags_but_trait_impl_does_not() {
        let bad = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());";
        assert_eq!(rules_of(&diags("x.rs", bad)), ["partial_cmp_unwrap"]);
        let expect = "v.sort_by(|a, b| a.partial_cmp(b).expect(\"finite\"));";
        assert_eq!(rules_of(&diags("x.rs", expect)), ["partial_cmp_unwrap"]);
        let imp = "fn partial_cmp(&self, other: &Self) -> Option<Ordering> { None }";
        assert!(diags("x.rs", imp).is_empty());
        let fallback = "a.partial_cmp(b).unwrap_or(Ordering::Equal);";
        assert!(diags("x.rs", fallback).is_empty());
    }

    #[test]
    fn wall_clock_and_spawn_respect_pool_exemption() {
        let src = "let t = std::time::Instant::now(); std::thread::spawn(|| {});";
        let d = diags("rust/src/sim/mod.rs", src);
        assert_eq!(rules_of(&d), ["wall_clock", "thread_spawn"]);
        assert!(diags("rust/src/util/pool.rs", src).is_empty());
    }

    #[test]
    fn print_exemptions() {
        let src = "println!(\"hello\");";
        assert_eq!(rules_of(&diags("rust/src/eval/mod.rs", src)), ["print_in_lib"]);
        assert!(diags("rust/src/main.rs", src).is_empty());
        assert!(diags("rust/src/report/table.rs", src).is_empty());
    }

    #[test]
    fn float_cast_needs_kernel_path_and_float_evidence() {
        let bad = "let b = (x * n as f64).floor() as usize;";
        assert_eq!(rules_of(&diags("rust/src/sim/mod.rs", bad)), ["float_int_cast"]);
        // Outside kernel paths the rule is silent.
        assert!(diags("rust/src/eval/mod.rs", bad).is_empty());
        // Integer-only cast with a float elsewhere in the statement:
        // the `..` range terminates the operand scan.
        let ok = "let v = (0..n as u32).map(|w| (0.0, w)).collect();";
        assert!(diags("rust/src/scheduler/pool.rs", ok).is_empty());
        let plain = "let w = workers as u64;";
        assert!(diags("rust/src/scheduler/pool.rs", plain).is_empty());
    }

    #[test]
    fn unordered_sum_needs_hash_in_statement() {
        // Same statement as a HashMap mention: flags (plus the
        // hash_collection finding for the map itself).
        let bad = "let t = read_map::<HashMap<u64, f64>>().values().sum::<f64>();";
        let d = diags("rust/src/eval/mod.rs", bad);
        assert!(d.iter().any(|x| x.rule == "unordered_float_sum"), "{d:?}");
        // Ordered iterator: silent.
        let ok = "let t: f64 = xs.iter().sum::<f64>();";
        assert!(diags("rust/src/eval/mod.rs", ok).is_empty());
    }

    #[test]
    fn test_regions_are_exempt_via_callback() {
        let src = "let t = std::time::Instant::now();";
        let lexed = lex(src);
        let d = run_rules("rust/src/sim/mod.rs", &lexed.tokens, &|_| true);
        assert!(d.is_empty());
    }
}

//! Synthetic planner: generates XML plan text with controllable quality.
//!
//! Quality profiles are calibrated to the paper's planner statistics:
//! * Table 5 (main planner): 76–78% valid, 13–14% repairable, 9–10%
//!   fallback, ~4.3–4.5 nodes per executed DAG.
//! * Table 7 (base vs SFT Llama3.2-3B): base plans are long and chain-like
//!   (R_comp ~ 10.7%), SFT plans expose parallelism (R_comp ~ 34.3%).
//!
//! Defect injection drives the validation/repair pipeline with realistic
//! failure modes: cycles, orphans, duplicate GENERATE nodes, unknown Rely
//! ids, oversize plans, and outright malformed XML (which exercises the
//! parse-failure fallback).

use super::{PlanText, Planner};
use crate::config::simparams::model_params;
use crate::dag::Role;
use crate::util::rng::Rng;
use crate::workload::Query;

/// Planner quality profile.
#[derive(Debug, Clone)]
pub struct PlannerProfile {
    pub name: &'static str,
    /// Probability the emitted plan is structurally valid as-is.
    pub p_valid: f64,
    /// Given a defect, probability it is light (repairable) vs hopeless.
    pub p_repairable_defect: f64,
    /// Node count range (inclusive).
    pub nodes: (usize, usize),
    /// Probability a middle node chains to its immediate predecessor only
    /// (1.0 -> pure chain, low -> wide DAGs).
    pub p_chain_edge: f64,
    /// Probability of reporting per-edge confidence attributes.
    pub p_report_conf: f64,
    /// Probability of reporting Req/Prod symbol attributes.
    pub p_report_symbols: f64,
    /// Plan-quality dimension means (Figure 5 radar, 0-10 scale):
    /// [soundness, dependency flow, clarity, attribute accuracy, relevance].
    pub quality_dims: [f64; 5],
}

impl PlannerProfile {
    /// Main-experiment planner (Table 5 statistics).
    pub fn paper_main() -> PlannerProfile {
        PlannerProfile {
            name: "llama3.2-3b-eag",
            p_valid: 0.77,
            p_repairable_defect: 0.58, // 13.5% repaired / 23% defective
            nodes: (3, 6),
            p_chain_edge: 0.35,
            p_report_conf: 0.7,
            p_report_symbols: 0.5,
            quality_dims: [6.8, 6.2, 7.1, 5.9, 6.9],
        }
    }

    /// Base Llama3.2-3B planner (Table 7 top row): long, chain-heavy plans.
    pub fn base_llama() -> PlannerProfile {
        PlannerProfile {
            name: "llama3.2-3b-base",
            p_valid: 0.62,
            p_repairable_defect: 0.5,
            nodes: (5, 7),
            p_chain_edge: 0.88,
            p_report_conf: 0.2,
            p_report_symbols: 0.1,
            quality_dims: [5.1, 4.3, 5.6, 4.2, 5.4],
        }
    }

    /// SFT-distilled planner (Table 7 bottom row): parallel structure.
    pub fn sft_llama() -> PlannerProfile {
        PlannerProfile {
            name: "llama3.2-3b-sft",
            p_valid: 0.80,
            p_repairable_defect: 0.6,
            nodes: (5, 7),
            p_chain_edge: 0.30,
            p_report_conf: 0.8,
            p_report_symbols: 0.6,
            quality_dims: [7.4, 7.8, 7.6, 6.8, 7.5],
        }
    }

    /// Reference large-model planner for the Figure 5 comparison.
    pub fn frontier_reference() -> PlannerProfile {
        PlannerProfile {
            name: "frontier-reference",
            p_valid: 0.93,
            p_repairable_defect: 0.8,
            nodes: (4, 7),
            p_chain_edge: 0.25,
            p_report_conf: 0.95,
            p_report_symbols: 0.9,
            quality_dims: [8.9, 8.7, 9.0, 8.2, 8.8],
        }
    }
}

/// XML-emitting synthetic planner.
pub struct SyntheticPlanner {
    pub profile: PlannerProfile,
    /// Edge-model tokens/s used for the decomposition latency.
    plan_tps: f64,
    plan_prefill_tps: f64,
}

impl SyntheticPlanner {
    pub fn new(profile: PlannerProfile) -> SyntheticPlanner {
        let m = model_params("llama3.2-3b").unwrap();
        SyntheticPlanner {
            profile,
            plan_tps: m.serving.tps,
            plan_prefill_tps: m.serving.prefill_tps,
        }
    }

    pub fn paper_main() -> SyntheticPlanner {
        SyntheticPlanner::new(PlannerProfile::paper_main())
    }

    fn step_desc(role: Role, i: usize, query: &Query, rng: &mut Rng) -> String {
        let domain = query.domain_name();
        match role {
            Role::Explain => format!(
                "Explain: what are the key elements, constraints, and required output format of this {domain} question?"
            ),
            Role::Analyze => {
                const VERBS: [&str; 5] =
                    ["derive", "verify", "evaluate", "decompose", "cross-check"];
                let v = rng.choice(&VERBS);
                format!("Analyze: {v} intermediate result {i} needed for the {domain} question")
            }
            Role::Generate => "Generate: based on the previous steps, what is the final answer?"
                .to_string(),
        }
    }

    /// Emit a structurally *valid* plan skeleton (before defect injection).
    fn emit_valid(&self, query: &Query, rng: &mut Rng) -> Vec<StepSpec> {
        let p = &self.profile;
        let n = rng.int_range(p.nodes.0, p.nodes.1 + 1);
        let mut steps: Vec<StepSpec> = Vec::with_capacity(n);
        for i in 0..n {
            let role = if i == 0 {
                Role::Explain
            } else if i == n - 1 {
                Role::Generate
            } else {
                Role::Analyze
            };
            let deps: Vec<usize> = if i == 0 {
                vec![]
            } else if i == n - 1 {
                // GENERATE depends on all current sinks.
                let mut is_sink = vec![true; i];
                for s in &steps {
                    for &d in &s.deps {
                        if d < i {
                            is_sink[d] = false;
                        }
                    }
                }
                (0..i).filter(|&k| is_sink[k]).collect()
            } else if rng.bernoulli(p.p_chain_edge) {
                vec![i - 1]
            } else {
                // Wide structure: attach to the root plus maybe one other.
                let mut d = vec![0];
                if i >= 2 && rng.bernoulli(0.35) {
                    let extra = rng.int_range(1, i);
                    if !d.contains(&extra) {
                        d.push(extra);
                    }
                }
                d
            };
            let tokens = if rng.bernoulli(0.8) {
                let (mu, _sig) = match role {
                    Role::Explain => (4.2, 0.35),
                    Role::Analyze => (4.8, 0.40),
                    Role::Generate => (4.6, 0.35),
                };
                (rng.lognormal(mu, 0.25) * query.tok_mult).round()
            } else {
                0.0
            };
            steps.push(StepSpec {
                id: i + 1,
                desc: Self::step_desc(role, i, query, rng),
                deps,
                conf: vec![],
                tokens,
            });
        }
        // Attach confidences.
        for s in steps.iter_mut() {
            if rng.bernoulli(p.p_report_conf) {
                s.conf = s.deps.iter().map(|_| rng.uniform(0.55, 1.0)).collect();
            }
        }
        steps
    }

    /// Inject a defect. Light defects are repairable; heavy defects usually
    /// force the chain fallback.
    fn inject_defect(&self, steps: &mut Vec<StepSpec>, heavy: bool, rng: &mut Rng) -> bool {
        // Returns true if the plan text should be outright corrupted.
        if heavy {
            // Heavy defects mostly produce unparseable output (the paper's
            // fallback-to-chain cases); occasionally a dense structural mess
            // that bounded repair may or may not salvage.
            match rng.below(8) {
                0..=5 => return true, // malformed XML
                6 => {
                    // Dense cycle among all middle nodes with confident edges.
                    let n = steps.len();
                    if n >= 3 {
                        for i in 1..n {
                            let j = if i + 1 < n { i + 1 } else { 1 };
                            steps[i].deps = vec![j];
                            steps[i].conf = vec![1.0];
                        }
                    }
                }
                _ => {
                    // Explode size beyond n_max with interdependent clones.
                    let n0 = steps.len();
                    for k in 0..6 {
                        let id = n0 + k + 1;
                        steps.push(StepSpec {
                            id,
                            desc: format!("Analyze: spurious expansion {k}"),
                            deps: vec![id - 1],
                            conf: vec![],
                            tokens: 0.0,
                        });
                    }
                    // And a cycle between the clones.
                    let last = steps.len() - 1;
                    steps[n0].deps.push(last + 1); // unknown id too
                }
            }
            return false;
        }
        match rng.below(5) {
            0 => {
                // Single back edge (cycle) with low confidence.
                if steps.len() >= 3 {
                    let n = steps.len();
                    let i = rng.int_range(1, n - 1);
                    steps[i].deps.push(n);
                    if !steps[i].conf.is_empty() {
                        steps[i].conf.push(rng.uniform(0.1, 0.4));
                    }
                }
            }
            1 => {
                // Orphan: drop all deps of a middle node.
                if steps.len() >= 3 {
                    let i = rng.int_range(1, steps.len() - 1);
                    steps[i].deps.clear();
                    steps[i].conf.clear();
                }
            }
            2 => {
                // Duplicate GENERATE.
                if steps.len() >= 3 {
                    let i = rng.int_range(1, steps.len() - 1);
                    steps[i].desc = "Generate: premature final answer".into();
                }
            }
            3 => {
                // Unknown Rely id.
                let n = steps.len();
                let i = rng.below(n);
                steps[i].deps.push(n + 7);
                if !steps[i].conf.is_empty() {
                    steps[i].conf.push(0.3);
                }
            }
            _ => {
                // Wrong root role.
                steps[0].desc = steps[0].desc.replacen("Explain:", "Analyze:", 1);
            }
        }
        false
    }

    fn render(steps: &[StepSpec]) -> String {
        let mut xml = String::from("<Plan>\n");
        for s in steps {
            let rely: Vec<String> = s.deps.iter().map(|d| (d + 1).to_string()).collect();
            xml.push_str(&format!(
                "  <Step ID=\"{}\" Task=\"{}\" Rely=\"{}\"",
                s.id,
                s.desc.replace('"', "&quot;"),
                rely.join(",")
            ));
            if !s.conf.is_empty() && s.conf.len() == s.deps.len() {
                let conf: Vec<String> = s.conf.iter().map(|c| format!("{c:.2}")).collect();
                xml.push_str(&format!(" Conf=\"{}\"", conf.join(",")));
            }
            if s.tokens > 0.0 {
                xml.push_str(&format!(" Tokens=\"{}\"", s.tokens));
            }
            xml.push_str("/>\n");
        }
        xml.push_str("</Plan>");
        xml
    }
}

struct StepSpec {
    id: usize,
    desc: String,
    deps: Vec<usize>,
    conf: Vec<f64>,
    tokens: f64,
}

impl Planner for SyntheticPlanner {
    fn plan_text(&self, query: &Query, rng: &mut Rng) -> PlanText {
        let mut steps = self.emit_valid(query, rng);
        let mut corrupt_text = false;
        if !rng.bernoulli(self.profile.p_valid) {
            let heavy = !rng.bernoulli(self.profile.p_repairable_defect);
            corrupt_text = self.inject_defect(&mut steps, heavy, rng);
        }
        let mut xml = Self::render(&steps);
        if corrupt_text {
            // Truncate mid-attribute: guaranteed parse failure.
            let cut = xml.len() / 2;
            xml.truncate(cut);
        }
        // Decomposition latency: prompt prefill + plan decode on the edge.
        let plan_tokens = 18.0 * steps.len() as f64 + 25.0;
        let prompt_tokens = query.query_tokens + 350.0; // EAG meta-prompt + exemplars
        let planning_latency =
            prompt_tokens / self.plan_prefill_tps + plan_tokens / self.plan_tps;
        PlanText { xml, planning_latency, plan_tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::{validate, RepairOutcome};
    use crate::workload::{generate_queries, Benchmark};

    fn queries(n: usize) -> Vec<Query> {
        generate_queries(Benchmark::Gpqa, n, 11)
    }

    #[test]
    fn plans_parse_and_execute() {
        let p = SyntheticPlanner::paper_main();
        let mut rng = Rng::new(0);
        for q in queries(100) {
            let plan = p.plan(&q, 7, &mut rng);
            assert!(validate(&plan.dag, 7).is_valid());
            assert!(plan.planning_latency > 0.0);
        }
    }

    #[test]
    fn outcome_rates_match_table5() {
        let p = SyntheticPlanner::paper_main();
        let mut rng = Rng::new(1);
        let mut valid = 0;
        let mut repaired = 0;
        let mut fallback = 0;
        let n = 1200;
        for q in queries(n) {
            match p.plan(&q, 7, &mut rng).outcome {
                RepairOutcome::Valid => valid += 1,
                RepairOutcome::Repaired(_) => repaired += 1,
                RepairOutcome::Fallback => fallback += 1,
            }
        }
        let vr = valid as f64 / n as f64;
        let rr = repaired as f64 / n as f64;
        let fr = fallback as f64 / n as f64;
        // Paper: 76-78 / 13-14 / 9-10 (percent). Allow simulation slack.
        assert!((0.68..=0.86).contains(&vr), "valid rate {vr}");
        assert!((0.06..=0.22).contains(&rr), "repaired rate {rr}");
        assert!((0.03..=0.17).contains(&fr), "fallback rate {fr}");
    }

    #[test]
    fn avg_nodes_in_paper_range() {
        let p = SyntheticPlanner::paper_main();
        let mut rng = Rng::new(2);
        let mut total = 0usize;
        let mut count = 0usize;
        for q in queries(400) {
            let plan = p.plan(&q, 7, &mut rng);
            if plan.outcome != RepairOutcome::Fallback {
                total += plan.dag.len();
                count += 1;
            }
        }
        let avg = total as f64 / count as f64;
        assert!((3.6..=5.2).contains(&avg), "avg nodes {avg} (paper: 4.3-4.5)");
    }

    #[test]
    fn sft_has_higher_compression_than_base() {
        let mut rng = Rng::new(3);
        let rcomp = |prof: PlannerProfile, rng: &mut Rng| {
            let p = SyntheticPlanner::new(prof);
            let mut acc = 0.0;
            let qs = queries(300);
            for q in &qs {
                let plan = p.plan(q, 7, rng);
                acc += plan.dag.compression_ratio().unwrap_or(0.0);
            }
            acc / qs.len() as f64
        };
        let base = rcomp(PlannerProfile::base_llama(), &mut rng);
        let sft = rcomp(PlannerProfile::sft_llama(), &mut rng);
        assert!(sft > base + 0.1, "sft {sft} base {base} (paper: 34.3 vs 10.7)");
        assert!((0.02..=0.25).contains(&base), "base R_comp {base}");
        assert!((0.2..=0.5).contains(&sft), "sft R_comp {sft}");
    }

    #[test]
    fn heavier_profiles_make_longer_plans() {
        let mut rng = Rng::new(4);
        let p = SyntheticPlanner::new(PlannerProfile::base_llama());
        let qs = queries(200);
        let mut total = 0usize;
        for q in &qs {
            let plan = p.plan(q, 7, &mut rng);
            total += plan.dag.len();
        }
        let avg = total as f64 / qs.len() as f64;
        assert!(avg > 4.8, "base planner avg steps {avg} (paper 5.84)");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = SyntheticPlanner::paper_main();
        let q = &queries(1)[0];
        let a = p.plan_text(q, &mut Rng::new(9)).xml;
        let b = p.plan_text(q, &mut Rng::new(9)).xml;
        assert_eq!(a, b);
    }

    #[test]
    fn planning_latency_scales_with_plan_length() {
        let p = SyntheticPlanner::paper_main();
        let q = &queries(1)[0];
        let mut rng = Rng::new(5);
        let t = p.plan_text(q, &mut rng);
        // ~0.4s prefill + 2-3s decode at 42 tps for ~5 steps.
        assert!(t.planning_latency > 1.0 && t.planning_latency < 6.0,
                "planning latency {}", t.planning_latency);
    }
}

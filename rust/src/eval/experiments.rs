//! Per-table / per-figure experiment implementations.
//!
//! Every function regenerates one artifact of the paper's evaluation
//! section on the simulation substrate and renders it in the paper's row
//! format. Absolute numbers are substrate-dependent; the *shape* — method
//! ordering, who wins each column, crossover locations — is the
//! reproduction target (see EXPERIMENTS.md for paper-vs-measured).

use crate::baselines::{Cot, Direct, Dot, HybridLlm, Method, Pasta, Sot};
use crate::bench::Table;
use crate::config::simparams::SimParams;
use crate::dag::RepairOutcome;
use crate::engine::Backend;
use crate::metrics::{MethodMetrics, QueryOutcome, SeedStats};
use crate::models::SimExecutor;
use crate::pipeline::{HybridFlowPipeline, PipelineConfig};
use crate::planner::synthetic::{PlannerProfile, SyntheticPlanner};
use crate::planner::Planner;
use crate::router::{MirrorPredictor, RoutePolicy};
use crate::scheduler::events::PositionHistogram;
use crate::scheduler::ScheduleConfig;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::{generate_queries, Benchmark, Query};
use std::path::PathBuf;
use std::sync::Arc;

/// All registered experiment ids.
pub const EXPERIMENT_IDS: [&str; 15] = [
    "calibrate", "table1", "table2", "table3", "table5", "table6_fig4", "fig3", "table7",
    "table8", "fig5", "d1_exposure", "ablations", "fleet_serve", "fleet_mixed_policy",
    "fleet_cache",
];

/// Shared experiment context.
#[derive(Clone)]
pub struct ExpContext {
    pub seeds: Vec<u64>,
    /// Query-count scale factor (1.0 = paper-sized sets).
    pub scale: f64,
    pub artifacts_dir: PathBuf,
    pub threads: usize,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            seeds: vec![11, 22, 33],
            scale: 1.0,
            artifacts_dir: crate::config::default_artifacts_dir(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }
}

impl ExpContext {
    /// Bench configuration from env: BENCH_SCALE (default 1.0 = paper
    /// sizes), BENCH_SEEDS (default 3).
    pub fn from_bench_env() -> ExpContext {
        let mut ctx = ExpContext::default();
        if let Some(s) = std::env::var("BENCH_SCALE").ok().and_then(|v| v.parse().ok()) {
            ctx.scale = s;
        }
        if let Some(n) = std::env::var("BENCH_SEEDS").ok().and_then(|v| v.parse::<u64>().ok()) {
            ctx.seeds = (0..n).map(|i| 11 + 11 * i).collect();
        }
        ctx
    }

    pub fn quick() -> ExpContext {
        ExpContext { seeds: vec![11], scale: 0.3, ..Default::default() }
    }

    fn n_queries(&self, bench: Benchmark) -> usize {
        ((bench.params().n_queries as f64 * self.scale).round() as usize).max(10)
    }

    /// Load the trained-router mirror (synthetic fallback keeps experiments
    /// runnable pre-`make artifacts`, with a loud note).
    pub fn predictor(&self) -> Arc<MirrorPredictor> {
        match MirrorPredictor::from_meta_file(&self.artifacts_dir.join("router_meta.json")) {
            Ok(p) => Arc::new(p),
            Err(e) => {
                // lint:allow(print_in_lib): loud fallback warning by design
                eprintln!(
                    "[eval] WARNING: trained router unavailable ({e}); using synthetic predictor"
                );
                Arc::new(MirrorPredictor::synthetic_for_tests())
            }
        }
    }

    pub fn hybridflow(&self, policy: RoutePolicy) -> HybridFlowPipeline {
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = policy;
        HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            self.predictor(),
            cfg,
        )
    }
}

/// Adapter: run a HybridFlow pipeline as a `Method` row.
pub struct HybridFlowMethod {
    pub pipeline: HybridFlowPipeline,
    pub row_name: String,
}

impl Method for HybridFlowMethod {
    fn name(&self) -> &str {
        &self.row_name
    }

    fn model_label(&self) -> String {
        format!(
            "{}&{}",
            self.pipeline.executor.profile(false).kind.label(),
            self.pipeline.executor.profile(true).kind.label()
        )
    }

    fn run(&self, query: &Query, rng: &mut Rng) -> QueryOutcome {
        self.pipeline.run_query(query, rng)
    }
}

/// Evaluate one method on one benchmark across seeds (parallel over seeds).
pub fn eval_method(
    method: Arc<dyn Method>,
    bench: Benchmark,
    ctx: &ExpContext,
    pool: &ThreadPool,
) -> MethodMetrics {
    let n = ctx.n_queries(bench);
    let jobs: Vec<u64> = ctx.seeds.clone();
    let seeds: Vec<SeedStats> = pool.map(jobs, move |seed| {
        let queries = generate_queries(bench, n, seed);
        let mut rng = Rng::new(seed ^ 0xABCD);
        let outcomes: Vec<QueryOutcome> =
            queries.iter().map(|q| method.run(q, &mut rng)).collect();
        SeedStats::from_outcomes(&outcomes)
    });
    MethodMetrics::from_seeds(&seeds)
}

fn method_grid(ctx: &ExpContext) -> Vec<Arc<dyn Method>> {
    let ex = SimExecutor::paper_pair;
    let sp = SimParams::default();
    vec![
        Arc::new(Direct::new(ex(), false)),
        Arc::new(Direct::new(ex(), true)),
        Arc::new(Cot::new(ex(), false)),
        Arc::new(Cot::new(ex(), true)),
        Arc::new(Sot::new(ex(), false)),
        Arc::new(Sot::new(ex(), true)),
        Arc::new(Pasta::new(ex(), false)),
        Arc::new(Pasta::new(ex(), true)),
        Arc::new(HybridLlm::paper_default(ex())),
        Arc::new(Dot::paper_default(ex())),
        Arc::new(HybridFlowMethod {
            pipeline: ctx.hybridflow(RoutePolicy::hybridflow(&sp)),
            row_name: "HybridFlow (Ours)".into(),
        }),
    ]
}

// ---------------------------------------------------------------------------
// Experiments.
// ---------------------------------------------------------------------------

/// Single-model reference accuracies vs. the paper's Table 1 targets —
/// the substrate calibration check.
pub fn calibrate(ctx: &ExpContext) -> String {
    let pool = ThreadPool::new(ctx.threads);
    let mut t = Table::new(
        "Calibration: single-model reference vs paper targets",
        &["Method", "Model", "Benchmark", "Acc (sim)", "Acc (paper)", "C_time (sim)", "C_time (paper)"],
    );
    let paper: &[(&str, bool, Benchmark, f64, f64)] = &[
        ("Direct", false, Benchmark::Gpqa, 16.89, 6.61),
        ("Direct", true, Benchmark::Gpqa, 51.79, 15.26),
        ("Direct", false, Benchmark::MmluPro, 22.83, 7.03),
        ("Direct", true, Benchmark::MmluPro, 65.50, 11.77),
        ("Direct", false, Benchmark::Aime24, 4.44, 9.92),
        ("Direct", true, Benchmark::Aime24, 37.78, 50.44),
        ("Direct", false, Benchmark::LiveBench, 12.00, 13.34),
        ("Direct", true, Benchmark::LiveBench, 58.25, 36.77),
        ("CoT", false, Benchmark::Gpqa, 25.54, 11.99),
        ("CoT", true, Benchmark::Gpqa, 57.28, 18.26),
        ("CoT", true, Benchmark::MmluPro, 72.00, 19.35),
        ("CoT", true, Benchmark::Aime24, 44.42, 56.70),
        ("CoT", true, Benchmark::LiveBench, 62.25, 29.77),
    ];
    for &(name, cloud, bench, acc_paper, time_paper) in paper {
        let m: Arc<dyn Method> = if name == "Direct" {
            Arc::new(Direct::new(SimExecutor::paper_pair(), cloud))
        } else {
            Arc::new(Cot::new(SimExecutor::paper_pair(), cloud))
        };
        let label = m.model_label();
        let metrics = eval_method(m, bench, ctx, &pool);
        t.row(vec![
            name.into(),
            label,
            bench.display().into(),
            format!("{:.2}", metrics.acc_mean),
            format!("{acc_paper:.2}"),
            format!("{:.2}", metrics.time_mean),
            format!("{time_paper:.2}"),
        ]);
    }
    t.render()
}

/// Table 1: accuracy of all methods across the four benchmarks.
pub fn table1(ctx: &ExpContext) -> String {
    let pool = ThreadPool::new(ctx.threads);
    let mut t = Table::new(
        "Table 1: Accuracy (%, mean+/-std)",
        &["Method", "Model", "GPQA", "MMLU-Pro", "AIME24", "LiveBench-Reasoning", "Avg"],
    );
    for m in method_grid(ctx) {
        let mut cells = vec![m.name().to_string(), m.model_label()];
        let mut accs = Vec::new();
        for bench in Benchmark::ALL {
            let metrics = eval_method(Arc::clone(&m), bench, ctx, &pool);
            accs.push(metrics.acc_mean);
            cells.push(metrics.acc_cell());
        }
        cells.push(format!("{:.2}", mean(&accs)));
        t.row(cells);
    }
    t.render()
}

/// Table 2: efficiency (C_time and C_API) of all methods.
pub fn table2(ctx: &ExpContext) -> String {
    let pool = ThreadPool::new(ctx.threads);
    let mut t = Table::new(
        "Table 2: Efficiency (C_time s / C_API $)",
        &["Method", "Model", "Metric", "GPQA", "MMLU-Pro", "AIME24", "LiveBench-Reasoning", "Avg"],
    );
    for m in method_grid(ctx) {
        let per_bench: Vec<MethodMetrics> = Benchmark::ALL
            .iter()
            .map(|&b| eval_method(Arc::clone(&m), b, ctx, &pool))
            .collect();
        let mut time_cells = vec![m.name().to_string(), m.model_label(), "C_time".to_string()];
        let mut times = Vec::new();
        for metrics in &per_bench {
            time_cells.push(metrics.time_cell());
            times.push(metrics.time_mean);
        }
        time_cells.push(format!("{:.2}", mean(&times)));
        t.row(time_cells);

        let mut api_cells = vec![m.name().to_string(), m.model_label(), "C_API".to_string()];
        let mut apis = Vec::new();
        for metrics in &per_bench {
            api_cells.push(metrics.api_cell());
            apis.push(metrics.api_mean);
        }
        let avg_api = mean(&apis);
        api_cells.push(if avg_api == 0.0 { "-".into() } else { format!("{avg_api:.4}") });
        t.row(api_cells);
    }
    t.render()
}

/// Table 3: routing-strategy ablation on GPQA.
pub fn table3(ctx: &ExpContext) -> String {
    let pool = ThreadPool::new(ctx.threads);
    let sp = SimParams::default();
    let bench = Benchmark::Gpqa;

    // Reference: edge CoT (the paper's Edge row is CoT on Llama3.2-3B).
    let edge_ref = eval_method(
        Arc::new(Cot::new(SimExecutor::paper_pair(), false)),
        bench,
        ctx,
        &pool,
    );

    let rows: Vec<(String, Arc<dyn Method>)> = vec![
        (
            "Cloud (all)".into(),
            Arc::new(HybridFlowMethod {
                pipeline: ctx.hybridflow(RoutePolicy::AllCloud),
                row_name: "Cloud".into(),
            }),
        ),
        (
            "Random".into(),
            Arc::new(HybridFlowMethod {
                pipeline: ctx.hybridflow(RoutePolicy::Random(0.42)),
                row_name: "Random".into(),
            }),
        ),
        (
            "Fixed Threshold (tau0=0.5)".into(),
            Arc::new(HybridFlowMethod {
                pipeline: ctx.hybridflow(RoutePolicy::FixedThreshold(0.5)),
                row_name: "Fixed".into(),
            }),
        ),
        ("HybridFlow-Chain".into(), {
            let mut p = ctx.hybridflow(RoutePolicy::hybridflow(&sp));
            p.config.schedule = ScheduleConfig { chain_mode: true, ..Default::default() };
            Arc::new(HybridFlowMethod { pipeline: p, row_name: "HybridFlow-Chain".into() })
        }),
        (
            "HybridFlow (Ours)".into(),
            Arc::new(HybridFlowMethod {
                pipeline: ctx.hybridflow(RoutePolicy::hybridflow(&sp)),
                row_name: "HybridFlow".into(),
            }),
        ),
        (
            "Oracle (knapsack bound)".into(),
            Arc::new(HybridFlowMethod {
                pipeline: ctx.hybridflow(RoutePolicy::Oracle),
                row_name: "Oracle".into(),
            }),
        ),
    ];

    let mut t = Table::new(
        "Table 3: Routing ablation on GPQA",
        &["Method", "Offload (%)", "Acc (%)", "Latency (s)", "API ($)", "Norm.Cost c", "Utility u"],
    );
    t.row(vec![
        "Edge (all)".into(),
        "0.0".into(),
        format!("{:.2}", edge_ref.acc_mean),
        format!("{:.2}", edge_ref.time_mean),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    for (label, m) in rows {
        let metrics = eval_method(m, bench, ctx, &pool);
        let (c, u) = metrics.norm_cost_and_utility(&sp, &edge_ref);
        t.row(vec![
            label,
            format!("{:.1}", metrics.offload_mean * 100.0),
            format!("{:.2}", metrics.acc_mean),
            format!("{:.2}", metrics.time_mean),
            metrics.api_cell(),
            c.map_or("-".into(), |v| format!("{v:.4}")),
            u.map_or("-".into(), |v| format!("{v:.4}")),
        ]);
    }
    t.render()
}

/// Table 5: planner validity/repair/fallback statistics.
pub fn table5(ctx: &ExpContext) -> String {
    let planner = SyntheticPlanner::paper_main();
    let mut t = Table::new(
        "Table 5: Planner DAG validity and repair",
        &["Benchmark", "Valid (%)", "Repaired (%)", "Fallback (%)", "#nodes (avg)"],
    );
    for bench in [Benchmark::Gpqa, Benchmark::LiveBench] {
        let n = (500.0 * ctx.scale).max(50.0) as usize;
        let mut valid = 0;
        let mut repaired = 0;
        let mut fallback = 0;
        let mut nodes = 0usize;
        let mut executed = 0usize;
        for seed in &ctx.seeds {
            let mut rng = Rng::new(seed ^ 0x7a5);
            for q in generate_queries(bench, n, *seed) {
                let plan = planner.plan(&q, 7, &mut rng);
                match plan.outcome {
                    RepairOutcome::Valid => valid += 1,
                    RepairOutcome::Repaired(_) => repaired += 1,
                    RepairOutcome::Fallback => fallback += 1,
                }
                if plan.outcome != RepairOutcome::Fallback {
                    nodes += plan.dag.len();
                    executed += 1;
                }
            }
        }
        let total = (valid + repaired + fallback) as f64;
        t.row(vec![
            bench.display().into(),
            format!("{:.0}", valid as f64 / total * 100.0),
            format!("{:.0}", repaired as f64 / total * 100.0),
            format!("{:.0}", fallback as f64 / total * 100.0),
            format!("{:.2}", nodes as f64 / executed.max(1) as f64),
        ]);
    }
    t.render()
}

/// Table 6 / Figure 4: fixed-threshold sweep on GPQA.
pub fn table6_fig4(ctx: &ExpContext) -> String {
    let pool = ThreadPool::new(ctx.threads);
    let sp = SimParams::default();
    let bench = Benchmark::Gpqa;
    let edge_ref =
        eval_method(Arc::new(Cot::new(SimExecutor::paper_pair(), false)), bench, ctx, &pool);

    let mut t = Table::new(
        "Table 6 / Figure 4: fixed offload threshold sweep on GPQA",
        &["tau0", "Offload (%)", "Acc (%)", "Latency (s)", "API ($)", "Norm.Cost c", "Utility u"],
    );
    let mut best: Option<(f64, f64)> = None;
    for k in (0..=10).rev() {
        let tau = k as f64 / 10.0;
        let m = Arc::new(HybridFlowMethod {
            pipeline: ctx.hybridflow(RoutePolicy::FixedThreshold(tau)),
            row_name: format!("tau={tau}"),
        });
        let metrics = eval_method(m, bench, ctx, &pool);
        let (c, u) = metrics.norm_cost_and_utility(&sp, &edge_ref);
        if let Some(uv) = u {
            if best.map_or(true, |(_, bu)| uv > bu) {
                best = Some((tau, uv));
            }
        }
        t.row(vec![
            format!("{tau:.1}"),
            format!("{:.2}", metrics.offload_mean * 100.0),
            format!("{:.2}", metrics.acc_mean),
            format!("{:.2}", metrics.time_mean),
            metrics.api_cell(),
            c.map_or("N/A".into(), |v| format!("{v:.4}")),
            u.map_or("N/A".into(), |v| format!("{v:.4}")),
        ]);
    }
    let mut out = t.render();
    if let Some((tau, u)) = best {
        out.push_str(&format!(
            "\nBest fixed threshold: tau0={tau:.1} (u={u:.4}); paper peaks at tau0=0.6 (u=0.6329).\n\
             The adaptive router (Table 3) should exceed every fixed point.\n"
        ));
    }
    out
}

/// Figure 3: edge/cloud distribution by subtask position + mean threshold.
pub fn fig3(ctx: &ExpContext) -> String {
    let sp = SimParams::default();
    // The paper's Figure 3 plots the Eq. 27 deployment, whose threshold
    // rises with cumulative k/l consumption - i.e. with subtask position.
    let pipeline = ctx.hybridflow(RoutePolicy::hybridflow_eq27(&sp));
    let mut hist = PositionHistogram::default();
    let n = ctx.n_queries(Benchmark::Gpqa);
    for seed in &ctx.seeds {
        let mut rng = Rng::new(seed ^ 0xF16);
        for q in generate_queries(Benchmark::Gpqa, n, *seed) {
            let (exec, _) = pipeline.run_query_traced(&q, &mut rng);
            hist.add(&exec.events);
        }
    }
    let mut t = Table::new(
        "Figure 3: executed subtasks by position (GPQA)",
        &["Position", "Edge", "Cloud", "Cloud share (%)", "Mean tau"],
    );
    for p in 0..hist.positions() {
        let e = hist.edge[p];
        let c = hist.cloud[p];
        let total = (e + c).max(1);
        t.row(vec![
            p.to_string(),
            e.to_string(),
            c.to_string(),
            format!("{:.1}", c as f64 / total as f64 * 100.0),
            format!("{:.3}", hist.mean_tau(p)),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "\nExpected shape (paper): cloud usage concentrates at early positions;\n\
         mean tau rises with position as budget burns; node counts shrink at depth.\n",
    );
    out
}

/// Table 7: base vs SFT planner (worker: edge model only).
pub fn table7(ctx: &ExpContext) -> String {
    let mut t = Table::new(
        "Table 7: Planner comparison (worker: Llama3.2-3B, GPQA)",
        &["Planner", "Avg Steps", "R_comp (%)", "C_time (s)", "Acc (%)"],
    );
    for (name, profile) in [
        ("Llama3.2-3B base", PlannerProfile::base_llama()),
        ("Llama3.2-3B SFT", PlannerProfile::sft_llama()),
    ] {
        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = RoutePolicy::AllEdge;
        let pipeline = HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::new(profile),
            ctx.predictor(),
            cfg,
        );
        let n = ctx.n_queries(Benchmark::Gpqa);
        let mut steps = Vec::new();
        let mut rcomp = Vec::new();
        let mut outcomes = Vec::new();
        for seed in &ctx.seeds {
            let mut rng = Rng::new(seed ^ 0x707);
            for q in generate_queries(Benchmark::Gpqa, n, *seed) {
                let plan = pipeline.planner.plan(&q, 7, &mut rng);
                steps.push(plan.dag.len() as f64);
                rcomp.push(plan.dag.compression_ratio().unwrap_or(0.0) * 100.0);
                outcomes.push(pipeline.run_query(&q, &mut rng));
            }
        }
        let stats = SeedStats::from_outcomes(&outcomes);
        t.row(vec![
            name.into(),
            format!("{:.2}", mean(&steps)),
            format!("{:.1}", mean(&rcomp)),
            format!("{:.2}", stats.time),
            format!("{:.2}", stats.acc),
        ]);
    }
    let mut out = t.render();
    out.push_str("\nPaper: base 5.84 steps / 10.7% / 10.81s / 20.0%; SFT 6.12 / 34.3% / 11.59s / 22.0%.\n");
    out
}

/// Table 8: model-pair swap (Qwen2.5-7B edge, DeepSeek-V3 cloud) on GPQA.
pub fn table8(ctx: &ExpContext) -> String {
    let pool = ThreadPool::new(ctx.threads);
    let sp = SimParams::default();
    let bench = Benchmark::Gpqa;
    let swap = SimExecutor::swap_pair;

    let hybrid = |policy: RoutePolicy, name: &str| -> Arc<dyn Method> {
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = policy;
        Arc::new(HybridFlowMethod {
            pipeline: HybridFlowPipeline::with_predictor(
                swap(),
                SyntheticPlanner::paper_main(),
                ctx.predictor(),
                cfg,
            ),
            row_name: name.into(),
        })
    };

    let rows: Vec<(&str, Arc<dyn Method>)> = vec![
        ("All-Edge CoT (Qwen2.5-7B)", Arc::new(Cot::new(swap(), false))),
        ("All-Cloud CoT (DeepSeek-V3)", Arc::new(Cot::new(swap(), true))),
        ("HybridLLM", Arc::new(HybridLlm::paper_default(swap()))),
        ("DoT", Arc::new(Dot::paper_default(swap()))),
        ("HybridFlow (Ours)", hybrid(RoutePolicy::hybridflow(&sp), "HybridFlow")),
    ];

    let mut t = Table::new(
        "Table 8: GPQA under swapped edge/cloud pair (Qwen2.5-7B + DeepSeek-V3)",
        &["Method", "Accuracy (%)", "API Cost (1e-3 $)", "Latency (s)"],
    );
    for (name, m) in rows {
        let metrics = eval_method(m, bench, ctx, &pool);
        t.row(vec![
            name.into(),
            format!("{:.1}", metrics.acc_mean),
            if metrics.api_mean == 0.0 {
                "NA".into()
            } else {
                format!("{:.2}", metrics.api_mean * 1e3)
            },
            format!("{:.2}", metrics.time_mean),
        ]);
    }
    let mut out = t.render();
    out.push_str("\nPaper: Edge 34/NA/19.52; Cloud 59/6.70/61.00; HybridLLM 47/3.63/47.87; DoT 49/1.80/40.90; HybridFlow 53/1.16/36.86.\n");
    out
}

/// Figure 5: planner quality across five intrinsic dimensions.
pub fn fig5(ctx: &ExpContext) -> String {
    let dims = ["Soundness", "DependencyFlow", "Clarity", "AttributeAcc", "Relevance"];
    let mut t = Table::new(
        "Figure 5: planner evaluation across five dimensions (0-10)",
        &["Planner", dims[0], dims[1], dims[2], dims[3], dims[4]],
    );
    for (name, profile) in [
        ("Ours (SFT)", PlannerProfile::sft_llama()),
        ("Base Llama3.2-3B", PlannerProfile::base_llama()),
        ("EAG main planner", PlannerProfile::paper_main()),
        ("Frontier reference", PlannerProfile::frontier_reference()),
    ] {
        // Two dims are *measured* from generated plans (soundness from
        // valid+repaired rate, dependency flow from R_comp); the judge-style
        // dims come from the profile's quality model with sampling noise.
        let planner = SyntheticPlanner::new(profile.clone());
        let n = (200.0 * ctx.scale).max(30.0) as usize;
        let mut rng = Rng::new(0x515);
        let mut ok = 0usize;
        let mut rcomp = 0.0;
        let qs = generate_queries(Benchmark::Gpqa, n, 99);
        for q in &qs {
            let plan = planner.plan(q, 7, &mut rng);
            if plan.outcome != RepairOutcome::Fallback {
                ok += 1;
            }
            rcomp += plan.dag.compression_ratio().unwrap_or(0.0);
        }
        let soundness = ok as f64 / n as f64 * 10.0;
        let depflow = (rcomp / n as f64) / 0.5 * 10.0; // 0.5 R_comp ~ full marks
        let judged: Vec<f64> = profile
            .quality_dims
            .iter()
            .map(|&q| (q + rng.normal_ms(0.0, 0.15)).clamp(0.0, 10.0))
            .collect();
        t.row(vec![
            name.into(),
            format!("{soundness:.1}"),
            format!("{:.1}", depflow.min(10.0)),
            format!("{:.1}", judged[2]),
            format!("{:.1}", judged[3]),
            format!("{:.1}", judged[4]),
        ]);
    }
    t.render()
}

/// App. D.1: cloud data-exposure proxy (Eqs. 29-31) across paradigms.
pub fn d1_exposure(ctx: &ExpContext) -> String {
    use crate::metrics::exposure::Exposure;
    let sp = SimParams::default();
    let bench = Benchmark::Gpqa;
    let n = ctx.n_queries(bench);

    let mut t = Table::new(
        "App. D.1: cloud exposure proxy on GPQA (tokens transmitted to cloud)",
        &["Paradigm", "E_cloud (tok/query)", "E_bar (norm.)", "Cloud calls/query", "Acc (%)"],
    );
    let rows: Vec<(&str, RoutePolicy)> = vec![
        ("Edge-only", RoutePolicy::AllEdge),
        ("Cloud-only (per-subtask)", RoutePolicy::AllCloud),
        ("HybridFlow", RoutePolicy::hybridflow(&sp)),
        ("HybridFlow (Eq. 27)", RoutePolicy::hybridflow_eq27(&sp)),
    ];
    for (name, policy) in rows {
        let pipeline = ctx.hybridflow(policy);
        let mut total = Exposure::default();
        let mut correct = 0usize;
        let mut queries_run = 0usize;
        for seed in &ctx.seeds {
            let mut rng = Rng::new(seed ^ 0xD1);
            for q in generate_queries(bench, n, *seed) {
                let (exec, _) = pipeline.run_query_traced(&q, &mut rng);
                total.merge(&Exposure::from_events(&exec.events));
                correct += usize::from(exec.correct);
                queries_run += 1;
            }
        }
        let qf = queries_run.max(1) as f64;
        t.row(vec![
            name.into(),
            format!("{:.0}", total.e_cloud / qf),
            if total.e_cloud + total.e_edge > 0.0 {
                format!("{:.3}", total.normalized())
            } else {
                "-".into()
            },
            format!("{:.2}", total.n_cloud_calls as f64 / qf),
            format!("{:.2}", correct as f64 / qf * 100.0),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "\nPaper claim (App. D.1): HybridFlow reduces the exposure *surface* vs\n\
         cloud-only by offloading a subset of subtasks and transmitting only\n\
         (s_i, dep answers), never the full query; it is not a privacy guarantee.\n",
    );
    out
}

/// Design-choice ablations DESIGN.md calls out: edge-worker count, cloud
/// concurrency, and the planner subtask cap n_max.
pub fn ablations(ctx: &ExpContext) -> String {
    let sp = SimParams::default();
    let bench = Benchmark::Gpqa;
    let n = ctx.n_queries(bench);

    let run = |mut cfg_mut: Box<dyn FnMut(&mut PipelineConfig)>| -> (f64, f64, f64) {
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg_mut(&mut cfg);
        let pipeline = HybridFlowPipeline::with_predictor(
            SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            ctx.predictor(),
            cfg,
        );
        let mut correct = 0usize;
        let (mut lat, mut api) = (0.0, 0.0);
        let mut count = 0usize;
        for seed in &ctx.seeds {
            let mut rng = Rng::new(seed ^ 0xAB1);
            for q in generate_queries(bench, n, *seed) {
                let o = pipeline.run_query(&q, &mut rng);
                correct += usize::from(o.correct);
                lat += o.latency;
                api += o.api_cost;
                count += 1;
            }
        }
        let cf = count.max(1) as f64;
        (correct as f64 / cf * 100.0, lat / cf, api / cf)
    };

    let mut t = Table::new(
        "Ablations: resource topology and planner cap (GPQA, HybridFlow)",
        &["Variant", "Acc (%)", "C_time (s)", "C_API ($)"],
    );
    for workers in [1usize, 2, 4] {
        let (acc, lat, api) = run(Box::new(move |c| c.schedule.edge_workers = workers));
        t.row(vec![format!("edge workers = {workers}"), format!("{acc:.2}"), format!("{lat:.2}"), format!("{api:.4}")]);
    }
    for cw in [1usize, 2, 8] {
        let (acc, lat, api) = run(Box::new(move |c| c.schedule.cloud_workers = cw));
        t.row(vec![format!("cloud concurrency = {cw}"), format!("{acc:.2}"), format!("{lat:.2}"), format!("{api:.4}")]);
    }
    for nmax in [3usize, 5, 7] {
        let (acc, lat, api) = run(Box::new(move |c| c.n_max = nmax));
        t.row(vec![format!("planner n_max = {nmax}"), format!("{acc:.2}"), format!("{lat:.2}"), format!("{api:.4}")]);
    }

    // Observation-noise sensitivity: degrade the router's difficulty /
    // criticality observations and watch routing quality decay toward the
    // Random baseline (motivates the paper's online calibration).
    for noise_mult in [1.0f64, 2.0, 4.0] {
        let mut executor = SimExecutor::paper_pair();
        executor.sp.diff_noise_std *= noise_mult;
        executor.sp.crit_noise_std *= noise_mult;
        let pipeline = HybridFlowPipeline::with_predictor(
            executor,
            SyntheticPlanner::paper_main(),
            ctx.predictor(),
            PipelineConfig::paper_default(&sp),
        );
        let mut correct = 0usize;
        let (mut lat, mut api) = (0.0, 0.0);
        let mut count = 0usize;
        for seed in &ctx.seeds {
            let mut rng = Rng::new(seed ^ 0xAB2);
            for q in generate_queries(bench, n, *seed) {
                let o = pipeline.run_query(&q, &mut rng);
                correct += usize::from(o.correct);
                lat += o.latency;
                api += o.api_cost;
                count += 1;
            }
        }
        let cf = count.max(1) as f64;
        t.row(vec![
            format!("observation noise x{noise_mult}"),
            format!("{:.2}", correct as f64 / cf * 100.0),
            format!("{:.2}", lat / cf),
            format!("{:.4}", api / cf),
        ]);
    }
    let mut out = t.render();
    out.push_str("\nExpected: more edge workers cut C_time toward the cloud-parallel bound;\n\
        cloud concurrency=1 serializes API calls (latency rises, accuracy flat);\n\
        small n_max truncates plans (coarser routing granularity).\n");
    out
}

/// Fleet serving: queueing delay, tail sojourn, offload rate, and budget
/// pressure as the open-loop arrival rate sweeps from idle to saturated.
///
/// Three tenants share an 8-edge-worker / 16-cloud-call fleet; two tenants
/// draw finite dollar pools from a shared global budget, so the sweep also
/// shows cap-forced edge execution once spend runs dry. Contention is the
/// new axis the per-query tables cannot express: the same router, executor,
/// and workload, but fleet-level `C_used(t)` and shared worker pools.
///
/// Declarative: the whole rate grid is one
/// `scenario::presets::fleet_serve_sweep` (each cell is the `fleet_serve`
/// spec at one swept rate), fanned out across the thread pool by the
/// sweep engine — per-cell results are byte-identical to running the
/// cells serially.
pub fn fleet_serve(ctx: &ExpContext) -> String {
    use crate::scenario::presets;

    let bench = Benchmark::Gpqa;
    let n = ((120.0 * ctx.scale).round() as usize).max(20);
    let seed = *ctx.seeds.first().unwrap_or(&11);

    let sweep = presets::fleet_serve_sweep(bench, n, seed)
        .run(ctx.predictor(), ctx.threads)
        .expect("static fleet_serve rate grid resolves");

    let mut t = Table::new(
        "Fleet serving: contention sweep (GPQA, 3 tenants, 8 edge / 16 cloud workers)",
        &[
            "Arrival (q/s)", "Admit p99 (s)", "Queue p99 (s)", "Sojourn p50 (s)",
            "Sojourn p99 (s)", "Offload (%)", "Forced-edge", "C_API ($)", "Edge util (%)",
        ],
    );
    for cell in &sweep.cells {
        let rate = cell.values[0];
        let report = &cell.report;
        t.row(vec![
            format!("{rate:.2}"),
            format!("{:.2}", report.admission_delay.p99),
            format!("{:.2}", report.queue_wait.p99),
            format!("{:.2}", report.sojourn.p50),
            format!("{:.2}", report.sojourn.p99),
            format!("{:.1}", report.offload_rate * 100.0),
            report.forced_edge.to_string(),
            format!("{:.4}", report.total_api_cost),
            format!("{:.1}", report.edge_utilization * 100.0),
        ]);
    }
    let mut out = t.render();
    out.push_str(
        "\nExpected shape: queueing delay and p99 sojourn explode past the edge-pool\n\
         saturation point while offload rises (the router sees fleet-level pressure);\n\
         the capped tenant accumulates forced-to-edge decisions at every rate.\n",
    );
    out
}

/// Mixed-policy fleet + hedged speculative dispatch.
///
/// Exercises the two engine seams together: three tenants run *different*
/// routers in one fleet (per-tenant policy overrides in the scenario
/// topology), and the same workload is served twice — hedging off, then
/// on. With hedging, edge-routed pivotal subtasks dispatch speculative
/// cloud replicas; first finish wins, losers are cancelled with budget
/// refunds. The comparison to read: hedging should cut the sojourn tail
/// (p95/p99) at essentially unchanged accuracy, paying only the consumed
/// share of cancelled speculative calls.
///
/// The scenario itself is `scenario::presets::mixed_policy` — the same
/// spec `examples/fleet_mixed_policy.rs` runs and
/// `scenarios/fleet_mixed_policy.json` ships.
pub fn fleet_mixed_policy(ctx: &ExpContext) -> String {
    use crate::scenario::presets::{self, MixedPolicyKnobs};
    use crate::scenario::Report as FleetReport;

    let bench = Benchmark::Gpqa;
    let n = ((90.0 * ctx.scale).round() as usize).max(18);
    let seed = *ctx.seeds.first().unwrap_or(&11);

    let run = |hedge: bool| -> FleetReport {
        let knobs = MixedPolicyKnobs { hedge, ..Default::default() };
        presets::mixed_policy(bench, n, 0.6, seed, &knobs)
            .build(ctx.predictor())
            .expect("canonical preset spec is valid")
            .run()
    };

    let off = run(false);
    let on = run(true);

    let acc = |r: &FleetReport| {
        r.results.iter().filter(|q| q.exec.correct).count() as f64
            / r.results.len().max(1) as f64
            * 100.0
    };

    let mut t = Table::new(
        "Mixed-policy fleet: hedged speculative dispatch off vs on (GPQA, 3 tenants)",
        &[
            "Hedge", "Sojourn p50 (s)", "Sojourn p95 (s)", "Sojourn p99 (s)", "Acc (%)",
            "Offload (%)", "C_API ($)", "Cancelled", "Refund ($)",
        ],
    );
    for (label, r) in [("off", &off), ("on", &on)] {
        t.row(vec![
            label.into(),
            format!("{:.2}", r.sojourn.p50),
            format!("{:.2}", r.sojourn.p95),
            format!("{:.2}", r.sojourn.p99),
            format!("{:.2}", acc(r)),
            format!("{:.1}", r.offload_rate * 100.0),
            format!("{:.4}", r.total_api_cost),
            r.hedge_cancelled.to_string(),
            format!("{:.4}", r.hedge_refund),
        ]);
    }

    let mut per_tenant = Table::new(
        "Per-tenant routing under overrides (hedge on)",
        &["Tenant", "Policy", "Decided", "Offload (%)", "Spend ($)"],
    );
    let policies = ["HybridFlow (default)", "Fixed(tau0=0.65)", "AllEdge"];
    for (tp, policy) in on.tenants.iter().zip(policies) {
        per_tenant.row(vec![
            tp.name.clone(),
            policy.into(),
            tp.state.n_decided.to_string(),
            format!("{:.1}", tp.state.offload_rate() * 100.0),
            format!("{:.4}", tp.state.k_used),
        ]);
    }

    let mut out = t.render();
    out.push('\n');
    out.push_str(&per_tenant.render());
    let dp95 = off.sojourn.p95 - on.sojourn.p95;
    out.push_str(&format!(
        "\nhedging moved sojourn p95 by {:+.2}s ({} -> {:.2}s) and accuracy by {:+.2} pts \
         ({} speculative losers cancelled, ${:.4} refunded of ${:.4} billed).\n\
         Expected shape: p95/p99 drop (pivotal subtasks stop queueing on the edge pool),\n\
         accuracy holds or rises slightly (cloud winners are drawn from the stronger model),\n\
         and the API bill rises only by the consumed share of cancelled replicas.\n",
        -dp95,
        format!("{:.2}s", off.sojourn.p95),
        on.sojourn.p95,
        acc(&on) - acc(&off),
        on.hedge_cancelled,
        on.hedge_refund,
        on.total_api_cost + on.hedge_refund,
    ));
    out
}

/// Cloud tokens actually transmitted over a fleet run (the App. D.1
/// payload proxy, same rule as `metrics::exposure`): input tokens of
/// every event that dispatched a cloud call — cloud winners *and* hedged
/// edge-wins, whose speculative cloud replica carried the payload before
/// cancellation. Cache hits transmit nothing.
pub fn fleet_cloud_tokens(report: &crate::scheduler::fleet::FleetReport) -> f64 {
    report
        .results
        .iter()
        .flat_map(|r| r.exec.events.iter())
        .filter(|e| (e.cloud || e.hedged) && !e.cached)
        .map(|e| e.in_tokens)
        .sum()
}

/// Cross-query result cache on a Zipf-popularity fleet: sweep cache
/// capacity against hit rate, transmitted cloud tokens, API spend, and
/// sojourn p50/p95. Capacity 0 is the cache-off baseline; every other row
/// serves the identical workload, so token/latency deltas are pure cache
/// effect. A second mini-table compares eviction policies at one
/// capacity.
///
/// The scenario itself is `scenario::presets::fleet_cache` — the same
/// spec `examples/fleet_cache.rs` runs and `scenarios/fleet_cache.json`
/// ships; the capacity grid is `presets::fleet_cache_sweep` (shipped as
/// `scenarios/fleet_cache_sweep.json`), run across the thread pool by the
/// sweep engine with per-cell results byte-identical to serial execution.
pub fn fleet_cache(ctx: &ExpContext) -> String {
    use crate::cache::CachePolicyKind;
    use crate::scenario::presets::{self, FleetCacheKnobs};
    use crate::scenario::Report as FleetReport;

    let bench = Benchmark::Gpqa;
    let n = ((120.0 * ctx.scale).round() as usize).max(24);
    let seed = *ctx.seeds.first().unwrap_or(&11);
    let zipf_distinct = (n / 10).max(4);

    let run = |capacity: usize, policy: CachePolicyKind| -> FleetReport {
        let knobs = FleetCacheKnobs { capacity, policy, zipf_distinct, ..Default::default() };
        presets::fleet_cache(bench, n, 0.5, seed, &knobs)
            .build(ctx.predictor())
            .expect("canonical preset spec is valid")
            .run()
    };

    let acc = |r: &FleetReport| {
        r.results.iter().filter(|q| q.exec.correct).count() as f64
            / r.results.len().max(1) as f64
            * 100.0
    };

    let mut t = Table::new(
        &format!(
            "Result cache on a Zipf fleet (GPQA, {n} queries, {zipf_distinct} prototypes, \
             s=1.1, LRU, shared tier)"
        ),
        &[
            "Capacity", "Hit rate (%)", "Cloud tokens", "Tokens saved", "C_API ($)",
            "Sojourn p50 (s)", "Sojourn p95 (s)", "Acc (%)",
        ],
    );
    // The capacity grid as one declarative sweep across the thread pool
    // (capacity 0 = the cache-off baseline cell).
    let knobs = FleetCacheKnobs { zipf_distinct, ..Default::default() };
    let sweep = presets::fleet_cache_sweep(bench, n, 0.5, seed, &knobs)
        .run(ctx.predictor(), ctx.threads)
        .expect("static fleet_cache capacity grid resolves");
    let mut baseline_tokens = None;
    for cell in &sweep.cells {
        let capacity = cell.values[0] as usize;
        let report = &cell.report;
        let tokens = fleet_cloud_tokens(report);
        if capacity == 0 {
            baseline_tokens = Some(tokens);
        }
        let (hit_rate, saved) = report
            .cache
            .as_ref()
            .map_or((0.0, 0.0), |c| (c.hit_rate() * 100.0, c.tokens_saved));
        t.row(vec![
            if capacity == 0 { "off".into() } else { capacity.to_string() },
            format!("{hit_rate:.1}"),
            format!("{tokens:.0}"),
            format!("{saved:.0}"),
            format!("{:.4}", report.total_api_cost),
            format!("{:.2}", report.sojourn.p50),
            format!("{:.2}", report.sojourn.p95),
            format!("{:.2}", acc(report)),
        ]);
    }

    let mut pt = Table::new(
        "Eviction policy at capacity 64 (same workload)",
        &["Policy", "Hit rate (%)", "Evictions", "Expired", "Tokens saved", "C_API ($)"],
    );
    for policy in [
        CachePolicyKind::Lru,
        CachePolicyKind::Lfu,
        CachePolicyKind::Ttl(120.0),
    ] {
        let report = run(64, policy);
        let c = report.cache.clone().unwrap_or_default();
        pt.row(vec![
            policy.label(),
            format!("{:.1}", c.hit_rate() * 100.0),
            c.evictions.to_string(),
            c.expirations.to_string(),
            format!("{:.0}", c.tokens_saved),
            format!("{:.4}", report.total_api_cost),
        ]);
    }

    let mut out = t.render();
    out.push('\n');
    out.push_str(&pt.render());
    if let Some(base) = baseline_tokens {
        out.push_str(&format!(
            "\ncache-off transmits {base:.0} cloud tokens; every cached row should transmit \
             strictly fewer at comparable accuracy.\n\
             Expected shape: hit rate and tokens saved grow with capacity until the working\n\
             set (distinct prototypes x plan size x 2 sides) fits; p50 sojourn drops as hits\n\
             complete at coordinator latency instead of model latency.\n",
        ));
    }
    out
}

/// Run an experiment by id.
pub fn run_experiment(id: &str, ctx: &ExpContext) -> anyhow::Result<String> {
    Ok(match id {
        "calibrate" => calibrate(ctx),
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "table3" => table3(ctx),
        "table5" => table5(ctx),
        "table6_fig4" => table6_fig4(ctx),
        "fig3" => fig3(ctx),
        "table7" => table7(ctx),
        "table8" => table8(ctx),
        "fig5" => fig5(ctx),
        "d1_exposure" => d1_exposure(ctx),
        "ablations" => ablations(ctx),
        "fleet_serve" => fleet_serve(ctx),
        "fleet_mixed_policy" => fleet_mixed_policy(ctx),
        "fleet_cache" => fleet_cache(ctx),
        other => anyhow::bail!(
            "unknown experiment '{other}'; available: {}",
            EXPERIMENT_IDS.join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_ctx() -> ExpContext {
        ExpContext { seeds: vec![1], scale: 0.08, ..Default::default() }
    }

    #[test]
    fn experiment_registry_rejects_unknown() {
        assert!(run_experiment("table99", &tiny_ctx()).is_err());
    }

    #[test]
    fn table5_runs_tiny() {
        let out = table5(&tiny_ctx());
        assert!(out.contains("Valid"));
        assert!(out.contains("GPQA"));
    }

    #[test]
    fn fig5_runs_tiny() {
        let out = fig5(&tiny_ctx());
        assert!(out.contains("Soundness"));
        assert!(out.lines().count() >= 7);
    }

    #[test]
    fn table7_runs_tiny() {
        let out = table7(&tiny_ctx());
        assert!(out.contains("SFT"));
        assert!(out.contains("R_comp"));
    }

    #[test]
    fn fleet_mixed_policy_runs_tiny() {
        let out = fleet_mixed_policy(&tiny_ctx());
        assert!(out.contains("Mixed-policy fleet"));
        assert!(out.contains("Per-tenant routing"));
        // Both hedge rows rendered, and the edge-pinned tenant stayed off
        // the cloud for its routed decisions.
        assert!(out.contains("| off"));
        assert!(out.contains("| on"));
        assert!(out.contains("edge-pinned"));
    }

    #[test]
    fn mixed_policy_scenario_hedging_engages() {
        // Structural pin of the acceptance scenario: with hedging on, the
        // canonical mixed-policy fleet actually speculates (losers are
        // cancelled, refunds are non-negative, tail stats are finite) and
        // per-tenant overrides hold. The p95-improvement claim itself is
        // read from the experiment table — at test scale (tens of queries)
        // the tail quantile is too noisy to pin as a strict inequality
        // without making the suite flaky.
        use crate::scenario::presets::{self, MixedPolicyKnobs};

        let run = |hedge: bool| {
            let knobs = MixedPolicyKnobs { hedge, ..Default::default() };
            presets::mixed_policy(Benchmark::Gpqa, 24, 0.6, 11, &knobs)
                .build(std::sync::Arc::new(
                    crate::router::MirrorPredictor::synthetic_for_tests(),
                ))
                .expect("canonical preset spec is valid")
                .run()
        };
        let off = run(false);
        let on = run(true);
        assert_eq!(off.hedge_cancelled, 0);
        assert!(on.hedge_cancelled > 0, "hedging never engaged in the canonical scenario");
        assert!(on.hedge_refund >= 0.0);
        assert!(on.sojourn.p95.is_finite() && off.sojourn.p95.is_finite());
        // Without hedging the edge-pinned tenant never touches the cloud;
        // with hedging its only cloud activity is speculation (winners
        // count as offloads, cancelled losers as refunds).
        assert_eq!(off.tenants[2].state.n_offloaded, 0);
        assert_eq!(off.tenants[2].state.k_used, 0.0);
    }

    #[test]
    fn fleet_cache_runs_tiny() {
        let out = fleet_cache(&tiny_ctx());
        assert!(out.contains("Result cache on a Zipf fleet"));
        assert!(out.contains("Eviction policy at capacity 64"));
        assert!(out.contains("| off"), "cache-off baseline row present");
        assert!(out.contains("| 256"), "capacity sweep rows present");
    }

    #[test]
    fn fleet_cache_scenario_hits_and_cuts_cloud_tokens() {
        // Acceptance pin: on a Zipf trace the cached fleet reports hit
        // rate > 0.2 and transmits strictly fewer cloud tokens than the
        // cache-off run of the identical workload.
        use crate::scenario::presets::{self, FleetCacheKnobs};

        let run = |capacity: usize| {
            let knobs = FleetCacheKnobs {
                capacity,
                zipf_exponent: 1.2,
                zipf_distinct: 4,
                ..Default::default()
            };
            // Low rate: most repeats arrive after their prototype's first
            // execution finished (entries are availability-gated on the
            // virtual clock).
            presets::fleet_cache(Benchmark::Gpqa, 40, 0.1, 11, &knobs)
                .build(std::sync::Arc::new(
                    crate::router::MirrorPredictor::synthetic_for_tests(),
                ))
                .expect("canonical preset spec is valid")
                .run()
        };
        let off = run(0);
        let on = run(256);
        assert!(off.cache.is_none());
        let stats = on.cache.as_ref().expect("cache stats");
        assert!(
            stats.hit_rate() > 0.2,
            "hit rate {:.3} below the acceptance floor",
            stats.hit_rate()
        );
        assert!(
            fleet_cloud_tokens(&on) < fleet_cloud_tokens(&off),
            "cached run must transmit strictly fewer cloud tokens ({} vs {})",
            fleet_cloud_tokens(&on),
            fleet_cloud_tokens(&off)
        );
        assert!(stats.tokens_saved > 0.0);
    }

    #[test]
    fn fleet_serve_runs_tiny() {
        let out = fleet_serve(&tiny_ctx());
        assert!(out.contains("Fleet serving"));
        assert!(out.contains("Sojourn p99"));
        // One row per swept arrival rate.
        assert!(out.lines().filter(|l| l.starts_with("| 0.") || l.starts_with("| 1.") || l.starts_with("| 2.")).count() >= 5);
    }
}

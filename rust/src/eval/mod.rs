//! Experiment harness: one entry point per paper table/figure, shared by
//! the CLI (`hybridflow exp <id>`) and the bench binaries
//! (`cargo bench --bench table1` ...).

pub mod experiments;

pub use experiments::{run_experiment, ExpContext, EXPERIMENT_IDS};

//! Rust mirror of `python/compile/simparams.py` — the shared generative
//! constants of the simulation substrate.
//!
//! The defaults below are the single rust-side source of truth; when
//! `artifacts/simparams.json` is present, [`SimParams::load`] cross-checks
//! the two copies and fails loudly on drift (see
//! `rust/tests/artifacts_integration.rs`), so the python and rust mirrors
//! cannot silently diverge.

use crate::util::json::Json;
use std::path::Path;

/// Domains in capability-vector order (must match python `DOMAINS`).
pub const DOMAINS: [&str; 4] = ["math", "science", "general", "logic"];

/// Feature vector layout (must match python `FEAT_*`).
pub const FEAT_ROLE: usize = 0;
pub const FEAT_DIFF1: usize = 3;
pub const FEAT_DIFF2: usize = 4;
pub const FEAT_TOKENS: usize = 5;
pub const FEAT_DOMAIN: usize = 6;
pub const FEAT_POS: usize = 10;
pub const FEAT_FANIN: usize = 11;
pub const FEAT_FANOUT: usize = 12;
pub const FEAT_NSUB: usize = 13;
pub const FEAT_SINK: usize = 14;
pub const FEAT_CRIT: usize = 15;
pub const FEAT_DIM: usize = 16;
pub const ROUTER_IN_DIM: usize = FEAT_DIM + 1;
pub const ROUTER_HIDDEN: usize = 64;

pub const TOKEN_NORM: f64 = 512.0;
pub const FAN_NORM: f64 = 4.0;

/// Serving profile of one model endpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingProfile {
    /// Decode speed, tokens/s.
    pub tps: f64,
    /// Prefill speed, tokens/s.
    pub prefill_tps: f64,
    /// Mean network round-trip (s); 0 for on-device models.
    pub rtt_mean: f64,
    /// Lognormal sigma of the RTT jitter.
    pub rtt_sigma: f64,
    /// $ per input token.
    pub price_in: f64,
    /// $ per output token.
    pub price_out: f64,
}

/// Per-benchmark workload shape.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchmarkParams {
    /// Difficulty Beta(a, b).
    pub beta: (f64, f64),
    /// Domain index into [`DOMAINS`].
    pub domain: usize,
    /// Output-token multiplier.
    pub tok_mult: f64,
    /// Query input-token lognormal (mu, sigma).
    pub query_tokens: (f64, f64),
    /// Paper's evaluation set size.
    pub n_queries: usize,
}

/// One simulated model: capabilities + serving profile.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelParams {
    pub name: &'static str,
    /// Per-domain capability (same order as [`DOMAINS`]).
    pub caps: [f64; 4],
    pub serving: ServingProfile,
}

/// All generative-model constants.
#[derive(Debug, Clone, PartialEq)]
pub struct SimParams {
    pub cap_temp: f64,
    pub diff_noise_std: f64,
    pub crit_noise_std: f64,
    pub nmax: usize,
    pub phi: (f64, f64),
    /// Probability a non-GENERATE subtask is pivotal.
    pub crit_p: f64,
    /// Baseline criticality of non-pivotal subtasks.
    pub crit_base: f64,
    /// Beta(a, b) of the pivotal-criticality boost.
    pub crit_high_beta: (f64, f64),
    /// Pivotal probability decays with topological position (early
    /// analysis resolves the key steps; Fig. 3's generative premise).
    pub crit_pos_decay: f64,
    pub generate_crit: f64,
    pub cloud_verbosity: f64,
    pub cot_token_mult: f64,
    /// Role output-token lognormal (mu, sigma): EXPLAIN, ANALYZE, GENERATE.
    pub role_tokens: [(f64, f64); 3],
    /// Direct-prompting output tokens (mu, sigma): edge, cloud.
    pub direct_tokens: [(f64, f64); 2],
    pub eps_utility: f64,
    pub l_max_sub: f64,
    pub k_max_sub: f64,
    pub tau0: f64,
    pub k_max_global: f64,
    pub l_max_global: f64,
    pub c_max: f64,
    pub dual_eta: f64,
    pub dual_gamma: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            cap_temp: 0.12,
            diff_noise_std: 0.08,
            crit_noise_std: 0.15,
            nmax: 7,
            phi: (0.55, 0.95),
            crit_p: 0.38,
            crit_base: 0.06,
            crit_high_beta: (8.0, 2.0),
            crit_pos_decay: 0.75,
            generate_crit: 0.35,
            cloud_verbosity: 3.0,
            cot_token_mult: 1.7,
            role_tokens: [(4.0, 0.35), (4.6, 0.40), (4.4, 0.35)],
            direct_tokens: [(5.6, 0.30), (6.9, 0.25)],
            eps_utility: 1.0e-4,
            l_max_sub: 10.0,
            k_max_sub: 0.02,
            tau0: 0.1,
            k_max_global: 0.02,
            l_max_global: 40.0,
            c_max: 0.5,
            dual_eta: 0.35,
            dual_gamma: 0.5,
        }
    }
}

impl SimParams {
    /// Load from `artifacts/simparams.json`, verifying it matches the
    /// compiled-in defaults (fails on drift between python and rust mirrors).
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<SimParams> {
        let json = Json::parse_file(&artifacts_dir.join("simparams.json"))?;
        let loaded = Self::from_json(&json)?;
        let compiled = SimParams::default();
        if loaded != compiled {
            anyhow::bail!(
                "simparams drift between python (artifacts/simparams.json) and rust defaults:\n  loaded:   {loaded:?}\n  compiled: {compiled:?}"
            );
        }
        Ok(loaded)
    }

    /// Parse the JSON dump written by `python -m compile.aot`.
    pub fn from_json(j: &Json) -> anyhow::Result<SimParams> {
        let f = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("simparams.json missing numeric '{key}'"))
        };
        let pair = |key: &str| -> anyhow::Result<(f64, f64)> {
            let arr = j
                .get(key)
                .and_then(Json::f64_array)
                .ok_or_else(|| anyhow::anyhow!("simparams.json missing pair '{key}'"))?;
            anyhow::ensure!(arr.len() == 2, "'{key}' must have 2 entries");
            Ok((arr[0], arr[1]))
        };
        let role_pair = |name: &str| -> anyhow::Result<(f64, f64)> {
            let arr = j
                .path(&["role_tokens", name])
                .and_then(Json::f64_array)
                .ok_or_else(|| anyhow::anyhow!("missing role_tokens.{name}"))?;
            Ok((arr[0], arr[1]))
        };
        let direct = |name: &str| -> anyhow::Result<(f64, f64)> {
            let arr = j
                .path(&["direct_tokens", name])
                .and_then(Json::f64_array)
                .ok_or_else(|| anyhow::anyhow!("missing direct_tokens.{name}"))?;
            Ok((arr[0], arr[1]))
        };
        Ok(SimParams {
            cap_temp: f("cap_temp")?,
            diff_noise_std: f("diff_noise_std")?,
            crit_noise_std: f("crit_noise_std")?,
            nmax: f("nmax")? as usize,
            phi: pair("phi")?,
            crit_p: f("crit_p")?,
            crit_base: f("crit_base")?,
            crit_high_beta: pair("crit_high_beta")?,
            crit_pos_decay: f("crit_pos_decay")?,
            generate_crit: f("generate_crit")?,
            cloud_verbosity: f("cloud_verbosity")?,
            cot_token_mult: f("cot_token_mult")?,
            role_tokens: [role_pair("EXPLAIN")?, role_pair("ANALYZE")?, role_pair("GENERATE")?],
            direct_tokens: [direct("edge")?, direct("cloud")?],
            eps_utility: f("eps_utility")?,
            l_max_sub: f("l_max_sub")?,
            k_max_sub: f("k_max_sub")?,
            tau0: f("tau0")?,
            k_max_global: f("k_max_global")?,
            l_max_global: f("l_max_global")?,
            c_max: f("c_max")?,
            dual_eta: f("dual_eta")?,
            dual_gamma: f("dual_gamma")?,
        })
    }
}

/// Compiled-in model zoo (mirrors python `MODEL_CAPS` / `MODEL_SERVING`).
pub fn model_params(name: &str) -> Option<ModelParams> {
    let p = |tps, prefill_tps, rtt_mean, rtt_sigma, price_in, price_out| ServingProfile {
        tps,
        prefill_tps,
        rtt_mean,
        rtt_sigma,
        price_in,
        price_out,
    };
    Some(match name {
        "llama3.2-3b" => ModelParams {
            name: "llama3.2-3b",
            caps: [0.35, 0.38, 0.27, 0.25],
            serving: p(42.0, 900.0, 0.0, 0.0, 0.0, 0.0),
        },
        "gpt-4.1" => ModelParams {
            name: "gpt-4.1",
            caps: [0.66, 0.595, 0.55, 0.54],
            serving: p(75.0, 4000.0, 0.45, 0.35, 2.0e-6, 8.0e-6),
        },
        "qwen2.5-7b" => ModelParams {
            name: "qwen2.5-7b",
            caps: [0.42, 0.44, 0.34, 0.32],
            serving: p(28.0, 600.0, 0.0, 0.0, 0.0, 0.0),
        },
        "deepseek-v3" => ModelParams {
            name: "deepseek-v3",
            caps: [0.68, 0.615, 0.57, 0.56],
            serving: p(24.0, 3000.0, 0.70, 0.40, 0.27e-6, 1.10e-6),
        },
        _ => return None,
    })
}

/// Compiled-in benchmark table (mirrors python `BENCHMARKS`).
pub fn benchmark_params(name: &str) -> Option<BenchmarkParams> {
    let dom = |d: &str| DOMAINS.iter().position(|x| *x == d).unwrap();
    Some(match name {
        "gpqa" => BenchmarkParams {
            beta: (6.0, 2.5),
            domain: dom("science"),
            tok_mult: 1.2,
            query_tokens: (5.3, 0.35),
            n_queries: 195,
        },
        "mmlu_pro" => BenchmarkParams {
            beta: (3.5, 3.0),
            domain: dom("general"),
            tok_mult: 0.8,
            query_tokens: (4.9, 0.35),
            n_queries: 200,
        },
        "aime24" => BenchmarkParams {
            beta: (8.0, 1.8),
            domain: dom("math"),
            tok_mult: 2.6,
            query_tokens: (4.6, 0.30),
            n_queries: 30,
        },
        "livebench" => BenchmarkParams {
            beta: (4.0, 2.5),
            domain: dom("logic"),
            tok_mult: 2.0,
            query_tokens: (5.1, 0.40),
            n_queries: 100,
        },
        _ => return None,
    })
}

/// Verify the model/benchmark tables in a loaded JSON match the compiled-in
/// zoo (used by the artifacts integration test).
pub fn verify_zoo_against_json(j: &Json) -> anyhow::Result<()> {
    for name in ["llama3.2-3b", "gpt-4.1", "qwen2.5-7b", "deepseek-v3"] {
        let m = model_params(name).unwrap();
        let caps = j
            .path(&["model_caps", name])
            .and_then(Json::f64_array)
            .ok_or_else(|| anyhow::anyhow!("missing model_caps.{name}"))?;
        anyhow::ensure!(caps == m.caps.to_vec(), "caps drift for {name}: {caps:?} vs {:?}", m.caps);
        let s = j
            .path(&["model_serving", name])
            .and_then(Json::f64_array)
            .ok_or_else(|| anyhow::anyhow!("missing model_serving.{name}"))?;
        let want = vec![
            m.serving.tps,
            m.serving.prefill_tps,
            m.serving.rtt_mean,
            m.serving.rtt_sigma,
            m.serving.price_in,
            m.serving.price_out,
        ];
        anyhow::ensure!(
            s.iter().zip(&want).all(|(a, b)| (a - b).abs() < 1e-12),
            "serving drift for {name}: {s:?} vs {want:?}"
        );
    }
    for name in ["gpqa", "mmlu_pro", "aime24", "livebench"] {
        let b = benchmark_params(name).unwrap();
        let beta = j
            .path(&["benchmarks", name, "beta"])
            .and_then(Json::f64_array)
            .ok_or_else(|| anyhow::anyhow!("missing benchmarks.{name}.beta"))?;
        anyhow::ensure!(beta == vec![b.beta.0, b.beta.1], "beta drift for {name}");
        let dom = j
            .path(&["benchmarks", name, "domain"])
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing benchmarks.{name}.domain"))?;
        anyhow::ensure!(DOMAINS[b.domain] == dom, "domain drift for {name}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_consistent() {
        let p = SimParams::default();
        assert!(p.phi.0 < p.phi.1);
        assert!(p.tau0 >= 0.0 && p.tau0 <= 1.0);
        assert_eq!(p.nmax, 7);
        assert_eq!(FEAT_DIM, 16);
        assert_eq!(ROUTER_IN_DIM, 17);
    }

    #[test]
    fn zoo_has_all_models() {
        for name in ["llama3.2-3b", "gpt-4.1", "qwen2.5-7b", "deepseek-v3"] {
            let m = model_params(name).unwrap();
            assert_eq!(m.name, name);
            assert!(m.serving.tps > 0.0);
        }
        assert!(model_params("gpt-5").is_none());
    }

    #[test]
    fn edge_models_are_free_and_local() {
        for name in ["llama3.2-3b", "qwen2.5-7b"] {
            let m = model_params(name).unwrap();
            assert_eq!(m.serving.price_out, 0.0);
            assert_eq!(m.serving.rtt_mean, 0.0);
        }
        for name in ["gpt-4.1", "deepseek-v3"] {
            let m = model_params(name).unwrap();
            assert!(m.serving.price_out > 0.0);
            assert!(m.serving.rtt_mean > 0.0);
        }
    }

    #[test]
    fn cloud_caps_dominate_edge_caps() {
        let edge = model_params("llama3.2-3b").unwrap();
        let cloud = model_params("gpt-4.1").unwrap();
        for d in 0..4 {
            assert!(cloud.caps[d] > edge.caps[d], "domain {d}");
        }
    }

    #[test]
    fn benchmarks_cover_paper_eval() {
        for name in ["gpqa", "mmlu_pro", "aime24", "livebench"] {
            let b = benchmark_params(name).unwrap();
            assert!(b.n_queries > 0);
            assert!(b.domain < 4);
        }
        assert!(benchmark_params("gsm8k").is_none());
    }

    #[test]
    fn from_json_roundtrip_via_handbuilt() {
        // Build a JSON blob exactly as python would and parse it back.
        let p = SimParams::default();
        let text = format!(
            r#"{{
              "cap_temp": {}, "diff_noise_std": {}, "crit_noise_std": {},
              "nmax": {}, "phi": [{}, {}], "crit_p": {}, "crit_base": {}, "crit_high_beta": [{}, {}], "crit_pos_decay": {},
              "generate_crit": {}, "cloud_verbosity": {}, "cot_token_mult": {},
              "role_tokens": {{"EXPLAIN": [{}, {}], "ANALYZE": [{}, {}], "GENERATE": [{}, {}]}},
              "direct_tokens": {{"edge": [5.6, 0.30], "cloud": [6.9, 0.25]}},
              "eps_utility": {}, "l_max_sub": {}, "k_max_sub": {},
              "tau0": {}, "k_max_global": {}, "l_max_global": {},
              "c_max": {}, "dual_eta": {}, "dual_gamma": {}
            }}"#,
            p.cap_temp, p.diff_noise_std, p.crit_noise_std, p.nmax, p.phi.0, p.phi.1,
            p.crit_p, p.crit_base, p.crit_high_beta.0, p.crit_high_beta.1, p.crit_pos_decay,
            p.generate_crit, p.cloud_verbosity,
            p.cot_token_mult,
            p.role_tokens[0].0, p.role_tokens[0].1, p.role_tokens[1].0, p.role_tokens[1].1,
            p.role_tokens[2].0, p.role_tokens[2].1,
            p.eps_utility, p.l_max_sub, p.k_max_sub, p.tau0,
            p.k_max_global, p.l_max_global, p.c_max, p.dual_eta, p.dual_gamma
        );
        let parsed = SimParams::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, p);
    }
}

//! Configuration: compiled-in simulation constants (mirrored with python)
//! plus runtime configuration loaded from JSON files / CLI flags.

pub mod simparams;

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Top-level runtime configuration for the coordinator binary and examples.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Directory holding AOT artifacts (`router*.hlo.txt`, ...).
    pub artifacts_dir: PathBuf,
    /// Worker threads for the scheduler's real-dispatch pool.
    pub workers: usize,
    /// Use the PJRT-backed router predictor (vs pure-rust mirror).
    pub use_pjrt: bool,
    /// Run the edge-LM PJRT forward inside simulated edge executions.
    pub edge_lm_compute: bool,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            artifacts_dir: default_artifacts_dir(),
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            use_pjrt: true,
            edge_lm_compute: false,
            seed: 0,
        }
    }
}

/// Locate `artifacts/` relative to the current dir or the crate root.
pub fn default_artifacts_dir() -> PathBuf {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ];
    for c in &candidates {
        if c.join("router.hlo.txt").exists() {
            return c.clone();
        }
    }
    candidates[0].clone()
}

impl RuntimeConfig {
    /// Load overrides from a JSON config file.
    pub fn from_file(path: &Path) -> anyhow::Result<RuntimeConfig> {
        let j = Json::parse_file(path)?;
        let mut cfg = RuntimeConfig::default();
        if let Some(d) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(d);
        }
        if let Some(w) = j.get("workers").and_then(Json::as_usize) {
            cfg.workers = w.max(1);
        }
        if let Some(b) = j.get("use_pjrt").and_then(Json::as_bool) {
            cfg.use_pjrt = b;
        }
        if let Some(b) = j.get("edge_lm_compute").and_then(Json::as_bool) {
            cfg.edge_lm_compute = b;
        }
        if let Some(s) = j.get("seed").and_then(Json::as_f64) {
            cfg.seed = s as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = RuntimeConfig::default();
        assert!(c.workers >= 1);
        assert!(c.use_pjrt);
    }

    #[test]
    fn from_file_overrides() {
        let dir = std::env::temp_dir().join("hf_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"workers": 2, "use_pjrt": false, "seed": 9, "artifacts_dir": "/tmp/a"}"#,
        )
        .unwrap();
        let c = RuntimeConfig::from_file(&p).unwrap();
        assert_eq!(c.workers, 2);
        assert!(!c.use_pjrt);
        assert_eq!(c.seed, 9);
        assert_eq!(c.artifacts_dir, PathBuf::from("/tmp/a"));
    }
}

//! Miniature property-based testing framework (`proptest` is not available
//! offline).
//!
//! Usage pattern (`no_run`: doctest executables cannot locate the xla
//! shared libraries in this offline environment; the unit tests below
//! exercise the same paths):
//!
//! ```no_run
//! use hybridflow::testing::{forall, Gen};
//! forall("sorted stays sorted", 200, |g| {
//!     let mut v = g.vec_f64(0..50, -1e3..1e3);
//!     v.sort_by(|a, b| a.partial_cmp(b).unwrap());
//!     v.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```
//!
//! On failure the framework re-runs with the failing case's seed and panics
//! with that seed so the case is exactly reproducible; integer and vector
//! generators shrink toward small values first by sampling sizes from a
//! low-biased distribution, which keeps failing cases readable.

pub mod fuzz;

use crate::util::rng::Rng;
use std::ops::Range;

/// Case generator handed to property closures.
pub struct Gen {
    pub rng: Rng,
    /// Seed of the current case (reported on failure).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { rng: Rng::new(seed), case_seed: seed }
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.rng.int_range(r.start, r.end)
    }

    /// Small-biased size: half the draws land in the lower third.
    pub fn size(&mut self, max: usize) -> usize {
        if max == 0 {
            return 0;
        }
        if self.rng.bernoulli(0.5) {
            self.rng.below(max / 3 + 1)
        } else {
            self.rng.below(max + 1)
        }
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        self.rng.uniform(r.start, r.end)
    }

    pub fn unit_f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn vec_f64(&mut self, len: Range<usize>, vals: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.uniform(vals.start, vals.end)).collect()
    }

    pub fn vec_usize(&mut self, len: Range<usize>, vals: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.int_range(vals.start, vals.end)).collect()
    }

    pub fn string(&mut self, len: Range<usize>) -> String {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| {
                // Mix of ASCII, escapes-needing chars, and a few multibyte.
                const POOL: &[char] =
                    &['a', 'b', 'z', '0', '9', ' ', '"', '\\', '\n', '\t', '<', '>', '&', '\u{e9}', '\u{1F600}'];
                *self.rng.choice(POOL)
            })
            .collect()
    }
}

/// Run `prop` on `cases` generated cases; panic with a reproducible seed on
/// the first failure (boolean false or inner panic).
pub fn forall<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> bool,
{
    forall_seeded(name, cases, 0xC0FFEE, prop)
}

/// `forall` with an explicit base seed (used to reproduce failures).
pub fn forall_seeded<F>(name: &str, cases: u64, base_seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> bool,
{
    for i in 0..cases {
        let case_seed = base_seed.wrapping_add(i).wrapping_mul(0x9E3779B97f4A7C15);
        let mut g = Gen::new(case_seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        match outcome {
            Ok(true) => {}
            Ok(false) => panic!(
                "property '{name}' FAILED at case {i} (reproduce with forall_seeded(.., 1, {case_seed:#x}, ..))"
            ),
            Err(e) => {
                let msg = e
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| e.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property '{name}' PANICKED at case {i}: {msg} (reproduce with forall_seeded(.., 1, {case_seed:#x}, ..))"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("tautology", 50, |g| {
            let v = g.vec_f64(0..10, -1.0..1.0);
            v.len() <= 10
        });
    }

    #[test]
    #[should_panic(expected = "FAILED")]
    fn failing_property_panics_with_seed() {
        forall("always false eventually", 20, |g| g.usize_in(0..100) < 95);
    }

    #[test]
    #[should_panic(expected = "PANICKED")]
    fn panicking_property_is_caught() {
        forall("panics", 5, |_g| -> bool { panic!("inner") });
    }

    #[test]
    fn generators_respect_ranges() {
        forall("ranges", 200, |g| {
            let u = g.usize_in(3..9);
            let f = g.f64_in(-2.0..2.0);
            let v = g.vec_usize(0..5, 10..20);
            (3..9).contains(&u)
                && (-2.0..2.0).contains(&f)
                && v.iter().all(|x| (10..20).contains(x))
        });
    }

    #[test]
    fn size_is_small_biased() {
        let mut g = Gen::new(1);
        let sizes: Vec<usize> = (0..2000).map(|_| g.size(90)).collect();
        let small = sizes.iter().filter(|&&s| s <= 30).count();
        assert!(small as f64 / 2000.0 > 0.5);
    }

    #[test]
    fn reproducible_by_seed() {
        let mut a = Gen::new(99);
        let mut b = Gen::new(99);
        assert_eq!(a.vec_f64(5..6, 0.0..1.0), b.vec_f64(5..6, 0.0..1.0));
    }
}

//! Adversarial scenario fuzzing + invariant harness.
//!
//! A deterministic, seed-driven generator of random-but-valid
//! [`ScenarioSpec`]s covering the full declarative surface (topology,
//! workload, engine — every policy, arrival process, and cache
//! configuration), plus an *adversarial* mode that mutates specs toward
//! edge values (zero workers, zero-dollar caps, empty traces, huge
//! rates). Each generated spec runs through [`Session::run`] under a
//! reusable invariant checker:
//!
//! * the event clock is monotone;
//! * per-side worker occupancy never exceeds the configured pool
//!   (`max(1)` for a zero-worker side's phantom claim slot);
//! * tenant spend never exceeds its cap by more than one call, and
//!   global spend equals the sum of tenant spends;
//! * cache partitions never exceed their configured capacity;
//! * report aggregates equal their recomputation from per-query
//!   outcomes, and every reported number is finite;
//! * re-running the identical session is byte-identical (trace and
//!   report JSON);
//! * sharded determinism: at forced shard counts 1 and 4 the report,
//!   trace, and observability-artifact bytes are independent of the
//!   worker-thread count, and `shards = 1` through the sharded merge
//!   path is byte-identical to the unsharded kernel;
//! * observability is read-only: an observe-off twin of every observed
//!   spec reproduces the trace and report byte-for-byte, every opened
//!   span closes exactly once with
//!   `planned <= queued <= dispatched <= finished`, worker lanes never
//!   run overlapping spans (outside chain mode), and the metrics series
//!   is monotone in virtual time and bounded by the per-shard snapshot
//!   cap;
//! * fault accounting is coherent: the `faults` roll-up appears iff the
//!   spec carries a fault layer, `retries = failures + timeouts`, failed
//!   attempts never outnumber attempts, no node exceeds its retry budget
//!   (so every query terminates — the DAG never wedges, even at
//!   certain-failure probabilities or under a horizon-spanning outage),
//!   outage rejections bill nothing and occupy no worker time, degraded
//!   attempts land on the edge, hedging stays off while the layer is
//!   active, and refunds are finite and non-negative (budget
//!   conservation — `total_api_cost = global spend = Σ tenant spends` —
//!   is re-checked on every faulty run, so timeout refunds cannot leak);
//! * a *silent* fault layer (every probability zero, no outages, no
//!   timeout) reproduces a faults-off twin byte-for-byte: trace, report
//!   JSON (minus the `faults` roll-up), and observability artifacts;
//! * `parse(render(spec)) == spec` and `render` is a fixpoint.
//!
//! When a case fails, [`minimize`] greedily shrinks the offending spec
//! toward defaults (re-checking the failure each step) so corpus entries
//! land in `rust/tests/corpus/` already minimized.
//!
//! Wired in three places: the bounded test suite (`rust/tests/fuzz.rs`,
//! case count via `HYBRIDFLOW_FUZZ_CASES`), the CLI
//! (`hybridflow fuzz --cases N --seed S [--adversarial]`), and the
//! regression corpus (`rust/tests/corpus/*.json` — every bug this
//! harness flushed out is checked in as a minimized spec).
//!
//! Case addressing: case `i` under base seed `S` generates the same spec
//! as case `0` under base seed `S + i`, so any failure reproduces with
//! `hybridflow fuzz --cases 1 --seed <S+i>`.

use crate::cache::CachePolicyKind;
use crate::fault::{FaultConfig, OutageWindow, ResilienceConfig};
use crate::obs::{ObserveConfig, MAX_METRIC_SNAPSHOTS};
use crate::router::MirrorPredictor;
use crate::scenario::{
    CacheSpec, EngineSpec, PolicySpec, Report, ScenarioSpec, Session, TenantSpec, TopologySpec,
    WorkloadSpec,
};
use crate::testing::Gen;
use crate::util::json::Json;
use crate::workload::trace::{ArrivalProcess, ZipfMix};
use crate::workload::Benchmark;
use std::sync::Arc;

/// Same golden-ratio case-seed derivation as [`super::forall_seeded`]:
/// `seed(base, case) = (base + case) * PHI64`, which makes case `i` under
/// base `S` identical to case `0` under base `S + i` (one-line repros).
const PHI64: u64 = 0x9E3779B97f4A7C15;

fn pick<'a, T>(g: &mut Gen, xs: &'a [T]) -> &'a T {
    &xs[g.usize_in(0..xs.len())]
}

/// A random fault block spanning the interesting domain: probabilities
/// across all of [0, 1] *including both endpoints* (p = 1 forces the
/// degradation path; p = 0 must stay silent), outage windows from
/// zero-length (matches nothing — half-open) to horizon-spanning.
fn random_faults(g: &mut Gen) -> FaultConfig {
    fn prob(g: &mut Gen) -> f64 {
        match g.usize_in(0..5) {
            0 => 0.0,
            1 => 1.0,
            _ => g.f64_in(0.0..0.3),
        }
    }
    let outages = (0..g.usize_in(0..3))
        .map(|_| {
            let (start, end) = match g.usize_in(0..3) {
                0 => {
                    let t = g.f64_in(0.0..50.0);
                    (t, t) // zero-length: half-open, matches nothing
                }
                1 => (0.0, 1e9), // spans any realistic horizon
                _ => {
                    let s = g.f64_in(0.0..50.0);
                    (s, s + g.f64_in(0.0..30.0))
                }
            };
            OutageWindow { cloud: g.bool(), start, end }
        })
        .collect();
    FaultConfig {
        edge_fail_p: prob(g),
        cloud_fail_p: prob(g),
        straggler_p: prob(g),
        straggler_mult: g.f64_in(1.0..10.0),
        seed: g.usize_in(0..1_000) as u64,
        outages,
    }
}

/// A random resilience block: timeouts from "fires on every call" (far
/// below any profiled service time) to "never fires", retry budgets from
/// 0 (first failure degrades) to 16.
fn random_resilience(g: &mut Gen) -> ResilienceConfig {
    ResilienceConfig {
        timeout: match g.usize_in(0..4) {
            0 => None,
            1 => Some(1e-3),
            2 => Some(g.f64_in(0.1..120.0)),
            _ => Some(1e9),
        },
        max_retries: *pick(g, &[0usize, 1, 3, 16]),
        backoff_base: g.f64_in(0.0..1.0),
        backoff_jitter: g.f64_in(0.0..1.0),
        failover_after: *pick(g, &[0usize, 1, 2, 8]),
    }
}

fn random_policy(g: &mut Gen) -> PolicySpec {
    match g.usize_in(0..8) {
        0 => PolicySpec::HybridFlow,
        1 => PolicySpec::HybridFlowEq27,
        2 => PolicySpec::HybridFlowCalibrated,
        3 => PolicySpec::AllEdge,
        4 => PolicySpec::AllCloud,
        5 => PolicySpec::Oracle,
        6 => PolicySpec::Random(g.unit_f64()),
        _ => PolicySpec::Fixed(g.f64_in(0.0..1.5)),
    }
}

/// A random spec over the full declarative surface. Every value is drawn
/// from the *valid* domain (the spec passes [`ScenarioSpec::validate`]);
/// the adversarial pass mutates from here toward boundaries.
fn random_spec(g: &mut Gen) -> ScenarioSpec {
    let n_tenants = g.usize_in(1..9);
    let tenants = (0..n_tenants)
        .map(|i| TenantSpec {
            name: format!("t{i}"),
            // Zero-dollar caps are valid (and interesting: every decision
            // is forced to the edge), so draw them explicitly sometimes.
            k_cap: match g.usize_in(0..4) {
                0 => None,
                1 => Some(0.0),
                _ => Some(g.f64_in(0.0..0.5)),
            },
            policy: if g.bool() { Some(random_policy(g)) } else { None },
        })
        .collect();
    let arrival = match g.usize_in(0..3) {
        0 => ArrivalProcess::Poisson { rate: g.f64_in(0.05..5.0) },
        1 => ArrivalProcess::Periodic { gap: g.f64_in(0.0..5.0) },
        _ => ArrivalProcess::Trace(g.vec_f64(0..6, 0.0..20.0)),
    };
    ScenarioSpec {
        name: "fuzz".into(),
        seed: g.usize_in(0..1_000_000) as u64,
        topology: TopologySpec {
            edge_workers: g.usize_in(0..5),
            cloud_workers: g.usize_in(0..9),
            admission_limit: g.usize_in(0..4),
            global_k_cap: if g.bool() { Some(g.f64_in(0.0..1.0)) } else { None },
            // Sharding is fuzzed from day one: half the specs stay on the
            // unsharded kernel, the rest split across 2 or 4 shards.
            shards: *pick(g, &[1usize, 1, 2, 4]),
            tenants,
        },
        workload: WorkloadSpec {
            benchmark: *pick(g, &Benchmark::ALL),
            n: g.usize_in(1..9),
            arrival,
            zipf: if g.bool() {
                Some(ZipfMix::new(g.f64_in(0.0..2.5), g.usize_in(1..6)))
            } else {
                None
            },
        },
        engine: EngineSpec {
            policy: random_policy(g),
            chain_mode: g.bool(),
            batch_frontier: g.bool(),
            hedge: g.bool(),
            hedge_threshold: g.f64_in(0.0..1.2),
            n_max: g.usize_in(1..8),
            // Always on: rerun byte-identity is checked on the trace.
            record_trace: true,
            cache: match g.usize_in(0..4) {
                0 => None,
                _ => Some(CacheSpec {
                    capacity: *pick(g, &[0usize, 1, 4, 64]),
                    policy: match g.usize_in(0..3) {
                        0 => CachePolicyKind::Lru,
                        1 => CachePolicyKind::Lfu,
                        _ => CachePolicyKind::Ttl(g.f64_in(0.5..50.0)),
                    },
                    shared_tier: g.bool(),
                }),
            },
            // Observability is fuzzed from day one: half the specs record
            // spans and/or metrics; the other half stay fully off (and
            // every observed case gets an observe-off twin in `run_case`).
            observe: if g.bool() {
                Some(ObserveConfig {
                    spans: g.bool(),
                    metrics: g.bool(),
                    metrics_interval: g.f64_in(0.1..10.0),
                })
            } else {
                None
            },
            // The fault layer is fuzzed from day one. Either block alone
            // activates it (the missing half takes its defaults); specs
            // carrying neither must take the exact pre-fault code path.
            faults: if g.bool() { Some(random_faults(g)) } else { None },
            resilience: if g.bool() { Some(random_resilience(g)) } else { None },
        },
    }
}

/// Mutate a valid spec toward edge values (1–3 mutations). Every
/// mutation stays inside the valid domain — the point is to stress the
/// kernel's boundary behavior, not the validator (rejection paths are
/// covered by the `reject_*` corpus and unit tests).
fn adversarialize(g: &mut Gen, spec: &mut ScenarioSpec) {
    for _ in 0..g.usize_in(1..4) {
        match g.usize_in(0..17) {
            0 => spec.topology.edge_workers = *pick(g, &[0usize, 1, 1024]),
            1 => spec.topology.cloud_workers = *pick(g, &[0usize, 1, 1024]),
            2 => spec.topology.admission_limit = g.usize_in(0..2),
            3 => spec.workload.n = 1,
            4 => {
                for t in &mut spec.topology.tenants {
                    t.k_cap = Some(*pick(g, &[0.0, 1e-9, 1e9]));
                }
            }
            5 => {
                spec.workload.arrival = match g.usize_in(0..3) {
                    0 => ArrivalProcess::Poisson { rate: *pick(g, &[1e-6, 1e6]) },
                    1 => ArrivalProcess::Periodic { gap: 0.0 },
                    // Degenerate traces: empty (extends from t=0) and
                    // constant (a recorded burst stays a burst).
                    _ => ArrivalProcess::Trace(if g.bool() { vec![] } else { vec![3.0; 4] }),
                };
            }
            6 => {
                spec.engine.hedge = true;
                spec.engine.hedge_threshold = *pick(g, &[0.0, 1.0, 1e9]);
            }
            7 => {
                let capacity = g.usize_in(0..2);
                match &mut spec.engine.cache {
                    Some(c) => c.capacity = capacity,
                    None => {
                        spec.engine.cache = Some(CacheSpec {
                            capacity,
                            policy: CachePolicyKind::Lru,
                            shared_tier: g.bool(),
                        });
                    }
                }
            }
            8 => spec.workload.zipf = Some(ZipfMix::new(*pick(g, &[0.0, 8.0]), 1)),
            9 => spec.engine.n_max = 1,
            10 => spec.topology.global_k_cap = Some(*pick(g, &[0.0, 1e-9, 1e9])),
            // More shards than queries (or workers) is a legal topology:
            // some shards simply receive no arrivals.
            11 => spec.topology.shards = *pick(g, &[1usize, 2, 4, 8]),
            12 => spec.engine.chain_mode = true,
            13 => {
                // Observability at an extreme cadence: a tiny interval
                // floods the snapshot series (bounded per shard by
                // MAX_METRIC_SNAPSHOTS), a huge one collapses it to the
                // t = 0 row.
                spec.engine.observe = Some(ObserveConfig {
                    spans: true,
                    metrics: true,
                    metrics_interval: *pick(g, &[1e-4, 1e6]),
                });
            }
            14 => spec.engine.observe = None,
            15 => {
                // Fault layer at the extremes: certain failure on one
                // side, the other side dark for the whole run (or for a
                // zero-length instant), a timeout below any realistic
                // service time, and retry budgets of 0 or 16. The kernel
                // must still terminate every query (degradation) with the
                // books balanced.
                let edge_down = g.bool();
                spec.engine.faults = Some(FaultConfig {
                    edge_fail_p: if edge_down { 1.0 } else { 0.0 },
                    cloud_fail_p: if edge_down { 0.0 } else { 1.0 },
                    straggler_p: *pick(g, &[0.0, 1.0]),
                    straggler_mult: *pick(g, &[1.0, 100.0]),
                    seed: 1,
                    outages: vec![OutageWindow {
                        cloud: !edge_down,
                        start: 0.0,
                        end: *pick(g, &[0.0, 1e12]),
                    }],
                });
                spec.engine.resilience = Some(ResilienceConfig {
                    timeout: if g.bool() { Some(1e-6) } else { None },
                    max_retries: *pick(g, &[0usize, 16]),
                    backoff_base: *pick(g, &[0.0, 10.0]),
                    backoff_jitter: *pick(g, &[0.0, 1.0]),
                    failover_after: *pick(g, &[0usize, 1]),
                });
            }
            _ => {
                spec.engine.faults = None;
                spec.engine.resilience = None;
            }
        }
    }
}

/// Deterministically generate the spec for `(base_seed, case)`. The same
/// pair always yields the same spec, across the CLI and the test suite.
pub fn spec_for_case(base_seed: u64, case: usize, adversarial: bool) -> ScenarioSpec {
    let case_seed = base_seed.wrapping_add(case as u64).wrapping_mul(PHI64);
    let mut g = Gen::new(case_seed);
    let mut spec = random_spec(&mut g);
    if adversarial {
        adversarialize(&mut g, &mut spec);
    }
    spec
}

/// Run one spec through the kernel under the full invariant set. Returns
/// the list of violations (empty = the case is clean). Panics inside
/// build/run are caught and reported as violations, so a fuzz sweep
/// always completes its report.
pub fn run_case(spec: &ScenarioSpec) -> Vec<String> {
    let mut v = Vec::new();

    if let Err(e) = spec.validate() {
        v.push(format!("generator emitted an invalid spec: {e}"));
        return v;
    }

    // Serialization contract: parse(render(spec)) == spec, render is a
    // fixpoint, and the rendered spec carries no NaN artifacts.
    let text = spec.render();
    match ScenarioSpec::parse(&text) {
        Err(e) => v.push(format!("render() of a valid spec failed to re-parse: {e}")),
        Ok(back) => {
            if back != *spec {
                v.push("parse(render(spec)) != spec (serialization round trip)".into());
            } else if back.render() != text {
                v.push("render(parse(render(spec))) != render(spec) (fixpoint)".into());
            }
        }
    }

    // Static-analysis coherence: the feasibility checker must never
    // panic on any generated spec, must render byte-identically across
    // reruns, and must not pass a spec that `build()` goes on to reject
    // (checked against the build outcome below).
    let checker_passed = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let r = crate::analysis::scenario::check_spec(spec);
        (r.passed(), r.render())
    })) {
        Err(e) => {
            v.push(format!("feasibility checker panicked: {}", panic_message(&e)));
            false
        }
        Ok((passed, rendered)) => {
            if crate::analysis::scenario::check_spec(spec).render() != rendered {
                v.push("feasibility checker rerun is not byte-identical".into());
            }
            passed
        }
    };

    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<(Session, Report, Report)> {
            let session = spec.build(Arc::new(MirrorPredictor::synthetic_for_tests()))?;
            let a = session.run();
            let b = session.run();
            Ok((session, a, b))
        },
    ));
    match outcome {
        Err(e) => v.push(format!("panicked during build/run: {}", panic_message(&e))),
        Ok(Err(e)) => {
            if checker_passed {
                v.push(format!("feasibility checker passed a spec that build() rejects: {e}"));
            }
            v.push(format!("valid spec failed to build: {e}"));
        }
        Ok(Ok((session, a, b))) => {
            check_report(spec, &session, &a, &mut v);
            if a.trace_text() != b.trace_text() {
                v.push("rerun event trace is not byte-identical".into());
            }
            if a.to_json().to_string_pretty() != b.to_json().to_string_pretty() {
                v.push("rerun report JSON is not byte-identical".into());
            }
            if a.obs != b.obs {
                v.push("rerun observability artifacts are not identical".into());
            }
            check_obs(spec, &a, &mut v);
            check_faults(spec, &a, &mut v);
            check_sharding_identities(spec, &session, &a, &mut v);
        }
    }
    v
}

/// The observability invariant set. Observability must be *read-only*:
/// stripping the `observe` block from a spec reproduces the instrumented
/// run's kernel decisions byte-for-byte, and the recorded artifacts must
/// be internally consistent (spans closed and time-ordered, worker lanes
/// exclusive, snapshot series monotone and bounded).
fn check_obs(spec: &ScenarioSpec, r: &Report, v: &mut Vec<String>) {
    let Some(cfg) = &spec.engine.observe else {
        if r.obs.is_some() || r.critical_path.is_some() {
            v.push("observe-off report carries observability artifacts".into());
        }
        return;
    };

    // -- observe-off twin: identical kernel decisions -------------------
    let mut off_spec = spec.clone();
    off_spec.engine.observe = None;
    match off_spec.build(Arc::new(MirrorPredictor::synthetic_for_tests())) {
        Err(e) => v.push(format!("observe-off twin failed to build: {e}")),
        Ok(twin) => {
            let off = twin.run();
            if off.trace_text() != r.trace_text() {
                v.push("enabling observability changed the event trace".into());
            }
            // The instrumented report may carry the extra `critical_path`
            // key; everything else must match the twin byte-for-byte.
            let mut on_json = r.to_json();
            if let Json::Obj(o) = &mut on_json {
                o.remove("critical_path");
            }
            if off.to_json().to_string_pretty() != on_json.to_string_pretty() {
                v.push("enabling observability changed the report JSON".into());
            }
        }
    }

    let Some(obs) = &r.obs else {
        v.push("observe-on report carries no artifacts".into());
        return;
    };

    // -- span lifecycle -------------------------------------------------
    if obs.unclosed_spans != 0 {
        v.push(format!("{} span(s) opened but never closed", obs.unclosed_spans));
    }
    if !cfg.spans && !obs.spans.is_empty() {
        v.push("spans recorded with the span recorder off".into());
    }
    for sp in &obs.spans {
        if !(sp.planned <= sp.queued && sp.queued <= sp.dispatched && sp.dispatched <= sp.finished)
        {
            v.push(format!(
                "span (q={}, node={}) violates planned <= queued <= dispatched <= finished: \
                 [{}, {}, {}, {}]",
                sp.q, sp.node, sp.planned, sp.queued, sp.dispatched, sp.finished
            ));
        }
        if sp.q >= spec.workload.n {
            v.push(format!("span names query {} in an n={} workload", sp.q, spec.workload.n));
        }
    }
    // Worker lanes are exclusive: a worker serves (or holds a hedge
    // reservation for) one job at a time. Chain-mode queries bypass the
    // pools (no worker assignment), and cache hits occupy no worker, so
    // both stay out of the overlap sweep.
    if !spec.engine.chain_mode {
        let mut lanes: std::collections::BTreeMap<(usize, usize), Vec<(f64, f64)>> =
            std::collections::BTreeMap::new();
        for sp in &obs.spans {
            if !sp.cached {
                lanes.entry((sp.shard, sp.lane())).or_default().push((sp.dispatched, sp.finished));
            }
        }
        for ((shard, lane), iv) in &lanes {
            if max_overlap(iv) > 1 {
                v.push(format!("shard {shard} lane {lane} runs overlapping spans"));
            }
        }
    }

    // -- metrics series -------------------------------------------------
    if !cfg.metrics && !obs.snapshots.is_empty() {
        v.push("metrics snapshots recorded with the metrics recorder off".into());
    }
    if cfg.metrics && obs.snapshots.is_empty() {
        v.push("metrics on but the snapshot series is empty".into());
    }
    let shards = spec.topology.shards.max(1);
    if obs.snapshots.len() > MAX_METRIC_SNAPSHOTS * shards {
        v.push(format!(
            "{} snapshots exceed the {MAX_METRIC_SNAPSHOTS}-per-shard cap",
            obs.snapshots.len()
        ));
    }
    for w in obs.snapshots.windows(2) {
        if w[1].t < w[0].t {
            v.push(format!("snapshot times regress: {} after {}", w[1].t, w[0].t));
            break;
        }
    }
    for s in &obs.snapshots {
        for (label, x) in [
            ("snapshot.t", s.t),
            ("snapshot.global_spent", s.global_spent),
            ("snapshot.latency_mean", s.latency_mean),
        ] {
            check_finite(label, x, v);
        }
    }
}

/// The fault-layer invariant set (see the module docs for the list):
/// roll-up/spec coherence, attempt accounting, per-event fault-mark
/// semantics, and the silent-layer twin identity.
fn check_faults(spec: &ScenarioSpec, r: &Report, v: &mut Vec<String>) {
    let layer_on = spec.engine.faults.is_some() || spec.engine.resilience.is_some();
    let Some(f) = &r.faults else {
        if layer_on {
            v.push("fault layer on but the report carries no faults roll-up".into());
        }
        for q in &r.results {
            for e in &q.exec.events {
                if !e.fault.is_default() {
                    v.push(format!(
                        "faults-off trace carries a fault mark on query {} node {}",
                        q.query_id, e.node
                    ));
                }
            }
        }
        return;
    };
    if !layer_on {
        v.push("faults-off report carries a faults roll-up".into());
        return;
    }

    // -- roll-up accounting ---------------------------------------------
    // Every failed, timed-out, or outage-rejected attempt schedules
    // exactly one retry (or the degradation attempt), so the counters are
    // coupled: retries = failures + timeouts, and both are attempts.
    if f.retries != f.failures + f.timeouts {
        v.push(format!(
            "fault retries {} != failures {} + timeouts {}",
            f.retries, f.failures, f.timeouts
        ));
    }
    if f.failures + f.timeouts > f.attempts {
        v.push(format!(
            "{} failure(s) + {} timeout(s) outnumber {} attempt(s)",
            f.failures, f.timeouts, f.attempts
        ));
    }
    if f.degraded_queries > r.results.len() {
        v.push(format!(
            "{} degraded queries in an n={} workload",
            f.degraded_queries,
            r.results.len()
        ));
    }
    check_finite("faults.refund", f.refund, v);
    if f.refund < -1e-12 {
        v.push(format!("negative fault refund {}", f.refund));
    }
    let avail = f.availability();
    if !avail.is_finite() || !(-1e-9..=1.0 + 1e-9).contains(&avail) {
        v.push(format!("availability {avail} outside [0, 1]"));
    }

    // -- per-event fault-mark semantics -----------------------------------
    // The retry budget bounds every node's attempt index (the degradation
    // attempt sits at exactly max_retries + 1), outage rejections perform
    // no work (zero cost, zero duration), degraded attempts run on the
    // edge, failed attempts are never correct, and hedging is disabled
    // while the layer is active.
    let rc = spec.engine.resilience.clone().unwrap_or_default();
    let max_attempts = rc.max_retries as u32 + 1;
    for q in &r.results {
        for e in &q.exec.events {
            if e.fault.attempt > max_attempts {
                v.push(format!(
                    "query {} node {} reached attempt {} with a retry budget of {}",
                    q.query_id, e.node, e.fault.attempt, rc.max_retries
                ));
            }
            if e.fault.outage && (e.api_cost != 0.0 || e.finish != e.start) {
                v.push(format!(
                    "query {} node {} outage rejection billed {} over [{}, {}]",
                    q.query_id, e.node, e.api_cost, e.start, e.finish
                ));
            }
            if e.fault.degraded && e.cloud {
                v.push(format!(
                    "query {} node {} degraded onto the cloud side",
                    q.query_id, e.node
                ));
            }
            if (e.fault.failed || e.fault.timeout) && e.correct {
                v.push(format!(
                    "query {} node {} failed attempt marked correct",
                    q.query_id, e.node
                ));
            }
            if e.hedged {
                v.push(format!(
                    "query {} node {} hedged with the fault layer active",
                    q.query_id, e.node
                ));
            }
        }
    }

    // -- silent layer twin ------------------------------------------------
    // A fault layer that can never fire must reproduce a faults-off run
    // byte-for-byte (modulo the `faults` roll-up). Hedging is forced off
    // in the twin because the fault layer disables it regardless.
    let fc = spec.engine.faults.clone().unwrap_or_default();
    let silent = fc.edge_fail_p == 0.0
        && fc.cloud_fail_p == 0.0
        && fc.straggler_p == 0.0
        && fc.outages.is_empty()
        && rc.timeout.is_none();
    if !silent {
        return;
    }
    let mut twin_spec = spec.clone();
    twin_spec.engine.faults = None;
    twin_spec.engine.resilience = None;
    twin_spec.engine.hedge = false;
    match twin_spec.build(Arc::new(MirrorPredictor::synthetic_for_tests())) {
        Err(e) => v.push(format!("faults-off twin failed to build: {e}")),
        Ok(twin) => {
            let off = twin.run();
            if off.trace_text() != r.trace_text() {
                v.push("a silent fault layer changed the event trace".into());
            }
            let mut on_json = r.to_json();
            if let Json::Obj(o) = &mut on_json {
                o.remove("faults");
            }
            if off.to_json().to_string_pretty() != on_json.to_string_pretty() {
                v.push("a silent fault layer changed the report JSON".into());
            }
            if off.obs != r.obs {
                v.push("a silent fault layer changed the observability artifacts".into());
            }
        }
    }
}

/// The sharding determinism contract, checked on every fuzzed spec:
///
/// * **thread-count byte-identity** — forcing the workload through 1 and
///   4 kernel shards, the report JSON and trace must not depend on how
///   many OS threads carried the shards (1 vs 4);
/// * **shard/serial identity** — `shards = 1` through the sharded
///   fan-out/merge path must be byte-identical to the plain unsharded
///   kernel (and, when the spec itself says `shards = 1`, to the
///   session's own primary run).
fn check_sharding_identities(
    spec: &ScenarioSpec,
    session: &Session,
    primary: &Report,
    v: &mut Vec<String>,
) {
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut sv = Vec::new();
        for shards in [1usize, 4] {
            let serial = session.run_sharded(shards, 1);
            let threaded = session.run_sharded(shards, 4);
            if serial.trace_text() != threaded.trace_text() {
                sv.push(format!("shards={shards}: trace differs between 1 and 4 worker threads"));
            }
            if serial.to_json().to_string_pretty() != threaded.to_json().to_string_pretty() {
                sv.push(format!(
                    "shards={shards}: report JSON differs between 1 and 4 worker threads"
                ));
            }
            // The exported artifacts must be byte-identical across
            // thread counts too (the report JSON does not embed them).
            let trace_of = |r: &Report| r.obs.as_ref().map(|o| o.chrome_trace_text());
            let metrics_of = |r: &Report| r.obs.as_ref().map(|o| o.metrics_jsonl());
            if trace_of(&serial) != trace_of(&threaded) {
                sv.push(format!(
                    "shards={shards}: trace artifact differs between 1 and 4 worker threads"
                ));
            }
            if metrics_of(&serial) != metrics_of(&threaded) {
                sv.push(format!(
                    "shards={shards}: metrics artifact differs between 1 and 4 worker threads"
                ));
            }
            if shards == 1 {
                let arrivals = spec.workload.arrivals(session.tenants.len(), spec.seed);
                let plain = crate::sim::run_fleet(
                    &session.pipeline,
                    &session.fleet,
                    session.tenants.clone(),
                    arrivals,
                    spec.seed,
                );
                if serial.trace_text() != plain.trace_text() {
                    sv.push("shards=1 trace is not byte-identical to the unsharded kernel".into());
                }
                if serial.to_json().to_string_pretty() != plain.to_json().to_string_pretty() {
                    sv.push(
                        "shards=1 report JSON is not byte-identical to the unsharded kernel".into(),
                    );
                }
                if serial.obs != plain.obs {
                    sv.push(
                        "shards=1 observability artifacts differ from the unsharded kernel".into(),
                    );
                }
                if spec.topology.shards == 1
                    && serial.trace_text() != primary.trace_text()
                {
                    sv.push("shards=1 trace drifted from the session's primary run".into());
                }
            }
        }
        sv
    }));
    match outcome {
        Ok(sv) => v.extend(sv),
        Err(e) => v.push(format!("panicked during sharded runs: {}", panic_message(&e))),
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    e.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| e.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// Sweep-line maximum overlap of `(start, finish)` intervals, releasing
/// before acquiring at equal times (a worker freed at `t` can serve a job
/// starting at `t`). Mirrors the pool-occupancy property in
/// `scheduler/fleet.rs`.
fn max_overlap(intervals: &[(f64, f64)]) -> usize {
    let mut points: Vec<(f64, i32)> = Vec::with_capacity(intervals.len() * 2);
    for &(s, f) in intervals {
        points.push((s, 1));
        points.push((f, -1));
    }
    points.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut cur = 0i32;
    let mut best = 0i32;
    for (_, d) in points {
        cur += d;
        best = best.max(cur);
    }
    best.max(0) as usize
}

fn check_finite(label: &str, x: f64, v: &mut Vec<String>) {
    if !x.is_finite() {
        v.push(format!("{label} is not finite: {x}"));
    }
}

/// The single-run invariant set (see the module docs for the list).
/// Bounds that scale with parallel infrastructure (pool occupancy, cap
/// overshoot) widen with `spec.topology.shards`: each shard owns its own
/// pools and budget gates, so a sharded fleet can legitimately hold
/// `shards × workers` jobs in service and overshoot a cap by one call
/// *per shard*.
fn check_report(spec: &ScenarioSpec, session: &Session, r: &Report, v: &mut Vec<String>) {
    let shards = spec.topology.shards.max(1);
    // -- clock ----------------------------------------------------------
    if !r.clock_monotone {
        v.push("event heap popped times out of order (clock_monotone = false)".into());
    }

    // -- report totals vs per-query outcomes ----------------------------
    if r.results.len() != spec.workload.n {
        v.push(format!(
            "report carries {} results for an n={} workload",
            r.results.len(),
            spec.workload.n
        ));
    }
    let horizon = r.results.iter().map(|q| q.completed_at).fold(0.0f64, f64::max);
    if (r.horizon - horizon).abs() > 1e-9 {
        v.push(format!("horizon {} != max completed_at {horizon}", r.horizon));
    }
    let qps = r.results.len() as f64 / horizon.max(1e-9);
    if (r.throughput_qps - qps).abs() > 1e-9 {
        v.push(format!("throughput_qps {} != recomputed {qps}", r.throughput_qps));
    }
    let forced: usize = r.results.iter().map(|q| q.forced_edge).sum();
    if r.forced_edge != forced {
        v.push(format!("forced_edge {} != per-query sum {forced}", r.forced_edge));
    }
    let n_decided: usize = r.tenants.iter().map(|t| t.state.n_decided).sum();
    let n_offloaded: usize = r.tenants.iter().map(|t| t.state.n_offloaded).sum();
    let offload = if n_decided == 0 { 0.0 } else { n_offloaded as f64 / n_decided as f64 };
    if (r.offload_rate - offload).abs() > 1e-9 {
        v.push(format!("offload_rate {} != tenant-sum recomputation {offload}", r.offload_rate));
    }
    if r.sojourn.n != r.results.len() {
        v.push(format!(
            "sojourn summary covers {} samples for {} queries",
            r.sojourn.n,
            r.results.len()
        ));
    }
    for q in &r.results {
        if q.admitted < q.arrival - 1e-9 {
            v.push(format!("query {} admitted ({}) before arrival ({})", q.query_id, q.admitted, q.arrival));
        }
        if q.plan_done < q.admitted - 1e-9 {
            v.push(format!("query {} planned ({}) before admission ({})", q.query_id, q.plan_done, q.admitted));
        }
        if q.completed_at < q.plan_done - 1e-9 {
            v.push(format!("query {} completed ({}) before planning ({})", q.query_id, q.completed_at, q.plan_done));
        }
        for e in &q.exec.events {
            if !(e.start.is_finite() && e.finish.is_finite()) || e.finish < e.start - 1e-9 {
                v.push(format!(
                    "query {} node {} has a malformed service interval [{}, {}]",
                    q.query_id, e.node, e.start, e.finish
                ));
            }
            if !e.api_cost.is_finite() || e.api_cost < 0.0 {
                v.push(format!("query {} node {} billed a bad cost {}", q.query_id, e.node, e.api_cost));
            }
        }
    }

    // -- numeric health of the rendered surfaces ------------------------
    for (label, x) in [
        ("total_api_cost", r.total_api_cost),
        ("offload_rate", r.offload_rate),
        ("throughput_qps", r.throughput_qps),
        ("horizon", r.horizon),
        ("edge_utilization", r.edge_utilization),
        ("cloud_utilization", r.cloud_utilization),
        ("hedge_refund", r.hedge_refund),
        ("sojourn.mean", r.sojourn.mean),
        ("sojourn.p50", r.sojourn.p50),
        ("sojourn.p95", r.sojourn.p95),
        ("sojourn.max", r.sojourn.max),
    ] {
        check_finite(label, x, v);
    }
    if r.render().contains("NaN") {
        v.push("rendered report contains NaN".into());
    }

    // -- budget conservation --------------------------------------------
    let max_call = r
        .results
        .iter()
        .flat_map(|q| q.exec.events.iter())
        .map(|e| e.api_cost)
        .fold(0.0f64, f64::max);
    for t in &r.tenants {
        if t.state.k_used < -1e-12 {
            v.push(format!("tenant '{}' has negative spend {}", t.name, t.state.k_used));
        }
        // Overshoot bounded by one call per shard: each shard's gate is
        // checked before each bill, so spend can pass the cap by at most
        // the priciest call on every shard.
        let slack = max_call * shards as f64;
        if t.k_cap.is_finite() && t.state.k_used > t.k_cap + slack + 1e-9 {
            v.push(format!(
                "tenant '{}' spent {} against cap {} (max single call {max_call}, {shards} shard(s))",
                t.name, t.state.k_used, t.k_cap
            ));
        }
    }
    let tenant_sum: f64 = r.tenants.iter().map(|t| t.state.k_used).sum();
    if (r.global.k_spent - tenant_sum).abs() > 1e-9 {
        v.push(format!(
            "global spend {} != sum of tenant spends {tenant_sum}",
            r.global.k_spent
        ));
    }
    if r.global.k_cap.is_finite()
        && r.global.k_spent > r.global.k_cap + max_call * shards as f64 + 1e-9
    {
        v.push(format!(
            "global spend {} exceeds cap {} by more than one call per shard",
            r.global.k_spent, r.global.k_cap
        ));
    }
    if (r.total_api_cost - r.global.k_spent).abs() > 1e-9 {
        v.push(format!(
            "total_api_cost {} != global spend {}",
            r.total_api_cost, r.global.k_spent
        ));
    }

    // -- pool occupancy -------------------------------------------------
    // Chain-mode queries bypass the shared pools entirely; cached hits
    // occupy no worker. Winner events are a lower bound on concurrent
    // claims under hedging (losers are not in the event list), so the
    // bound below must hold for them in every mode that uses the pools.
    if !spec.engine.chain_mode {
        let mut edge_iv = Vec::new();
        let mut cloud_iv = Vec::new();
        for q in &r.results {
            for e in &q.exec.events {
                if e.cached {
                    continue;
                }
                if e.cloud {
                    cloud_iv.push((e.start, e.finish));
                } else {
                    edge_iv.push((e.start, e.finish));
                }
            }
        }
        // A zero-worker side still carries one phantom claim slot (the
        // engine's historical `max(1)` padding) — per shard, since every
        // shard models its own pools.
        let edge_cap = spec.topology.edge_workers.max(1) * shards;
        let cloud_cap = spec.topology.cloud_workers.max(1) * shards;
        let edge_peak = max_overlap(&edge_iv);
        let cloud_peak = max_overlap(&cloud_iv);
        if edge_peak > edge_cap {
            v.push(format!(
                "edge occupancy peaked at {edge_peak} with only {} worker(s) configured",
                spec.topology.edge_workers
            ));
        }
        if cloud_peak > cloud_cap {
            v.push(format!(
                "cloud occupancy peaked at {cloud_peak} with only {} worker(s) configured",
                spec.topology.cloud_workers
            ));
        }
        for (label, u) in [("edge", r.edge_utilization), ("cloud", r.cloud_utilization)] {
            if !(-1e-9..=1.0 + 1e-6).contains(&u) {
                v.push(format!("{label} utilization {u} outside [0, 1]"));
            }
        }
    }

    // -- cache capacity -------------------------------------------------
    if let Some(cache) = session.pipeline.config.schedule.cache.as_deref() {
        let cap = cache.capacity();
        for ti in 0..r.tenants.len() {
            let len = cache.len(ti);
            if len > cap {
                v.push(format!("tenant {ti} cache partition holds {len} entries over capacity {cap}"));
            }
        }
        if cache.shared_len() > cap {
            v.push(format!(
                "shared cache tier holds {} entries over capacity {cap}",
                cache.shared_len()
            ));
        }
    }
}

/// Greedily shrink a failing spec toward defaults while preserving the
/// failure, so corpus entries check in minimized (the PR 6 convention for
/// `rust/tests/corpus/`).
///
/// `fails` is the predicate to preserve — typically
/// `|s| !run_case(s).is_empty()`. Each step proposes one single-field
/// simplification (drop a tenant, clear a cap, halve the workload, reset
/// an engine knob…); a candidate is kept only if it still validates *and*
/// still fails. Steps loop to a fixpoint, so e.g. the workload halves all
/// the way down while the failure survives. A spec that does not fail is
/// returned unchanged.
pub fn minimize<F: Fn(&ScenarioSpec) -> bool>(spec: &ScenarioSpec, fails: F) -> ScenarioSpec {
    let mut cur = spec.clone();
    if !fails(&cur) {
        return cur;
    }
    loop {
        let mut shrunk = false;
        for cand in shrink_steps(&cur) {
            if cand != cur && cand.validate().is_ok() && fails(&cand) {
                cur = cand;
                shrunk = true;
                // Restart the step list from the new, smaller spec.
                break;
            }
        }
        if !shrunk {
            return cur;
        }
    }
}

/// All single-step simplifications of `cur`, biggest wins first. Steps
/// that would not change the spec are emitted anyway and filtered by the
/// `cand != cur` check in [`minimize`].
fn shrink_steps(cur: &ScenarioSpec) -> Vec<ScenarioSpec> {
    let mut out: Vec<ScenarioSpec> = Vec::new();
    {
        let mut step = |f: &dyn Fn(&mut ScenarioSpec)| {
            let mut c = cur.clone();
            f(&mut c);
            out.push(c);
        };
        // Workload size dominates run time: try the floor, then halving.
        step(&|s| s.workload.n = 1);
        step(&|s| s.workload.n /= 2);
        // Drop tenants from the end (the validator keeps >= 1).
        step(&|s| {
            s.topology.tenants.pop();
        });
        // Simplify the arrival process and workload shape.
        step(&|s| s.workload.arrival = ArrivalProcess::Periodic { gap: 1.0 });
        step(&|s| s.workload.zipf = None);
        // Engine knobs back to defaults, one at a time.
        step(&|s| s.engine.cache = None);
        step(&|s| s.engine.hedge = false);
        step(&|s| s.engine.hedge_threshold = EngineSpec::default().hedge_threshold);
        step(&|s| s.engine.chain_mode = false);
        step(&|s| s.engine.batch_frontier = EngineSpec::default().batch_frontier);
        step(&|s| s.engine.policy = PolicySpec::HybridFlow);
        step(&|s| s.engine.n_max = EngineSpec::default().n_max);
        step(&|s| s.engine.observe = None);
        // Fault layer off first (the biggest win), then half at a time,
        // then individual knobs so a failure that needs one live fault
        // mechanism keeps exactly that one.
        step(&|s| {
            s.engine.faults = None;
            s.engine.resilience = None;
        });
        step(&|s| s.engine.faults = None);
        step(&|s| s.engine.resilience = None);
        step(&|s| {
            if let Some(f) = &mut s.engine.faults {
                f.outages.clear();
            }
        });
        step(&|s| {
            if let Some(r) = &mut s.engine.resilience {
                r.timeout = None;
            }
        });
        // Per-tenant fields: clear each tenant's cap / policy override
        // individually so a failure that needs one capped tenant keeps
        // exactly that one.
        for i in 0..cur.topology.tenants.len() {
            step(&move |s: &mut ScenarioSpec| s.topology.tenants[i].k_cap = None);
            step(&move |s: &mut ScenarioSpec| s.topology.tenants[i].policy = None);
        }
        // Topology toward the minimal fleet.
        step(&|s| s.topology.edge_workers = 1);
        step(&|s| s.topology.cloud_workers = 1);
        step(&|s| s.topology.admission_limit = 0);
        step(&|s| s.topology.global_k_cap = None);
        step(&|s| s.topology.shards = 1);
        step(&|s| s.seed = 0);
    }
    out
}

/// Human-readable failure report: the violations, the offending spec as
/// canonical JSON, and a one-line repro command.
pub fn failure_report(
    spec: &ScenarioSpec,
    base_seed: u64,
    case: usize,
    adversarial: bool,
    violations: &[String],
) -> String {
    let mut out = format!(
        "fuzz case {case} (base seed {base_seed}{}) violated {} invariant(s):\n",
        if adversarial { ", adversarial" } else { "" },
        violations.len()
    );
    for viol in violations {
        out.push_str("  - ");
        out.push_str(viol);
        out.push('\n');
    }
    out.push_str("\nspec:\n");
    out.push_str(&spec.render());
    out.push_str(&format!(
        "\nreproduce: hybridflow fuzz --cases 1 --seed {}{}\n",
        base_seed.wrapping_add(case as u64),
        if adversarial { " --adversarial" } else { "" },
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_generation_is_deterministic_and_case_addressable() {
        let a = spec_for_case(7, 5, true);
        let b = spec_for_case(7, 5, true);
        assert_eq!(a, b, "same (base, case) must yield the same spec");
        // The repro identity behind `fuzz --cases 1 --seed <base+case>`.
        let repro = spec_for_case(12, 0, true);
        assert_eq!(spec_for_case(7, 5, true), repro);
        // Different cases genuinely differ.
        assert_ne!(spec_for_case(7, 5, false), spec_for_case(7, 6, false));
    }

    #[test]
    fn generated_specs_are_valid() {
        for case in 0..24 {
            for adversarial in [false, true] {
                let spec = spec_for_case(0xBEEF, case, adversarial);
                spec.validate().unwrap_or_else(|e| {
                    panic!("case {case} (adversarial={adversarial}) invalid: {e}\n{}", spec.render())
                });
            }
        }
    }

    #[test]
    fn smoke_cases_hold_all_invariants() {
        // The bounded randomized sweeps live in rust/tests/fuzz.rs; this
        // is the in-crate smoke check that the harness itself works.
        for case in 0..4 {
            for adversarial in [false, true] {
                let spec = spec_for_case(1, case, adversarial);
                let violations = run_case(&spec);
                assert!(
                    violations.is_empty(),
                    "{}",
                    failure_report(&spec, 1, case, adversarial, &violations)
                );
            }
        }
    }

    #[test]
    fn fault_extremes_hold_all_invariants() {
        // The issue-list extremes, hand-built: certain edge failure, a
        // horizon-spanning outage on the other side, a zero-length window
        // (must match nothing), stragglers on every call, and the two
        // retry-budget endpoints (0: first failure degrades; 16: a long
        // retry ladder) — once with a timeout below any service time.
        let mut spec = spec_for_case(21, 0, false);
        spec.topology.shards = 1;
        spec.workload.n = 4;
        for (max_retries, timeout) in [(0usize, Some(1e-6)), (16, None)] {
            let mut s = spec.clone();
            s.engine.faults = Some(FaultConfig {
                edge_fail_p: 1.0,
                cloud_fail_p: 0.0,
                straggler_p: 1.0,
                straggler_mult: 8.0,
                seed: 3,
                outages: vec![
                    OutageWindow { cloud: true, start: 0.0, end: 1e12 },
                    OutageWindow { cloud: false, start: 5.0, end: 5.0 },
                ],
            });
            s.engine.resilience = Some(ResilienceConfig {
                timeout,
                max_retries,
                backoff_base: 0.01,
                backoff_jitter: 0.5,
                failover_after: 1,
            });
            let violations = run_case(&s);
            assert!(violations.is_empty(), "{}", failure_report(&s, 21, 0, false, &violations));
        }
    }

    #[test]
    fn run_case_reports_violations_instead_of_panicking() {
        // An invalid spec must come back as a violation string, not a
        // panic or a silent pass.
        let mut spec = spec_for_case(2, 0, false);
        spec.workload.n = 0;
        let violations = run_case(&spec);
        assert!(!violations.is_empty());
        assert!(violations[0].contains("invalid spec"), "{violations:?}");
    }

    #[test]
    fn failure_report_carries_spec_and_repro_line() {
        let spec = spec_for_case(3, 4, true);
        let report = failure_report(&spec, 3, 4, true, &["boom".into()]);
        assert!(report.contains("boom"));
        assert!(report.contains("\"topology\""), "spec JSON embedded");
        assert!(report.contains("fuzz --cases 1 --seed 7 --adversarial"), "{report}");
    }

    #[test]
    fn minimizer_shrinks_toward_defaults_while_preserving_failure() {
        // A busy adversarial spec, with hedging forced on so the
        // "failure" predicate (`engine.hedge`) is live.
        let mut spec = spec_for_case(9, 3, true);
        spec.engine.hedge = true;
        spec.topology.shards = 4;
        spec.engine.observe = Some(ObserveConfig::default());
        spec.engine.faults = Some(FaultConfig { edge_fail_p: 0.5, ..FaultConfig::default() });
        spec.engine.resilience = Some(ResilienceConfig::default());
        let min = minimize(&spec, |s| s.engine.hedge);
        assert!(min.engine.hedge, "the preserved failure survives");
        assert!(min.validate().is_ok(), "minimized spec stays valid");
        assert_eq!(min.workload.n, 1, "workload shrinks to the floor");
        assert_eq!(min.topology.tenants.len(), 1, "tenants drop to one");
        assert_eq!(min.topology.shards, 1, "shards reset to the unsharded kernel");
        assert_eq!(min.workload.arrival, ArrivalProcess::Periodic { gap: 1.0 });
        assert!(min.workload.zipf.is_none());
        assert!(min.engine.cache.is_none());
        assert!(min.engine.observe.is_none(), "observability resets to off");
        assert!(min.engine.faults.is_none(), "fault injection resets to off");
        assert!(min.engine.resilience.is_none(), "resilience resets to off");
        assert!(min.topology.tenants[0].k_cap.is_none());
        assert!(min.topology.tenants[0].policy.is_none());
        assert_eq!(min.seed, 0);
    }

    #[test]
    fn minimizer_returns_non_failing_spec_unchanged() {
        let spec = spec_for_case(9, 3, false);
        assert_eq!(minimize(&spec, |_| false), spec);
    }

    #[test]
    fn minimizer_respects_a_field_coupled_predicate() {
        // A predicate that needs a *specific* tenant's cap must keep that
        // cap while everything else still shrinks.
        let mut spec = spec_for_case(11, 2, false);
        spec.topology.tenants[0].k_cap = Some(0.01);
        let min = minimize(&spec, |s| {
            s.topology.tenants.first().map_or(false, |t| t.k_cap == Some(0.01))
        });
        assert_eq!(min.topology.tenants.len(), 1);
        assert_eq!(min.topology.tenants[0].k_cap, Some(0.01), "load-bearing cap survives");
        assert_eq!(min.workload.n, 1);
    }

    #[test]
    fn max_overlap_sweep_line() {
        assert_eq!(max_overlap(&[]), 0);
        assert_eq!(max_overlap(&[(0.0, 1.0), (1.0, 2.0)]), 1, "release before acquire at t=1");
        assert_eq!(max_overlap(&[(0.0, 2.0), (1.0, 3.0), (1.5, 4.0)]), 3);
    }
}

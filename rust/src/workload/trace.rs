//! Query-trace record / replay (JSONL) and open-loop arrival processes.
//!
//! Serving systems are evaluated on traces; this module serializes
//! workloads and execution outcomes so runs can be archived, diffed, and
//! replayed bit-exactly (`hybridflow serve --trace-out` / examples). The
//! trace format is line-delimited JSON, one query per line.
//!
//! [`ArrivalProcess`] generates the arrival timestamps the fleet simulator
//! consumes: Poisson (open-loop, the serving-paper standard), periodic, or
//! a recorded offset trace.

use crate::metrics::QueryOutcome;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{Benchmark, Query};

/// Open-loop arrival-time generator for fleet workloads. All variants are
/// deterministic given `(self, n, seed)`.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals at `rate` queries per virtual second (i.i.d.
    /// exponential inter-arrival gaps).
    Poisson { rate: f64 },
    /// Fixed inter-arrival `gap` seconds (arrival i at `i * gap`).
    Periodic { gap: f64 },
    /// Explicit absolute arrival offsets (sorted ascending before use, so
    /// the nondecreasing contract holds for any input order). When fewer
    /// than `n` offsets are given, the tail continues past the last offset
    /// at the trace's mean gap. Degenerate traces keep the "starting near
    /// 0" contract explicit: an **empty** trace starts at t=0.0 and
    /// extends at a 1.0s gap; a **single-entry** trace extends at a 1.0s
    /// gap (no recorded gap to average); a **constant** trace (all offsets
    /// equal) has mean gap 0, so every extended arrival lands on the
    /// repeated offset.
    Trace(Vec<f64>),
}

impl ArrivalProcess {
    /// Sample `n` nondecreasing arrival times starting near 0.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<f64> {
        match self {
            ArrivalProcess::Poisson { rate } => {
                assert!(*rate > 0.0, "poisson rate must be positive");
                let mut rng = Rng::new(seed ^ 0xA11C_0FFE_E5C0_FFEE);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(*rate);
                        t
                    })
                    .collect()
            }
            ArrivalProcess::Periodic { gap } => {
                assert!(*gap >= 0.0, "periodic gap must be non-negative");
                (0..n).map(|i| i as f64 * gap).collect()
            }
            ArrivalProcess::Trace(times) => {
                let mut sorted = times.clone();
                sorted.sort_by(f64::total_cmp);
                let mean_gap = if sorted.len() >= 2 {
                    (sorted[sorted.len() - 1] - sorted[0]) / (sorted.len() - 1) as f64
                } else {
                    1.0
                };
                let mut out: Vec<f64> = sorted.into_iter().take(n).collect();
                if out.is_empty() && n > 0 {
                    // Empty trace: start at 0.0 (the documented "starting
                    // near 0" contract; extending from t=1.0 skipped it).
                    out.push(0.0);
                }
                let mut t = out.last().copied().unwrap_or(0.0);
                while out.len() < n {
                    t += mean_gap;
                    out.push(t);
                }
                out
            }
        }
    }
}

/// Zipf-popularity repetition knob: rewrites a fresh query list so
/// arrivals draw from a pool of `distinct` prototype queries with
/// P(prototype of popularity rank r) ∝ 1 / (r + 1)^exponent. Real fleet
/// traffic is heavy-tailed — a few prompts dominate — and this is the
/// workload shape that makes the cross-query result cache
/// ([`crate::cache::SubtaskCache`]) earn hits: repeated prototypes carry
/// identical query *content* (ids included), so their subtask
/// fingerprints collide by construction.
///
/// Deterministic in `(input queries, seed)`; `exponent = 0` degenerates
/// to a uniform draw over the prototype pool.
#[derive(Debug, Clone, PartialEq)]
pub struct ZipfMix {
    /// Skew `s` of the popularity law (serving-paper convention: ~0.9-1.2
    /// for production LLM traffic).
    pub exponent: f64,
    /// Number of distinct prototype queries (clamped to the input size).
    pub distinct: usize,
}

impl ZipfMix {
    pub fn new(exponent: f64, distinct: usize) -> ZipfMix {
        assert!(exponent >= 0.0, "zipf exponent must be non-negative");
        ZipfMix { exponent, distinct: distinct.max(1) }
    }

    /// Replace each query with a Zipf-drawn prototype (the first
    /// `distinct` entries of `queries`, in order of popularity). Output
    /// length equals input length.
    pub fn apply(&self, queries: &[Query], seed: u64) -> Vec<Query> {
        if queries.is_empty() {
            return Vec::new();
        }
        let d = self.distinct.min(queries.len());
        let weights: Vec<f64> =
            (0..d).map(|r| 1.0 / ((r + 1) as f64).powf(self.exponent)).collect();
        let mut rng = Rng::new(seed ^ 0x21bf_5eed_21bf_5eed);
        queries.iter().map(|_| queries[rng.categorical(&weights)].clone()).collect()
    }
}

/// One recorded query + outcome.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub query: Query,
    pub outcome: Option<QueryOutcome>,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.query.id as f64)),
            ("benchmark", Json::Str(self.query.benchmark.name().into())),
            ("domain", Json::Num(self.query.domain as f64)),
            ("difficulty", Json::Num(self.query.difficulty)),
            ("query_tokens", Json::Num(self.query.query_tokens)),
            ("tok_mult", Json::Num(self.query.tok_mult)),
        ];
        if let Some(o) = &self.outcome {
            fields.push(("correct", Json::Bool(o.correct)));
            fields.push(("latency", Json::Num(o.latency)));
            fields.push(("api_cost", Json::Num(o.api_cost)));
            fields.push(("offload_rate", Json::Num(o.offload_rate)));
            fields.push(("n_subtasks", Json::Num(o.n_subtasks as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TraceRecord> {
        let get_num = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("trace record missing '{k}'"))
        };
        let bench_name = j
            .get("benchmark")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("trace record missing 'benchmark'"))?;
        let benchmark = Benchmark::parse(bench_name)
            .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{bench_name}'"))?;
        let query = Query {
            id: get_num("id")? as u64,
            benchmark,
            domain: get_num("domain")? as usize,
            difficulty: get_num("difficulty")?,
            query_tokens: get_num("query_tokens")?,
            tok_mult: get_num("tok_mult")?,
        };
        let outcome = match j.get("correct") {
            Some(Json::Bool(correct)) => Some(QueryOutcome {
                correct: *correct,
                latency: get_num("latency")?,
                api_cost: get_num("api_cost")?,
                offload_rate: get_num("offload_rate")?,
                n_subtasks: get_num("n_subtasks")? as usize,
            }),
            _ => None,
        };
        Ok(TraceRecord { query, outcome })
    }
}

/// Serialize records as JSONL text.
pub fn write_jsonl(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse JSONL text into records (skips blank lines; errors on bad lines).
pub fn read_jsonl(text: &str) -> anyhow::Result<Vec<TraceRecord>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", ln + 1))?;
        out.push(TraceRecord::from_json(&j).map_err(|e| anyhow::anyhow!("trace line {}: {e}", ln + 1))?);
    }
    Ok(out)
}

/// Extract just the queries for replay.
pub fn queries_of(records: &[TraceRecord]) -> Vec<Query> {
    records.iter().map(|r| r.query.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generate_queries;

    fn sample_records() -> Vec<TraceRecord> {
        generate_queries(Benchmark::Gpqa, 5, 1)
            .into_iter()
            .enumerate()
            .map(|(i, query)| TraceRecord {
                query,
                outcome: (i % 2 == 0).then(|| QueryOutcome {
                    correct: i == 0,
                    latency: 12.5 + i as f64,
                    api_cost: 0.002 * i as f64,
                    offload_rate: 0.4,
                    n_subtasks: 4,
                }),
            })
            .collect()
    }

    #[test]
    fn jsonl_roundtrip_exact() {
        let records = sample_records();
        let text = write_jsonl(&records);
        let back = read_jsonl(&text).unwrap();
        assert_eq!(back.len(), records.len());
        for (a, b) in records.iter().zip(&back) {
            assert_eq!(a.query.id, b.query.id);
            assert_eq!(a.query.difficulty, b.query.difficulty);
            assert_eq!(a.query.benchmark, b.query.benchmark);
            match (&a.outcome, &b.outcome) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.correct, y.correct);
                    assert_eq!(x.latency, y.latency);
                    assert_eq!(x.n_subtasks, y.n_subtasks);
                }
                (None, None) => {}
                _ => panic!("outcome presence mismatch"),
            }
        }
    }

    #[test]
    fn replay_reproduces_results() {
        // Record a run, replay the queries, verify identical outcomes.
        use crate::config::simparams::SimParams;
        use crate::pipeline::{HybridFlowPipeline, PipelineConfig};
        use crate::planner::synthetic::SyntheticPlanner;
        use crate::router::{MirrorPredictor, RoutePolicy};
        use crate::util::rng::Rng;
        use std::sync::Arc;

        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = RoutePolicy::hybridflow(&sp);
        let pipeline = HybridFlowPipeline::with_predictor(
            crate::models::SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        );
        let queries = generate_queries(Benchmark::MmluPro, 10, 3);
        let run = |qs: &[crate::workload::Query]| -> Vec<QueryOutcome> {
            qs.iter()
                .map(|q| {
                    let mut rng = Rng::new(q.id ^ 0xFEED);
                    pipeline.run_query(q, &mut rng)
                })
                .collect()
        };
        let outcomes = run(&queries);
        let records: Vec<TraceRecord> = queries
            .iter()
            .zip(&outcomes)
            .map(|(q, o)| TraceRecord { query: q.clone(), outcome: Some(*o) })
            .collect();
        let text = write_jsonl(&records);

        // Replay from the serialized trace.
        let replayed = read_jsonl(&text).unwrap();
        let outcomes2 = run(&queries_of(&replayed));
        for (a, b) in outcomes.iter().zip(&outcomes2) {
            assert_eq!(a.correct, b.correct);
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.api_cost, b.api_cost);
        }
    }

    #[test]
    fn bad_lines_error_with_location() {
        let err = read_jsonl("{\"id\": 1}\nnot json\n").unwrap_err();
        assert!(err.to_string().contains("line 1") || err.to_string().contains("line 2"));
    }

    #[test]
    fn poisson_arrivals_deterministic_and_calibrated() {
        let p = ArrivalProcess::Poisson { rate: 2.0 };
        let a = p.sample(4000, 7);
        let b = p.sample(4000, 7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival gap ~ 1/rate.
        let mean_gap = a[a.len() - 1] / a.len() as f64;
        assert!((mean_gap - 0.5).abs() < 0.05, "mean gap {mean_gap}");
        let c = p.sample(100, 8);
        assert_ne!(a[..100], c[..]);
    }

    #[test]
    fn periodic_arrivals_exact() {
        let a = ArrivalProcess::Periodic { gap: 1.5 }.sample(4, 0);
        assert_eq!(a, vec![0.0, 1.5, 3.0, 4.5]);
    }

    #[test]
    fn zipf_mix_is_deterministic_and_skewed() {
        let qs = generate_queries(Benchmark::Gpqa, 400, 9);
        let mix = ZipfMix::new(1.1, 8);
        let a = mix.apply(&qs, 5);
        let b = mix.apply(&qs, 5);
        assert_eq!(a.len(), 400);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id, "deterministic in (queries, seed)");
        }
        // Every output is one of the 8 prototypes, content included.
        let proto_ids: Vec<u64> = qs[..8].iter().map(|q| q.id).collect();
        assert!(a.iter().all(|q| proto_ids.contains(&q.id)));
        // Popularity skew: rank 0 strictly more frequent than rank 7.
        let count = |id: u64| a.iter().filter(|q| q.id == id).count();
        assert!(count(proto_ids[0]) > count(proto_ids[7]));
        assert!(count(proto_ids[0]) > 400 / 8, "head rank must beat uniform share");
        // Different seed reshuffles the assignment.
        let c = mix.apply(&qs, 6);
        assert!(a.iter().zip(&c).any(|(x, y)| x.id != y.id));
    }

    #[test]
    fn zipf_mix_edge_cases() {
        let qs = generate_queries(Benchmark::Gpqa, 5, 1);
        // distinct larger than the pool clamps to the pool.
        let wide = ZipfMix::new(1.0, 50).apply(&qs, 0);
        assert_eq!(wide.len(), 5);
        // distinct = 1 repeats the single prototype verbatim.
        let single = ZipfMix::new(1.0, 1).apply(&qs, 0);
        assert!(single.iter().all(|q| q.id == qs[0].id));
        assert!(ZipfMix::new(1.0, 3).apply(&[], 0).is_empty());
    }

    #[test]
    fn trace_arrivals_extend_past_end() {
        let a = ArrivalProcess::Trace(vec![0.0, 1.0, 4.0]).sample(5, 0);
        assert_eq!(a.len(), 5);
        assert_eq!(&a[..3], &[0.0, 1.0, 4.0]);
        // Mean gap of the recorded trace is 2.0.
        assert!((a[3] - 6.0).abs() < 1e-12 && (a[4] - 8.0).abs() < 1e-12);
        // Unsorted input is sorted first, keeping the output nondecreasing.
        let unsorted = ArrivalProcess::Trace(vec![10.0, 0.0]).sample(3, 0);
        assert_eq!(unsorted, vec![0.0, 10.0, 20.0]);
    }

    #[test]
    fn trace_arrivals_degenerate_traces() {
        // Empty trace: starts at 0.0 (regression — it used to extend from
        // t=1.0, violating the documented "starting near 0" contract).
        let empty = ArrivalProcess::Trace(vec![]).sample(3, 0);
        assert_eq!(empty, vec![0.0, 1.0, 2.0]);
        // Single-entry trace: no recorded gap, extends at 1.0s.
        let single = ArrivalProcess::Trace(vec![5.0]).sample(3, 0);
        assert_eq!(single, vec![5.0, 6.0, 7.0]);
        // Constant trace: mean gap is 0, so every extended arrival lands
        // on the repeated offset (a recorded burst stays a burst).
        let constant = ArrivalProcess::Trace(vec![2.0, 2.0]).sample(4, 0);
        assert_eq!(constant, vec![2.0, 2.0, 2.0, 2.0]);
        // n smaller than the trace just truncates.
        let truncated = ArrivalProcess::Trace(vec![0.0, 1.0, 2.0]).sample(2, 0);
        assert_eq!(truncated, vec![0.0, 1.0]);
    }
}

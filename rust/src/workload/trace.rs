//! Query-trace record / replay (JSONL).
//!
//! Serving systems are evaluated on traces; this module serializes
//! workloads and execution outcomes so runs can be archived, diffed, and
//! replayed bit-exactly (`hybridflow serve --trace-out` / examples). The
//! trace format is line-delimited JSON, one query per line.

use crate::metrics::QueryOutcome;
use crate::util::json::Json;
use crate::workload::{Benchmark, Query};

/// One recorded query + outcome.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    pub query: Query,
    pub outcome: Option<QueryOutcome>,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Num(self.query.id as f64)),
            ("benchmark", Json::Str(self.query.benchmark.name().into())),
            ("domain", Json::Num(self.query.domain as f64)),
            ("difficulty", Json::Num(self.query.difficulty)),
            ("query_tokens", Json::Num(self.query.query_tokens)),
            ("tok_mult", Json::Num(self.query.tok_mult)),
        ];
        if let Some(o) = &self.outcome {
            fields.push(("correct", Json::Bool(o.correct)));
            fields.push(("latency", Json::Num(o.latency)));
            fields.push(("api_cost", Json::Num(o.api_cost)));
            fields.push(("offload_rate", Json::Num(o.offload_rate)));
            fields.push(("n_subtasks", Json::Num(o.n_subtasks as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TraceRecord> {
        let get_num = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("trace record missing '{k}'"))
        };
        let bench_name = j
            .get("benchmark")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("trace record missing 'benchmark'"))?;
        let benchmark = Benchmark::parse(bench_name)
            .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{bench_name}'"))?;
        let query = Query {
            id: get_num("id")? as u64,
            benchmark,
            domain: get_num("domain")? as usize,
            difficulty: get_num("difficulty")?,
            query_tokens: get_num("query_tokens")?,
            tok_mult: get_num("tok_mult")?,
        };
        let outcome = match j.get("correct") {
            Some(Json::Bool(correct)) => Some(QueryOutcome {
                correct: *correct,
                latency: get_num("latency")?,
                api_cost: get_num("api_cost")?,
                offload_rate: get_num("offload_rate")?,
                n_subtasks: get_num("n_subtasks")? as usize,
            }),
            _ => None,
        };
        Ok(TraceRecord { query, outcome })
    }
}

/// Serialize records as JSONL text.
pub fn write_jsonl(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse JSONL text into records (skips blank lines; errors on bad lines).
pub fn read_jsonl(text: &str) -> anyhow::Result<Vec<TraceRecord>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line {}: {e}", ln + 1))?;
        out.push(TraceRecord::from_json(&j).map_err(|e| anyhow::anyhow!("trace line {}: {e}", ln + 1))?);
    }
    Ok(out)
}

/// Extract just the queries for replay.
pub fn queries_of(records: &[TraceRecord]) -> Vec<Query> {
    records.iter().map(|r| r.query.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generate_queries;

    fn sample_records() -> Vec<TraceRecord> {
        generate_queries(Benchmark::Gpqa, 5, 1)
            .into_iter()
            .enumerate()
            .map(|(i, query)| TraceRecord {
                query,
                outcome: (i % 2 == 0).then(|| QueryOutcome {
                    correct: i == 0,
                    latency: 12.5 + i as f64,
                    api_cost: 0.002 * i as f64,
                    offload_rate: 0.4,
                    n_subtasks: 4,
                }),
            })
            .collect()
    }

    #[test]
    fn jsonl_roundtrip_exact() {
        let records = sample_records();
        let text = write_jsonl(&records);
        let back = read_jsonl(&text).unwrap();
        assert_eq!(back.len(), records.len());
        for (a, b) in records.iter().zip(&back) {
            assert_eq!(a.query.id, b.query.id);
            assert_eq!(a.query.difficulty, b.query.difficulty);
            assert_eq!(a.query.benchmark, b.query.benchmark);
            match (&a.outcome, &b.outcome) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.correct, y.correct);
                    assert_eq!(x.latency, y.latency);
                    assert_eq!(x.n_subtasks, y.n_subtasks);
                }
                (None, None) => {}
                _ => panic!("outcome presence mismatch"),
            }
        }
    }

    #[test]
    fn replay_reproduces_results() {
        // Record a run, replay the queries, verify identical outcomes.
        use crate::config::simparams::SimParams;
        use crate::pipeline::{HybridFlowPipeline, PipelineConfig};
        use crate::planner::synthetic::SyntheticPlanner;
        use crate::router::{MirrorPredictor, RoutePolicy};
        use crate::util::rng::Rng;
        use std::sync::Arc;

        let sp = SimParams::default();
        let mut cfg = PipelineConfig::paper_default(&sp);
        cfg.policy = RoutePolicy::hybridflow(&sp);
        let pipeline = HybridFlowPipeline::with_predictor(
            crate::models::SimExecutor::paper_pair(),
            SyntheticPlanner::paper_main(),
            Arc::new(MirrorPredictor::synthetic_for_tests()),
            cfg,
        );
        let queries = generate_queries(Benchmark::MmluPro, 10, 3);
        let run = |qs: &[crate::workload::Query]| -> Vec<QueryOutcome> {
            qs.iter()
                .map(|q| {
                    let mut rng = Rng::new(q.id ^ 0xFEED);
                    pipeline.run_query(q, &mut rng)
                })
                .collect()
        };
        let outcomes = run(&queries);
        let records: Vec<TraceRecord> = queries
            .iter()
            .zip(&outcomes)
            .map(|(q, o)| TraceRecord { query: q.clone(), outcome: Some(*o) })
            .collect();
        let text = write_jsonl(&records);

        // Replay from the serialized trace.
        let replayed = read_jsonl(&text).unwrap();
        let outcomes2 = run(&queries_of(&replayed));
        for (a, b) in outcomes.iter().zip(&outcomes2) {
            assert_eq!(a.correct, b.correct);
            assert_eq!(a.latency, b.latency);
            assert_eq!(a.api_cost, b.api_cost);
        }
    }

    #[test]
    fn bad_lines_error_with_location() {
        let err = read_jsonl("{\"id\": 1}\nnot json\n").unwrap_err();
        assert!(err.to_string().contains("line 1") || err.to_string().contains("line 2"));
    }
}
